"""Plan-space search + the on-disk tuning DB.

The closed loop: given a recorded trace (and optionally a fitted
:class:`~repro.tune.fit.NetFit`), :func:`search` walks the tunable
:class:`~repro.core.api.CollectiveConfig` fields — ``bucket_bytes`` ×
schedule crossover × ``overlap_dispatch`` × ``epilogue_hoist`` — by
coordinate descent, recompiling the program per candidate (pure-Python
pipeline) and scoring each plan with :func:`repro.tune.replay.replay`
in microseconds.  Winners persist per (program structure, topology,
config family) in a JSON tuning DB, which ``engine.compile`` and
``gradient_sync`` consult when ``CollectiveConfig.autotune`` is on: a
DB hit applies the stored overrides without re-searching; a miss
searches once and stores.

DB location: ``CollectiveConfig.tune_db`` > ``$ACIS_TUNE_DB`` >
``./.acis_tune.json``.  Invalidation: entries key on a hash of the
program's leaf avals, topology (axis names/sizes/tiers) and the
non-tunable config fields, so any of those changing misses cleanly; a
file whose ``schema`` differs from :data:`DB_SCHEMA` is ignored
wholesale (stale winners are merely defaults, never errors).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
from typing import Any, Callable, Optional

from repro.tune.replay import replay as _replay

DB_SCHEMA = 1
DEFAULT_DB_PATH = ".acis_tune.json"
DB_ENV_VAR = "ACIS_TUNE_DB"

# the CollectiveConfig fields the tuner varies — exactly the fields the
# compiled-program cache keys must include (api.CollectiveConfig.cache_key)
TUNABLE_FIELDS = ("bucket_bytes", "latency_optimal_below",
                  "overlap_dispatch", "epilogue_hoist",
                  "use_kernels", "batch_rings", "batch_rings_bytes")

# candidate values per field; None in bucket_bytes = the netmodel-derived
# default, 0 = bucketing off.  Coordinate descent keeps evaluations at
# the sum, not the product, of these.
DEFAULT_SPACE = {
    "bucket_bytes": (None, 1 << 20, 2 << 20, 4 << 20, 8 << 20, 16 << 20, 0),
    "latency_optimal_below": (0, 16384, 1 << 17),
    "overlap_dispatch": (True, False),
    "epilogue_hoist": (True, False),
    # Pallas bulk data path: fused pack+combine kernels on/off, and
    # merging a wave's same-axis rings into one batched launch.  The
    # bytes knob bounds which members merge: None = compiler default
    # per-member cap, 0 = merge everything regardless of size.
    "use_kernels": (False, True),
    "batch_rings": (False, True),
    "batch_rings_bytes": (None, 1 << 18, 0),
}

# incremented per executed search — how the tests assert a DB hit did
# NOT re-search
SEARCHES_RUN = 0


@dataclasses.dataclass(frozen=True)
class SearchResult:
    overrides: dict                # winning {field: value}
    score: float                   # replayed seconds of the winner
    default_score: float           # replayed seconds of the base config
    n_evals: int
    rows: tuple = ()               # ((overrides, score), …) every eval


def plan_key(name: str, in_avals, topo, cfg) -> str:
    """Stable DB key for one (program, topology, config family).

    Hashes the leaf avals, the topology (names/sizes/tiers) and the
    *non-tunable* config fields — two configs differing only in tuned
    fields share an entry (that is the point), anything else misses.
    """
    avals = tuple((tuple(a.shape), str(a.dtype)) for a in (in_avals or ()))
    axes = tuple((ax.name, ax.size, ax.tier)
                 for ax in getattr(topo, "axes", ()))
    fam = tuple(getattr(cfg, f, None)
                for f in ("backend", "codec", "compressor", "topk_ratio"))
    blob = repr((name, avals, axes, fam)).encode()
    return hashlib.sha1(blob).hexdigest()[:16]


class TuneDB:
    """The on-disk winner store: ``{schema, entries: {key: entry}}``.

    Reads are mtime-cached; writes are read-modify-write through a
    same-directory temp file + atomic replace, so concurrent processes
    at worst lose a win, never corrupt the file.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path or os.environ.get(DB_ENV_VAR, DEFAULT_DB_PATH)
        self._entries: Optional[dict] = None
        self._mtime: Optional[float] = None

    def _load(self) -> dict:
        try:
            mtime = os.path.getmtime(self.path)
        except OSError:
            self._entries, self._mtime = {}, None
            return self._entries
        if self._entries is not None and mtime == self._mtime:
            return self._entries
        try:
            with open(self.path) as f:
                data = json.load(f)
        except (OSError, ValueError):
            data = {}
        if data.get("schema") != DB_SCHEMA:
            data = {}                  # foreign/stale DB: start clean
        self._entries = dict(data.get("entries", {}))
        self._mtime = mtime
        return self._entries

    def lookup(self, key: str) -> Optional[dict]:
        """The stored entry (``{"overrides": …, "score": …}``) or None."""
        return self._load().get(key)

    def store(self, key: str, overrides: dict, **meta) -> None:
        entries = dict(self._load())
        entries[key] = {"overrides": dict(overrides), **meta}
        payload = {"schema": DB_SCHEMA, "entries": entries}
        d = os.path.dirname(os.path.abspath(self.path))
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=2, sort_keys=True)
                f.write("\n")
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        self._entries, self._mtime = entries, None


def search(build: Callable[[Any], Any], *, base,
           trace=None, fit=None,
           space: Optional[dict] = None) -> SearchResult:
    """Coordinate-descent over the tunable config fields.

    ``build(config)`` compiles the program under a candidate config and
    returns the :class:`~repro.core.compiler.CompiledProgram`; ``base``
    is the starting :class:`~repro.core.api.CollectiveConfig`.  Each
    candidate plan is scored by replaying it against ``trace`` (under
    ``fit`` when given); with no trace the score is the pure analytic
    ``program_time`` — the offline-search mode ``autotune`` uses.
    Returns the winning overrides (only fields that differ from
    ``base``).
    """
    global SEARCHES_RUN
    SEARCHES_RUN += 1
    space = dict(DEFAULT_SPACE if space is None else space)
    cache: dict[tuple, float] = {}
    rows: list[tuple] = []

    def score_of(assign: dict) -> float:
        key = tuple(sorted(assign.items()))
        if key in cache:
            return cache[key]
        cfg = dataclasses.replace(base, **assign)
        compiled = build(cfg)
        r = _replay(
            compiled.plan, trace, compiled.topology, fit=fit,
            overlapped=assign.get("overlap_dispatch",
                                  getattr(base, "overlap_dispatch", True)))
        cache[key] = r.t_end
        rows.append((dict(assign), r.t_end))
        return r.t_end

    current = {f: getattr(base, f) for f in TUNABLE_FIELDS if f in space}
    default_score = score_of(current)
    best = default_score
    for field, values in space.items():
        if field not in current:
            continue
        for v in values:
            cand = {**current, field: v}
            s = score_of(cand)
            if s < best:
                best, current = s, cand
    overrides = {f: v for f, v in current.items()
                 if v != getattr(base, f)}
    return SearchResult(overrides=overrides, score=best,
                        default_score=default_score,
                        n_evals=len(cache), rows=tuple(rows))


def tuned_config(base, build: Callable[[Any], Any], *, key: str,
                 db: Optional[TuneDB] = None,
                 db_path: Optional[str] = None,
                 trace=None, fit=None, space: Optional[dict] = None):
    """The config ``engine.compile`` should actually use.

    DB hit → apply the stored overrides (no search); miss → run
    :func:`search` once, persist the winner, apply it.  Unknown override
    fields from a future build are dropped rather than crashing.
    """
    from repro.obs import metrics as _obs

    db = db or TuneDB(db_path)
    entry = db.lookup(key)
    if entry is None:
        _obs.RECORDER.count("tune.db_search")
        res = search(build, base=base, trace=trace, fit=fit, space=space)
        db.store(key, res.overrides, score=res.score,
                 default_score=res.default_score, evals=res.n_evals)
        overrides = res.overrides
    else:
        _obs.RECORDER.count("tune.db_hit")
        overrides = entry.get("overrides", {})
    overrides = {f: v for f, v in overrides.items()
                 if f in TUNABLE_FIELDS and hasattr(base, f)}
    return dataclasses.replace(base, **overrides)
