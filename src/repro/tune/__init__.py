"""repro.tune — profile-guided plan autotuning.

The ACiS software stack closes its loop by *observing* the deployed
program and refining the plan from measurements rather than from the
analytic model alone (§V: evaluate → map → refine).  This package is
that loop for the repro:

  1. **record** (:mod:`repro.tune.trace`) — per-stage wall-clock traces
     from the dataplane simulator, from the executor's instrumented
     eager mode, or from interleaved prefix timing of jitted programs;
     JSONL on disk, schema-versioned.
  2. **fit** (:mod:`repro.tune.fit`) — least-squares
     :class:`~repro.core.netmodel.NetParams` from traces: per-tier
     latency/bandwidth, the host-fallback detour, and the per-tier
     overlap fractions (``fit_tier_overlap`` as one special case).
  3. **replay** (:mod:`repro.tune.replay`) — score a *candidate* plan
     against a recording: measured times where stages match, fitted
     model times where they don't.
  4. **search** (:mod:`repro.tune.search`) — coordinate descent over
     the tunable config fields with replay as the objective; winners
     persist to ``.acis_tune.json`` and are applied transparently by
     ``engine.compile`` / ``gradient_sync`` when
     ``CollectiveConfig(autotune=True)``.
"""

from repro.tune.fit import (NetFit, TunedTopology, fit_net_params,
                            fit_overlap, fit_traces)
from repro.tune.replay import ReplayResult, StageScore, replay
from repro.tune.search import (DEFAULT_SPACE, SearchResult, TuneDB,
                               plan_key, search, tuned_config)
from repro.tune.trace import (SCHEMA_VERSION, ProgramTrace, StageTrace,
                              from_sim, interleaved_medians, load_jsonl,
                              record_instrumented, record_sim,
                              record_stagewise, save_jsonl)

__all__ = [
    "SCHEMA_VERSION", "StageTrace", "ProgramTrace", "from_sim",
    "record_sim", "record_instrumented", "record_stagewise",
    "interleaved_medians", "save_jsonl", "load_jsonl",
    "NetFit", "TunedTopology", "fit_net_params", "fit_overlap",
    "fit_traces",
    "ReplayResult", "StageScore", "replay",
    "DEFAULT_SPACE", "SearchResult", "TuneDB", "plan_key", "search",
    "tuned_config",
]
