"""Device-queue replay: score a candidate plan against a recorded trace.

The searcher needs to rank plan variants (bucket sizes, schedules,
dispatch modes) in microseconds, not by re-running JAX.  The replayer
walks a candidate :class:`~repro.core.executor.ExecutionPlan` wave by
wave, advancing one queue per mesh axis exactly like the runtime's
dispatch groups: stages sharing an axis serialize on its queue, queues
of one wave run concurrently and the wave ends at the longest queue
plus the *other* queues' exposed (injection-serialization) share — the
same merge the dataplane simulator performs and the analytic
``program_time`` prices.

Per stage the replayer prefers **measured** time: a recorded stage with
the same (kind, axis, schedule, payload bytes) is popped from the trace
(each record used at most once) and contributes its recorded duration
and — when the recorder knew it — its recorded serialization share.
Stages with no matching record (the candidate plan reshaped the work)
fall back to the analytic model, under fitted parameters when a
:class:`~repro.tune.fit.NetFit` is given.  With an empty trace the
replayed time therefore *is* ``netmodel.program_time``; with a full
self-trace it reproduces the recording — the two fixed points the tests
pin.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Optional

from repro.core import netmodel


@dataclasses.dataclass(frozen=True)
class StageScore:
    """How one candidate-plan stage was priced during a replay."""

    stage: int
    kind: str
    axis: str
    t: float
    source: str                    # "measured" | "model"


@dataclasses.dataclass(frozen=True)
class ReplayResult:
    t_end: float
    stages: tuple[StageScore, ...]
    matched: int
    modeled: int

    @property
    def match_fraction(self) -> float:
        n = self.matched + self.modeled
        return self.matched / n if n else 0.0


def _match_key(kind: str, axis: str, schedule: str,
               nbytes: Optional[int]) -> tuple:
    return (kind, axis, schedule, nbytes)


def _pool(trace) -> dict:
    """Recorded stages as FIFO queues per match key — a candidate stage
    consumes at most one record, in recorded order (deterministic)."""
    pool: dict = collections.defaultdict(collections.deque)
    if trace is None:
        return pool
    for ts in getattr(trace, "stages", trace):
        pool[_match_key(ts.kind, ts.axis, ts.schedule, ts.bytes)].append(
            (ts.duration, ts.t_ser))
    return pool


def replay(plan, trace=None, topo=None, *,
           fit=None, p: netmodel.NetParams = netmodel.PAPER,
           overlap: Optional[dict] = None,
           overlapped: bool = True) -> ReplayResult:
    """Score ``plan`` against ``trace``.

    ``topo`` is the candidate's compile topology (axis sizes + tiers);
    ``fit`` substitutes fitted link parameters and overlap fractions for
    the model-priced stages (:class:`~repro.tune.fit.NetFit`);
    ``overlapped=False`` scores the serial dispatch mode (every queue of
    a wave serializes — the ``overlap_dispatch=False`` runtime).  The
    same inputs always produce the identical score: the replay is pure
    arithmetic over the recording.
    """
    if fit is not None:
        topo = fit.wrap(topo) if topo is not None else topo
        p = fit.params()
        ov = dict(netmodel.TIER_OVERLAP)
        ov.update(fit.overlap)
    else:
        ov = dict(netmodel.TIER_OVERLAP)
    if overlap:
        ov.update(overlap)

    pool = _pool(trace)
    scores: list[StageScore] = []
    matched = modeled = 0
    t_total = 0.0
    for wave in plan.waves:
        # one queue per axis ('' pools the axis-less local stages, whose
        # 'local' tier overlap is 1.0 — never re-exposed)
        chain: dict[str, float] = {}
        exposed: dict[str, float] = {}
        for i in wave:
            st = plan.stages[i]
            ir = getattr(st, "ir", None)
            key = _match_key(st.kind, st.axis, st.schedule,
                             getattr(ir, "bytes_in", None))
            q = pool.get(key)
            tier = netmodel._tier_of(st.axis, topo)
            if q:
                dt, ser = q.popleft()
                matched += 1
                src = "measured"
            else:
                dt = netmodel.plan_stage_time(st, topo, p) or 0.0
                ser = None
                modeled += 1
                src = "model"
            if ser is None:
                ser = (1.0 - ov.get(tier, 1.0)) * dt
            chain[st.axis] = chain.get(st.axis, 0.0) + dt
            exposed[st.axis] = exposed.get(st.axis, 0.0) + ser
            scores.append(StageScore(i, st.kind, st.axis, dt, src))
        if not chain:
            continue
        if not overlapped:
            t_total += sum(chain.values())
            continue
        critical = max(chain, key=chain.get)
        t_total += chain[critical] + sum(
            e for ax, e in exposed.items() if ax != critical)
    return ReplayResult(t_end=t_total, stages=tuple(scores),
                        matched=matched, modeled=modeled)
