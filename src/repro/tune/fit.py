"""Fit :class:`~repro.core.netmodel.NetParams` from recorded traces.

Every ring-schedule stage time is linear in the link unknowns
(:class:`repro.core.netmodel.StageTerms`)::

    t = hops·hop_T + wire_bytes·(1/bw_T) + detours·D + host_bytes·(1/hbw)
        + [compute and mpi terms charged at their priors]

with per-tier unknowns ``hop_T`` (= fpga_link + port) and ``1/bw_T``,
plus two global host-fallback unknowns: the detour constant ``D``
(= 2·pcie + mpi_overhead) and the endpoint stream rate ``1/host_bw``.
:func:`fit_net_params` solves the normal equations of that design over
every recorded stage, with the same drop-and-resolve degeneracy handling
as :func:`repro.core.netmodel.fit_tier_overlap`: a column with no
support, or (nearly) collinear with the others, is unidentifiable from
these traces — it keeps its prior and the system is re-solved without
it, so the returned fit stays consistent with the equations it came
from.

:func:`fit_traces` then re-runs ``fit_tier_overlap`` on the whole-program
end-to-end times *under the fitted tiers* — the per-tier exposure
decomposition ``netmodel._wave_terms`` exposes makes the overlap
fractions one more linear special case of the same machinery.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.core import netmodel


class TunedTopology:
    """A topology view whose per-axis link parameters come from a fit.

    Duck-types :class:`repro.core.compiler.Topology` for everything the
    cost model and the simulator read (``axes``/``spec``/``size``/
    ``net``), but resolves ``net(axis)`` through ``{tier: NetParams}``
    instead of the global :data:`repro.core.netmodel.TIERS` constants —
    so fitted parameters flow into ``plan_stage_time``/``program_time``
    without mutating module state.
    """

    def __init__(self, topo, tiers: dict):
        self._topo = topo
        self._tiers = dict(tiers)

    @property
    def axes(self):
        return self._topo.axes

    def names(self):
        return self._topo.names()

    def spec(self, name):
        return self._topo.spec(name)

    def size(self, name):
        return self._topo.size(name)

    def net(self, name) -> netmodel.NetParams:
        spec = self._topo.spec(name)
        tier = spec.tier if spec is not None else "ici"
        return self._tiers.get(tier, self._topo.net(name))

    def with_sizes(self, sizes: dict) -> "TunedTopology":
        return TunedTopology(self._topo.with_sizes(sizes), self._tiers)


@dataclasses.dataclass(frozen=True)
class NetFit:
    """A fitted network model: per-tier link params + overlap fractions.

    ``dropped`` names the unidentifiable columns left at their priors
    (e.g. ``"dci.hop"`` when no trace stage ever crossed the dci tier);
    ``residual`` is the rms relative error of the fitted per-stage times
    over the stages that entered the design.
    """

    tiers: dict                    # tier name → NetParams
    overlap: dict                  # tier name → overlap fraction
    detour: float                  # fitted 2·pcie + mpi_overhead (s)
    host_bw: float                 # fitted endpoint stream rate (B/s)
    residual: float = 0.0
    n_stages: int = 0
    dropped: tuple = ()

    def wrap(self, topo) -> TunedTopology:
        """``topo`` with this fit's per-tier link parameters."""
        return TunedTopology(topo, self.tiers)

    def params(self, tier: str = "ici") -> netmodel.NetParams:
        return self.tiers.get(tier, netmodel.PAPER)

    def program_time(self, plan, topo) -> float:
        """:func:`repro.core.netmodel.program_time` under this fit."""
        return netmodel.program_time(plan, self.wrap(topo),
                                     self.params(), overlap=self.overlap)


def _stage_rows(samples, tiers: Sequence[str]):
    """(coeff_vector, residual_target, rel_scale) per usable stage.

    Columns: ``[hop_T, invbw_T] * tiers + [detour, inv_host_bw]``.  The
    compute and extra-mpi terms are charged at their prior rates and
    subtracted from the measured time — the CGRA device and the software
    stack are not what the wire fit estimates.
    """
    cols = [f"{t}.{u}" for t in tiers for u in ("hop", "invbw")]
    cols += ["host.detour", "host.invbw"]
    rows = []
    for plan, topo, trace in samples:
        stages = getattr(trace, "stages", trace)
        for ts in stages:
            i = ts.stage
            if not 0 <= i < len(plan.stages):
                continue
            st = plan.stages[i]
            if st.kind != ts.kind:
                continue
            got = netmodel.plan_stage_terms(st, topo)
            if got is None:
                continue
            tier, terms, placement = got
            p_prior = topo.net(st.axis) if st.axis else netmodel.PAPER
            fixed = 0.0
            if terms.compute_bytes:
                fixed += terms.compute_bytes / netmodel.accel_rate(
                    p_prior, placement)
            fixed += terms.mpi_msgs * p_prior.mpi_overhead
            coeff = [0.0] * len(cols)
            if tier in tiers:
                base = 2 * tiers.index(tier)
                coeff[base] = terms.hops
                coeff[base + 1] = terms.wire_bytes
            elif terms.hops or terms.wire_bytes:
                # a tier outside the fit keeps its prior wire cost
                fixed += terms.hops * (p_prior.fpga_link + p_prior.port) \
                    + terms.wire_bytes / p_prior.bw
            coeff[-2] = terms.detours
            coeff[-1] = terms.host_bytes
            if not any(coeff):
                continue
            rows.append((coeff, ts.duration - fixed, max(ts.duration,
                                                         1e-12)))
    return cols, rows


def _solve_dropping(cols, rows, priors):
    """Normal-equations solve with fit_tier_overlap's drop-and-resolve:
    columns without support or collinear with the rest fall back to their
    prior value and the system is re-solved without them."""
    live = list(range(len(cols)))
    while True:
        k = len(live)
        if k == 0:
            return dict(priors), tuple(cols)
        gram = [[0.0] * k for _ in range(k)]
        rhs = [0.0] * k
        for coeff, target, _ in rows:
            r = target - sum(coeff[j] * priors[cols[j]]
                             for j in range(len(cols)) if j not in live)
            for a in range(k):
                ca = coeff[live[a]]
                if not ca:
                    continue
                rhs[a] += ca * r
                for b in range(k):
                    gram[a][b] += ca * coeff[live[b]]
        dead = next((j for a, j in enumerate(live)
                     if gram[a][a] <= 0.0), None)
        a_mat = None
        if dead is None:
            a_mat = [row[:] + [rhs[a]] for a, row in enumerate(gram)]
            for col in range(k):
                piv = max(range(col, k), key=lambda r_: abs(a_mat[r_][col]))
                scale = max(abs(gram[col][col]), 1e-30)
                if abs(a_mat[piv][col]) < 1e-9 * scale:
                    dead = live[col]
                    break
                a_mat[col], a_mat[piv] = a_mat[piv], a_mat[col]
                for r_ in range(k):
                    if r_ != col and a_mat[r_][col]:
                        f = a_mat[r_][col] / a_mat[col][col]
                        a_mat[r_] = [x - f * y
                                     for x, y in zip(a_mat[r_], a_mat[col])]
        if dead is not None:
            live.remove(dead)
            continue
        fitted = dict(priors)
        for a, j in enumerate(live):
            fitted[cols[j]] = max(a_mat[a][-1] / a_mat[a][a], 0.0)
        dropped = tuple(cols[j] for j in range(len(cols))
                        if j not in live)
        return fitted, dropped


def fit_net_params(samples, *, tiers: Sequence[str] = ("ici", "dci"),
                   p: netmodel.NetParams = netmodel.PAPER) -> NetFit:
    """Least-squares :class:`NetFit` (link params only; overlap fractions
    stay at :data:`~repro.core.netmodel.TIER_OVERLAP` — use
    :func:`fit_traces` for the full fit).

    ``samples`` is an iterable of ``(plan, topo, trace)`` where ``trace``
    is a :class:`~repro.tune.trace.ProgramTrace` (or bare list of
    :class:`~repro.tune.trace.StageTrace`) recorded from that plan.
    """
    samples = list(samples)
    tiers = tuple(tiers)
    cols, rows = _stage_rows(samples, tiers)
    priors = {}
    for t in tiers:
        tp = netmodel.TIERS.get(t, p)
        priors[f"{t}.hop"] = tp.fpga_link + tp.port
        priors[f"{t}.invbw"] = 1.0 / tp.bw
    priors["host.detour"] = 2 * p.pcie + p.mpi_overhead
    priors["host.invbw"] = 1.0 / p.host_bw
    fitted, dropped = _solve_dropping(cols, rows, priors)

    detour = fitted["host.detour"]
    host_bw = 1.0 / max(fitted["host.invbw"], 1e-30)
    tier_params = {}
    for t in tiers:
        prior_t = netmodel.TIERS.get(t, p)
        hop = fitted[f"{t}.hop"]
        tier_params[t] = dataclasses.replace(
            prior_t,
            fpga_link=max(hop - prior_t.port, 0.0),
            bw=1.0 / max(fitted[f"{t}.invbw"], 1e-30),
            mpi_overhead=max(detour - 2 * p.pcie, 0.0),
            host_bw=host_bw)

    # rms relative residual of the fitted per-stage times
    err2, n_used = 0.0, 0
    for coeff, target, scale in rows:
        pred = sum(c * fitted[cols[j]] for j, c in enumerate(coeff))
        err2 += ((pred - target) / scale) ** 2
        n_used += 1
    residual = math.sqrt(err2 / n_used) if n_used else 0.0

    from repro.obs import metrics as _obs
    _obs.RECORDER.count("tune.fit_runs")

    return NetFit(tiers=tier_params, overlap=dict(netmodel.TIER_OVERLAP),
                  detour=detour, host_bw=host_bw, residual=residual,
                  n_stages=n_used, dropped=dropped)


def fit_overlap(samples, fit: NetFit, *,
                tiers: Sequence[str] = ("ici", "dci")) -> dict:
    """:func:`repro.core.netmodel.fit_tier_overlap` under fitted link
    parameters — the special case the full fit reduces to once the
    per-stage times are pinned.  ``samples`` as in :func:`fit_net_params`
    (whole-program ``trace.t_end`` is the measurement)."""
    wrapped = [(plan, fit.wrap(topo), getattr(trace, "t_end", trace))
               for plan, topo, trace in samples]
    return netmodel.fit_tier_overlap(wrapped, tiers=tuple(tiers),
                                     p=fit.params())


def fit_traces(samples, *, tiers: Sequence[str] = ("ici", "dci"),
               p: netmodel.NetParams = netmodel.PAPER,
               overlap: bool = True) -> NetFit:
    """The full fit: link parameters from per-stage durations, then the
    per-tier overlap fractions from the end-to-end times under those
    parameters.  Multi-axis samples identify the overlap; single-axis
    samples leave it at the calibrated default (drop-and-resolve)."""
    samples = list(samples)
    fit = fit_net_params(samples, tiers=tiers, p=p)
    if overlap:
        fit = dataclasses.replace(
            fit, overlap={**fit.overlap,
                          **fit_overlap(samples, fit, tiers=tiers)})
    from repro.obs import metrics as _obs
    _obs.RECORDER.event("tune.fit", residual=fit.residual,
                        n_stages=fit.n_stages, dropped=fit.dropped)
    return fit
