"""Stage-trace recording: the observed side of the tuning loop.

A :class:`StageTrace` is one executed plan stage with wall-clock
boundaries — the shared currency of the whole ``repro.tune`` subsystem,
and literally the same type as the observability layer's
:class:`repro.obs.spans.StageSpan` (so recorded traces export straight
to Perfetto via :mod:`repro.obs.timeline`).  Three recorders emit it:

  * :func:`from_sim` converts a dataplane-simulator
    :class:`~repro.cgra.simulate.SimReport` (each ``SimStage`` already
    carries its branch start timestamp and injection-serialization
    share), so the record → fit → replay → search loop is testable
    without hardware;
  * :func:`record_instrumented` runs a rank-local
    :class:`~repro.core.compiler.CompiledProgram` eagerly with the
    executor's instrumented mode (``perf_counter`` around a
    ``block_until_ready`` per stage);
  * :func:`record_stagewise` attributes per-stage time to a *jitted*
    program by timing plan prefixes interleaved — the generalization of
    the A/B machinery in ``benchmarks/execplan.py`` (same idea: pair the
    variants inside one loop so clock drift cancels, take medians).

Traces serialize to JSONL (:func:`save_jsonl` / :func:`load_jsonl`):
one ``program`` header line followed by one line per stage, all stamped
with :data:`SCHEMA_VERSION` — a loader refuses records from a different
schema rather than silently misreading fields.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Callable, Optional, Sequence

from repro.obs.spans import StageSpan

SCHEMA_VERSION = 1

# the stage record IS the obs layer's shared span schema — one type,
# emitted by the executor's instrument hook, stored by this module,
# exported by repro.obs.timeline.  Kept under its historical name here.
StageTrace = StageSpan


@dataclasses.dataclass(frozen=True)
class ProgramTrace:
    """One recorded end-to-end run of a compiled program's plan."""

    name: str
    stages: tuple[StageTrace, ...]
    axes: dict
    t_end: float
    source: str = "unknown"        # "sim" | "instrumented" | "stagewise"
    schema: int = SCHEMA_VERSION

    @property
    def t_serial(self) -> float:
        """Sum of per-stage durations (the no-overlap cost)."""
        return sum(s.duration for s in self.stages)


# ---------------------------------------------------------------------------
# recorders
# ---------------------------------------------------------------------------

def _stage_meta(compiled, i: int) -> tuple[Optional[int], str]:
    st = compiled.stages[i]
    m = getattr(st.ir, "bytes_in", None) if st.ir is not None else None
    pl = st.placement.describe() if st.placement is not None else ""
    return m, pl


def from_sim(compiled, report) -> ProgramTrace:
    """A :class:`ProgramTrace` from a dataplane-simulator run.

    ``report.stages`` is in plan-stage order (every stage simulates), so
    row *i* pairs with ``compiled.stages[i]`` — the pairing that fills
    in the payload bytes and placement the replayer matches on.
    """
    if len(report.stages) != len(compiled.stages):
        raise ValueError(
            f"report has {len(report.stages)} stages, program has "
            f"{len(compiled.stages)} — not a run of this program")
    rows = []
    for i, s in enumerate(report.stages):
        m, pl = _stage_meta(compiled, i)
        rows.append(StageTrace(
            stage=i, kind=s.kind, axis=s.axis, wave=s.wave,
            t_start=s.t_start, t_end=s.t_start + s.t_sim, bytes=m,
            schedule=s.schedule, placement=pl, t_ser=s.t_ser))
    return ProgramTrace(
        name=getattr(compiled.source, "name", "program"),
        stages=tuple(rows), axes=dict(report.axes),
        t_end=report.t_end, source="sim")


def record_sim(compiled, sim, *inputs) -> tuple:
    """Run ``compiled`` on a :class:`~repro.cgra.simulate.SwitchSim` and
    return ``(outputs, trace, report)``."""
    outs, report = sim.run(compiled, *inputs)
    return outs, from_sim(compiled, report), report


def record_instrumented(compiled, *xs, arenas=None,
                        axes: Optional[dict] = None) -> tuple:
    """Run a rank-local program eagerly with per-stage timing.

    Returns ``(outputs, trace)`` (outputs include the new arenas when
    ``arenas`` is passed, mirroring the program call).  Timestamps are
    normalized so the first stage starts at 0.  Only meaningful outside
    ``jit`` — see :func:`repro.core.executor.execute`.
    """
    from repro.obs import spans as _spans

    records: list[StageTrace] = []
    out = compiled(*xs, arenas=arenas, instrument=records)
    # the executor already emits the shared StageSpan schema (payload
    # bytes and placement attached) — just re-anchor t=0
    rows = _spans.normalize(records)
    t_end = max((s.t_end for s in rows), default=0.0)
    trace = ProgramTrace(
        name=getattr(compiled.source, "name", "program"),
        stages=tuple(rows), axes=dict(axes or {}), t_end=t_end,
        source="instrumented")
    return out, trace


def interleaved_medians(runs: dict[str, Callable[[], None]], *,
                        iters: int = 5, warmup: int = 1) -> dict[str, float]:
    """Median wall-clock of several zero-arg runners, timed interleaved.

    The generalized A/B machinery: iteration *k* runs every variant once
    before any variant runs iteration *k+1*, so slow clock drift and
    machine noise hit all variants alike and the medians stay
    comparable.  Returns ``{name: median_seconds}``.
    """
    import numpy as np

    for _ in range(max(warmup, 0)):
        for fn in runs.values():
            fn()
    samples: dict[str, list[float]] = {k: [] for k in runs}
    for _ in range(max(iters, 1)):
        for k, fn in runs.items():
            t0 = time.perf_counter()
            fn()
            samples[k].append(time.perf_counter() - t0)
    return {k: float(np.median(v)) for k, v in samples.items()}


def _prefix_plan(plan, k: int):
    """The plan truncated to its first ``k`` stages, every produced value
    an output (nothing for a jit to dead-code-eliminate)."""
    from repro.core import executor

    stages = tuple(plan.stages[:k])
    outs = tuple(v for st in stages for v in st.out_vids)
    return executor.build_plan(stages, plan.num_inputs, outs)


def record_stagewise(compiled, runner_factory: Callable, *,
                     iters: int = 5,
                     axes: Optional[dict] = None) -> ProgramTrace:
    """Per-stage wall-clock for a *jitted* program via prefix timing.

    ``runner_factory(prefix_plan)`` must return a zero-arg callable that
    executes the prefix plan end to end (typically ``shard_map`` + ``jit``
    over the caller's mesh, blocking on the result).  The k-stage prefix
    is timed against the (k-1)-stage prefix interleaved; the difference
    is attributed to stage k-1.  Costs n_stages compiles — a profiling
    tool, not a fast path.
    """
    plan = compiled.plan
    n = len(plan.stages)
    runs = {str(k): runner_factory(_prefix_plan(plan, k))
            for k in range(n + 1)}
    meds = interleaved_medians(runs, iters=iters)
    wave_of = {i: w for w, ws in enumerate(plan.waves) for i in ws}
    rows, t = [], 0.0
    for i in range(n):
        st = plan.stages[i]
        dt = max(meds[str(i + 1)] - meds[str(i)], 0.0)
        m, pl = _stage_meta(compiled, i)
        rows.append(StageTrace(
            stage=i, kind=st.kind, axis=st.axis, wave=wave_of.get(i, 0),
            t_start=t, t_end=t + dt, bytes=m, schedule=st.schedule,
            placement=pl))
        t += dt
    return ProgramTrace(
        name=getattr(compiled.source, "name", "program"),
        stages=tuple(rows), axes=dict(axes or {}), t_end=t,
        source="stagewise")


# ---------------------------------------------------------------------------
# JSONL persistence
# ---------------------------------------------------------------------------

def save_jsonl(path, traces: Sequence[ProgramTrace] | ProgramTrace) -> None:
    """Write traces as JSONL: per trace one ``program`` header line, then
    one ``stage`` line per stage, all carrying the schema version."""
    if isinstance(traces, ProgramTrace):
        traces = [traces]
    with open(path, "w") as f:
        for tr in traces:
            f.write(json.dumps({
                "record": "program", "schema": tr.schema, "name": tr.name,
                "axes": {k: int(v) for k, v in tr.axes.items()},
                "t_end": tr.t_end, "source": tr.source}) + "\n")
            for s in tr.stages:
                f.write(json.dumps(
                    {"record": "stage", **dataclasses.asdict(s)}) + "\n")


def load_jsonl(path) -> list[ProgramTrace]:
    """Load every trace from a JSONL file written by :func:`save_jsonl`.

    Refuses records whose ``schema`` differs from
    :data:`SCHEMA_VERSION` — the on-disk format is versioned precisely
    so a replayer never misreads fields recorded by a different build.
    """
    traces: list[ProgramTrace] = []
    header: Optional[dict] = None
    stages: list[StageTrace] = []

    def flush():
        if header is not None:
            traces.append(ProgramTrace(
                name=header["name"], stages=tuple(stages),
                axes=dict(header.get("axes", {})),
                t_end=float(header["t_end"]),
                source=header.get("source", "unknown")))

    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            kind = rec.pop("record", None)
            if kind == "program":
                if rec.get("schema") != SCHEMA_VERSION:
                    raise ValueError(
                        f"trace schema {rec.get('schema')!r} != "
                        f"{SCHEMA_VERSION} — re-record with this build")
                flush()
                header, stages = rec, []
            elif kind == "stage":
                if header is None:
                    raise ValueError("stage record before program header")
                stages.append(StageTrace(**rec))
            else:
                raise ValueError(f"unknown record type {kind!r}")
    flush()
    return traces
