"""Serving paths: cache init, prefill, and single-token decode.

Caches mirror the stacked-layer structure: one stacked cache pytree per
period position (scanned together with the params), plus unstacked caches
for remainder layers.  Cache kinds per block:

  self/dense_self/moe_self(GQA) — {k, v}: [B, S, Hkv, dh]
  moe_self(MLA)                 — {c_kv, k_rope}: [B, S, ·] (57× smaller)
  window                        — ring buffer [B, W, Hkv, dh] + slot pos
  lru                           — {h: [B, W], conv: [B, cw-1, W]}
  rwkv                          — {s: [B, H, K, V], x_tok, x_ch: [B, D]}

decode_step cost is O(1) in generated length for lru/rwkv (the long_500k
story) and O(S) attention reads for KV-cache kinds.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import parallel as TP
from repro.models import rglru as RG
from repro.models import rwkv6 as RW
from repro.models.config import ModelConfig
from repro.models.transformer import (_norm, _period_of, apply_block, logits)

PyTree = Any


def _block_cache(cfg: ModelConfig, kind: str, batch: int, seq: int,
                 dtype=jnp.bfloat16) -> PyTree:
    if kind in ("self", "dense_self", "enc_self", "moe_self"):
        if kind in ("dense_self", "moe_self") and cfg.mla is not None:
            return MLA.init_mla_cache(batch, seq, cfg.mla, dtype)
        return A.init_gqa_cache(batch, seq, cfg.n_kv_heads, cfg.head_dim,
                                dtype)
    if kind == "window":
        return A.init_window_cache(batch, min(cfg.hybrid.window, seq),
                                   cfg.n_kv_heads, cfg.head_dim, dtype)
    if kind == "lru":
        return RG.init_rglru_cache(batch, cfg.hybrid, cfg.d_model, dtype)
    if kind == "rwkv":
        return RW.init_rwkv6_cache(batch, cfg.d_model, dtype)
    if kind == "dec_self_cross":
        return A.init_gqa_cache(batch, seq, cfg.n_kv_heads, cfg.head_dim,
                                dtype)
    if kind == "cross":
        return {}  # context is static; nothing cached
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, seq: int,
               dtype=jnp.bfloat16) -> PyTree:
    period, n_periods, rem = _period_of(cfg)

    def stack(kind):
        one = _block_cache(cfg, kind, batch, seq, dtype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_periods,) + a.shape).copy(), one)

    cache = {"layers": {f"pos{j}_{kind}": stack(kind)
                        for j, kind in enumerate(period)},
             "rem": {f"rem{j}_{kind}": _block_cache(cfg, kind, batch, seq,
                                                    dtype)
                     for j, kind in enumerate(rem)}}
    return cache


# ---------------------------------------------------------------------------
# single-block decode
# ---------------------------------------------------------------------------

def block_decode(p: PyTree, x: jax.Array, cache: PyTree, index: jax.Array,
                 cfg: ModelConfig, kind: str, *,
                 context: Optional[jax.Array] = None
                 ) -> tuple[jax.Array, PyTree]:
    akw = dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, d_head=cfg.head_dim,
               qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta)
    tp = TP.current()
    if kind in ("self", "dense_self", "moe_self"):
        xin = _norm(p["ln1"], x, cfg)
        if kind in ("dense_self", "moe_self") and cfg.mla is not None:
            h, cache = MLA.mla_decode(p["attn"], xin, cache, index,
                                      n_heads=cfg.n_heads, cfg=cfg.mla,
                                      rope_theta=cfg.rope_theta)
        else:
            h, cache = A.gqa_decode(p["attn"], xin, cache, index, **akw)
        if tp is not None:
            h = tp.attn_reduce(h)
        x = x + h
        if kind == "moe_self":
            y, _ = MOE.moe_ffn(p["moe"], _norm(p["ln2"], x, cfg), cfg.moe,
                               cfg.activation)
            x = x + y
        else:
            f = L.ffn(p["ffn"], _norm(p["ln2"], x, cfg), cfg.activation)
            if tp is not None:
                f = tp.ffn_reduce(f)
            x = x + f
    elif kind == "window":
        h, cache = A.window_decode(p["attn"], _norm(p["ln1"], x, cfg), cache,
                                   index, window=cfg.hybrid.window, **akw)
        x = x + h
        x = x + L.ffn(p["ffn"], _norm(p["ln2"], x, cfg), cfg.activation)
    elif kind == "lru":
        h, cache = RG.rglru_decode(p["mixer"], _norm(p["ln1"], x, cfg),
                                   cache, cfg=cfg.hybrid)
        x = x + h
        x = x + L.ffn(p["ffn"], _norm(p["ln2"], x, cfg), cfg.activation)
    elif kind == "rwkv":
        x, cache = RW.rwkv6_decode(
            p["tok"], p["ch"], x, cache,
            lambda z: _norm(p["ln1"], z, cfg),
            lambda z: _norm(p["ln2"], z, cfg))
    elif kind == "dec_self_cross":
        h, cache = A.gqa_decode(p["attn"], _norm(p["ln1"], x, cfg), cache,
                                index, use_rope=False, **akw)
        x = x + h
        h = A.gqa_attention(p["xattn"], _norm(p["ln_x"], x, cfg),
                            context=context, causal=False, use_rope=False,
                            chunk=cfg.attn_chunk, **akw)
        x = x + h
        x = x + L.ffn(p["ffn"], _norm(p["ln2"], x, cfg), cfg.activation)
    elif kind == "cross":
        h = A.gqa_attention(p["attn"], _norm(p["ln1"], x, cfg),
                            context=context, causal=False,
                            chunk=cfg.attn_chunk, **akw)
        x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * h
        f = L.ffn(p["ffn"], _norm(p["ln2"], x, cfg), cfg.activation)
        x = x + jnp.tanh(p["gate_ffn"]).astype(x.dtype) * f
    else:
        raise ValueError(kind)
    return x, cache


# ---------------------------------------------------------------------------
# decode step over the whole stack
# ---------------------------------------------------------------------------

def decode_step(params: PyTree, cfg: ModelConfig, token: jax.Array,
                cache: PyTree, index: jax.Array, *,
                context: Optional[jax.Array] = None
                ) -> tuple[jax.Array, PyTree]:
    """token: [B] int32; ``index`` scalar or per-row [B] vector.
    Returns (logits [B, V], new_cache)."""
    x = L.embed_lookup(params["embed"], token[:, None])
    if cfg.family == "encdec":
        idx = jnp.asarray(index)
        if idx.ndim > 0:
            pos = jnp.take(params["dec_pos"], idx, axis=0)[:, None, :]
        else:
            pos = jax.lax.dynamic_slice_in_dim(
                params["dec_pos"], idx, 1, 0)[None]
        x = x + pos.astype(x.dtype)
    period, _, rem = _period_of(cfg)
    prefix_rem = cfg.family == "moe" and bool(rem)

    def run_rem(x, cache_rem):
        new = {}
        for name in sorted(cache_rem):
            kind = name.split("_", 1)[1]
            blk = params["rem"][name]
            x, c = block_decode(blk, x, cache_rem[name], index, cfg, kind,
                                context=context)
            new[name] = c
        return x, new

    new_cache = {"layers": None, "rem": cache["rem"]}
    if prefix_rem:
        x, new_cache["rem"] = run_rem(x, cache["rem"])

    def period_body(x, pc):
        pp, cc = pc
        new_cc = {}
        for j, kind in enumerate(period):
            name = f"pos{j}_{kind}"
            x, c = block_decode(pp[name], x, cc[name], index, cfg, kind,
                                context=context)
            new_cc[name] = c
        return x, new_cc

    n_per = jax.tree.leaves(params["layers"])[0].shape[0]
    x, new_layer_cache = jax.lax.scan(
        period_body, x, (params["layers"], cache["layers"]),
        unroll=n_per if cfg.analysis_unroll else 1)
    new_cache["layers"] = new_layer_cache

    if not prefix_rem:
        x, new_cache["rem"] = run_rem(x, cache["rem"])

    x = _norm(params["final_norm"], x, cfg)
    lg = logits(params, cfg, x)[:, 0, :]
    return lg, new_cache


# ---------------------------------------------------------------------------
# prefill: forward pass that also fills the caches
# ---------------------------------------------------------------------------

def prefill(params: PyTree, cfg: ModelConfig, tokens: jax.Array,
            cache: PyTree, *, context: Optional[jax.Array] = None
            ) -> tuple[jax.Array, PyTree]:
    """Fill caches with a whole prompt [B, T]; returns (last_logits, cache).

    Implemented as T sequential decode steps under lax.fori_loop for state
    kinds (exact for every cache kind).  For pure-GQA stacks a fast batched
    path projects K/V for the whole prompt in one forward pass.
    """
    period, _, rem = _period_of(cfg)
    kinds = set(period) | {n.split("_", 1)[1] for n in cache["rem"]}
    if kinds <= {"self", "dense_self"} and cfg.mla is None:
        return _prefill_gqa_fast(params, cfg, tokens, cache, context=context)

    b, t = tokens.shape

    def body(i, carry):
        lg, cache = carry
        lg, cache = decode_step(params, cfg, tokens[:, i], cache, i,
                                context=context)
        return lg, cache

    lg0 = jnp.zeros((b, cfg.vocab), jnp.float32)
    lg, cache = jax.lax.fori_loop(0, t, body, (lg0, cache))
    return lg, cache


def _prefill_gqa_fast(params, cfg, tokens, cache, *, context=None):
    """Batched prefill for homogeneous GQA stacks: one forward pass emits
    every layer's K/V (collected as scan ys) plus the last-token logits."""
    from repro.models.transformer import forward
    b, t = tokens.shape
    x = L.embed_lookup(params["embed"], tokens)
    period, _, rem = _period_of(cfg)

    # Single pass per layer: reuse apply_block for the hidden stream and
    # project K/V once more for the cache (cheap relative to attention).
    def body(x, pp):
        new_kv = {}
        for j, kind in enumerate(period):
            name = f"pos{j}_{kind}"
            p = pp[name]
            xin = _norm(p["ln1"], x, cfg)
            pos = jnp.arange(t)[None]
            _, k, v = A._project_qkv(p["attn"], xin, xin, cfg.n_heads,
                                     cfg.n_kv_heads, cfg.head_dim,
                                     cfg.qk_norm, cfg.rope_theta, pos, pos)
            x, _ = apply_block(p, x, cfg, kind, context=context)
            new_kv[name] = {"k": k, "v": v}
        return x, new_kv

    x, kv = jax.lax.scan(body, x, params["layers"])
    x = _norm(params["final_norm"], x, cfg)
    lg = logits(params, cfg, x[:, -1:, :])[:, 0, :]

    seq = jax.tree.leaves(cache["layers"])[0].shape[2]

    def place(full, new):  # full: [P, B, S, H, d]; new: [P, B, T, H, d]
        return jax.lax.dynamic_update_slice_in_dim(
            full, new.astype(full.dtype), 0, axis=2)

    new_cache = {"layers": {}, "rem": cache["rem"]}
    for name, c in cache["layers"].items():
        new_cache["layers"][name] = {
            "k": place(c["k"], kv[name]["k"]),
            "v": place(c["v"], kv[name]["v"])}
    return lg, new_cache
