"""Model assembly: blocks per family + scan-over-layers + decode paths.

Scan-over-layers keeps HLO size O(1) in depth (the 60-layer 236B dry-run
compiles in seconds at 512 devices) and is wrapped in jax.checkpoint per the
config remat policy.  Heterogeneous stacks (hybrid RG-LRU patterns, VLM
cross-attention interleave) scan over *periods* — a period is the repeating
unit, each position in it with its own stacked params — plus an unscanned
remainder.

Families:
  dense   — [attn, ffn] × L
  moe     — [attn, moe-ffn] × L (optional leading dense layers; MLA option)
  hybrid  — pattern ("lru","lru","attn") × periods (+ remainder), local attn
  ssm     — [rwkv6 token mix, channel mix] × L
  encdec  — encoder [attn,ffn] × Le ; decoder [self, cross, ffn] × L
  vlm     — period [cross, self×(k-1)] × (L/k)
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import parallel as TP
from repro.models import rglru as RG
from repro.models import rwkv6 as RW
from repro.models.config import ModelConfig
from repro.sharding.act import shard_act

PyTree = Any


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)  # full


# ---------------------------------------------------------------------------
# block init / apply (single layer)
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, kind: str) -> PyTree:
    """kind ∈ {self, window, cross, lru, moe_self, rwkv, enc_self}."""
    dt = L._dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: dict = {"ln1": L.init_norm(d, cfg.norm), "ln2": L.init_norm(d, cfg.norm)}
    if kind in ("self", "window", "enc_self"):
        p["attn"] = A.init_gqa(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim, cfg.qk_norm, dt)
        p["ffn"] = L.init_ffn(ks[1], d, cfg.d_ff, cfg.activation, dt)
    elif kind == "cross":
        p["attn"] = A.init_gqa(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim, cfg.qk_norm, dt)
        p["ffn"] = L.init_ffn(ks[1], d, cfg.d_ff, cfg.activation, dt)
        p["gate_attn"] = jnp.zeros((), jnp.float32)
        p["gate_ffn"] = jnp.zeros((), jnp.float32)
    elif kind == "lru":
        p["mixer"] = RG.init_rglru(ks[0], d, cfg.hybrid, dt)
        p["ffn"] = L.init_ffn(ks[1], d, cfg.d_ff, cfg.activation, dt)
    elif kind == "moe_self":
        if cfg.mla is not None:
            p["attn"] = MLA.init_mla(ks[0], d, cfg.n_heads, cfg.mla, dt)
        else:
            p["attn"] = A.init_gqa(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.head_dim, cfg.qk_norm, dt)
        p["moe"] = MOE.init_moe(ks[1], d, cfg.moe, cfg.activation, dt)
    elif kind == "dense_self":  # leading dense layers of a MoE stack
        if cfg.mla is not None:
            p["attn"] = MLA.init_mla(ks[0], d, cfg.n_heads, cfg.mla, dt)
        else:
            p["attn"] = A.init_gqa(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.head_dim, cfg.qk_norm, dt)
        p["ffn"] = L.init_ffn(ks[1], d, cfg.moe.d_ff_dense or cfg.d_ff,
                              cfg.activation, dt)
    elif kind == "rwkv":
        p = {"ln1": L.init_norm(d, cfg.norm), "ln2": L.init_norm(d, cfg.norm),
             "tok": RW.init_rwkv6(ks[0], d, dt),
             "ch": RW.init_channel_mix(ks[1], d, cfg.d_ff, dt)}
    elif kind == "dec_self_cross":
        p["attn"] = A.init_gqa(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim, cfg.qk_norm, dt)
        p["ln_x"] = L.init_norm(d, cfg.norm)
        p["xattn"] = A.init_gqa(ks[1], d, cfg.n_heads, cfg.n_kv_heads,
                                cfg.head_dim, False, dt)
        p["ffn"] = L.init_ffn(ks[2], d, cfg.d_ff, cfg.activation, dt)
    else:
        raise ValueError(f"unknown block kind {kind}")
    return p


def _norm(p, x, cfg):
    return L.apply_norm(p, x, eps=cfg.norm_eps)


def apply_block(p: PyTree, x: jax.Array, cfg: ModelConfig, kind: str, *,
                context: Optional[jax.Array] = None,
                q_offset: int = 0) -> tuple[jax.Array, jax.Array]:
    """Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    akw = dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, d_head=cfg.head_dim,
               qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta,
               chunk=cfg.attn_chunk, q_offset=q_offset,
               unroll=cfg.analysis_unroll)
    tp = TP.current()
    if kind in ("self", "enc_self", "window"):
        h = A.gqa_attention(p["attn"], _norm(p["ln1"], x, cfg),
                            causal=(kind != "enc_self"),
                            window=cfg.hybrid.window if kind == "window"
                            else None,
                            use_rope=cfg.family not in ("encdec",), **akw)
        if tp is not None:
            h = tp.attn_reduce(h)
        x = x + h
        f = L.ffn(p["ffn"], _norm(p["ln2"], x, cfg), cfg.activation)
        if tp is not None:
            f = tp.ffn_reduce(f)
        x = x + f
    elif kind == "cross":
        h = A.gqa_attention(p["attn"], _norm(p["ln1"], x, cfg),
                            context=context, causal=False, **akw)
        x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * h
        f = L.ffn(p["ffn"], _norm(p["ln2"], x, cfg), cfg.activation)
        x = x + jnp.tanh(p["gate_ffn"]).astype(x.dtype) * f
    elif kind == "lru":
        x = x + RG.rglru_block(p["mixer"], _norm(p["ln1"], x, cfg),
                               cfg=cfg.hybrid)
        x = x + L.ffn(p["ffn"], _norm(p["ln2"], x, cfg), cfg.activation)
    elif kind in ("moe_self", "dense_self"):
        if cfg.mla is not None:
            h = MLA.mla_attention(p["attn"], _norm(p["ln1"], x, cfg),
                                  n_heads=cfg.n_heads, cfg=cfg.mla,
                                  rope_theta=cfg.rope_theta,
                                  q_offset=q_offset, chunk=cfg.attn_chunk,
                                  unroll=cfg.analysis_unroll)
        else:
            h = A.gqa_attention(p["attn"], _norm(p["ln1"], x, cfg),
                                causal=True, **akw)
        if tp is not None:
            h = tp.attn_reduce(h)
        x = x + h
        if kind == "moe_self":
            y, aux = MOE.moe_ffn(p["moe"], _norm(p["ln2"], x, cfg), cfg.moe,
                                 cfg.activation)
            x = x + y
        else:
            f = L.ffn(p["ffn"], _norm(p["ln2"], x, cfg), cfg.activation)
            if tp is not None:
                f = tp.ffn_reduce(f)
            x = x + f
    elif kind == "rwkv":
        x = x + RW.rwkv6_token_mix(p["tok"], _norm(p["ln1"], x, cfg),
                                   chunk=cfg.wkv_chunk,
                                   unroll=cfg.analysis_unroll)
        x = x + RW.rwkv6_channel_mix(p["ch"], _norm(p["ln2"], x, cfg))
    elif kind == "dec_self_cross":
        h = A.gqa_attention(p["attn"], _norm(p["ln1"], x, cfg), causal=True,
                            use_rope=False, **akw)
        x = x + h
        h = A.gqa_attention(p["xattn"], _norm(p["ln_x"], x, cfg),
                            context=context, causal=False, use_rope=False,
                            **akw)
        x = x + h
        x = x + L.ffn(p["ffn"], _norm(p["ln2"], x, cfg), cfg.activation)
    else:
        raise ValueError(kind)
    x = shard_act(x, "dp", None, None)
    return x, aux


# ---------------------------------------------------------------------------
# layer-stack schedules (which kind at which depth)
# ---------------------------------------------------------------------------

def layer_schedule(cfg: ModelConfig) -> list[str]:
    if cfg.family == "dense":
        return ["self"] * cfg.n_layers
    if cfg.family == "moe":
        lead = cfg.moe.first_dense_layers
        return ["dense_self"] * lead + ["moe_self"] * (cfg.n_layers - lead)
    if cfg.family == "hybrid":
        pat = list(cfg.hybrid.pattern)
        return [("window" if pat[i % len(pat)] == "attn" else "lru")
                for i in range(cfg.n_layers)]
    if cfg.family == "ssm":
        return ["rwkv"] * cfg.n_layers
    if cfg.family == "encdec":
        return ["dec_self_cross"] * cfg.n_layers
    if cfg.family == "vlm":
        k = cfg.vlm.cross_every
        return [("cross" if i % k == 0 else "self")
                for i in range(cfg.n_layers)]
    raise ValueError(cfg.family)


def _period_of(cfg: ModelConfig) -> tuple[list[str], int, list[str]]:
    """(period_kinds, n_periods, remainder_kinds)."""
    sched = layer_schedule(cfg)
    if cfg.family == "hybrid":
        period = [("window" if p == "attn" else p)
                  for p in cfg.hybrid.pattern]
    elif cfg.family == "vlm":
        k = cfg.vlm.cross_every
        period = ["cross"] + ["self"] * (k - 1)
    elif cfg.family == "moe" and cfg.moe.first_dense_layers:
        # leading dense layers are the remainder-prefix; period is moe
        n = cfg.n_layers - cfg.moe.first_dense_layers
        return ["moe_self"], n, sched[:cfg.moe.first_dense_layers]
    else:
        return [sched[0]], cfg.n_layers, []
    n_periods = cfg.n_layers // len(period)
    rem = sched[n_periods * len(period):]
    return period, n_periods, rem


# ---------------------------------------------------------------------------
# stack init
# ---------------------------------------------------------------------------

def init_stack(key, cfg: ModelConfig) -> PyTree:
    dt = L._dtype(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    period, n_periods, rem = _period_of(cfg)
    p: dict = {
        "embed": L.embed_init(keys[0], cfg.vocab, cfg.d_model, dt),
        "final_norm": L.init_norm(cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(keys[1], cfg.d_model, cfg.vocab, dt)

    def stacked_init(k, kind, n):
        return jax.vmap(lambda kk: init_block(kk, cfg, kind))(
            jax.random.split(k, n))

    p["layers"] = {f"pos{j}_{kind}": stacked_init(jax.random.fold_in(
        keys[2], j), kind, n_periods) for j, kind in enumerate(period)}
    p["rem"] = {f"rem{j}_{kind}": init_block(
        jax.random.fold_in(keys[3], j), cfg, kind)
        for j, kind in enumerate(rem)}

    if cfg.family == "encdec":
        e = cfg.encdec
        p["enc"] = {
            "pos": (0.02 * jax.random.normal(
                keys[4], (e.encoder_seq, cfg.d_model), jnp.float32)).astype(dt),
            "layers": {"pos0_enc_self": stacked_init(
                keys[5], "enc_self", e.n_encoder_layers)},
            "final_norm": L.init_norm(cfg.d_model, cfg.norm),
        }
        p["dec_pos"] = (0.02 * jax.random.normal(
            keys[6], (cfg.max_seq, cfg.d_model), jnp.float32)).astype(dt)
    return p


# ---------------------------------------------------------------------------
# forward (training / prefill)
# ---------------------------------------------------------------------------

def _scan_stack(p_layers: PyTree, x: jax.Array, cfg: ModelConfig,
                period: list[str], *, context=None, q_offset=0
                ) -> tuple[jax.Array, jax.Array]:
    def period_body(x, period_params):
        aux = jnp.zeros((), jnp.float32)
        for j, kind in enumerate(period):
            blk = period_params[f"pos{j}_{kind}"]
            x, a = apply_block(blk, x, cfg, kind, context=context,
                               q_offset=q_offset)
            aux = aux + a
        return x, aux

    body = _remat(lambda x, pp: period_body(x, pp), cfg.remat)
    if cfg.scan_layers:
        n = jax.tree.leaves(p_layers)[0].shape[0]
        x, auxs = jax.lax.scan(lambda c, pp: body(c, pp), x, p_layers,
                               unroll=n if cfg.analysis_unroll else 1)
        return x, auxs.sum()
    # unrolled (analysis probes / tiny models) — keep the remat policy so
    # recompute FLOPs are counted identically to the scanned program
    n = jax.tree.leaves(p_layers)[0].shape[0]
    aux_total = jnp.zeros((), jnp.float32)
    for i in range(n):
        sl = jax.tree.map(lambda a: a[i], p_layers)
        x, aux = body(x, sl)
        aux_total = aux_total + aux
    return x, aux_total


def encode(params: PyTree, cfg: ModelConfig,
           enc_embeds: jax.Array) -> jax.Array:
    """Whisper-style encoder over stub frame embeddings [B, Te, D]."""
    e = params["enc"]
    x = enc_embeds + e["pos"][None, :enc_embeds.shape[1], :].astype(
        enc_embeds.dtype)
    x, _ = _scan_stack(e["layers"], x, cfg, ["enc_self"])
    return _norm(e["final_norm"], x, cfg)


def forward(params: PyTree, cfg: ModelConfig, tokens: jax.Array, *,
            context: Optional[jax.Array] = None,
            q_offset: int = 0) -> tuple[jax.Array, jax.Array]:
    """tokens [B, T] -> (hidden [B, T, D], aux_loss).

    ``context``: encoder memory (encdec) or image embeddings (vlm).
    """
    x = L.embed_lookup(params["embed"], tokens)
    if cfg.family == "encdec":
        x = x + params["dec_pos"][None, q_offset:q_offset + tokens.shape[1],
                                  :].astype(x.dtype)
    period, _, rem = _period_of(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    # remainder-prefix (moe leading dense layers) runs first
    prefix_rem = cfg.family == "moe" and bool(rem)
    if prefix_rem:
        for name, blk in params["rem"].items():
            kind = name.split("_", 1)[1]
            x, aux = apply_block(blk, x, cfg, kind, context=context,
                                 q_offset=q_offset)
            aux_total += aux
    x, aux = _scan_stack(params["layers"], x, cfg, period, context=context,
                         q_offset=q_offset)
    aux_total += aux
    if not prefix_rem:
        for name, blk in params["rem"].items():
            kind = name.split("_", 1)[1]
            x, aux = apply_block(blk, x, cfg, kind, context=context,
                                 q_offset=q_offset)
            aux_total += aux
    x = _norm(params["final_norm"], x, cfg)
    return x, aux_total


def logits(params: PyTree, cfg: ModelConfig, hidden: jax.Array) -> jax.Array:
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return L.logits_head(hidden, w)
