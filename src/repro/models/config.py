"""Model configuration — one dataclass covering all assigned families.

Families: dense | moe | hybrid (RG-LRU + local attn) | ssm (RWKV6) |
encdec (whisper) | vlm (cross-attn image layers).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0           # routed experts
    top_k: int = 0
    n_shared: int = 0            # shared (always-on) experts
    d_ff_expert: int = 0         # per-expert hidden dim
    d_ff_shared: int = 0         # shared-expert hidden dim (total)
    first_dense_layers: int = 0  # leading dense layers (deepseek style)
    d_ff_dense: int = 0          # hidden dim of those dense layers
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 0             # compressed KV width (c_kv)
    q_lora: int = 0              # compressed Q width (0 = full-rank Q)
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    # layer pattern, e.g. ("lru", "lru", "attn") repeating; remainder = prefix
    pattern: Sequence[str] = ()
    window: int = 2048           # local attention window
    lru_width: int = 0           # RG-LRU recurrent width (0 = d_model)
    conv_width: int = 4          # temporal conv in recurrent block


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    n_encoder_layers: int = 0
    encoder_seq: int = 1500      # whisper audio frames (post conv-stub)
    encoder_causal: bool = False


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    cross_every: int = 0         # a cross-attn layer every k-th layer
    image_tokens: int = 1601     # vision patch tokens (stub-provided)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0              # 0 = d_model // n_heads
    activation: str = "swiglu"   # swiglu | geglu | relu2 | gelu
    norm: str = "rms"            # rms | layer
    qk_norm: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    max_seq: int = 8192
    norm_eps: float = 1e-6
    moe: MoEConfig = MoEConfig()
    mla: Optional[MLAConfig] = None
    hybrid: HybridConfig = HybridConfig()
    encdec: EncDecConfig = EncDecConfig()
    vlm: VLMConfig = VLMConfig()
    # --- numerics / execution ---
    dtype: str = "bfloat16"      # activation/param compute dtype
    param_dtype: str = "bfloat16"
    remat: str = "full"          # full | dots | none
    scan_layers: bool = True
    attn_chunk: int = 1024       # flash-attention KV block
    wkv_chunk: int = 32          # WKV6 chunked-parallel block
    # Analysis (dry-run) mode: unroll every lax.scan so XLA cost_analysis
    # counts all iterations (While bodies are otherwise counted once).
    # Never used for real execution.
    analysis_unroll: bool = False
    # --- training ---
    optimizer: str = "adamw"     # adamw | adafactor
    # parallelism layout: "fsdp_tp" (2-D, default) or "pure_dp" (batch over
    # BOTH mesh axes, params FSDP over data only, no TP) — the right-sizing
    # option for models whose TP collectives dominate at 256 chips.
    parallelism: str = "fsdp_tp"
    # --- sub-quadratic marker (long_500k eligibility) ---
    subquadratic: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd = self.head_dim
        nq, nkv = self.n_heads, self.n_kv_heads
        emb = v * d * (1 if self.tie_embeddings else 2)

        def attn_params():
            if self.mla is not None:
                m = self.mla
                qdim = nq * (m.nope_head_dim + m.rope_head_dim)
                q = (d * m.q_lora + m.q_lora * qdim) if m.q_lora else d * qdim
                kv = d * (m.kv_lora + m.rope_head_dim)
                kv += m.kv_lora * nq * (m.nope_head_dim + m.v_head_dim)
                out = nq * m.v_head_dim * d
                return q + kv + out
            return d * hd * (nq + 2 * nkv) + nq * hd * d

        def ffn_params(dff):
            mult = 3 if self.activation == "swiglu" else 2
            return mult * d * dff

        if self.family == "moe":
            m = self.moe
            n_moe = L - m.first_dense_layers
            blk = m.first_dense_layers * ffn_params(m.d_ff_dense or f)
            blk += n_moe * (m.n_experts * ffn_params(m.d_ff_expert)
                            + ffn_params(m.d_ff_shared)
                            + d * m.n_experts)  # router
            blk += L * attn_params()
        elif self.family == "ssm":
            # rwkv6: token-mix (r,k,v,w,g,out ≈ 6 d² low-rank-ish) + channel-mix
            blk = L * (6 * d * d + 2 * d * f)
        elif self.family == "hybrid":
            pat = list(self.hybrid.pattern) or ["attn"]
            n_attn = sum(1 for i in range(L) if pat[i % len(pat)] == "attn")
            n_lru = L - n_attn
            w = self.hybrid.lru_width or d
            blk = n_attn * attn_params() + n_lru * (2 * d * w + w * d + 3 * w)
            blk += L * ffn_params(f)
        else:
            blk = L * (attn_params() + ffn_params(f))
            if self.family == "encdec":
                e = self.encdec
                blk += e.n_encoder_layers * (attn_params() + ffn_params(f))
                blk += L * attn_params()          # decoder cross-attn
            if self.family == "vlm" and self.vlm.cross_every:
                n_cross = L // self.vlm.cross_every
                blk += n_cross * attn_params()
        return emb + blk

    def active_param_count(self) -> int:
        """Activated params per token (MoE top-k accounting)."""
        if self.family != "moe":
            return self.param_count()
        m = self.moe
        full = self.param_count()
        mult = 3 if self.activation == "swiglu" else 2
        n_moe = self.n_layers - m.first_dense_layers
        all_experts = n_moe * m.n_experts * mult * self.d_model * m.d_ff_expert
        active = n_moe * m.top_k * mult * self.d_model * m.d_ff_expert
        return full - all_experts + active
