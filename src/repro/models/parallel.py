"""Tensor-parallel hook — how serving communication reaches the models.

The decode/prefill math in ``models/decode.py`` / ``models/transformer.py``
/ ``models/moe.py`` is written rank-local: under tensor parallelism each
rank holds a column slice of wq/wk/wv/wi (so attention and FFN partials
are *partial sums* after wo) and a slice of the expert stack (so the MoE
slot tensor must be resharded group-major -> expert-major).  Where those
partials need the network, the model consults the active
:class:`TensorParallel` hook instead of calling a collective directly —
so the same model code runs

  * unsharded (no hook installed: every method is identity),
  * under GSPMD (``sharding/act.py`` constraints, hook absent),
  * rank-local under ``shard_map`` with the hook supplying the
    communication — XLA built-ins, direct acis rings, or compiled switch
    programs (``repro.serve.collectives``).

The hook is installed with :func:`tensor_parallel` around the *trace* of
the decode program; the installed hook's methods run at trace time and
stage whatever communication they choose into the jitted program.
"""

from __future__ import annotations

import contextlib
from typing import Optional

import jax

# Active-hook stack, consulted at trace time (mirrors tracing._ACTIVE:
# installation brackets a trace, not a runtime call).
_ACTIVE: list["TensorParallel"] = []


class TensorParallel:
    """Communication points the models expose under tensor parallelism.

    The base class is the identity hook — every method returns its input
    unchanged — so model code may call the active hook unconditionally.
    Subclasses (see ``repro.serve.collectives``) override the methods
    with real collectives over their mesh axis.
    """

    def attn_reduce(self, h: jax.Array) -> jax.Array:
        """Sum attention-output partials [B, T, D] (after the sliced wo)."""
        return h

    def ffn_reduce(self, f: jax.Array) -> jax.Array:
        """Sum dense-FFN output partials [B, T, D] (after the sliced wo)."""
        return f

    def moe_dispatch(self, xem: jax.Array) -> jax.Array:
        """Reshard the MoE slot tensor expert-major: [E, S, D] with every
        rank holding all tokens -> [E/tp, S, D] rows of this rank's
        experts (the group->expert all-to-all)."""
        return xem

    def moe_combine(self, yem: jax.Array,
                    shared_partial: Optional[jax.Array] = None):
        """Inverse reshard of expert outputs [E/tp, S, D] -> [E, S, D]
        (every rank again sees all experts' outputs), optionally fused
        with the all-reduce of the shared-expert partial — the Type-4
        AR+A2A pair.  Returns ``(yem_full, shared_reduced)`` where
        ``shared_reduced`` is None iff ``shared_partial`` was."""
        return yem, shared_partial


def current() -> Optional[TensorParallel]:
    """The innermost installed hook, or None (run unhooked)."""
    return _ACTIVE[-1] if _ACTIVE else None


@contextlib.contextmanager
def tensor_parallel(hook: TensorParallel):
    """Install ``hook`` for model calls traced inside the block."""
    _ACTIVE.append(hook)
    try:
        yield hook
    finally:
        _ACTIVE.pop()
