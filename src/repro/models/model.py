"""Model — the public facade over the zoo.

    model = Model(cfg)
    params         = model.init(key)                  # or jax.eval_shape
    hidden, aux    = model.forward(params, tokens)    # train path
    logits         = model.logits(params, hidden)
    cache          = model.init_cache(batch, seq)
    lg, cache      = model.prefill(params, tokens, cache)
    lg, cache      = model.decode_step(params, token, cache, index)

``context_inputs`` describes the stub-modality inputs (whisper frame
embeddings / vision patch embeddings) as shapes so launch/input_specs can
construct ShapeDtypeStructs.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import decode as D
from repro.models import transformer as T
from repro.models.config import ModelConfig

PyTree = Any


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- params --------------------------------------------------------------

    def init(self, key: jax.Array) -> PyTree:
        return T.init_stack(key, self.cfg)

    def param_shapes(self) -> PyTree:
        return jax.eval_shape(self.init, jax.random.key(0))

    # -- stub modality frontends (assignment: backbone only) ------------------

    def context_inputs(self, batch: int) -> Optional[jax.ShapeDtypeStruct]:
        cfg = self.cfg
        if cfg.family == "encdec":
            return jax.ShapeDtypeStruct(
                (batch, cfg.encdec.encoder_seq, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            return jax.ShapeDtypeStruct(
                (batch, cfg.vlm.image_tokens, cfg.d_model), jnp.bfloat16)
        return None

    def _context(self, params, context):
        """encdec runs its encoder over the stub embeddings; vlm uses the
        patch embeddings directly."""
        if context is None:
            return None
        if self.cfg.family == "encdec":
            return T.encode(params, self.cfg, context)
        return context

    # -- training ------------------------------------------------------------

    def forward(self, params: PyTree, tokens: jax.Array, *,
                context: Optional[jax.Array] = None
                ) -> tuple[jax.Array, jax.Array]:
        ctx = self._context(params, context)
        return T.forward(params, self.cfg, tokens, context=ctx)

    def logits(self, params: PyTree, hidden: jax.Array) -> jax.Array:
        return T.logits(params, self.cfg, hidden)

    # -- serving ---------------------------------------------------------------

    def init_cache(self, batch: int, seq: int,
                   dtype=jnp.bfloat16) -> PyTree:
        return D.init_cache(self.cfg, batch, seq, dtype)

    def prefill(self, params: PyTree, tokens: jax.Array, cache: PyTree, *,
                context: Optional[jax.Array] = None
                ) -> tuple[jax.Array, PyTree]:
        ctx = self._context(params, context)
        return D.prefill(params, self.cfg, tokens, cache, context=ctx)

    def decode_step(self, params: PyTree, token: jax.Array, cache: PyTree,
                    index, *, context: Optional[jax.Array] = None
                    ) -> tuple[jax.Array, PyTree]:
        ctx = self._context(params, context)
        return D.decode_step(params, self.cfg, token, cache, index,
                             context=ctx)
