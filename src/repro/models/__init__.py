"""repro.models — composable model zoo for the 10 assigned architectures."""

from repro.models.config import (EncDecConfig, HybridConfig, MLAConfig,
                                 ModelConfig, MoEConfig, VLMConfig)
from repro.models.model import Model

__all__ = ["EncDecConfig", "HybridConfig", "MLAConfig", "Model",
           "ModelConfig", "MoEConfig", "VLMConfig"]
