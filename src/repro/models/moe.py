"""Mixture-of-Experts FFN — grouped scatter/gather dispatch (EP-shardable).

Dispatch is the production TPU formulation (MaxText-style "dropped token"
MoE): tokens are split into groups aligned with the data shards; within a
group, routing/capacity bookkeeping is local and tokens are *scattered*
into per-expert capacity slots (O(N·k·D) data movement — NOT the GShard
one-hot dispatch einsum, whose O(N·E·C·D) FLOPs rival the expert compute
itself at E=160).  The group→expert reshard of the slot tensor is where
GSPMD inserts the all-to-all — the exact communication pattern ACiS Type 4
fuses (core/fused.fused_allreduce_alltoall).

Routing: softmax → top-k → renormalize (Qwen-MoE style), plus the standard
load-balancing auxiliary loss.  Fixed per-group capacity keeps shapes
static (TPU requirement); overflow tokens drop (combine weight 0) exactly
as in GShard.  Single-token decode uses capacity = group size (no drops).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import parallel as TP
from repro.models.config import MoEConfig

PyTree = Any

# Target tokens per dispatch group.  Must be small enough that the group
# count covers the data axis (G % dp == 0) for every assigned cell —
# otherwise the [G, slots, D] dispatch tensor replicates across data
# shards (observed: 39 GB/device on deepseek-v2 before this was sized).
GROUP_TOKENS = 4096


def init_moe(key, d_model: int, cfg: MoEConfig, activation: str,
             dtype=jnp.bfloat16) -> PyTree:
    ks = jax.random.split(key, 8)
    e, f = cfg.n_experts, cfg.d_ff_expert

    def stack(k, d_in, d_out):
        keys = jax.random.split(k, e)
        return jnp.stack([L.dense_init(kk, d_in, d_out, dtype) for kk in keys])

    p = {"router": L.dense_init(ks[0], d_model, e, jnp.float32, scale=0.02)}
    if activation in ("swiglu", "geglu"):
        p["experts"] = {"wi_gate": stack(ks[1], d_model, f),
                        "wi_up": stack(ks[2], d_model, f),
                        "wo": stack(ks[3], f, d_model)}
    else:
        p["experts"] = {"wi": stack(ks[1], d_model, f),
                        "wo": stack(ks[3], f, d_model)}
    if cfg.n_shared:
        p["shared"] = L.init_ffn(ks[4], d_model,
                                 cfg.d_ff_shared or cfg.n_shared * f,
                                 activation, dtype)
    return p


def _expert_ffn(experts: PyTree, xe: jax.Array, activation: str) -> jax.Array:
    """xe: [E, S, D] -> [E, S, D] through per-expert FFN weights."""
    if activation in ("swiglu", "geglu"):
        gate = jnp.einsum("esd,edf->esf", xe, experts["wi_gate"])
        up = jnp.einsum("esd,edf->esf", xe, experts["wi_up"])
        act = jax.nn.silu if activation == "swiglu" else \
            (lambda a: jax.nn.gelu(a, approximate=True))
        h = act(gate) * up
    else:
        h = jnp.einsum("esd,edf->esf", xe, experts["wi"])
        h = jnp.square(jax.nn.relu(h)) if activation == "relu2" else \
            jax.nn.gelu(h, approximate=True)
    return jnp.einsum("esf,efd->esd", h, experts["wo"])


def _n_groups(n_tok: int) -> int:
    if n_tok <= GROUP_TOKENS:
        return 1
    g = n_tok // GROUP_TOKENS
    while n_tok % g:
        g -= 1
    return max(g, 1)


def moe_ffn(p: PyTree, x: jax.Array, cfg: MoEConfig, activation: str
            ) -> tuple[jax.Array, jax.Array]:
    """x: [B, T, D].  Returns (y, aux_loss)."""
    b, t, d = x.shape
    n_tok = b * t
    e, k = cfg.n_experts, cfg.top_k
    g = _n_groups(n_tok)
    ng = n_tok // g
    if t == 1:                                   # decode: never drop
        cap = ng
    else:
        cap = max(1, int(ng * k * cfg.capacity_factor / e))
    xt = x.reshape(g, ng, d)

    logits = (xt.astype(jnp.float32) @ p["router"])            # [G, Ng, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)              # [G, Ng, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # position-in-expert within the group, k-major priority (GShard order)
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)    # [G, Ng, k, E]
    flat = onehot.transpose(0, 2, 1, 3).reshape(g, k * ng, e)  # choice-major
    pos_flat = jnp.cumsum(flat, axis=1) - flat
    pos = pos_flat.reshape(g, k, ng, e).transpose(0, 2, 1, 3)  # [G, Ng, k, E]
    pos_in_e = jnp.sum(pos * onehot, axis=-1).astype(jnp.int32)  # [G, Ng, k]
    keep = pos_in_e < cap

    # scatter tokens into capacity slots: xe [G, E*cap(+dump), D].
    # Slot buffers live in the activation dtype (bf16): each slot receives
    # at most ONE token (positions are unique), so the "accumulation" is
    # really placement — no precision is lost, and the buffers are the
    # dominant MoE activation (f32 here cost 2× memory: 23 GB/device on
    # the 236B prefill cell before this).
    n_slots = e * cap
    slot = jnp.where(keep, gate_idx * cap + pos_in_e, n_slots)  # [G, Ng, k]
    xe = jnp.zeros((g, n_slots + 1, d), x.dtype)
    for j in range(k):                       # k small: one scatter per choice
        xe = jax.vmap(lambda buf, s, v: buf.at[s].add(v))(
            xe, slot[:, :, j], xt)
    xe = xe[:, :n_slots, :]

    from repro.sharding.act import shard_act
    xe = shard_act(xe.reshape(g, e, cap, d), "dp", None, None, None)
    # group-major -> expert-major: THE all-to-all (GSPMD inserts it here).
    # Slot dim stays DATA-sharded: when E doesn't divide the model axis
    # (qwen2: 60 experts on 16) "tp" drops and a replicated slot tensor
    # would force activation-sized all-reduces in the expert FFN
    # (observed: 83 s/step collective time on qwen2-moe before this).
    xem = xe.transpose(1, 0, 2, 3).reshape(e, g * cap, d)
    xem = shard_act(xem, "tp", "dp", None)
    tp = TP.current()
    if tp is not None:                       # rank-local TP (serving path)
        xem = tp.moe_dispatch(xem)           # [E, S, D] -> [E/tp, S, D]
    yem = _expert_ffn(p["experts"], xem, activation)
    yem = shard_act(yem, "tp", "dp", None)
    # expert-major -> group-major: the second all-to-all (bf16 on the wire)
    shared_y = None
    if tp is not None:
        # the shared-expert partial (sliced FFN needing an all-reduce)
        # rides the combine all-to-all: FuseHops merges the independent
        # same-axis pair into one Type-4 allreduce+alltoall stage
        part = L.ffn(p["shared"], xt, activation) if "shared" in p else None
        yem, shared_y = tp.moe_combine(yem, part)
    elif "shared" in p:
        shared_y = L.ffn(p["shared"], xt, activation)
    ye = yem.reshape(e, g, cap, d).transpose(1, 0, 2, 3)
    ye = ye.reshape(g, n_slots, d)
    ye = jnp.concatenate([ye, jnp.zeros((g, 1, d), ye.dtype)], axis=1)

    y = jnp.zeros((g, ng, d), jnp.float32)
    for j in range(k):                       # gather + weighted combine
        yj = jnp.take_along_axis(ye, slot[:, :, j][..., None], axis=1)
        wj = (gate_vals[:, :, j] * keep[:, :, j].astype(jnp.float32))
        y = y + yj.astype(jnp.float32) * wj[..., None]

    if shared_y is not None:
        y = y + shared_y.astype(jnp.float32)

    # load-balance aux: E * sum_e f_e * p_e
    me = probs.mean(axis=(0, 1))
    ce = onehot[:, :, 0, :].mean(axis=(0, 1))
    aux = e * jnp.sum(me * ce) * cfg.router_aux_weight
    return y.reshape(b, t, d).astype(x.dtype), aux
