"""Multi-head Latent Attention (DeepSeek-V2).

KV is compressed to a low-rank latent ``c_kv`` [B, T, kv_lora] plus a shared
rope key [B, T, rope_dim]; per-head K/V are decompressed on the fly.  The
decode cache stores only (c_kv, k_rope): 512+64 floats/token for the 236-B
config vs 2·128·128 for vanilla MHA — a 57× cache reduction, which is what
makes the 32k-decode cell of deepseek-v2-236b feasible at all.

Heads here use separate "nope" (content) and "rope" (position) sub-keys,
matching the published architecture.
"""

from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.attention import NEG_INF
from repro.models.config import MLAConfig

PyTree = Any


def init_mla(key, d_model: int, n_heads: int, cfg: MLAConfig,
             dtype=jnp.bfloat16) -> PyTree:
    ks = jax.random.split(key, 8)
    qdim = cfg.nope_head_dim + cfg.rope_head_dim
    p = {
        "w_dkv": L.dense_init(ks[0], d_model, cfg.kv_lora + cfg.rope_head_dim,
                              dtype),
        "kv_norm": L.init_rmsnorm(cfg.kv_lora),
        "w_uk": L.dense_init(ks[1], cfg.kv_lora,
                             n_heads * cfg.nope_head_dim, dtype),
        "w_uv": L.dense_init(ks[2], cfg.kv_lora,
                             n_heads * cfg.v_head_dim, dtype),
        "wo": L.dense_init(ks[3], n_heads * cfg.v_head_dim, d_model, dtype),
    }
    if cfg.q_lora:
        p["w_dq"] = L.dense_init(ks[4], d_model, cfg.q_lora, dtype)
        p["q_norm"] = L.init_rmsnorm(cfg.q_lora)
        p["w_uq"] = L.dense_init(ks[5], cfg.q_lora, n_heads * qdim, dtype)
    else:
        p["wq"] = L.dense_init(ks[4], d_model, n_heads * qdim, dtype)
    return p


def _queries(p, x, n_heads, cfg, positions, rope_theta):
    b, t, _ = x.shape
    qdim = cfg.nope_head_dim + cfg.rope_head_dim
    if "w_dq" in p:
        q = L.rmsnorm(p["q_norm"], x @ p["w_dq"]) @ p["w_uq"]
    else:
        q = x @ p["wq"]
    q = q.reshape(b, t, n_heads, qdim)
    q_nope = q[..., :cfg.nope_head_dim]
    q_rope = L.apply_rope(q[..., cfg.nope_head_dim:], positions, rope_theta)
    return q_nope, q_rope


def _latents(p, x, cfg, positions, rope_theta):
    b, t, _ = x.shape
    dkv = x @ p["w_dkv"]
    c_kv = L.rmsnorm(p["kv_norm"], dkv[..., :cfg.kv_lora])
    k_rope = L.apply_rope(dkv[..., cfg.kv_lora:][:, :, None, :],
                          positions, rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def _attend(p, q_nope, q_rope, c_kv, k_rope, n_heads, cfg, *,
            causal, q_offset, kv_len=None, chunk=1024, unroll=False):
    """Latent-space attention via the absorbed-projection trick.

    score = q_nope·(W_uk c) + q_rope·k_rope = (W_uk^T q_nope ⊕ q_rope)·(c ⊕
    k_rope) — i.e. an MQA flash attention with a single shared "key"
    (c_kv ⊕ k_rope) and "value" c_kv.  Per-head K/V are never materialized;
    the context is lifted through W_uv after the softmax.  Reuses the
    KV-chunked online-softmax kernel, so 32k prefill stays O(Tq·chunk).
    """
    b, tq, h, _ = q_nope.shape
    scale = 1.0 / math.sqrt(cfg.nope_head_dim + cfg.rope_head_dim)
    w_uk = p["w_uk"].reshape(cfg.kv_lora, n_heads, cfg.nope_head_dim)
    q_lat = jnp.einsum("bqhd,khd->bqhk", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    q_eff = jnp.concatenate([q_lat,
                             q_rope.astype(jnp.float32)], axis=-1)
    k_eff = jnp.concatenate([c_kv, k_rope], axis=-1)[:, :, None, :]
    v_eff = c_kv[:, :, None, :]
    from repro.models.attention import flash_attention
    ctx_lat = flash_attention(
        q_eff, k_eff.astype(jnp.float32), v_eff.astype(jnp.float32),
        causal=causal, q_offset=q_offset, kv_len=kv_len, chunk=chunk,
        softmax_scale=scale, unroll=unroll)                     # [B, Tq, H, kv_lora]
    w_uv = p["w_uv"].reshape(cfg.kv_lora, n_heads, cfg.v_head_dim)
    out = jnp.einsum("bqhk,khv->bqhv", ctx_lat.astype(jnp.float32),
                     w_uv.astype(jnp.float32))
    return out.reshape(b, tq, n_heads * cfg.v_head_dim)


def mla_attention(p: PyTree, x: jax.Array, *, n_heads: int, cfg: MLAConfig,
                  rope_theta: float = 10000.0, q_offset: int = 0,
                  chunk: int = 1024, unroll: bool = False) -> jax.Array:
    b, t, _ = x.shape
    pos = (q_offset + jnp.arange(t))[None]
    q_nope, q_rope = _queries(p, x, n_heads, cfg, pos, rope_theta)
    c_kv, k_rope = _latents(p, x, cfg, pos, rope_theta)
    out = _attend(p, q_nope, q_rope, c_kv, k_rope, n_heads, cfg,
                  causal=True, q_offset=q_offset, chunk=chunk, unroll=unroll)
    return out.astype(x.dtype) @ p["wo"]


def init_mla_cache(batch: int, seq: int, cfg: MLAConfig,
                   dtype=jnp.bfloat16) -> PyTree:
    return {"c_kv": jnp.zeros((batch, seq, cfg.kv_lora), dtype),
            "k_rope": jnp.zeros((batch, seq, cfg.rope_head_dim), dtype)}


def mla_decode(p: PyTree, x: jax.Array, cache: PyTree, index: jax.Array, *,
               n_heads: int, cfg: MLAConfig, rope_theta: float = 10000.0,
               unroll: bool = False) -> tuple[jax.Array, PyTree]:
    """``index``: scalar or per-row [B] vector (continuous batching)."""
    b = x.shape[0]
    idx = jnp.asarray(index)
    vec = idx.ndim > 0
    pos = (idx[:, None] if vec else jnp.full((b, 1), idx)).astype(jnp.int32)
    q_nope, q_rope = _queries(p, x, n_heads, cfg, pos, rope_theta)
    c_new, kr_new = _latents(p, x, cfg, pos, rope_theta)
    if vec:
        rows = jnp.arange(b)
        c_kv = cache["c_kv"].at[rows, idx].set(
            c_new[:, 0].astype(cache["c_kv"].dtype))
        k_rope = cache["k_rope"].at[rows, idx].set(
            kr_new[:, 0].astype(cache["k_rope"].dtype))
    else:
        c_kv = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_new.astype(cache["c_kv"].dtype), idx, axis=1)
        k_rope = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), idx,
            axis=1)
    out = _attend(p, q_nope, q_rope, c_kv, k_rope, n_heads, cfg,
                  causal=False, q_offset=idx, kv_len=idx + 1, unroll=unroll)
    return out.astype(x.dtype) @ p["wo"], {"c_kv": c_kv, "k_rope": k_rope}
