"""Base layers (pure-functional): norms, dense, embedding, RoPE, FFN, conv.

Params are plain nested dicts of jax.Arrays; ``init_*`` builds them,
``apply``-style functions consume them.  Sharding is attached later by
path-pattern rules (repro/sharding/rules.py) so layer code stays
mesh-agnostic.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

PyTree = Any


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.bfloat16,
               scale: Optional[float] = None) -> jax.Array:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.bfloat16) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.float32) -> PyTree:
    return {"scale": jnp.ones((d,), dtype)}


def _rmsnorm_fwd_impl(scale, x, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    y = (xf * inv * scale.astype(jnp.float32)).astype(x.dtype)
    return y, (xf, inv)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rmsnorm(scale, x, eps):
    return _rmsnorm_fwd_impl(scale, x, eps)[0]


def _rmsnorm_fwd(scale, x, eps):
    y, res = _rmsnorm_fwd_impl(scale, x, eps)
    # zero-size sentinel carries the primal dtype (dtypes aren't jax types)
    return y, (scale, jnp.zeros((0,), x.dtype)) + res


def _rmsnorm_bwd(eps, res, g):
    """Backward in f32 internally, but the cotangent LEAVES in the primal
    dtype: without this, the f32 upcast promotes the whole residual-stream
    cotangent chain to f32 and every TP all-reduce on the backward path
    doubles its wire bytes (measured: the dominant collective in the dense
    train cells)."""
    scale, xdt_sentinel, xf, inv = res
    xdt = xdt_sentinel.dtype
    gf = g.astype(jnp.float32)
    sf = scale.astype(jnp.float32)
    xhat = xf * inv
    dscale = jnp.sum(gf * xhat, axis=tuple(range(gf.ndim - 1)))
    gx = gf * sf
    d = xf.shape[-1]
    dx = inv * (gx - xhat * jnp.mean(gx * xhat, axis=-1, keepdims=True))
    return dscale.astype(scale.dtype), dx.astype(xdt)


_rmsnorm.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def rmsnorm(p: PyTree, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    return _rmsnorm(p["scale"], x, eps)


def init_layernorm(d: int, dtype=jnp.float32) -> PyTree:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: PyTree, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


def init_norm(d: int, kind: str = "rms") -> PyTree:
    return init_layernorm(d) if kind == "layer" else init_rmsnorm(d)


def apply_norm(p: PyTree, x: jax.Array, kind: str = "rms",
               eps: float = 1e-6) -> jax.Array:
    return layernorm(p, x, eps) if "bias" in p else rmsnorm(p, x, eps)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(d_head: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32)
                            / d_head))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """x: [..., T, H, d_head]; positions: [..., T] (absolute)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                     # [d/2]
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # [...,T,1,d/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# FFN variants
# ---------------------------------------------------------------------------

def init_ffn(key, d: int, f: int, activation: str, dtype=jnp.bfloat16) -> PyTree:
    ks = jax.random.split(key, 3)
    if activation in ("swiglu", "geglu"):
        return {"wi_gate": dense_init(ks[0], d, f, dtype),
                "wi_up": dense_init(ks[1], d, f, dtype),
                "wo": dense_init(ks[2], f, d, dtype)}
    return {"wi": dense_init(ks[0], d, f, dtype),
            "wo": dense_init(ks[1], f, d, dtype)}


def ffn(p: PyTree, x: jax.Array, activation: str) -> jax.Array:
    from repro.sharding.act import shard_act
    if activation == "swiglu":
        h = jax.nn.silu(x @ p["wi_gate"]) * (x @ p["wi_up"])
    elif activation == "geglu":
        h = jax.nn.gelu(x @ p["wi_gate"], approximate=True) * (x @ p["wi_up"])
    elif activation == "relu2":
        h = jnp.square(jax.nn.relu(x @ p["wi"]))
    elif activation == "gelu":
        h = jax.nn.gelu(x @ p["wi"], approximate=True)
    else:
        raise ValueError(f"unknown activation {activation!r}")
    if h.ndim == 3:
        h = shard_act(h, "dp", None, "tp")
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# causal temporal conv (RG-LRU branch / audio-style frontends)
# ---------------------------------------------------------------------------

def init_conv1d(key, width: int, channels: int, dtype=jnp.bfloat16) -> PyTree:
    k = jax.random.normal(key, (width, channels), jnp.float32) / math.sqrt(width)
    return {"kernel": k.astype(dtype), "bias": jnp.zeros((channels,), dtype)}


def causal_conv1d(p: PyTree, x: jax.Array) -> jax.Array:
    """Depthwise causal conv over time.  x: [B, T, C]."""
    width = p["kernel"].shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + pad[:, i:i + x.shape[1], :] * p["kernel"][i]
    return out + p["bias"]


def conv1d_decode(p: PyTree, window: jax.Array, x_t: jax.Array
                  ) -> tuple[jax.Array, jax.Array]:
    """Single-step conv with a rolling window cache.

    window: [B, width-1, C] (the last width-1 inputs); x_t: [B, C].
    Returns (y_t, new_window).
    """
    width = p["kernel"].shape[0]
    full = jnp.concatenate([window, x_t[:, None, :]], axis=1)  # [B, width, C]
    y = jnp.einsum("bwc,wc->bc", full, p["kernel"]) + p["bias"]
    return y, full[:, 1:, :]


# ---------------------------------------------------------------------------
# embedding / logits
# ---------------------------------------------------------------------------

def embed_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    return jnp.take(table, ids, axis=0)


def logits_head(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: [..., D] @ w: [D, V] in f32 for stable softmax/CE."""
    return (x.astype(jnp.float32) @ w.astype(jnp.float32))
