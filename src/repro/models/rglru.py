"""RG-LRU recurrent block (RecurrentGemma / Griffin).

    x1 = causal_conv(W_x u),  g = W_g u
    r_t = sigmoid(w_r ⊙ x1 + b_r)        (recurrence gate)
    i_t = sigmoid(w_i ⊙ x1 + b_i)        (input gate)
    a_t = exp(-c · softplus(Λ) · r_t)     (data-dependent decay, c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x1_t)
    y   = W_out (h ⊙ gelu(g))

The scan is the affine recurrence h_t = a_t h_{t-1} + b_t — Type 3 look-
aside state.  Training uses the chunked log-step scan (kernels/chunk_scan
semantics; models run the jnp form so jax.grad applies, the Pallas kernel
is validated against the same oracle).  Decode carries (h, conv window) —
O(1) state, which is why the hybrid arch runs the long_500k cell.

Sequence parallelism: `rglru_scan_sp` splits T across the mesh axis and
joins chunks with the ACiS Type 3 cross-rank scan of (prod a, h) pairs.
"""

from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import HybridConfig

PyTree = Any
_C = 8.0


def init_rglru(key, d_model: int, cfg: HybridConfig,
               dtype=jnp.bfloat16) -> PyTree:
    w = cfg.lru_width or d_model
    ks = jax.random.split(key, 6)
    # Λ init so that a ∈ (0.9, 0.999) at r = 0.5 (Griffin appendix)
    lam = jnp.log(jnp.expm1(-2.0 * jnp.log(
        jax.random.uniform(ks[0], (w,), jnp.float32, 0.9, 0.999)) / _C))
    return {
        "wx": L.dense_init(ks[1], d_model, w, dtype),
        "wg": L.dense_init(ks[2], d_model, w, dtype),
        "conv": L.init_conv1d(ks[3], cfg.conv_width, w, dtype),
        "wout": L.dense_init(ks[4], w, d_model, dtype),
        "lam": lam,
        "w_r": jnp.zeros((w,), jnp.float32),
        "b_r": jnp.zeros((w,), jnp.float32),
        "w_i": jnp.zeros((w,), jnp.float32),
        "b_i": jnp.zeros((w,), jnp.float32),
    }


def _gates(p, x1):
    x1f = x1.astype(jnp.float32)
    r = jax.nn.sigmoid(p["w_r"] * x1f + p["b_r"])
    i = jax.nn.sigmoid(p["w_i"] * x1f + p["b_i"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * x1f)
    return a, b


def _affine_scan(a: jax.Array, b: jax.Array, h0: jax.Array) -> jax.Array:
    """h_t = a_t h_{t-1} + b_t over axis 1.  a, b: [B, T, W].

    Implemented with `lax.associative_scan` (Blelloch) over the affine
    monoid (A, B)∘(A', B') = (A·A', A'·B + B') — log-depth, MXU/VPU
    parallel, the production Griffin formulation (and fully visible to
    XLA cost analysis, unlike a While loop)."""
    def combine(lo, hi):
        return lo[0] * hi[0], hi[0] * lo[1] + hi[1]

    aa, bb = jax.lax.associative_scan(combine, (a, b), axis=1)
    del aa
    return bb + jnp.cumprod(a, axis=1) * h0[:, None, :] if h0 is not None \
        else bb


def rglru_block(p: PyTree, u: jax.Array, *, cfg: HybridConfig,
                h0: Optional[jax.Array] = None) -> jax.Array:
    """u: [B, T, D] -> [B, T, D]."""
    bsz = u.shape[0]
    w = p["wx"].shape[1]
    from repro.sharding.act import shard_act
    x1 = shard_act(L.causal_conv1d(p["conv"], u @ p["wx"]), "dp", None, "tp")
    g = shard_act(u @ p["wg"], "dp", None, "tp")
    a, b = _gates(p, x1)
    if h0 is None:
        h0 = jnp.zeros((bsz, w), jnp.float32)
    h = _affine_scan(a, b, h0)
    y = (h * jax.nn.gelu(g.astype(jnp.float32), approximate=True))
    return y.astype(u.dtype) @ p["wout"]


# ---------------------------------------------------------------------------
# decode (single token, carried state)
# ---------------------------------------------------------------------------

def init_rglru_cache(batch: int, cfg: HybridConfig, d_model: int,
                     dtype=jnp.bfloat16) -> PyTree:
    w = cfg.lru_width or d_model
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype)}


def rglru_decode(p: PyTree, u_t: jax.Array, cache: PyTree, *,
                 cfg: HybridConfig) -> tuple[jax.Array, PyTree]:
    """u_t: [B, 1, D]."""
    x_t = (u_t[:, 0, :] @ p["wx"])
    x1, conv_win = L.conv1d_decode(p["conv"], cache["conv"], x_t)
    g = u_t[:, 0, :] @ p["wg"]
    a, b = _gates(p, x1)
    h = a * cache["h"] + b
    y = h * jax.nn.gelu(g.astype(jnp.float32), approximate=True)
    out = y.astype(u_t.dtype) @ p["wout"]
    return out[:, None, :], {"h": h, "conv": conv_win}


# ---------------------------------------------------------------------------
# sequence-parallel scan (ACiS Type 3 joins the chunks across ranks)
# ---------------------------------------------------------------------------

def rglru_scan_sp(a: jax.Array, b: jax.Array, axis_name: str) -> jax.Array:
    """Each rank holds a contiguous T-chunk of (a, b); the cross-rank carry
    is an exclusive rank-scan of the affine monoid (A, B) ∘ (A', B') =
    (A·A', A·B' + B) — the look-aside carry walking the network."""
    from repro.core.ring import rank_prefix_scan
    from repro.core.types import Monoid

    h_local = _affine_scan(a, b, jnp.zeros((a.shape[0], a.shape[2]),
                                           jnp.float32))
    a_prod = jnp.prod(a, axis=1)                    # [B, W]
    h_last = h_local[:, -1, :]

    affine = Monoid(
        "affine",
        lambda lo, hi: (lo[0] * hi[0], hi[0] * lo[1] + hi[1]),
        lambda s: (jnp.ones(s[0].shape, s[0].dtype),
                   jnp.zeros(s[1].shape, s[1].dtype)),
        commutative=False)
    carry = rank_prefix_scan((a_prod, h_last), axis_name, affine,
                             exclusive=True)
    carry_in = carry[1]
    # h_t (global) = h_t(local, h0=0) + (prod_{s<=t} a_s) * carry_in
    a_cum = jnp.cumprod(a, axis=1)
    return h_local + a_cum * carry_in[:, None, :]
