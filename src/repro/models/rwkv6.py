"""RWKV-6 "Finch" block: WKV6 token mix (data-dependent decay) + channel mix.

Token mix (per head, head dim K = V = 64):
    token-shift lerp (learned μ per channel) feeds r, k, v, g and the
    decay LoRA:  w_t = exp(-exp(w0 + tanh(x̄ A) B))  (data-dependent)
    o_t = WKV(r, k, v, w, u)   — the recurrence of kernels/rwkv6_recurrence
    out = W_o (groupnorm(o) ⊙ silu(g))

Channel mix:
    out = sigmoid(W_r x̄r) ⊙ (W_v relu(W_k x̄k)²)

The WKV state S[H, K, V] is the Type 3 look-aside memory of this arch; the
`wkv_sp` variant chunks the sequence across a mesh axis and joins with the
cross-rank scan of the (decay-product, state) affine pair — sequence
parallelism for the 500k cell.
"""

from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L

PyTree = Any
HEAD = 64
LORA = 64


def init_rwkv6(key, d: int, dtype=jnp.bfloat16) -> PyTree:
    ks = jax.random.split(key, 12)
    h = d // HEAD
    return {
        "mu": {name: jnp.full((d,), 0.5, jnp.float32)
               for name in ("r", "k", "v", "g", "w")},
        "wr": L.dense_init(ks[0], d, d, dtype),
        "wk": L.dense_init(ks[1], d, d, dtype),
        "wv": L.dense_init(ks[2], d, d, dtype),
        "wg": L.dense_init(ks[3], d, d, dtype),
        "wo": L.dense_init(ks[4], d, d, dtype),
        "w0": jnp.full((d,), -6.0, jnp.float32),   # base decay (w ≈ 1-2e-3)
        "w_lora_a": L.dense_init(ks[5], d, LORA, jnp.float32, scale=0.01),
        "w_lora_b": L.dense_init(ks[6], LORA, d, jnp.float32, scale=0.01),
        "u": (0.1 * jax.random.normal(ks[7], (h, HEAD), jnp.float32)),
        "ln_o": L.init_rmsnorm(d),
    }


def _token_shift(x: jax.Array, x_prev: Optional[jax.Array] = None
                 ) -> jax.Array:
    """x_{t-1} stream.  x: [B, T, D]; x_prev: [B, D] (decode carry)."""
    if x_prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]
    return x_prev[:, None, :]


def _mix(mu: jax.Array, x: jax.Array, xs: jax.Array) -> jax.Array:
    return x + (xs - x) * mu.astype(x.dtype)


def _wkv_inputs(p, x, xs):
    b, t, d = x.shape
    h = d // HEAD
    r = _mix(p["mu"]["r"], x, xs) @ p["wr"]
    k = _mix(p["mu"]["k"], x, xs) @ p["wk"]
    v = _mix(p["mu"]["v"], x, xs) @ p["wv"]
    g = _mix(p["mu"]["g"], x, xs) @ p["wg"]
    # decay LoRA runs in the activation dtype (its cotangents ride the
    # TP collectives; f32 here doubled the wire — §Perf rwkv6 iteration);
    # only the exponentials stay f32.
    xw = _mix(p["mu"]["w"], x, xs)
    dw = jnp.tanh(xw @ p["w_lora_a"].astype(xw.dtype)) \
        @ p["w_lora_b"].astype(xw.dtype)
    w = jnp.exp(-jnp.exp(p["w0"] + dw.astype(jnp.float32)))
    from repro.sharding.act import shard_act
    hd = lambda z: shard_act(z.reshape(b, t, h, HEAD), "dp", None, "tp", None)
    return hd(r), hd(k), hd(v), g, hd(w)


def wkv(r, k, v, w, u, s0=None):
    """Batched multi-head WKV6.  r,k,w: [B,T,H,K], v: [B,T,H,V], u: [H,K].

    Returns (o: [B,T,H,V], s_final: [B,H,K,V]).  lax.scan over T (the
    oracle semantics of kernels/rwkv6_recurrence; the Pallas kernel is the
    TPU fast path for serving).
    """
    b, t, h, kk = r.shape
    vv = v.shape[-1]
    if s0 is None:
        s0 = jnp.zeros((b, h, kk, vv), jnp.float32)

    def step(s, rkvw):
        rt, kt, vt, wt = rkvw                       # [B,H,K],[B,H,V]...
        kv = kt[..., :, None] * vt[..., None, :]    # [B,H,K,V]
        ot = jnp.einsum("bhkv,bhk->bhv", s + u[:, :, None] * kv, rt)
        s = wt[..., :, None] * s + kv
        return s, ot

    xs = jax.tree.map(lambda z: z.swapaxes(0, 1).astype(jnp.float32),
                      (r, k, v, w))
    s_final, o = jax.lax.scan(step, s0, xs)
    return o.swapaxes(0, 1).astype(v.dtype), s_final


def wkv_chunked(r, k, v, w, u, *, chunk: int = 32, s0=None,
                unroll: bool = False):
    """Chunked-parallel WKV6 — the MXU training path.

    Equivalent to :func:`wkv` (the scan oracle) but processes time in
    chunks: intra-chunk interactions become masked [C,C] matmuls with
    per-channel decay factored as q̃_t·k̃_s = (r_t e^{L_{t-1}-L_h})·
    (k_s e^{L_h-L_s}) (L = cumulative log-decay, shifted by the chunk
    midpoint L_h so both factors stay within f32 range for |log w|·C/2 ≲
    80); inter-chunk flows through the carried state S with strictly
    negative exponents.  Extreme decays (w → 0) need a smaller ``chunk``.
    """
    b, t, h, kk = r.shape
    vv = v.shape[-1]
    c = min(chunk, t)
    pad = (-t) % c
    if pad:
        zk = jnp.zeros((b, pad, h, kk), jnp.float32)
        r = jnp.concatenate([r.astype(jnp.float32), zk], 1)
        k = jnp.concatenate([k.astype(jnp.float32), zk], 1)
        v = jnp.concatenate([v.astype(jnp.float32),
                             jnp.zeros((b, pad, h, vv), jnp.float32)], 1)
        w = jnp.concatenate([w.astype(jnp.float32),
                             jnp.ones((b, pad, h, kk), jnp.float32)], 1)
    tp = t + pad
    nc = tp // c

    def resh(z, dd):
        return z.astype(jnp.float32).reshape(b, nc, c, h, dd) \
            .transpose(1, 0, 3, 2, 4)          # [NC, B, H, C, dd]

    rc, kc, vc, wc = resh(r, kk), resh(k, kk), resh(v, vv), resh(w, kk)
    if s0 is None:
        s0 = jnp.zeros((b, h, kk, vv), jnp.float32)

    mask_lt = jnp.tril(jnp.ones((c, c), jnp.float32), k=-1)   # s < t

    def per_chunk(S, xs):
        rr, kk_, vv_, ww = xs                   # [B,H,C,·]
        lw = jnp.log(jnp.maximum(ww, 1e-30))    # [B,H,C,K]
        L = jnp.cumsum(lw, axis=2)              # inclusive
        L_prev = L - lw                         # exclusive (L_{t-1})
        L_half = L[:, :, c // 2:c // 2 + 1, :]
        q_in = rr * jnp.exp(L_prev - L_half)    # [B,H,C,K]
        k_in = kk_ * jnp.exp(L_half - L)
        A = jnp.einsum("bhtk,bhsk->bhts", q_in, k_in) * mask_lt
        o = jnp.einsum("bhts,bhsv->bhtv", A, vv_)
        # diagonal (current-token u-boosted) term
        o = o + jnp.einsum("bhtk,bhtv->bhtv", rr * u[None, :, None, :] * kk_,
                           vv_)
        # inter-chunk: state contribution (exponents <= 0)
        q_cross = rr * jnp.exp(L_prev)
        o = o + jnp.einsum("bhtk,bhkv->bhtv", q_cross, S)
        # state update
        k_dec = kk_ * jnp.exp(L[:, :, -1:, :] - L)
        S = jnp.exp(L[:, :, -1, :])[..., None] * S + \
            jnp.einsum("bhtk,bhtv->bhkv", k_dec, vv_)
        return S, o

    S, os_ = jax.lax.scan(per_chunk, s0, (rc, kc, vc, wc),
                          unroll=nc if unroll else 1)
    o = os_.transpose(1, 0, 3, 2, 4).reshape(b, tp, h, vv)[:, :t]
    return o.astype(v.dtype), S


def rwkv6_token_mix(p: PyTree, x: jax.Array, *,
                    chunked: bool | None = None, chunk: int = 32,
                    unroll: bool = False) -> jax.Array:
    b, t, d = x.shape
    xs = _token_shift(x)
    r, k, v, g, w = _wkv_inputs(p, x, xs)
    use_chunked = chunked if chunked is not None else t >= 64
    if use_chunked:
        o, _ = wkv_chunked(r, k, v, w, p["u"], chunk=chunk, unroll=unroll)
    else:
        o, _ = wkv(r, k, v, w, p["u"])
    o = L.rmsnorm(p["ln_o"], o.reshape(b, t, d))
    return (o * jax.nn.silu(g.astype(o.dtype))) @ p["wo"]


def init_channel_mix(key, d: int, f: int, dtype=jnp.bfloat16) -> PyTree:
    ks = jax.random.split(key, 3)
    return {
        "mu": {name: jnp.full((d,), 0.5, jnp.float32) for name in ("k", "r")},
        "wk": L.dense_init(ks[0], d, f, dtype),
        "wv": L.dense_init(ks[1], f, d, dtype),
        "wr": L.dense_init(ks[2], d, d, dtype),
    }


def rwkv6_channel_mix(p: PyTree, x: jax.Array) -> jax.Array:
    xs = _token_shift(x)
    kk = jnp.square(jax.nn.relu(_mix(p["mu"]["k"], x, xs) @ p["wk"]))
    rr = jax.nn.sigmoid((_mix(p["mu"]["r"], x, xs) @ p["wr"])
                        .astype(jnp.float32)).astype(x.dtype)
    return rr * (kk @ p["wv"])


# ---------------------------------------------------------------------------
# decode (state caches: WKV state + last-token shifts)
# ---------------------------------------------------------------------------

def init_rwkv6_cache(batch: int, d: int, dtype=jnp.bfloat16) -> PyTree:
    h = d // HEAD
    return {"s": jnp.zeros((batch, h, HEAD, HEAD), jnp.float32),
            "x_tok": jnp.zeros((batch, d), dtype),
            "x_ch": jnp.zeros((batch, d), dtype)}


def rwkv6_decode(p_tok: PyTree, p_ch: PyTree, x: jax.Array, cache: PyTree,
                 norm_tok, norm_ch) -> tuple[jax.Array, PyTree]:
    """One token through token-mix + channel-mix with carried state.

    x: [B, 1, D] (post-embedding); norms applied here to keep the carried
    pre-norm streams consistent.
    """
    b, _, d = x.shape
    h = d // HEAD
    xn = norm_tok(x)
    xs = _token_shift(xn, cache["x_tok"])
    r, k, v, g, w = _wkv_inputs(p_tok, xn, xs)
    sq = lambda z: z[:, 0]
    kv = sq(k)[..., :, None] * sq(v)[..., None, :]
    o = jnp.einsum("bhkv,bhk->bhv",
                   cache["s"] + p_tok["u"][:, :, None] * kv,
                   sq(r).astype(jnp.float32))
    s_new = sq(w).astype(jnp.float32)[..., :, None] * cache["s"] + kv
    o = L.rmsnorm(p_tok["ln_o"], o.reshape(b, 1, d).astype(x.dtype))
    x = x + (o * jax.nn.silu(g.astype(o.dtype))) @ p_tok["wo"]

    xn2 = norm_ch(x)
    xs2 = _token_shift(xn2, cache["x_ch"])
    kk = jnp.square(jax.nn.relu(_mix(p_ch["mu"]["k"], xn2, xs2) @ p_ch["wk"]))
    rr = jax.nn.sigmoid((_mix(p_ch["mu"]["r"], xn2, xs2) @ p_ch["wr"])
                        .astype(jnp.float32)).astype(x.dtype)
    x = x + rr * (kk @ p_ch["wv"])
    new_cache = {"s": s_new, "x_tok": xn[:, 0], "x_ch": xn2[:, 0]}
    return x, new_cache
