"""Attention: GQA flash-attention (KV-chunked, online softmax) + decode.

One implementation serves every attention in the zoo:
  * full causal (dense LMs, training/prefill)
  * sliding-window causal (recurrentgemma local attention)
  * non-causal (whisper encoder)
  * cross attention (whisper decoder, llama-vision image layers)
  * single-token decode against a KV cache

The KV-chunked online-softmax formulation (lax.scan over KV blocks with
running max / denominator) bounds live memory to O(Tq · chunk) — mandatory
for the 32k-prefill cells — and is the standard XLA-level flash pattern on
TPU.  f32 softmax statistics throughout.
"""

from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

PyTree = Any
NEG_INF = -1e30


def _gqa_expand(q: jax.Array, n_kv: int) -> jax.Array:
    """[B, T, Hq, d] -> [B, T, Hkv, G, d]."""
    b, t, hq, d = q.shape
    return q.reshape(b, t, n_kv, hq // n_kv, d)


def flash_attention(
    q: jax.Array,            # [B, Tq, Hq, d]
    k: jax.Array,            # [B, Tk, Hkv, d]
    v: jax.Array,            # [B, Tk, Hkv, dv]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int | jax.Array = 0,
    kv_len: Optional[jax.Array] = None,   # valid KV prefix (decode masking)
    chunk: int = 1024,
    softmax_scale: Optional[float] = None,
    unroll: bool = False,
) -> jax.Array:
    b, tq, hq, d = q.shape
    _, tk, hkv, dv = v.shape
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(d)
    qf = _gqa_expand(q.astype(jnp.float32) * scale, hkv)   # [B,Tq,Hkv,G,d]
    g = qf.shape[3]

    chunk = min(chunk, tk)
    pad = (-tk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nkc = (tk + pad) // chunk
    ks = k.reshape(b, nkc, chunk, hkv, d).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, nkc, chunk, hkv, dv).transpose(1, 0, 2, 3, 4)

    # q_offset / kv_len may be scalars or per-batch [B] vectors
    # (continuous batching: every slot sits at its own position).
    q_off = jnp.asarray(q_offset)
    per_batch = q_off.ndim > 0 or (kv_len is not None
                                   and jnp.asarray(kv_len).ndim > 0)
    q_pos = (q_off[..., None] + jnp.arange(tq))             # [Tq] or [B,Tq]
    if per_batch:
        q_pos = jnp.broadcast_to(q_pos.reshape(-1, tq), (b, tq))

    def body(carry, xs):
        m, l, acc = carry
        kc, vc, ci = xs                                     # [B,C,Hkv,d], idx
        s = jnp.einsum("bqhgd,bchd->bqhgc", qf,
                       kc.astype(jnp.float32))              # [B,Tq,Hkv,G,C]
        k_pos = ci * chunk + jnp.arange(chunk)              # [C]
        valid = k_pos < tk                                  # [C]
        if kv_len is not None:
            kl = jnp.asarray(kv_len)
            if kl.ndim > 0:
                valid = valid[None, :] & (k_pos[None, :] < kl[:, None])
            else:
                valid = valid & (k_pos < kl)
        if per_batch:
            mask = jnp.broadcast_to(
                valid if valid.ndim == 2 else valid[None, :],
                (b, chunk))[:, None, :]                     # [B,1,C]
            mask = jnp.broadcast_to(mask, (b, tq, chunk))
            if causal:
                mask = mask & (k_pos[None, None, :] <= q_pos[:, :, None])
            if window is not None:
                mask = mask & (k_pos[None, None, :]
                               > q_pos[:, :, None] - window)
            s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
        else:
            mask = jnp.broadcast_to(valid[None, :], (tq, chunk))
            if causal:
                mask = mask & (k_pos[None, :] <= q_pos[:, None])
            if window is not None:
                mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bqhgc,bchv->bqhgv", p, vc.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, tq, hkv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, tq, hkv, g), jnp.float32)
    a0 = jnp.zeros((b, tq, hkv, g, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (ks, vs, jnp.arange(nkc)),
        unroll=nkc if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, tq, hq, dv).astype(v.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer (params + apply)
# ---------------------------------------------------------------------------

def init_gqa(key, d_model: int, n_heads: int, n_kv: int, d_head: int,
             qk_norm: bool = False, dtype=jnp.bfloat16) -> PyTree:
    from repro.models import layers as L
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.dense_init(ks[0], d_model, n_heads * d_head, dtype),
        "wk": L.dense_init(ks[1], d_model, n_kv * d_head, dtype),
        "wv": L.dense_init(ks[2], d_model, n_kv * d_head, dtype),
        "wo": L.dense_init(ks[3], n_heads * d_head, d_model, dtype),
    }
    if qk_norm:
        p["q_norm"] = L.init_rmsnorm(d_head)
        p["k_norm"] = L.init_rmsnorm(d_head)
    return p


def _project_qkv(p, x, xc, n_heads, n_kv, d_head, qk_norm, rope_theta,
                 q_positions, k_positions, use_rope=True):
    from repro.models import layers as L
    b, t, _ = x.shape
    tc = xc.shape[1]
    q = (x @ p["wq"]).reshape(b, t, n_heads, d_head)
    k = (xc @ p["wk"]).reshape(b, tc, n_kv, d_head)
    v = (xc @ p["wv"]).reshape(b, tc, n_kv, d_head)
    if qk_norm:
        q = L.rmsnorm(p["q_norm"], q)
        k = L.rmsnorm(p["k_norm"], k)
    if use_rope:
        q = L.apply_rope(q, q_positions, rope_theta)
        k = L.apply_rope(k, k_positions, rope_theta)
    from repro.sharding.act import shard_act
    q = shard_act(q, "dp", None, "tp", None)
    k = shard_act(k, "dp", None, "tp", None)
    v = shard_act(v, "dp", None, "tp", None)
    return q, k, v


def gqa_attention(
    p: PyTree, x: jax.Array, *, n_heads: int, n_kv: int, d_head: int,
    causal: bool = True, window: Optional[int] = None, qk_norm: bool = False,
    rope_theta: float = 10000.0, q_offset: int = 0, chunk: int = 1024,
    context: Optional[jax.Array] = None, use_rope: bool = True,
    unroll: bool = False,
) -> jax.Array:
    """Self (context=None) or cross attention over full sequences."""
    xc = x if context is None else context
    b, t, _ = x.shape
    q_pos = q_offset + jnp.arange(t)
    k_pos = jnp.arange(xc.shape[1])
    q, k, v = _project_qkv(p, x, xc, n_heads, n_kv, d_head, qk_norm,
                           rope_theta, q_pos[None], k_pos[None],
                           use_rope=use_rope and context is None)
    out = flash_attention(q, k, v, causal=causal and context is None,
                          window=window, q_offset=q_offset, chunk=chunk,
                          unroll=unroll)
    return out.reshape(b, t, n_heads * d_head) @ p["wo"]


def gqa_decode(
    p: PyTree, x: jax.Array, cache: PyTree, index: jax.Array, *,
    n_heads: int, n_kv: int, d_head: int, window: Optional[int] = None,
    qk_norm: bool = False, rope_theta: float = 10000.0,
    use_rope: bool = True, unroll: bool = False,
) -> tuple[jax.Array, PyTree]:
    """One-token decode.  x: [B, 1, D]; cache: {k,v: [B, S, Hkv, d]}.

    ``index`` is a scalar (lockstep batch) or an int32 [B] vector
    (continuous batching: per-slot positions; cache writes are per-row
    scatters and masking is per-row).
    """
    b = x.shape[0]
    idx = jnp.asarray(index)
    vec = idx.ndim > 0
    pos = (idx[:, None] if vec else jnp.full((b, 1), idx)).astype(jnp.int32)
    q, k_new, v_new = _project_qkv(
        p, x, x, n_heads, n_kv, d_head, qk_norm, rope_theta, pos, pos,
        use_rope=use_rope)
    if vec:
        rows = jnp.arange(b)
        k = cache["k"].at[rows, idx].set(
            k_new[:, 0].astype(cache["k"].dtype))
        v = cache["v"].at[rows, idx].set(
            v_new[:, 0].astype(cache["v"].dtype))
    else:
        k = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k_new.astype(cache["k"].dtype), idx, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v_new.astype(cache["v"].dtype), idx, axis=1)
    out = flash_attention(q, k, v, causal=False, window=window,
                          q_offset=idx, kv_len=idx + 1,
                          chunk=min(4096, k.shape[1]), unroll=unroll)
    y = out.reshape(b, 1, n_heads * d_head) @ p["wo"]
    return y, {"k": k, "v": v}


def init_gqa_cache(batch: int, seq: int, n_kv: int, d_head: int,
                   dtype=jnp.bfloat16) -> PyTree:
    return {"k": jnp.zeros((batch, seq, n_kv, d_head), dtype),
            "v": jnp.zeros((batch, seq, n_kv, d_head), dtype)}


# ---------------------------------------------------------------------------
# sliding-window decode with a ring-buffer cache — O(window) state, the
# reason the hybrid arch is long_500k-eligible.
# ---------------------------------------------------------------------------

def init_window_cache(batch: int, window: int, n_kv: int, d_head: int,
                      dtype=jnp.bfloat16) -> PyTree:
    return {"k": jnp.zeros((batch, window, n_kv, d_head), dtype),
            "v": jnp.zeros((batch, window, n_kv, d_head), dtype),
            "pos": jnp.full((batch, window), -1, jnp.int32)}


def window_decode(
    p: PyTree, x: jax.Array, cache: PyTree, index: jax.Array, *,
    n_heads: int, n_kv: int, d_head: int, window: int,
    qk_norm: bool = False, rope_theta: float = 10000.0,
) -> tuple[jax.Array, PyTree]:
    """One-token decode against a ring buffer of the last ``window`` KVs.

    ``index``: scalar or per-row [B] vector (continuous batching)."""
    b = x.shape[0]
    idx = jnp.asarray(index)
    idx_b = jnp.broadcast_to(idx, (b,)).astype(jnp.int32)   # [B]
    pos = idx_b[:, None]
    q, k_new, v_new = _project_qkv(
        p, x, x, n_heads, n_kv, d_head, qk_norm, rope_theta, pos, pos)
    slot = idx_b % window
    rows = jnp.arange(b)
    k = cache["k"].at[rows, slot].set(k_new[:, 0].astype(cache["k"].dtype))
    v = cache["v"].at[rows, slot].set(v_new[:, 0].astype(cache["v"].dtype))
    slot_pos = cache["pos"].at[rows, slot].set(idx_b)

    scale = 1.0 / math.sqrt(d_head)
    qe = _gqa_expand(q.astype(jnp.float32) * scale, n_kv)  # [B,1,Hkv,G,d]
    s = jnp.einsum("bqhgd,bwhd->bqhgw", qe, k.astype(jnp.float32))
    valid = ((slot_pos >= 0) & (slot_pos <= idx_b[:, None])
             & (slot_pos > idx_b[:, None] - window))        # [B, W]
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqhgw,bwhv->bqhgv", a, v.astype(jnp.float32))
    y = out.reshape(b, 1, n_heads * d_head).astype(x.dtype) @ p["wo"]
    return y, {"k": k, "v": v, "pos": slot_pos}
