"""repro.train — optimizer, loss, step builders, fault-tolerant loop, PP."""
