"""Train-step builders.

Two execution strategies, selected by the collective backend:

  * ``gspmd`` (backend "xla") — everything under pjit/GSPMD: params
    FSDP×TP sharded, gradient reduction and TP collectives inserted by the
    partitioner.  The passive-network baseline; also the path every dry-run
    cell lowers through.

  * ``acis`` (backends "acis*") — the gradient-sync phase runs in a
    `shard_map` region that is *manual* over the DP axes and auto over
    "model": per-shard grads are synchronized explicitly through the
    CollectiveEngine (ring / hierarchical / compressed-with-error-feedback),
    then the optimizer applies the update inside the region.  This is the
    paper's MPI-transparency point: the model code is identical, only the
    transport changed.  Params are replicated over DP axes in this mode
    (TP/EP sharding over "model" still applies).

Both support microbatched gradient accumulation (lax.scan) — the
communication-efficiency knob that interacts with compression (one sync per
step regardless of microbatch count).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: F401

from repro.core.api import CollectiveEngine
from repro.models.model import Model
from repro.obs import metrics as _obs
from repro.sharding import rules
from repro.train.loss import cross_entropy
from repro.train.optimizer import Optimizer

PyTree = Any


@dataclasses.dataclass
class TrainState:
    params: PyTree
    opt: PyTree
    step: jax.Array
    ef_residual: Optional[PyTree] = None   # Type 3 look-aside memory
    # persistent gradient-sync bucket arenas (engine.init_arenas):
    # threaded through the step and donated with the state, so the
    # Coalesce bucket packs write in place instead of re-allocating a 2×
    # transient every sync
    sync_arenas: Optional[tuple] = None


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt, s.step, s.ef_residual, s.sync_arenas),
               None),
    lambda aux, ch: TrainState(*ch))


def _loss_fn(model: Model, params, tokens, context, mesh: Optional[Mesh]):
    """tokens: [b, T+1] — inputs tokens[:, :-1], targets tokens[:, 1:]."""
    hidden, aux = model.forward(params, tokens[:, :-1], context=context)
    logits = model.logits(params, hidden)
    if mesh is not None:
        logits = rules.constrain(logits, mesh, rules.logits_spec(mesh))
    loss, metrics = cross_entropy(logits, tokens[:, 1:])
    metrics["aux"] = aux
    return loss + aux, metrics


def _accumulate_grads(model, params, batch, microbatches, mesh):
    """lax.scan over microbatch slices; returns (mean grads, mean metrics)."""
    tokens = batch["tokens"]
    context = batch.get("context")
    b = tokens.shape[0]
    assert b % microbatches == 0, (b, microbatches)
    mb = b // microbatches

    def grads_of(tok, ctx):
        return jax.grad(
            lambda p: _loss_fn(model, p, tok, ctx, mesh), has_aux=True
        )(params)

    if microbatches == 1:
        g, m = grads_of(tokens, context)
        return g, m

    tok_mb = tokens.reshape(microbatches, mb, *tokens.shape[1:])
    ctx_mb = None if context is None else \
        context.reshape(microbatches, mb, *context.shape[1:])
    if mesh is not None:
        # keep the BATCH dim data-sharded after the microbatch split —
        # otherwise GSPMD happily shards the microbatch dim over 'data'
        # and inserts full-rematerialization resharding inside the scan.
        dp = rules.dp_axes(mesh, model.cfg.parallelism)
        tok_mb = rules.constrain(
            tok_mb, mesh, P(None, dp, *([None] * (tok_mb.ndim - 2))))
        if ctx_mb is not None:
            ctx_mb = rules.constrain(
                ctx_mb, mesh, P(None, dp, *([None] * (ctx_mb.ndim - 2))))

    def body(acc, xs):
        tok, ctx = xs
        g, m = grads_of(tok, ctx)
        acc_g, acc_m = acc
        acc_g = jax.tree.map(lambda a, x: a + x.astype(a.dtype), acc_g, g)
        acc_m = jax.tree.map(lambda a, x: a + x, acc_m, m)
        return (acc_g, acc_m), ()

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    m0 = {"nll": 0.0, "z_loss": 0.0, "accuracy": 0.0, "aux": 0.0}
    m0 = jax.tree.map(jnp.float32, m0)
    xs = (tok_mb, ctx_mb) if ctx_mb is not None else (tok_mb, None)
    unroll = microbatches if model.cfg.analysis_unroll else 1
    if ctx_mb is None:
        (g, m), _ = jax.lax.scan(
            lambda acc, tok: body(acc, (tok, None)), (g0, m0), tok_mb,
            unroll=unroll)
    else:
        (g, m), _ = jax.lax.scan(body, (g0, m0), xs, unroll=unroll)
    inv = 1.0 / microbatches
    return jax.tree.map(lambda x: x * inv, g), \
        jax.tree.map(lambda x: x * inv, m)


# ---------------------------------------------------------------------------
# GSPMD strategy (xla backend / dry-run path)
# ---------------------------------------------------------------------------

def build_train_step_gspmd(model: Model, optimizer: Optimizer, mesh: Mesh,
                           *, microbatches: int = 1,
                           donate: bool = True) -> Callable:
    """Returns jitted (state, batch) -> (state, metrics) with sharded I/O."""

    def step_fn(state: TrainState, batch) -> tuple[TrainState, dict]:
        from repro.sharding.act import activation_sharding
        with activation_sharding(mesh, parallelism=model.cfg.parallelism):
            return _step_body(state, batch)

    def _step_body(state: TrainState, batch) -> tuple[TrainState, dict]:
        grads, metrics = _accumulate_grads(
            model, state.params, batch, microbatches, mesh)
        new_params, new_opt = optimizer.update(
            grads, state.opt, state.params, state.step)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                          for g in jax.tree.leaves(grads)))
        metrics["grad_norm"] = gn
        return TrainState(new_params, new_opt, state.step + 1,
                          state.ef_residual), metrics

    par = model.cfg.parallelism
    pspecs = rules.param_specs(model.param_shapes(), mesh, par)
    opt_shapes = jax.eval_shape(optimizer.init, model.param_shapes())
    ospecs = _opt_specs(opt_shapes, pspecs)
    state_specs = TrainState(pspecs, ospecs, P(), None)
    batch_specs = {"tokens": rules.batch_spec(mesh, extra_dims=1,
                                              parallelism=par)}
    if model.context_inputs(1) is not None:   # stub-modality archs
        batch_specs["context"] = rules.batch_spec(mesh, extra_dims=2,
                                                  parallelism=par)
    out_metric_specs = {k: P() for k in
                        ("nll", "z_loss", "accuracy", "aux", "grad_norm")}
    state_shardings = _ns(mesh, state_specs)
    fn = jax.jit(
        step_fn,
        in_shardings=(state_shardings, _ns(mesh, batch_specs)),
        out_shardings=(state_shardings, _ns(mesh, out_metric_specs)),
        donate_argnums=(0,) if donate else (),
    )
    fn.state_shardings = state_shardings  # type: ignore[attr-defined]
    fn.place_state = lambda st: jax.device_put(st, state_shardings)  # type: ignore[attr-defined]
    return fn


def _ns(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        spec_tree, is_leaf=lambda s: isinstance(s, P) or s is None)


def _opt_specs(opt_shapes: PyTree, pspecs: PyTree) -> PyTree:
    """Optimizer-state sharding: match the param's spec when the shapes
    coincide (m/v), drop trailing axes for factored stats, scalars repl."""
    flat_p = {tuple(str(k) for k in path): spec
              for path, spec in
              jax.tree_util.tree_flatten_with_path(pspecs)[0]}

    def one(path, leaf):
        # find a param spec whose path is a suffix-compatible prefix
        keys = tuple(str(k) for k in path)
        for pk, spec in flat_p.items():
            if all(any(pp == kk for kk in keys) for pp in pk):
                if len(spec) == len(leaf.shape):
                    return spec
                # factored stats: take leading dims of the param spec
                return P(*tuple(spec)[:len(leaf.shape)])
        return P()

    return jax.tree_util.tree_map_with_path(one, opt_shapes)


# ---------------------------------------------------------------------------
# ACiS strategy (explicit in-network gradient sync)
# ---------------------------------------------------------------------------

def build_train_step_acis(model: Model, optimizer: Optimizer, mesh: Mesh,
                          engine: CollectiveEngine, *,
                          microbatches: int = 1,
                          donate: bool = False,
                          recorder=None) -> Callable:
    """Params replicated over DP axes (TP over 'model' untouched); gradient
    sync + update run manual-over-DP via the CollectiveEngine.

    When the state carries ``sync_arenas`` (see :func:`init_state` with
    ``arenas=True``), they are threaded through the sync and returned in
    the new state; pass ``donate=True`` so the whole state — arenas
    included — is donated to the step and XLA writes the bucket packs in
    place instead of allocating a 2× transient per sync.  ``donate``
    invalidates the state passed in (the usual donation contract), so it
    is opt-in.

    ``recorder`` (a :class:`repro.obs.Recorder`) wraps the jitted step
    with host-side telemetry: ``train.steps`` counts calls, and — only
    when the recorder is enabled — ``train.step_s`` observes blocking
    wall-clock per step (the block changes dispatch overlap, so it is
    never imposed on un-recorded runs).  Defaults to the process-wide
    ``obs`` recorder read at call time.
    """
    dp = rules.dp_axes(mesh)
    manual_axes = set(dp)

    def step_fn(state: TrainState, batch) -> tuple[TrainState, dict]:
        def local(params, opt, step, residual, arenas, tokens, context):
            b = {"tokens": tokens}
            if context is not None:
                b["context"] = context
            grads, metrics = _accumulate_grads(
                model, params, b, microbatches, None)
            if arenas is not None:
                synced, new_residual, new_arenas = engine.gradient_sync(
                    grads, residual, arenas=arenas)
            else:
                synced, new_residual = engine.gradient_sync(grads, residual)
                new_arenas = None
            new_params, new_opt = optimizer.update(synced, opt, params, step)
            metrics = jax.tree.map(
                lambda x: jax.lax.pmean(x, dp), metrics)
            gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                              for g in jax.tree.leaves(synced)))
            metrics["grad_norm"] = gn
            return new_params, new_opt, new_residual, new_arenas, metrics

        tokens = batch["tokens"]
        context = batch.get("context")
        in_specs = (P(), P(), P(), P(), P(), P(dp), P(dp))
        out_specs = (P(), P(), P(), P(), P())
        if context is None:
            fn = lambda p, o, s, r, a, t: local(p, o, s, r, a, t, None)
            in_specs = in_specs[:6]
        else:
            fn = local
        mapped = jax.shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            axis_names=manual_axes, check_vma=False)
        args = (state.params, state.opt, state.step, state.ef_residual,
                state.sync_arenas, tokens) \
            + (() if context is None else (context,))
        new_params, new_opt, new_residual, new_arenas, metrics = \
            mapped(*args)
        return TrainState(new_params, new_opt, state.step + 1,
                          new_residual, new_arenas), metrics

    jitted = jax.jit(step_fn, donate_argnums=(0,) if donate else ())

    @functools.wraps(jitted)
    def timed(state, batch):
        rec = recorder if recorder is not None else _obs.RECORDER
        if not rec.enabled:
            return jitted(state, batch)
        import time
        t0 = time.perf_counter()
        out = jax.block_until_ready(jitted(state, batch))
        rec.count("train.steps")
        rec.observe("train.step_s", time.perf_counter() - t0)
        return out

    return timed


def init_state(model: Model, optimizer: Optimizer, key,
               engine: Optional[CollectiveEngine] = None, *,
               mesh: Optional[Mesh] = None,
               arenas: bool = False,
               microbatches: int = 1) -> TrainState:
    """``arenas=True`` (acis backends, ``mesh`` required) additionally
    allocates the persistent gradient-sync bucket arenas so the step can
    write bucket packs in place — pair with
    ``build_train_step_acis(..., donate=True)``.  Pass the step's
    ``microbatches`` too: it decides the grad dtypes the arenas must
    match (accumulated grads are f32, single-microbatch grads carry the
    param dtype)."""
    params = model.init(key)
    opt = optimizer.init(params)
    residual = None
    sync_arenas = None
    if engine is not None and engine.config.backend != "xla":
        residual = engine.init_state(params)
        if arenas:
            if mesh is None:
                raise ValueError("init_state(arenas=True) needs mesh= — "
                                 "bucket boundaries depend on the DP "
                                 "ring sizes")
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            axis_sizes = {a: sizes[a]
                          for a in (engine.inner_axis, engine.outer_axis)
                          if a is not None and a in sizes}
            grads_like = jax.tree.map(
                lambda p: jax.ShapeDtypeStruct(
                    p.shape,
                    jnp.float32 if microbatches > 1 else p.dtype),
                params)
            sync_arenas = engine.init_arenas(grads_like,
                                             axis_sizes=axis_sizes)
    return TrainState(params, opt, jnp.zeros((), jnp.int32), residual,
                      sync_arenas)
