"""GPipe-style pipeline parallelism over a "pipe" mesh axis.

Stage s holds layers [s·L/S, (s+1)·L/S); microbatches stream through with
`lax.ppermute` handoffs between neighbouring stages inside one shard_map
program.  The schedule is the classic GPipe fill-steady-drain loop expressed
as a `lax.scan` over T = M + S - 1 ticks: at tick t, stage s processes
microbatch t - s (when 0 ≤ t - s < M).

This composes with the ACiS engine: the stage handoff IS a point-to-point
on the torus, and the engine's Type 0 wire codecs apply to activations in
transit (activation compression across stages).  PP is off by default for
the assigned cells (the 2-axis production mesh maps pod→DP); it is provided
— and tested at small scale — as the third axis for 1000+-node layouts.
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.wire import IDENTITY, WireCodec

PyTree = Any


def pipeline_forward(
    stage_fn: Callable[[PyTree, jax.Array], jax.Array],
    stage_params: PyTree,          # leaves stacked [S, ...] (sharded by pipe)
    x_microbatches: jax.Array,     # [M, mb, ...] (replicated input)
    axis_name: str = "pipe",
    codec: WireCodec = IDENTITY,
) -> jax.Array:
    """Rank-local (inside shard_map over ``axis_name``).

    Every rank holds its stage's params (leading stacked dim already
    scattered by shard_map in_specs).  Returns the final-stage outputs
    [M, mb, ...] (valid on the last rank; callers ppermute/collect).
    """
    s_count = lax.axis_size(axis_name)
    sid = lax.axis_index(axis_name)
    m = x_microbatches.shape[0]
    ticks = m + s_count - 1
    perm = [(j, j + 1) for j in range(s_count - 1)]

    mb_shape = x_microbatches.shape[1:]
    out = jnp.zeros((m,) + mb_shape, x_microbatches.dtype)

    def tick(carry, t):
        inflight, out = carry                     # inflight: [mb, ...]
        mb_id = t - sid                           # which microbatch we see
        active = (mb_id >= 0) & (mb_id < m)
        # stage 0 reads from the input stream; others from the wire
        src = jnp.where(
            sid == 0,
            x_microbatches[jnp.clip(mb_id, 0, m - 1)],
            inflight)
        y = stage_fn(stage_params, src)
        y = jnp.where(active, y, jnp.zeros_like(y))
        # last stage writes to its output slot; others forward
        out = jnp.where(
            (sid == s_count - 1) & active,
            out.at[jnp.clip(mb_id, 0, m - 1)].set(y),
            out)
        wire = codec.decode(codec.encode(y)) if codec is not IDENTITY else y
        inflight = lax.ppermute(wire.astype(y.dtype), axis_name, perm)
        return (inflight, out), ()

    inflight0 = jnp.zeros(mb_shape, x_microbatches.dtype)
    (_, out), _ = lax.scan(tick, (inflight0, out), jnp.arange(ticks))
    return out


def run_pipeline(
    mesh: Mesh,
    stage_fn: Callable,
    stage_params: PyTree,          # [S, ...] stacked
    x: jax.Array,                  # [M, mb, ...]
    codec: WireCodec = IDENTITY,
) -> jax.Array:
    """Wraps pipeline_forward in shard_map over the 'pipe' axis and
    broadcasts the final-stage result to all ranks."""
    s_count = mesh.shape["pipe"]

    def local(params, xin):
        y = pipeline_forward(stage_fn, params, xin, "pipe", codec)
        # deliver final-stage outputs everywhere (tree bcast from last rank)
        from repro.core.ring import tree_broadcast
        return tree_broadcast(y, "pipe", root=s_count - 1)

    stacked_specs = jax.tree.map(lambda _: P("pipe"), stage_params)
    fn = jax.shard_map(local, mesh=mesh,
                       in_specs=(stacked_specs, P()), out_specs=P(),
                       check_vma=False)
    return jax.jit(fn)(stage_params, x)
