"""Fault-tolerant training loop: checkpoint/restart, preemption handling,
straggler mitigation hooks, elastic resume.

The loop is deliberately dumb about *what* it runs (any jitted step_fn) and
careful about *how*: every side effect that matters for recovery is ordered
so that a kill at any point resumes bit-exactly — data position is a pure
function of the restored step, optimizer state travels with params, and the
error-feedback residual (when the ACiS compressed transport is on) is part
of the checkpointed state, because losing the look-aside memory would lose
gradient mass.
"""

from __future__ import annotations

import dataclasses
import os
import signal
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.data.pipeline import BigramStream
from repro.train.step import TrainState

PyTree = Any


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: Optional[str] = None
    keep_last: int = 3
    log_every: int = 10
    # straggler / fault injection (tests + chaos drills)
    fail_at_step: Optional[int] = None


class Preempted(RuntimeError):
    pass


class TrainLoop:
    def __init__(self, step_fn: Callable, stream: BigramStream,
                 cfg: LoopConfig, *, batch_transform: Optional[Callable] = None):
        self.step_fn = step_fn
        self.stream = stream
        self.cfg = cfg
        self.batch_transform = batch_transform or (lambda b, s: b)
        self._preempt = False
        self.metrics_log: list[dict] = []

    def request_preempt(self, *_):
        """SIGTERM-style graceful stop: finish the step, checkpoint, exit."""
        self._preempt = True

    def maybe_restore(self, state: TrainState,
                      shardings: Optional[PyTree] = None) -> TrainState:
        d = self.cfg.ckpt_dir
        if d and ckpt.latest_step(d) is not None:
            state, step, _ = ckpt.restore(d, state, shardings=shardings)
            return state
        return state

    def run(self, state: TrainState) -> TrainState:
        cfg = self.cfg
        if hasattr(self.step_fn, "place_state"):
            state = self.step_fn.place_state(state)
        start = int(np.asarray(state.step))
        for step in range(start, cfg.total_steps):
            if cfg.fail_at_step is not None and step == cfg.fail_at_step:
                raise RuntimeError(f"injected fault at step {step}")
            batch = self.stream.batch(step)
            batch = self.batch_transform(batch, step)
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            state, metrics = self.step_fn(state, batch)
            if step % cfg.log_every == 0 or step == cfg.total_steps - 1:
                m = {k: float(np.asarray(v)) for k, v in metrics.items()}
                m["step"] = step
                self.metrics_log.append(m)
            if cfg.ckpt_dir and (step + 1) % cfg.ckpt_every == 0:
                ckpt.save(cfg.ckpt_dir, step + 1, state,
                          keep_last=cfg.keep_last)
            if self._preempt:
                if cfg.ckpt_dir:
                    ckpt.save(cfg.ckpt_dir, step + 1, state,
                              keep_last=cfg.keep_last)
                raise Preempted(f"preempted after step {step}")
        return state


def run_with_restarts(make_loop: Callable[[], tuple["TrainLoop", TrainState]],
                      max_restarts: int = 3) -> tuple[TrainState, int]:
    """Supervisor: restart-from-checkpoint on failure (the single-process
    stand-in for a cluster controller rescheduling dead pods)."""
    restarts = 0
    while True:
        loop, state = make_loop()
        state = loop.maybe_restore(state)
        try:
            return loop.run(state), restarts
        except Preempted:
            raise
        except RuntimeError:
            restarts += 1
            if restarts > max_restarts:
                raise
