"""Next-token cross-entropy (stable, vocab-parallel-friendly) + z-loss."""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

PyTree = Any


def cross_entropy(logits: jax.Array, targets: jax.Array, *,
                  z_loss: float = 1e-4,
                  mask: Optional[jax.Array] = None
                  ) -> tuple[jax.Array, dict]:
    """logits: [B, T, V] (f32), targets: [B, T] int32.

    Works under GSPMD with vocab-sharded logits: logsumexp and the one-hot
    gather are einsum/reduce ops the partitioner handles with a single
    small all-reduce over the vocab axis.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)                       # [B, T]
    true_logit = jnp.take_along_axis(
        logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - true_logit
    zl = z_loss * jnp.square(lse)
    per_tok = nll + zl
    if mask is None:
        loss = per_tok.mean()
        denom = per_tok.size
    else:
        m = mask.astype(jnp.float32)
        denom = jnp.maximum(m.sum(), 1.0)
        loss = (per_tok * m).sum() / denom
    metrics = {
        "nll": (nll if mask is None else nll * mask).mean(),
        "z_loss": (zl if mask is None else zl * mask).mean(),
        "accuracy": ((logits.argmax(-1) == targets)
                     if mask is None else
                     (logits.argmax(-1) == targets) * mask)
        .astype(jnp.float32).mean(),
    }
    return loss, metrics
