"""Optimizers (from scratch): AdamW and Adafactor, plus LR schedules.

Functional (init_fn, update_fn) pairs over pytrees.  Optimizer state
inherits the parameter sharding (FSDP×TP) under GSPMD, which is ZeRO-ish by
construction; Adafactor's factored second moment is the memory-constrained
choice for the 236-B config (see configs/deepseek_v2_236b.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree, jax.Array], tuple[PyTree, PyTree]]
    name: str = "opt"


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def warmup_cosine(base_lr: float, warmup: int, total: int,
                  final_frac: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * jnp.minimum(1.0, step / max(warmup, 1))
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(lr: Callable | float = 1e-3, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          state_dtype=jnp.float32) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr))

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        lr_t = lr_fn(step)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = (b1 * m.astype(jnp.float32) + (1 - b1) * g)
            v = (b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g))
            mh, vh = m / c1, v / c2
            delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * \
                p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr_t * delta
            return new_p.astype(p.dtype), m.astype(state_dtype), \
                v.astype(state_dtype)

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        leaves = lambda i: jax.tree.map(lambda o: o[i], out,
                                        is_leaf=lambda o: isinstance(o, tuple))
        return leaves(0), {"m": leaves(1), "v": leaves(2)}

    return Optimizer(init, update, "adamw")


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; memory-lean for 100B+ params)
# ---------------------------------------------------------------------------

def adafactor(lr: Callable | float = 1e-2, decay: float = 0.8,
              eps: float = 1e-30, clip_threshold: float = 1.0,
              weight_decay: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr))

    def _factored(shape) -> bool:
        return len(shape) >= 2

    def init(params):
        def per(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree.map(per, params)}

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)
        lr_t = lr_fn(step)

        def upd(g, st, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(p.shape):
                vr = beta * st["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * st["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(axis=-1,
                                               keepdims=True)[..., None],
                                       eps))
                pre = g * jax.lax.rsqrt(denom + eps)
                new_st = {"vr": vr, "vc": vc}
            else:
                v = beta * st["v"] + (1 - beta) * g2
                pre = g * jax.lax.rsqrt(v + eps)
                new_st = {"v": v}
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(jnp.square(pre)) + 1e-12)
            pre = pre / jnp.maximum(1.0, rms / clip_threshold)
            new_p = p.astype(jnp.float32) - lr_t * (
                pre + weight_decay * p.astype(jnp.float32))
            return new_p.astype(p.dtype), new_st

        out = jax.tree_util.tree_map(
            upd, grads, state["f"], params,
            is_leaf=lambda x: isinstance(x, dict) and ("v" in x or "vr" in x))
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda o: isinstance(o, tuple))
        new_f = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda o: isinstance(o, tuple))
        return new_params, {"f": new_f}

    return Optimizer(init, update, "adafactor")


def make_optimizer(name: str, lr=None, total_steps: int = 10000) -> Optimizer:
    if name == "adamw":
        return adamw(lr or warmup_cosine(3e-4, 200, total_steps))
    if name == "adafactor":
        return adafactor(lr or warmup_cosine(1e-2, 200, total_steps))
    raise ValueError(f"unknown optimizer {name!r}")
