"""Cell lowering: build the (train|prefill|decode) program for one
(architecture × shape × mesh) and lower+compile it with ShapeDtypeStruct
inputs — no allocation ever happens; this is the multi-pod dry-run engine.

Returned artifacts per cell: the compiled object plus memory/cost analyses
and the HLO text for collective-bytes accounting (roofline/analysis.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch.shapes import SHAPES, ShapeCell, applicable
from repro.models import Model
from repro.sharding import rules
from repro.train import optimizer as opt_lib
from repro.train.step import TrainState, build_train_step_gspmd, _ns

PyTree = Any

# per-arch microbatch counts for train_4k (keeps live activations + logits
# within a 16 GB v5e during the batched step; tuned in §Perf)
TRAIN_MICROBATCHES = {
    "default": 8,
    "deepseek-v2-236b": 16,
    "nemotron-4-15b": 8,
}


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _tree_sds(tree: PyTree) -> PyTree:
    return jax.tree.map(lambda l: sds(l.shape, l.dtype), tree)


# ---------------------------------------------------------------------------
# input_specs — ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------

def input_specs(arch: str, shape_name: str) -> dict:
    """Shape/dtype stand-ins for the given cell (weak-type-correct,
    shardable, no device allocation)."""
    return _input_specs_cfg(configs.get(arch), SHAPES[shape_name])


def _input_specs_cfg(cfg, cell: ShapeCell) -> dict:
    model = Model(cfg)
    out: dict = {}
    if cell.kind == "train":
        out["tokens"] = sds((cell.global_batch, cell.seq_len + 1), jnp.int32)
        ctx = model.context_inputs(cell.global_batch)
        if ctx is not None:
            out["context"] = ctx
    elif cell.kind == "prefill":
        out["tokens"] = sds((cell.global_batch, cell.seq_len), jnp.int32)
        ctx = model.context_inputs(cell.global_batch)
        if ctx is not None:
            out["context"] = ctx
    else:  # decode
        out["token"] = sds((cell.global_batch,), jnp.int32)
        out["index"] = sds((), jnp.int32)
        out["cache"] = _tree_sds(jax.eval_shape(
            lambda: model.init_cache(cell.global_batch, cell.seq_len)))
        ctx = model.context_inputs(cell.global_batch)
        if ctx is not None:
            out["context"] = ctx
    return out


# ---------------------------------------------------------------------------
# cache sharding heuristics
# ---------------------------------------------------------------------------

def cache_specs(cache: PyTree, cfg, mesh: Mesh, batch: int) -> PyTree:
    """Decode-cache shardings: [stack?, B, S|W, heads?, d] — batch over DP,
    a heads/width-like dim over TP when divisible."""
    dp = rules.dp_axes(mesh)
    dp_total = 1
    for a in dp:
        dp_total *= mesh.shape[a]
    batch_ax = dp if batch % dp_total == 0 and batch > 1 else None
    tp_candidates = {cfg.n_kv_heads, cfg.n_heads, cfg.d_model // 64,
                     cfg.d_model, cfg.hybrid.lru_width or cfg.d_model}

    def one(path, leaf):
        ps = rules._path_str(path)
        stacked = ps.startswith("layers/")
        off = 1 if stacked else 0        # leading period-stack dim
        dims: list = [None] * len(leaf.shape)
        if len(leaf.shape) > off and leaf.shape[off] == batch:
            dims[off] = batch_ax
        for i in range(off + 2, len(leaf.shape)):
            d = leaf.shape[i]
            if d in tp_candidates and d % mesh.shape["model"] == 0:
                dims[i] = "model"
                break
        return P(*dims)

    return jax.tree_util.tree_map_with_path(one, cache)


# ---------------------------------------------------------------------------
# program builders per cell kind
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LoweredCell:
    arch: str
    shape: str
    mesh_desc: str
    lowered: Any
    args: tuple
    kind: str


def build_cell(arch: str, shape_name: str, mesh: Mesh, *,
               microbatches: Optional[int] = None,
               remat: Optional[str] = None,
               extra_config: Optional[dict] = None) -> LoweredCell:
    ok, reason = applicable(arch, shape_name)
    if not ok:
        raise ValueError(f"{arch}×{shape_name}: {reason}")
    cfg = configs.get(arch)
    overrides = dict(extra_config or {})
    if remat is not None:
        overrides["remat"] = remat
    if shape_name in ("prefill_32k", "decode_32k"):
        overrides.setdefault("max_seq", 32768)
    if shape_name == "long_500k":
        overrides.setdefault("max_seq", 524288)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    model = Model(cfg)
    cell = SHAPES[shape_name]
    ins = _input_specs_cfg(cfg, cell)

    if cell.kind == "train":
        mb = microbatches or TRAIN_MICROBATCHES.get(
            arch, TRAIN_MICROBATCHES["default"])
        optimizer = opt_lib.make_optimizer(cfg.optimizer)
        step = build_train_step_gspmd(model, optimizer, mesh,
                                      microbatches=mb, donate=True)
        state_sds = jax.eval_shape(
            lambda k: TrainState(model.init(k),
                                 optimizer.init(model.param_shapes()),
                                 jnp.zeros((), jnp.int32), None),
            jax.random.key(0))
        batch = {"tokens": ins["tokens"]}
        if "context" in ins:
            batch["context"] = ins["context"]
        lowered = step.lower(state_sds, batch)
        return LoweredCell(arch, shape_name, _mesh_desc(mesh), lowered,
                           (state_sds, batch), "train")

    pspecs = rules.param_specs(model.param_shapes(), mesh,
                               cfg.parallelism)
    pshard = _ns(mesh, pspecs)
    param_sds = _tree_sds(model.param_shapes())

    from repro.sharding.act import activation_sharding

    if cell.kind == "prefill":
        def prefill_fn(params, tokens, context=None):
            with activation_sharding(mesh, parallelism=cfg.parallelism):
                hidden, _ = model.forward(params, tokens, context=context)
                logits = model.logits(params, hidden[:, -1:, :])
                return logits[:, 0, :]

        bshard = NamedSharding(mesh, rules.batch_spec(mesh, extra_dims=1))
        args = [param_sds, ins["tokens"]]
        in_sh = [pshard, bshard]
        if "context" in ins:
            args.append(ins["context"])
            in_sh.append(NamedSharding(mesh,
                                       rules.batch_spec(mesh, extra_dims=2)))
        lowered = jax.jit(prefill_fn, in_shardings=tuple(in_sh)).lower(*args)
        return LoweredCell(arch, shape_name, _mesh_desc(mesh), lowered,
                           tuple(args), "prefill")

    # decode
    cshard = _ns(mesh, cache_specs(ins["cache"], cfg, mesh,
                                   cell.global_batch))
    dp_total = 1
    for a in rules.dp_axes(mesh):
        dp_total *= mesh.shape[a]
    tok_spec = rules.dp_axes(mesh) if cell.global_batch % dp_total == 0 \
        and cell.global_batch > 1 else None
    tshard = NamedSharding(mesh, P(tok_spec))

    def decode_fn(params, token, cache, index, context=None):
        with activation_sharding(mesh, parallelism=cfg.parallelism):
            return model.decode_step(params, token, cache, index,
                                     context=context)

    args = [param_sds, ins["token"], ins["cache"], ins["index"]]
    in_sh = [pshard, tshard, cshard, NamedSharding(mesh, P())]
    if "context" in ins:
        args.append(ins["context"])
        in_sh.append(NamedSharding(mesh, P(tok_spec, None, None)))
    lowered = jax.jit(decode_fn, in_shardings=tuple(in_sh),
                      donate_argnums=(2,)).lower(*args)
    return LoweredCell(arch, shape_name, _mesh_desc(mesh), lowered,
                       tuple(args), "decode")


def _mesh_desc(mesh: Mesh) -> str:
    return "x".join(f"{mesh.shape[a]}{a[0]}" for a in mesh.axis_names)


# ---------------------------------------------------------------------------
# linear probes — exact per-device cost recovery.
#
# XLA's cost_analysis counts a While body ONCE regardless of trip count, so
# the full (rolled-scan) artifact under-reports flops/bytes/collectives.
# HLO costs are exactly linear in (#periods, #microbatches) for these
# programs, so we lower small *unrolled* probes at (1,2) periods × (1,2)
# microbatches and solve for the per-period / per-microbatch / per-step
# components; the full-cell cost is their exact composition.  The probes
# ARE compiled dry-runs of the same program family (same sharding, same
# kernels) — only their loop structure is inlined.
# ---------------------------------------------------------------------------

ANALYSIS_OVERRIDES = dict(scan_layers=False, analysis_unroll=True,
                          attn_chunk=4096, wkv_chunk=512)


def probe_layer_counts(cfg) -> tuple[int, int, int]:
    """(period_len, rem_len, n_periods_full) for the probe ladder."""
    from repro.models.transformer import _period_of
    period, n_periods, rem = _period_of(cfg)
    return len(period), len(rem), n_periods


def build_probe(arch: str, shape_name: str, mesh: Mesh, *,
                periods: int, microbatches: int = 1,
                extra_config: Optional[dict] = None) -> LoweredCell:
    cfg0 = configs.get(arch)
    plen, rlen, _ = probe_layer_counts(cfg0)
    cell = SHAPES[shape_name]
    overrides = dict(ANALYSIS_OVERRIDES)
    overrides.update(extra_config or {})
    overrides["n_layers"] = rlen + periods * plen
    if cell.kind == "train":
        mb_cell = TRAIN_MICROBATCHES.get(arch, TRAIN_MICROBATCHES["default"])
        probe_mb_batch = cell.global_batch // mb_cell
        probe_batch = probe_mb_batch * microbatches
        # shrink the shape cell for the probe: same seq, smaller batch
        probe_cell = dataclasses.replace(cell, global_batch=probe_batch)
        return _build_with_cell(arch, shape_name, probe_cell, mesh,
                                overrides, microbatches)
    return _build_with_cell(arch, shape_name, cell, mesh, overrides, 1)


def _build_with_cell(arch, shape_name, cell, mesh, overrides, microbatches):
    """build_cell with an overridden ShapeCell (probe machinery)."""
    import repro.launch.cells as me
    orig = SHAPES[shape_name]
    try:
        SHAPES[shape_name] = cell
        return build_cell(arch, shape_name, mesh,
                          microbatches=microbatches,
                          extra_config=overrides)
    finally:
        SHAPES[shape_name] = orig


def compose_probe_costs(costs: dict, *, n_periods: int,
                        mb_cell: int, kind: str) -> dict:
    """Solve the linear system from probe costs and compose the full cell.

    ``costs``: {(periods, mb): {metric: value}}.  For serve kinds only
    (1,1) and (2,1) are needed; train adds (1,2) and (2,2).

      P(p, m) = O + m·E + p·(m·Lmb + Lstep)
    """
    out = {}
    metrics = costs[(1, 1)].keys()
    for met in metrics:
        p11 = costs[(1, 1)][met]
        p21 = costs[(2, 1)][met]
        if kind == "train":
            p12 = costs[(1, 2)][met]
            p22 = costs[(2, 2)][met]
            l_mb = (p22 - p12) - (p21 - p11)
            l_step = (p21 - p11) - l_mb
            e_mb = p12 - p11 - l_mb      # P12 - P11 = E + Lmb
            o = p11 - e_mb - l_mb - l_step
            total = (mb_cell * e_mb + n_periods * (mb_cell * l_mb + l_step)
                     + o)
        else:
            l_step = p21 - p11
            o = p11 - l_step
            total = o + n_periods * l_step
        out[met] = max(total, 0.0)
    return out
