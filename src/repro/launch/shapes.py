"""The assigned input-shape cells and their applicability matrix.

LM transformer shapes (seq_len × global_batch):
    train_4k      4,096 × 256    — training        (lowers train_step)
    prefill_32k  32,768 × 32     — inference prefill
    decode_32k   32,768 × 128    — one-token decode w/ 32k KV cache
    long_500k   524,288 × 1      — long-context decode (sub-quadratic only)

``long_500k`` requires sub-quadratic attention: run for recurrentgemma-9b
(local window + RG-LRU) and rwkv6-1.6b (O(1) state); SKIP(full-attention)
for the 8 dense-attention archs (DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import dataclasses

from repro import configs


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def applicable(arch: str, shape: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped)."""
    cfg = configs.get(arch)
    if shape == "long_500k" and not cfg.subquadratic:
        return False, "SKIP(full-attention)"
    return True, ""


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in configs.names() for s in SHAPES]


def runnable_cells() -> list[tuple[str, str]]:
    return [(a, s) for a, s in all_cells() if applicable(a, s)[0]]
