"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module does not touch jax device state — the dry-run sets
``--xla_force_host_platform_device_count=512`` before any jax init and only
then calls these.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 2, model: int = 4) -> jax.sharding.Mesh:
    """Small mesh over host devices (tests / examples)."""
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
