import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the
# device count at first backend init).  Everything below may import jax.

"""Multi-pod dry-run: lower + compile every (arch × shape) on the
production meshes and record memory/cost/collective analyses.

    python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --multi-pod
    python -m repro.launch.dryrun --all --out results/dryrun   # orchestrates
                                                               # subprocesses

Single-cell mode prints ``memory_analysis()`` / ``cost_analysis()`` (proving
the program fits and giving the roofline terms) and writes a JSON record.
``--all`` runs each cell in its own subprocess so one pathological cell
cannot take down the sweep, and aggregates per-cell JSONs.
"""

import argparse
import json
import subprocess
import sys
import time
import traceback


def _probe_costs(compiled) -> dict:
    from repro.roofline import analysis
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = analysis.collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "hbm_bytes": float(cost.get("bytes accessed", 0.0)),
            "coll_bytes": float(coll["total_bytes"])}


def run_cell(arch: str, shape: str, multi_pod: bool, out_path: str | None,
             *, microbatches=None, remat=None, skip_probes=False,
             extra_config=None) -> dict:
    import jax
    from repro.launch import cells
    from repro.launch.mesh import make_production_mesh
    from repro.launch.shapes import SHAPES
    from repro.roofline import analysis
    from repro import configs as cfgs

    # ---- 1. full production artifact (rolled scans): proves the sharding
    # is coherent at 256/512 chips and that memory fits.
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    built = cells.build_cell(arch, shape, mesh, microbatches=microbatches,
                             remat=remat, extra_config=extra_config)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = built.lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    print(f"== {arch} × {shape} × {built.mesh_desc} ==")
    print("memory_analysis:", mem)                 # proves it fits
    cost = compiled.cost_analysis()
    print("cost_analysis (rolled): flops={flops:.3e} bytes={ba:.3e}".format(
        flops=float(cost.get("flops", 0)),
        ba=float(cost.get("bytes accessed", 0))))

    # ---- 2. linear probes (unrolled): exact per-device roofline counts.
    # Single-pod only (the roofline table is single-pod per the spec);
    # multi-pod runs are the sharding proof, not the perf model.
    record: dict = {
        "arch": arch, "shape": shape, "mesh": built.mesh_desc,
        "multi_pod": multi_pod,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": {
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
        },
        "status": "ok",
    }

    if not multi_pod and not skip_probes:
        cfg = cfgs.get(arch)
        plen, rlen, n_periods = cells.probe_layer_counts(cfg)
        kind = SHAPES[shape].kind
        mb_cell = (microbatches or cells.TRAIN_MICROBATCHES.get(
            arch, cells.TRAIN_MICROBATCHES["default"])) \
            if kind == "train" else 1
        ladder = [(1, 1), (2, 1)] + ([(1, 2), (2, 2)]
                                     if kind == "train" else [])
        costs = {}
        for periods, mb in ladder:
            tp = time.time()
            probe = cells.build_probe(arch, shape, mesh, periods=periods,
                                      microbatches=mb,
                                      extra_config=extra_config)
            pc = probe.lowered.compile()
            costs[(periods, mb)] = _probe_costs(pc)
            print(f"probe(p={periods}, mb={mb}): "
                  f"flops={costs[(periods, mb)]['flops']:.3e} "
                  f"({time.time() - tp:.1f}s)")
            del probe, pc
        composed = cells.compose_probe_costs(
            costs, n_periods=n_periods, mb_cell=mb_cell, kind=kind)
        chips = 256
        roof = analysis.Roofline(
            arch=arch, shape=shape, mesh=built.mesh_desc, chips=chips,
            flops=composed["flops"], hbm_bytes=composed["hbm_bytes"],
            coll_bytes=composed["coll_bytes"],
            coll_detail={"probe_raw": {f"{p}x{m}": c
                                       for (p, m), c in costs.items()}},
            model_flops=analysis.model_flops_for(arch, shape),
            per_device_bytes=record["memory_analysis"]["temp_bytes"])
        record.update(roof.to_dict())
        record["probe_composition"] = {
            "n_periods": n_periods, "period_len": plen, "rem_len": rlen,
            "mb_cell": mb_cell}
        print(f"bottleneck={record['bottleneck']} "
              f"t_comp={record['t_compute_s']:.4f}s "
              f"t_mem={record['t_memory_s']:.4f}s "
              f"t_coll={record['t_collective_s']:.4f}s "
              f"useful={record['useful_flops_ratio']:.3f}")

    if out_path:
        with open(out_path, "w") as f:
            json.dump(record, f, indent=1)
    print(f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)")
    return record


def run_all(out_dir: str, multi_pod_too: bool = True,
            timeout: int = 2400) -> None:
    from repro.launch.shapes import all_cells, applicable

    os.makedirs(out_dir, exist_ok=True)
    results = []
    jobs = []
    for arch, shape in all_cells():
        ok, reason = applicable(arch, shape)
        meshes = [False] + ([True] if multi_pod_too else [])
        if not ok:
            for mp in meshes:
                results.append({"arch": arch, "shape": shape,
                                "multi_pod": mp, "status": reason})
            continue
        for mp in meshes:
            jobs.append((arch, shape, mp))

    for arch, shape, mp in jobs:
        tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}"
        out_path = os.path.join(out_dir, tag + ".json")
        if os.path.exists(out_path):
            with open(out_path) as f:
                results.append(json.load(f))
            print(f"[cached] {tag}")
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--out", out_path]
        if mp:
            cmd.append("--multi-pod")
        print(f"[run] {tag}", flush=True)
        try:
            proc = subprocess.run(cmd, capture_output=True, text=True,
                                  timeout=timeout)
            if proc.returncode == 0 and os.path.exists(out_path):
                with open(out_path) as f:
                    results.append(json.load(f))
            else:
                err = (proc.stderr or "")[-2000:]
                results.append({"arch": arch, "shape": shape,
                                "multi_pod": mp, "status": "FAIL",
                                "error": err})
                print(f"[FAIL] {tag}\n{err}", flush=True)
        except subprocess.TimeoutExpired:
            results.append({"arch": arch, "shape": shape, "multi_pod": mp,
                            "status": "TIMEOUT"})
            print(f"[TIMEOUT] {tag}", flush=True)

    with open(os.path.join(out_dir, "summary.json"), "w") as f:
        json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results if r.get("status") == "ok")
    n_skip = sum(1 for r in results if str(r.get("status", "")).startswith("SKIP"))
    n_bad = len(results) - n_ok - n_skip
    print(f"\n== dry-run sweep: {n_ok} ok, {n_skip} skipped, {n_bad} failed "
          f"of {len(results)} cell×mesh combos ==")
    if n_bad:
        sys.exit(1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--extra", default=None,
                    help="JSON dict of ModelConfig overrides "
                         "(perf-iteration lever)")
    args = ap.parse_args()
    if args.all:
        run_all(args.out or "results/dryrun",
                multi_pod_too=not args.single_pod_only)
    else:
        try:
            extra = json.loads(args.extra) if args.extra else None
            run_cell(args.arch, args.shape, args.multi_pod, args.out,
                     microbatches=args.microbatches, remat=args.remat,
                     extra_config=extra)
        except Exception:
            traceback.print_exc()
            sys.exit(1)


if __name__ == "__main__":
    main()
