"""Deterministic synthetic data pipeline (host-sharded, resumable).

Production shape without external datasets: a seeded ground-truth bigram
language (fixed transition table) generates token streams, so training has
real learnable structure (loss descends toward the bigram entropy) and the
e2e example can *prove* optimization works.  Batches are a pure function of
(seed, step, host_id) — resuming from a checkpoint reproduces the exact
stream, and each host of a multi-host pod draws disjoint shards (the
host_id/num_hosts split below is what a 1000-node launcher wires in).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Iterator, Optional

import jax
import numpy as np

PyTree = Any


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branching: int = 8       # out-degree of the bigram graph
    host_id: int = 0
    num_hosts: int = 1


class BigramStream:
    """Seeded bigram language; batches indexed by absolute step."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        root = np.random.default_rng(cfg.seed)
        # sparse-ish transition table: each token can be followed by
        # `branching` successors with dirichlet weights
        self.succ = root.integers(0, cfg.vocab,
                                  (cfg.vocab, cfg.branching)).astype(np.int32)
        self.probs = root.dirichlet(np.ones(cfg.branching),
                                    size=cfg.vocab).astype(np.float32)
        assert cfg.global_batch % cfg.num_hosts == 0
        self.host_batch = cfg.global_batch // cfg.num_hosts

    def entropy(self) -> float:
        """Per-token entropy of the generating process (nats) — the loss
        floor the model should approach."""
        h = -(self.probs * np.log(self.probs + 1e-9)).sum(axis=1)
        return float(h.mean())

    def batch(self, step: int) -> dict:
        """{tokens: [host_batch, seq_len + 1]} for this host at `step`."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, step, cfg.host_id, 0xACE5))
        b, t = self.host_batch, cfg.seq_len + 1
        toks = np.empty((b, t), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab, b)
        for i in range(1, t):
            u = rng.random((b, 1))
            cum = np.cumsum(self.probs[toks[:, i - 1]], axis=1)
            choice = (u < cum).argmax(axis=1)
            toks[:, i] = self.succ[toks[:, i - 1], choice]
        return {"tokens": toks}

    def iter_from(self, step: int) -> Iterator[dict]:
        while True:
            yield self.batch(step)
            step += 1


def synthetic_context(step: int, batch: int, tokens: int, d_model: int,
                      seed: int = 0) -> np.ndarray:
    """Stub modality embeddings (whisper frames / vision patches)."""
    rng = np.random.default_rng((seed, step, 0xC0DE))
    return rng.standard_normal((batch, tokens, d_model)).astype(np.float32)
