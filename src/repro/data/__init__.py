"""repro.data — deterministic synthetic data pipeline."""
