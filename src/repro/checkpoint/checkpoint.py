"""Sharded, atomic, resumable checkpoints (+ elastic reshard-on-restore).

Layout:
    <dir>/step_000120/
        manifest.json        tree structure, shapes, dtypes, step, mesh meta
        leaf_00000.npy ...   one file per pytree leaf
    <dir>/LATEST             atomic pointer (renamed into place)

Fault-tolerance contract:
  * saves are atomic (write to tmp dir, fsync manifest, rename) — a crash
    mid-save never corrupts the restore path;
  * `restore` takes target shardings, so a checkpoint written on one mesh
    restores onto another (elastic rescale: the global arrays are mesh-
    agnostic, jax re-shards on device_put);
  * integrity: every leaf carries a checksum in the manifest; restore
    verifies and refuses silently-corrupt shards (the Type 0 "CRC on the
    wire" idea applied to storage);
  * keep_last trims old steps only after LATEST points at the new one.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

PyTree = Any

# numpy can't serialize ml_dtypes natively; store them as raw integer views
# with the logical dtype recorded in the manifest.
_EXOTIC = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _to_storable(arr: np.ndarray) -> tuple[np.ndarray, str]:
    name = arr.dtype.name
    if name in _EXOTIC:
        return arr.view(_EXOTIC[name][1]), name
    return arr, name


def _from_storable(arr: np.ndarray, logical: str) -> np.ndarray:
    if logical in _EXOTIC:
        return arr.view(_EXOTIC[logical][0])
    return arr


def _leaf_paths(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return flat, treedef


def _path_str(path) -> str:
    return jax.tree_util.keystr(path)


def _checksum(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()[:16]


def save(ckpt_dir: str, step: int, tree: PyTree, *, keep_last: int = 3,
         extra: Optional[dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat, treedef = _leaf_paths(tree)
    step_name = f"step_{step:08d}"
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=f".tmp_{step_name}_")
    manifest = {"step": step, "leaves": [], "extra": extra or {},
                "treedef": str(treedef)}
    try:
        for i, (path, leaf) in enumerate(flat):
            arr = np.asarray(leaf)
            stored, logical = _to_storable(arr)
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), stored)
            manifest["leaves"].append({
                "path": _path_str(path), "file": fname,
                "shape": list(arr.shape), "dtype": logical,
                "checksum": _checksum(stored)})
        mpath = os.path.join(tmp, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        final = os.path.join(ckpt_dir, step_name)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # atomic LATEST pointer
    latest_tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(step_name)
        f.flush()
        os.fsync(f.fileno())
    os.rename(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    _trim(ckpt_dir, keep_last)
    return final


def _trim(ckpt_dir: str, keep_last: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    ptr = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    if not os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, like: PyTree, *, step: Optional[int] = None,
            shardings: Optional[PyTree] = None,
            verify: bool = True) -> tuple[PyTree, int, dict]:
    """Restore into the structure of ``like``; optionally device_put with
    ``shardings`` (a pytree of NamedSharding) — this is the elastic path."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    flat_like, treedef = _leaf_paths(like)
    by_path = {m["path"]: m for m in manifest["leaves"]}
    leaves = []
    shard_flat = None
    if shardings is not None:
        shard_flat = [s for _, s in _leaf_paths(shardings)[0]]
    for i, (path, leaf_like) in enumerate(flat_like):
        ps = _path_str(path)
        meta = by_path.get(ps)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {ps}")
        raw = np.load(os.path.join(d, meta["file"]))
        if verify and _checksum(raw) != meta["checksum"]:
            raise IOError(f"checksum mismatch for {ps} — corrupt shard")
        arr = _from_storable(raw, meta["dtype"])
        if list(arr.shape) != list(leaf_like.shape):
            raise ValueError(
                f"shape mismatch for {ps}: ckpt {arr.shape} vs "
                f"target {leaf_like.shape}")
        if shard_flat is not None:
            leaves.append(jax.device_put(arr, shard_flat[i]))
        else:
            # stay host-side & uncommitted: the next jitted step's
            # in_shardings will place the array on the current mesh —
            # this is what makes restore mesh-agnostic (elastic).
            leaves.append(arr)
        del raw
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, manifest["step"], manifest.get("extra", {})
