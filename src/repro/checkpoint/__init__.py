"""repro.checkpoint — atomic sharded checkpoints with elastic restore."""
