"""Model-drift watchdog: measured vs ``plan_stage_time``, online.

The paper's software loop is evaluate → map → refine (§V): the analytic
network model plans, recordings evaluate, and when the two diverge the
model must be *re-fitted* from the recordings (:mod:`repro.tune.fit`).
This module is the tripwire between those phases.

A :class:`DriftWatchdog` consumes recorded stage spans (simulator or
instrumented executor — the shared :class:`~repro.obs.spans.StageSpan`
schema), tracks the geometric-mean measured/model ratio per
``(kind, axis, schedule, bytes-bucket)`` key, and flags keys whose ratio
drifts past a threshold in either direction.  When any key is flagged it
emits a ``drift.refit_recommended`` event into the metrics recorder and
:meth:`DriftWatchdog.refit` hands the accumulated samples straight to
:func:`repro.tune.fit.fit_traces` — closing the loop.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

from repro.obs import metrics as _metrics

# a key must accumulate this many priced samples before it can fire —
# one noisy stage is a measurement, not a drift
DEFAULT_MIN_SAMPLES = 2
DEFAULT_THRESHOLD = 1.5


def bytes_bucket(nbytes: Optional[int]) -> int:
    """Log2 size bucket (0 for unknown payloads): stages within one
    bucket share a bandwidth regime, so their ratios pool."""
    if not nbytes or nbytes <= 0:
        return 0
    return max(int(nbytes).bit_length(), 1)


@dataclasses.dataclass(frozen=True)
class DriftAlert:
    """One drifted key: the pooled ratio and how far past threshold."""

    kind: str
    axis: str
    schedule: str
    bucket: int                    # log2 bytes bucket
    ratio: float                   # geometric-mean measured/model
    n: int                         # samples pooled

    @property
    def drift(self) -> float:
        """Symmetric drift magnitude: ``max(ratio, 1/ratio)``."""
        return max(self.ratio, 1.0 / self.ratio)

    def describe(self) -> str:
        return (f"{self.kind}@{self.axis or '-'}"
                f"[{self.schedule or '-'}, ~2^{self.bucket}B]: "
                f"meas/model x{self.ratio:.2f} over {self.n} stages")


@dataclasses.dataclass
class _Cell:
    log_sum: float = 0.0
    n: int = 0

    @property
    def ratio(self) -> float:
        return math.exp(self.log_sum / self.n) if self.n else 1.0


class DriftWatchdog:
    """Online measured-vs-model ratio tracking over recorded runs.

    ``threshold`` is symmetric: a key fires when its pooled ratio leaves
    ``[1/threshold, threshold]`` with at least ``min_samples`` samples.
    ``recorder`` defaults to the process recorder at call time, so the
    watchdog's counters/events land wherever the run's telemetry does.
    """

    def __init__(self, threshold: float = DEFAULT_THRESHOLD,
                 min_samples: int = DEFAULT_MIN_SAMPLES,
                 recorder: Optional[_metrics.Recorder] = None):
        if threshold <= 1.0:
            raise ValueError(f"threshold must be > 1, got {threshold}")
        self.threshold = float(threshold)
        self.min_samples = int(min_samples)
        self._recorder = recorder
        self._cells: dict[tuple, _Cell] = {}
        self._samples: list[tuple] = []    # (plan, topo, trace) for refit

    def _rec(self) -> _metrics.Recorder:
        return self._recorder if self._recorder is not None \
            else _metrics.RECORDER

    # -- accumulation --------------------------------------------------------

    def observe(self, plan, topo, trace) -> int:
        """Fold one recorded run in; returns the number of priced spans.

        ``trace`` is a :class:`~repro.tune.trace.ProgramTrace` (or a bare
        span sequence) recorded from ``plan``; spans whose stage index or
        kind doesn't match the plan, or whose payload the model cannot
        price, are skipped — cost what the model can see.
        """
        from repro.core import netmodel

        rec = self._rec()
        spans = getattr(trace, "stages", trace)
        priced = 0
        for ts in spans:
            i = getattr(ts, "stage", -1)
            if not 0 <= i < len(plan.stages):
                continue
            st = plan.stages[i]
            if getattr(st, "kind", "") != ts.kind:
                continue
            model = netmodel.plan_stage_time(st, topo)
            meas = ts.duration
            if not model or meas <= 0.0:
                continue
            key = (ts.kind, ts.axis, ts.schedule,
                   bytes_bucket(getattr(ts, "bytes", None)))
            cell = self._cells.setdefault(key, _Cell())
            cell.log_sum += math.log(meas / model)
            cell.n += 1
            priced += 1
        if priced:
            self._samples.append((plan, topo, trace))
            rec.count("drift.observations", priced)
        return priced

    # -- verdicts ------------------------------------------------------------

    def ratios(self) -> dict[tuple, tuple[float, int]]:
        """``{key: (geometric-mean ratio, n)}`` for every tracked key."""
        return {k: (c.ratio, c.n) for k, c in self._cells.items()}

    def alerts(self) -> list[DriftAlert]:
        """Keys past threshold, worst drift first."""
        out = []
        for (kind, axis, schedule, bucket), c in self._cells.items():
            if c.n < self.min_samples:
                continue
            r = c.ratio
            if max(r, 1.0 / r) > self.threshold:
                out.append(DriftAlert(kind, axis, schedule, bucket,
                                      ratio=r, n=c.n))
        out.sort(key=lambda a: -a.drift)
        return out

    def refit_recommended(self) -> bool:
        """True when any key drifted — and says so into the recorder
        (``drift.flagged`` counts, one ``drift.refit_recommended`` event
        naming the worst offender)."""
        alerts = self.alerts()
        if not alerts:
            return False
        rec = self._rec()
        rec.count("drift.flagged", len(alerts))
        worst = alerts[0]
        rec.event("drift.refit_recommended",
                  worst=worst.describe(), ratio=worst.ratio,
                  keys=len(alerts), threshold=self.threshold)
        return True

    def refit(self, samples: Optional[Sequence] = None, **fit_kw):
        """Run :func:`repro.tune.fit.fit_traces` over the accumulated
        ``(plan, topo, trace)`` samples (or explicit ones) — the re-fit
        the watchdog recommends.  Returns the :class:`~repro.tune.fit.
        NetFit`."""
        from repro.tune import fit as _fit

        use = list(samples) if samples is not None else list(self._samples)
        if not use:
            raise ValueError("no recorded samples to re-fit from")
        self._rec().count("drift.refits")
        return _fit.fit_traces(use, **fit_kw)

    def report(self) -> str:
        """Readable drift table (every key, flagged ones marked)."""
        lines = [f"drift watchdog: threshold x{self.threshold:.2f}, "
                 f"{len(self._cells)} keys, "
                 f"{sum(c.n for c in self._cells.values())} samples"]
        flagged = {(a.kind, a.axis, a.schedule, a.bucket)
                   for a in self.alerts()}
        for key in sorted(self._cells, key=str):
            kind, axis, schedule, bucket = key
            c = self._cells[key]
            mark = " <-- DRIFT" if key in flagged else ""
            lines.append(
                f"  {kind}@{axis or '-'}[{schedule or '-'}, "
                f"~2^{bucket}B]: x{c.ratio:.2f} (n={c.n}){mark}")
        if flagged:
            lines.append("  re-fit recommended "
                         "(repro.tune.fit.fit_traces / watchdog.refit())")
        return "\n".join(lines)
