"""Model-drift watchdog: measured vs ``plan_stage_time``, online.

The paper's software loop is evaluate → map → refine (§V): the analytic
network model plans, recordings evaluate, and when the two diverge the
model must be *re-fitted* from the recordings (:mod:`repro.tune.fit`).
This module is the tripwire between those phases.

A :class:`DriftWatchdog` consumes recorded stage spans (simulator or
instrumented executor — the shared :class:`~repro.obs.spans.StageSpan`
schema), tracks the geometric-mean measured/model ratio per
``(kind, axis, schedule, bytes-bucket)`` key, and flags keys whose ratio
drifts past a threshold in either direction.  When any key is flagged it
emits a ``drift.refit_recommended`` event into the metrics recorder and
:meth:`DriftWatchdog.refit` hands the accumulated samples straight to
:func:`repro.tune.fit.fit_traces` — closing the loop.

Not every divergence means the *model* is stale: a sick rank or a
degraded link drifts the measurements too, and re-fitting the global
model to a local fault would poison it.  :meth:`DriftWatchdog.classify`
separates the cases from two extra signals — per-rank span pools
(:meth:`observe_ranks`, each rank's completion time against the peer
median: a straggler pools high, a dead rank pools vanishingly low, a
uniform model shift pools at 1 for every rank) and the per-axis spread
of the flagged keys (one axis drifted while another stays quiet = that
*link*, not the model).  :meth:`refit_recommended` then stays quiet on
rank-/link-local faults (``drift.rank_local`` / ``drift.link_local``
events instead), recommending a re-fit only for global drift.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

from repro.obs import metrics as _metrics

# a key must accumulate this many priced samples before it can fire —
# one noisy stage is a measurement, not a drift
DEFAULT_MIN_SAMPLES = 2
DEFAULT_THRESHOLD = 1.5


def bytes_bucket(nbytes: Optional[int]) -> int:
    """Log2 size bucket (0 for unknown payloads): stages within one
    bucket share a bandwidth regime, so their ratios pool."""
    if not nbytes or nbytes <= 0:
        return 0
    return max(int(nbytes).bit_length(), 1)


@dataclasses.dataclass(frozen=True)
class DriftAlert:
    """One drifted key: the pooled ratio and how far past threshold."""

    kind: str
    axis: str
    schedule: str
    bucket: int                    # log2 bytes bucket
    ratio: float                   # geometric-mean measured/model
    n: int                         # samples pooled

    @property
    def drift(self) -> float:
        """Symmetric drift magnitude: ``max(ratio, 1/ratio)``."""
        return max(self.ratio, 1.0 / self.ratio)

    def describe(self) -> str:
        return (f"{self.kind}@{self.axis or '-'}"
                f"[{self.schedule or '-'}, ~2^{self.bucket}B]: "
                f"meas/model x{self.ratio:.2f} over {self.n} stages")


@dataclasses.dataclass(frozen=True)
class DriftVerdict:
    """What a divergence *is*: model stale, rank sick, or link degraded.

    ``verdict`` is one of ``"quiet"`` (nothing flagged), ``"rank"``
    (specific ranks deviate from their peers — mask them, don't refit),
    ``"link"`` (specific axes' keys drift while other observed axes stay
    quiet — degrade that tier, don't refit), ``"global"`` (every signal
    shifted together — the model is stale, refit).
    """

    verdict: str
    ranks: tuple[int, ...] = ()
    axes: tuple[str, ...] = ()
    ratio: float = 1.0              # worst pooled ratio behind the verdict

    @property
    def local(self) -> bool:
        return self.verdict in ("rank", "link")

    def describe(self) -> str:
        where = ""
        if self.ranks:
            where = f" ranks={list(self.ranks)}"
        if self.axes:
            where += f" axes={list(self.axes)}"
        return f"{self.verdict}{where} (x{self.ratio:.2f})"


@dataclasses.dataclass
class _Cell:
    log_sum: float = 0.0
    n: int = 0

    @property
    def ratio(self) -> float:
        return math.exp(self.log_sum / self.n) if self.n else 1.0


class DriftWatchdog:
    """Online measured-vs-model ratio tracking over recorded runs.

    ``threshold`` is symmetric: a key fires when its pooled ratio leaves
    ``[1/threshold, threshold]`` with at least ``min_samples`` samples.
    ``recorder`` defaults to the process recorder at call time, so the
    watchdog's counters/events land wherever the run's telemetry does.
    """

    def __init__(self, threshold: float = DEFAULT_THRESHOLD,
                 min_samples: int = DEFAULT_MIN_SAMPLES,
                 recorder: Optional[_metrics.Recorder] = None):
        if threshold <= 1.0:
            raise ValueError(f"threshold must be > 1, got {threshold}")
        self.threshold = float(threshold)
        self.min_samples = int(min_samples)
        self._recorder = recorder
        self._cells: dict[tuple, _Cell] = {}
        self._samples: list[tuple] = []    # (plan, topo, trace) for refit
        # rank → peer-relative _Cell (completion time vs run median):
        # the signal that separates "rank sick" from "model stale"
        self._rank_cells: dict[int, _Cell] = {}

    def _rec(self) -> _metrics.Recorder:
        return self._recorder if self._recorder is not None \
            else _metrics.RECORDER

    # -- accumulation --------------------------------------------------------

    def observe(self, plan, topo, trace) -> int:
        """Fold one recorded run in; returns the number of priced spans.

        ``trace`` is a :class:`~repro.tune.trace.ProgramTrace` (or a bare
        span sequence) recorded from ``plan``; spans whose stage index or
        kind doesn't match the plan, or whose payload the model cannot
        price, are skipped — cost what the model can see.
        """
        from repro.core import netmodel

        rec = self._rec()
        spans = getattr(trace, "stages", trace)
        priced = 0
        for ts in spans:
            i = getattr(ts, "stage", -1)
            if not 0 <= i < len(plan.stages):
                continue
            st = plan.stages[i]
            if getattr(st, "kind", "") != ts.kind:
                continue
            model = netmodel.plan_stage_time(st, topo)
            meas = ts.duration
            if not model or meas <= 0.0:
                continue
            key = (ts.kind, ts.axis, ts.schedule,
                   bytes_bucket(getattr(ts, "bytes", None)))
            cell = self._cells.setdefault(key, _Cell())
            cell.log_sum += math.log(meas / model)
            cell.n += 1
            priced += 1
        if priced:
            self._samples.append((plan, topo, trace))
            rec.count("drift.observations", priced)
        return priced

    def observe_ranks(self, rank_times: Sequence[float]) -> int:
        """Fold one run's per-rank completion times (seconds) into the
        per-rank pools, each rank against the *peer median* of the run.

        The peer-relative framing is the classifier: a straggling rank
        pools high, a dead rank (frozen clock — it produced almost no
        spans) pools vanishingly low, while a stale model shifts every
        rank together and no rank deviates from the median at all.
        """
        ts = [max(float(t), 0.0) for t in rank_times]
        if len(ts) < 2:
            return 0
        ordered = sorted(ts)
        mid = len(ordered) // 2
        med = ordered[mid] if len(ordered) % 2 else \
            0.5 * (ordered[mid - 1] + ordered[mid])
        if med <= 0.0:
            return 0
        floor = 1e-6 * med            # dead rank: frozen at ~0 — clamp so
        #                               the log is finite but far past any
        #                               threshold
        for r, t in enumerate(ts):
            cell = self._rank_cells.setdefault(r, _Cell())
            cell.log_sum += math.log(max(t, floor) / med)
            cell.n += 1
        self._rec().count("drift.rank_observations", len(ts))
        return len(ts)

    def observe_report(self, report, topo=None) -> int:
        """Fold a :class:`~repro.cgra.simulate.SimReport` in directly:
        per-stage simulated/model ratios into the key pools (the report
        carries its own ``t_model`` predictions) and ``rank_t_end`` into
        the per-rank pools.  Returns the number of priced stages."""
        rec = self._rec()
        priced = 0
        for s in report.stages:
            if not s.t_model or s.t_sim <= 0.0:
                continue
            key = (s.kind, s.axis, s.schedule, 0)
            cell = self._cells.setdefault(key, _Cell())
            cell.log_sum += math.log(s.t_sim / s.t_model)
            cell.n += 1
            priced += 1
        if priced:
            rec.count("drift.observations", priced)
        if getattr(report, "rank_t_end", ()):
            self.observe_ranks(report.rank_t_end)
        return priced

    # -- verdicts ------------------------------------------------------------

    def ratios(self) -> dict[tuple, tuple[float, int]]:
        """``{key: (geometric-mean ratio, n)}`` for every tracked key."""
        return {k: (c.ratio, c.n) for k, c in self._cells.items()}

    def alerts(self) -> list[DriftAlert]:
        """Keys past threshold, worst drift first."""
        out = []
        for (kind, axis, schedule, bucket), c in self._cells.items():
            if c.n < self.min_samples:
                continue
            r = c.ratio
            if max(r, 1.0 / r) > self.threshold:
                out.append(DriftAlert(kind, axis, schedule, bucket,
                                      ratio=r, n=c.n))
        out.sort(key=lambda a: -a.drift)
        return out

    def rank_alerts(self) -> list[tuple[int, float, int]]:
        """``(rank, peer-relative ratio, n)`` for every rank whose pooled
        ratio left ``[1/threshold, threshold]`` — straggler (high) or
        dead (vanishingly low) — worst first."""
        out = []
        for r, c in self._rank_cells.items():
            if c.n < self.min_samples:
                continue
            ratio = c.ratio
            if max(ratio, 1.0 / ratio) > self.threshold:
                out.append((r, ratio, c.n))
        out.sort(key=lambda t: -max(t[1], 1.0 / t[1]))
        return out

    def classify(self) -> DriftVerdict:
        """Attribute the observed divergence: ``rank`` / ``link`` /
        ``global`` / ``quiet``.

        Rank verdicts win (a sick rank also skews stage pools); a link
        verdict needs at least one *other* observed axis staying quiet —
        with a single axis in evidence a uniform drift is
        indistinguishable from a stale model, so it stays ``global``.
        """
        ranks = self.rank_alerts()
        if ranks:
            worst = ranks[0]
            return DriftVerdict("rank",
                                ranks=tuple(r for r, _, _ in ranks),
                                ratio=worst[1])
        alerts = self.alerts()
        if not alerts:
            return DriftVerdict("quiet")
        drifted = tuple(sorted({a.axis for a in alerts}))
        quiet = {axis for (_, axis, _, _), c in self._cells.items()
                 if c.n >= self.min_samples} - set(drifted)
        if quiet:
            return DriftVerdict("link", axes=drifted,
                                ratio=alerts[0].ratio)
        return DriftVerdict("global", axes=drifted,
                            ratio=alerts[0].ratio)

    def refit_recommended(self) -> bool:
        """True when the divergence is *global* — a stale model.  A
        rank- or link-local verdict is reported
        (``drift.rank_local`` / ``drift.link_local``) but does NOT
        recommend a refit: fitting the shared model to one sick rank or
        one degraded link would poison it for the healthy fabric."""
        verdict = self.classify()
        rec = self._rec()
        if verdict.verdict == "rank":
            rec.count("drift.rank_local", len(verdict.ranks))
            rec.event("drift.rank_local", ranks=list(verdict.ranks),
                      ratio=verdict.ratio)
            return False
        if verdict.verdict == "link":
            rec.count("drift.link_local", len(verdict.axes))
            rec.event("drift.link_local", axes=list(verdict.axes),
                      ratio=verdict.ratio)
            return False
        alerts = self.alerts()
        if not alerts:
            return False
        rec.count("drift.flagged", len(alerts))
        worst = alerts[0]
        rec.event("drift.refit_recommended",
                  worst=worst.describe(), ratio=worst.ratio,
                  keys=len(alerts), threshold=self.threshold)
        return True

    def refit(self, samples: Optional[Sequence] = None, **fit_kw):
        """Run :func:`repro.tune.fit.fit_traces` over the accumulated
        ``(plan, topo, trace)`` samples (or explicit ones) — the re-fit
        the watchdog recommends.  Returns the :class:`~repro.tune.fit.
        NetFit`."""
        from repro.tune import fit as _fit

        use = list(samples) if samples is not None else list(self._samples)
        if not use:
            raise ValueError("no recorded samples to re-fit from")
        self._rec().count("drift.refits")
        return _fit.fit_traces(use, **fit_kw)

    def report(self) -> str:
        """Readable drift table (every key, flagged ones marked)."""
        lines = [f"drift watchdog: threshold x{self.threshold:.2f}, "
                 f"{len(self._cells)} keys, "
                 f"{sum(c.n for c in self._cells.values())} samples"]
        flagged = {(a.kind, a.axis, a.schedule, a.bucket)
                   for a in self.alerts()}
        for key in sorted(self._cells, key=str):
            kind, axis, schedule, bucket = key
            c = self._cells[key]
            mark = " <-- DRIFT" if key in flagged else ""
            lines.append(
                f"  {kind}@{axis or '-'}[{schedule or '-'}, "
                f"~2^{bucket}B]: x{c.ratio:.2f} (n={c.n}){mark}")
        for r, ratio, n in self.rank_alerts():
            lines.append(f"  rank {r}: x{ratio:.2g} vs peer median "
                         f"(n={n}) <-- {'DEAD?' if ratio < 1 else 'SICK'}")
        if flagged or self._rank_cells:
            verdict = self.classify()
            lines.append(f"  verdict: {verdict.describe()}")
            if verdict.verdict == "global":
                lines.append("  re-fit recommended "
                             "(repro.tune.fit.fit_traces / "
                             "watchdog.refit())")
        return "\n".join(lines)
