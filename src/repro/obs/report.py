"""RunReport — one recorded run, aggregated (text + JSON + timeline).

The surfacing layer over the other three obs pieces: a
:class:`RunReport` holds a recorded trace, the metrics snapshot of the
run, and the drift watchdog's verdict, renders them as text
(:meth:`RunReport.text`) or JSON (:meth:`RunReport.to_json`), and dumps
the Perfetto timeline (:meth:`RunReport.save_trace`).
``CompiledProgram.explain(trace=report)`` accepts it directly — the
mispredict columns render from the report's trace.
"""

from __future__ import annotations

import json
from typing import Any, Optional

from repro.obs import metrics as _metrics
from repro.obs import timeline as _timeline


class RunReport:
    """Aggregate view of one recorded run.

    ``trace`` is a :class:`~repro.tune.trace.ProgramTrace` (any source:
    sim / instrumented / stagewise); ``compiled`` (optional) unlocks the
    per-stage explain table and drift analysis against the program's
    topology; ``recorder`` contributes the counter snapshot.
    """

    def __init__(self, trace=None, *, compiled=None,
                 recorder: Optional[_metrics.Recorder] = None,
                 topology=None, name: Optional[str] = None):
        self.trace = trace
        self.compiled = compiled
        self.recorder = recorder
        self.topology = topology if topology is not None \
            else getattr(compiled, "topology", None)
        self.name = name or getattr(trace, "name", None) \
            or getattr(getattr(compiled, "source", None), "name", None) \
            or "run"
        self._watchdog = None

    # -- assembly ------------------------------------------------------------

    @classmethod
    def from_run(cls, compiled, trace,
                 recorder: Optional[_metrics.Recorder] = None,
                 threshold: float = 1.5) -> "RunReport":
        """Build the report for one (program, recording) pair and run the
        drift watchdog over it."""
        from repro.obs.drift import DriftWatchdog

        rep = cls(trace, compiled=compiled, recorder=recorder)
        wd = DriftWatchdog(threshold=threshold, recorder=recorder)
        if compiled is not None and trace is not None \
                and rep.topology is not None:
            wd.observe(compiled.plan, rep.topology, trace)
        rep._watchdog = wd
        return rep

    @property
    def watchdog(self):
        return self._watchdog

    def drift_alerts(self) -> list:
        return self._watchdog.alerts() if self._watchdog is not None else []

    # -- output --------------------------------------------------------------

    def timeline(self) -> dict:
        """The Perfetto/Chrome trace-event dict for this run."""
        if self.trace is None:
            raise ValueError("report has no trace to export")
        return _timeline.chrome_trace(
            self.trace, getattr(self.compiled, "plan", None),
            name=self.name)

    def save_trace(self, path) -> str:
        return _timeline.save(path, self.timeline())

    def text(self) -> str:
        """The run, readable: explain table (or trace summary), drift
        verdict, counter snapshot."""
        lines: list[str] = []
        if self.compiled is not None:
            lines.append(self.compiled.explain(trace=self.trace))
        elif self.trace is not None:
            tr = self.trace
            lines.append(f"trace {self.name!r} ({len(tr.stages)} stages, "
                         f"source={getattr(tr, 'source', 'unknown')}, "
                         f"t_end={tr.t_end * 1e6:.1f}us)")
            per_axis: dict[str, float] = {}
            for s in tr.stages:
                per_axis[s.axis or "(local)"] = \
                    per_axis.get(s.axis or "(local)", 0.0) + s.duration
            for ax in sorted(per_axis):
                lines.append(f"  {ax}: {per_axis[ax] * 1e6:.1f}us serial")
        else:
            lines.append(f"run {self.name!r}: no trace recorded")
        if self._watchdog is not None:
            lines.append(self._watchdog.report())
        if self.recorder is not None:
            lines.append("counters:")
            for ln in self.recorder.summary().splitlines():
                lines.append(f"  {ln}")
        return "\n".join(lines)

    def to_json(self) -> dict:
        """Machine-readable aggregate (JSON-able dict)."""
        out: dict[str, Any] = {"name": self.name}
        if self.trace is not None:
            tr = self.trace
            out["trace"] = {
                "source": getattr(tr, "source", "unknown"),
                "t_end": tr.t_end,
                "t_serial": sum(s.duration for s in tr.stages),
                "stages": len(tr.stages),
                "axes": dict(getattr(tr, "axes", {})),
            }
        if self._watchdog is not None:
            out["drift"] = {
                "threshold": self._watchdog.threshold,
                "alerts": [a.describe() for a in self.drift_alerts()],
                "refit_recommended": bool(self.drift_alerts()),
            }
        if self.recorder is not None:
            out["metrics"] = self.recorder.snapshot()
        if self.compiled is not None:
            out["program"] = {
                "stages": len(self.compiled.stages),
                "waves": self.compiled.plan.n_waves,
                "axes": self.compiled.axes(),
            }
        return out

    def save(self, path) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)
            f.write("\n")
        return str(path)
