"""``python -m repro.obs`` — render reports / dump Perfetto traces.

    python -m repro.obs report  RECORDED.jsonl [--json]
    python -m repro.obs trace   RECORDED.jsonl -o OUT.trace.json

``RECORDED.jsonl`` is a trace file written by
:func:`repro.tune.trace.save_jsonl` (any recorder: simulator,
instrumented executor, stagewise).  ``report`` prints the aggregate
(text, or the JSON payload with ``--json``); ``trace`` converts the
recording to Chrome trace-event JSON loadable at ui.perfetto.dev.
Multi-trace files emit one report (or one process lane) per trace.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Optional, Sequence


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Render a recorded run, or dump its Perfetto timeline.")
    sub = ap.add_subparsers(dest="cmd", required=True)

    ap_rep = sub.add_parser("report", help="print the run report")
    ap_rep.add_argument("jsonl", help="trace file (tune.save_jsonl)")
    ap_rep.add_argument("--json", action="store_true",
                        help="emit the JSON payload instead of text")

    ap_tr = sub.add_parser("trace", help="write Chrome trace-event JSON")
    ap_tr.add_argument("jsonl", help="trace file (tune.save_jsonl)")
    ap_tr.add_argument("-o", "--out", default=None,
                       help="output path (default: <input>.trace.json)")

    args = ap.parse_args(argv)

    from repro.obs import timeline
    from repro.obs.report import RunReport
    from repro.tune import trace as tune_trace

    try:
        traces = tune_trace.load_jsonl(args.jsonl)
    except (OSError, ValueError, KeyError) as e:
        print(f"{args.jsonl}: not a recording "
              f"(expected tune.save_jsonl output): {e}", file=sys.stderr)
        return 1
    if not traces:
        print(f"{args.jsonl}: no traces", file=sys.stderr)
        return 1

    if args.cmd == "report":
        payloads = []
        for tr in traces:
            rep = RunReport(tr)
            if args.json:
                payloads.append(rep.to_json())
            else:
                print(rep.text())
        if args.json:
            json.dump(payloads if len(payloads) > 1 else payloads[0],
                      sys.stdout, indent=2, sort_keys=True)
            print()
        return 0

    out = args.out or (args.jsonl + ".trace.json")
    if len(traces) == 1:
        timeline.save(out, traces[0])
    else:
        events: list[dict] = []
        for pid, tr in enumerate(traces):
            events += timeline.chrome_trace(tr, pid=pid)["traceEvents"]
        timeline.save(out, {"traceEvents": events,
                            "displayTimeUnit": "ms"})
    print(f"wrote {out} ({sum(len(t.stages) for t in traces)} stage "
          f"spans from {len(traces)} trace(s))", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
