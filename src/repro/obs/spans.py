"""The shared stage-record schema — one span type for every recorder.

A :class:`StageSpan` is one executed (or simulated) plan stage with
wall-clock boundaries plus the identity fields a replayer or exporter
matches on.  It is the single currency of the observability layer:

  * :func:`repro.core.executor.execute` appends ``StageSpan`` records to
    its ``instrument`` hook (one per executed stage);
  * :mod:`repro.tune.trace` *is* this schema — ``tune.trace.StageTrace``
    is an alias of :class:`StageSpan`, so obs spans and tune traces are
    the same objects, not parallel formats needing conversion;
  * :mod:`repro.obs.timeline` exports sequences of spans (or anything
    span-shaped, e.g. a simulator ``SimStage``) as Chrome trace-event
    JSON.

Kept dependency-free (stdlib only) so both ``repro.core`` and
``repro.tune`` can import it without a cycle.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class StageSpan:
    """One executed stage: identity + wall-clock boundaries.

    ``stage`` indexes the owning plan's stage list; ``bytes`` is the raw
    per-rank payload (``StageIR.bytes_in``) so a replayer can match this
    record against stages of a *different* candidate plan; ``t_ser`` is
    the injection-serialization share of the duration when the recorder
    knows it (the simulator does; wall-clock recorders leave it None and
    the replayer falls back to the calibrated per-tier overlap
    fraction).
    """

    stage: int
    kind: str
    axis: str = ""
    wave: int = 0
    t_start: float = 0.0
    t_end: float = 0.0
    bytes: Optional[int] = None
    schedule: str = ""
    placement: str = ""
    t_ser: Optional[float] = None

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start


def normalize(spans: Sequence[StageSpan]) -> tuple[StageSpan, ...]:
    """The same spans shifted so the earliest ``t_start`` is 0."""
    t0 = min((s.t_start for s in spans), default=0.0)
    if not t0:
        return tuple(spans)
    return tuple(dataclasses.replace(s, t_start=s.t_start - t0,
                                     t_end=s.t_end - t0) for s in spans)


def from_stage(stage, index: int, wave: int, t_start: float,
               t_end: float) -> StageSpan:
    """A span for one plan stage, pulling identity/payload metadata off
    the stage itself (duck-typed: plans are deliberately dumb data, so
    every field degrades to its default when absent)."""
    ir = getattr(stage, "ir", None)
    pl = getattr(stage, "placement", None)
    return StageSpan(
        stage=index,
        kind=getattr(stage, "kind", ""),
        axis=getattr(stage, "axis", "") or "",
        wave=wave,
        t_start=t_start,
        t_end=t_end,
        bytes=getattr(ir, "bytes_in", None) if ir is not None else None,
        schedule=getattr(stage, "schedule", "") or "",
        placement=pl.describe() if pl is not None else "")
