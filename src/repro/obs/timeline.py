"""Perfetto wave timelines: spans → Chrome trace-event JSON.

One exporter for every recording the repo produces.  :func:`chrome_trace`
accepts anything with a ``stages`` sequence (or the bare sequence) whose
records are *span-shaped*:

  * :class:`repro.obs.spans.StageSpan` — the shared stage-record schema
    the instrumented executor emits and ``tune.trace`` stores
    (``t_start``/``t_end``);
  * :class:`repro.cgra.simulate.SimStage` — the dataplane simulator's
    per-stage report rows (``t_start``/``t_sim``; the injection-
    serialization share ``t_ser`` becomes a nested ``inject`` slice).

The emitted JSON is the Chrome trace-event format Perfetto (ui.perfetto.
dev) and ``chrome://tracing`` load directly: one thread lane (``tid``)
per mesh axis (axis-less local compute gets its own lane), every stage a
complete ``ph:"X"`` slice with microsecond ``ts``/``dur``, and every
ExecutionPlan wave boundary a ``ph:"i"`` instant event — overlapped
dispatch is *visible* as slices sharing a wall-clock interval on
different lanes, instead of inferred from medians.
"""

from __future__ import annotations

import json
from typing import Any, Optional, Sequence

US = 1e6                  # trace-event timestamps are in microseconds
LOCAL_LANE = "(local)"    # lane label for axis-less compute


def _span_bounds(s) -> tuple[float, float, Optional[float]]:
    """(t_start, t_end, t_ser) of one span-shaped record — StageSpan
    carries ``t_end``; a simulator ``SimStage`` carries ``t_sim``."""
    t0 = float(getattr(s, "t_start", 0.0))
    if hasattr(s, "t_end"):
        t1 = float(s.t_end)
    elif hasattr(s, "t_sim"):
        t1 = t0 + float(s.t_sim)
    else:
        raise TypeError(
            f"record {s!r} has neither t_end nor t_sim — not a stage span")
    return t0, t1, getattr(s, "t_ser", None)


def _stages_of(source) -> Sequence:
    stages = getattr(source, "stages", source)
    if not isinstance(stages, (list, tuple)):
        raise TypeError(f"cannot extract stage records from {source!r}")
    return stages


def lanes(source) -> dict[str, int]:
    """``{axis: tid}`` lane assignment, axes in first-use order (lane 1
    upward; tid 0 is reserved for the wave-boundary instants)."""
    out: dict[str, int] = {}
    for s in _stages_of(source):
        ax = getattr(s, "axis", "") or LOCAL_LANE
        if ax not in out:
            out[ax] = 1 + len(out)
    return out


def chrome_trace(source, plan=None, *, name: Optional[str] = None,
                 pid: int = 0) -> dict:
    """Chrome trace-event JSON (as a dict) for one recorded run.

    ``source`` is a :class:`~repro.tune.trace.ProgramTrace`, a
    :class:`~repro.cgra.simulate.SimReport`, an :class:`~repro.obs.
    report.RunReport`, or a bare sequence of span-shaped records.
    ``plan`` (an :class:`~repro.core.executor.ExecutionPlan`) is
    optional: when given, records missing a ``wave`` field inherit the
    plan's wave assignment and the instant events cover every plan wave
    (even ones the recording skipped).
    """
    source = getattr(source, "trace", source) \
        if not hasattr(source, "stages") and hasattr(source, "trace") \
        else source
    stages = _stages_of(source)
    lane_of = lanes(stages)
    label = name or getattr(source, "name", None) or "program"

    wave_of = {}
    if plan is not None:
        wave_of = {i: w for w, ws in enumerate(plan.waves) for i in ws}

    events: list[dict] = [
        {"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
         "args": {"name": f"acis:{label}"}},
        {"ph": "M", "name": "thread_name", "pid": pid, "tid": 0,
         "args": {"name": "waves"}},
    ]
    for ax, tid in lane_of.items():
        events.append({"ph": "M", "name": "thread_name", "pid": pid,
                       "tid": tid,
                       "args": {"name": ax if ax == LOCAL_LANE
                                else f"axis {ax}"}})

    wave_start: dict[int, float] = {}
    for idx, s in enumerate(stages):
        t0, t1, t_ser = _span_bounds(s)
        kind = getattr(s, "kind", "stage")
        ax = getattr(s, "axis", "") or LOCAL_LANE
        stage_i = getattr(s, "stage", idx)
        wave = getattr(s, "wave", None)
        if wave is None:
            wave = wave_of.get(stage_i, 0)
        wave_start[wave] = min(wave_start.get(wave, t0), t0)
        args: dict[str, Any] = {"stage": stage_i, "wave": wave}
        for f in ("schedule", "placement"):
            v = getattr(s, f, "")
            if v:
                # simulator rows carry the Placement object itself;
                # spans carry its describe() string — emit the string
                args[f] = v.describe() if hasattr(v, "describe") else v
        nbytes = getattr(s, "bytes", None)
        if nbytes is not None:
            args["bytes"] = int(nbytes)
        events.append({
            "ph": "X", "name": f"{kind}@{ax}" if ax != LOCAL_LANE
            else kind, "cat": kind, "pid": pid, "tid": lane_of[ax],
            "ts": t0 * US, "dur": max(t1 - t0, 0.0) * US, "args": args})
        if t_ser and 0.0 < t_ser <= (t1 - t0):
            # the injection-serialization share nests inside the stage
            # slice: the interval the shared port stays busy pushing
            # this stage's bytes (the part wave overlap cannot hide)
            events.append({
                "ph": "X", "name": "inject", "cat": "ser_hop",
                "pid": pid, "tid": lane_of[ax], "ts": t0 * US,
                "dur": float(t_ser) * US, "args": {"stage": stage_i}})

    if plan is not None:
        for w in range(plan.n_waves):
            wave_start.setdefault(w, max(wave_start.values(), default=0.0))
    for w in sorted(wave_start):
        events.append({
            "ph": "i", "name": f"wave {w}", "s": "p", "pid": pid,
            "tid": 0, "ts": wave_start[w] * US, "args": {"wave": w}})

    events.sort(key=lambda e: (e["ph"] != "M", e.get("ts", 0.0)))
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"program": label,
                          "source": getattr(source, "source", "unknown")}}


def save(path, source, plan=None, *, name: Optional[str] = None) -> str:
    """Write ``source`` (or an already-built trace dict) as a
    ``.trace.json`` Perfetto loads; returns ``path``."""
    trace = source if isinstance(source, dict) and "traceEvents" in source \
        else chrome_trace(source, plan, name=name)
    with open(path, "w") as f:
        json.dump(trace, f, indent=1)
        f.write("\n")
    return str(path)
