"""Events + metrics registry: process-local counters, gauges, histograms.

The rest of the repo emits into the module-level :data:`RECORDER`
(default :data:`null_recorder`, whose every method is a no-op) — so with
recording disabled an instrumented site costs one module-attribute read
plus an empty method call, and ``benchmarks/obs.py`` gates that bound in
CI.  Enable collection for a region with :func:`recording`::

    from repro import obs

    with obs.recording() as rec:
        engine.gradient_sync(...)          # or compile / simulate / serve
    print(rec.summary())

Counter catalogue (every name the repo currently emits):

========================  ==========  =====================================
name                      type        emitted by
========================  ==========  =====================================
compile.programs          counter     compiler.compile_rank_local per build
compile.cache_hit/_miss   counter     api.CollectiveEngine._sync_program
tune.db_hit/db_search     counter     tune.search.tuned_config
tune.fit_runs             counter     tune.fit.fit_net_params
arena.alloc/realloc       counter     api.CollectiveEngine.init_arenas
arena.roundtrip           counter     api gradient_sync arena threading
coalesce.bucket_fill_frac histogram   Coalesce bucket formation (bytes/cap)
emit.kernel_stage         counter     Emit under use_kernels (Pallas path)
emit.reference_stage      counter     Emit reference lowering
cgra.placed/host_fallback counter     compile placements (PlaceCGRA result)
plan.stage_bytes          histogram   per-stage payload at compile
plan.wave_width           histogram   stages per ExecutionPlan wave
exec.instrumented_stages  counter     executor instrument hook
exec.stage_s              histogram   instrumented per-stage seconds
sim.runs/sim.stages       counter     cgra.simulate.SwitchSim.run
serve.ticks/admitted/     counter     serve.ServeEngine.step
  retired
serve.active              gauge       active slots per tick
serve.queue_depth         gauge       queued requests at tick start
serve.decode_s            histogram   per-tick decode seconds (enabled only)
serve.decode_p50_s/p99_s  gauge       tick-latency percentiles over the
                                      sliding measurement window
serve.host_sync           counter     the tick's one device->host block
                                      (logits for sampling)
serve.slo_rejected        counter     requests dropped at admission: the
                                      SLOPolicy estimate misses deadline
serve.admit_deferred      counter     admits postponed (prefill cap)
serve.deadline_headroom_s gauge       min (deadline - elapsed) across
                                      active deadline-carrying slots
serve.program_cache_hit/  counter     serve.collectives.SwitchProgramCache
  _miss                               get_or_build
train.steps               counter     train step wrapper (recorder= passed)
train.step_s              histogram   per-step seconds (enabled only)
drift.observations        counter     obs.drift.DriftWatchdog.observe
drift.rank_observations   counter     watchdog per-rank span pools
drift.flagged             counter     watchdog keys past threshold
drift.rank_local/         counter     local verdicts (sick rank / degraded
  link_local                          link) — reported, refit suppressed
drift.refit_recommended   event       watchdog re-fit recommendation
tune.fit                  event       fit residual/stage count per fit
elastic.deadline_miss     counter     sync_with_deadline ranks past deadline
elastic.retry             counter     sync_with_deadline masked retries
elastic.rank_dropped/     counter     Membership.delta transitions
  rank_restored
recompile.programs_reused counter     engine.recompile cache outcomes
  /_rebuilt
recompile.arenas_reused/  counter     engine.recompile arena outcomes
  _rebuilt
topology.compile_cache_   counter     bounded LRU evictions from the
  evicted                             process-wide topology compile cache
sim.dead_ranks            counter     SwitchSim FaultPlan dead ranks per run
========================  ==========  =====================================
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
from typing import Iterator, Optional

# events kept per recorder before dropping (with a drop counter) — a
# telemetry layer must never be the thing that OOMs the run
MAX_EVENTS = 65536


@dataclasses.dataclass
class Hist:
    """Running aggregate of an observed distribution (no sample storage
    beyond the aggregate — O(1) per observe)."""

    n: int = 0
    total: float = 0.0
    sq: float = 0.0
    mn: float = math.inf
    mx: float = -math.inf

    def add(self, value: float) -> None:
        v = float(value)
        self.n += 1
        self.total += v
        self.sq += v * v
        self.mn = min(self.mn, v)
        self.mx = max(self.mx, v)

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def to_dict(self) -> dict:
        return {"n": self.n, "mean": self.mean,
                "min": self.mn if self.n else 0.0,
                "max": self.mx if self.n else 0.0,
                "total": self.total}


class Recorder:
    """Collects counters / gauges / histograms / events.

    Not thread-safe by design — one recorder per measured region; the
    hot paths it instruments are single-threaded host loops.
    """

    enabled = True

    def __init__(self):
        self.counters: dict[str, float] = {}
        self.gauges: dict[str, float] = {}
        self.hists: dict[str, Hist] = {}
        self.events: list[tuple[str, dict]] = []
        self.dropped_events = 0

    # -- emission ------------------------------------------------------------

    def count(self, name: str, n: float = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + n

    def gauge(self, name: str, value: float) -> None:
        self.gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        h = self.hists.get(name)
        if h is None:
            h = self.hists[name] = Hist()
        h.add(value)

    def event(self, name: str, **fields) -> None:
        if len(self.events) >= MAX_EVENTS:
            self.dropped_events += 1
            return
        self.events.append((name, fields))

    # -- reading -------------------------------------------------------------

    def counter(self, name: str) -> float:
        return self.counters.get(name, 0)

    def snapshot(self) -> dict:
        """Everything collected, as plain JSON-able data."""
        out = {
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "hists": {k: h.to_dict() for k, h in self.hists.items()},
            "events": [{"name": n, **f} for n, f in self.events],
        }
        if self.dropped_events:
            out["dropped_events"] = self.dropped_events
        return out

    def summary(self) -> str:
        """A readable multi-line dump, names sorted."""
        lines = []
        for k in sorted(self.counters):
            lines.append(f"{k} = {self.counters[k]:g}")
        for k in sorted(self.gauges):
            lines.append(f"{k} = {self.gauges[k]:g} (gauge)")
        for k in sorted(self.hists):
            h = self.hists[k]
            lines.append(f"{k}: n={h.n} mean={h.mean:g} "
                         f"min={h.mn:g} max={h.mx:g}")
        for name, fields in self.events:
            args = ", ".join(f"{k}={v}" for k, v in fields.items())
            lines.append(f"event {name}({args})")
        return "\n".join(lines) if lines else "(nothing recorded)"

    def clear(self) -> None:
        self.counters.clear()
        self.gauges.clear()
        self.hists.clear()
        self.events.clear()
        self.dropped_events = 0


class NullRecorder(Recorder):
    """The disabled default: every emission is a no-op, every read is
    empty.  Instrumented sites pay one attribute read + one empty call."""

    enabled = False

    def count(self, name, n=1):
        pass

    def gauge(self, name, value):
        pass

    def observe(self, name, value):
        pass

    def event(self, name, **fields):
        pass


null_recorder = NullRecorder()

# the process-wide recorder instrumented sites emit into.  Read it at
# call time (``metrics.RECORDER.count(...)``) — never bind it at import —
# so ``recording()`` swaps take effect everywhere.
RECORDER: Recorder = null_recorder


def current() -> Recorder:
    return RECORDER


def install(recorder: Optional[Recorder]) -> Recorder:
    """Make ``recorder`` (or the null recorder) the process recorder;
    returns the previous one so callers can restore it."""
    global RECORDER
    prev = RECORDER
    RECORDER = recorder if recorder is not None else null_recorder
    return prev


@contextlib.contextmanager
def recording(recorder: Optional[Recorder] = None) -> Iterator[Recorder]:
    """Install a recorder for the ``with`` body (a fresh one when not
    given), restoring the previous recorder on exit."""
    rec = recorder if recorder is not None else Recorder()
    prev = install(rec)
    try:
        yield rec
    finally:
        install(prev)
