"""repro.obs — unified switch telemetry.

The observability layer the paper's evaluate → map → refine loop (§V)
runs on: what did the switch program actually do, and does the model
still believe it?  Four pieces:

  1. **metrics** (:mod:`repro.obs.metrics`) — process-local counters /
     gauges / histograms behind a :class:`~repro.obs.metrics.Recorder`;
     compiler, executor, simulator, tune, serve and train all emit into
     the module-level recorder (a no-op ``null_recorder`` by default —
     enable with :func:`~repro.obs.metrics.recording`).
  2. **spans** (:mod:`repro.obs.spans`) — the shared stage-record
     schema.  ``tune.trace.StageTrace`` *is* :class:`~repro.obs.spans.
     StageSpan`; the executor's ``instrument`` hook emits it directly.
  3. **timeline** (:mod:`repro.obs.timeline`) — spans (executor *or*
     simulator) exported as Chrome trace-event JSON loadable in
     Perfetto: one lane per axis, wave boundaries as instants.
  4. **drift** (:mod:`repro.obs.drift`) — online measured-vs-model
     ratio tracking that recommends a re-fit (``repro.tune.fit``) when
     the analytic model stops describing reality.

:class:`~repro.obs.report.RunReport` aggregates one run;
``python -m repro.obs`` renders a report or dumps a ``.trace.json``
from a recorded JSONL trace.

``spans``/``metrics``/``timeline`` are dependency-free (stdlib only) so
``repro.core`` imports them without a cycle; ``drift``/``report`` (which
reach into ``repro.core.netmodel`` / ``repro.tune``) load lazily.
"""

from repro.obs import metrics, spans, timeline
from repro.obs.metrics import (NullRecorder, Recorder, current, install,
                               null_recorder, recording)
from repro.obs.spans import StageSpan
from repro.obs.timeline import chrome_trace

__all__ = [
    "metrics", "spans", "timeline", "drift", "report",
    "Recorder", "NullRecorder", "null_recorder", "current", "install",
    "recording", "StageSpan", "chrome_trace",
    "DriftWatchdog", "DriftAlert", "DriftVerdict", "RunReport",
]

_LAZY = {
    "drift": "repro.obs.drift",
    "report": "repro.obs.report",
    "DriftWatchdog": "repro.obs.drift",
    "DriftAlert": "repro.obs.drift",
    "DriftVerdict": "repro.obs.drift",
    "RunReport": "repro.obs.report",
}


def __getattr__(name):
    # drift/report import repro.core (netmodel) — deferred so that
    # repro.core.executor can import repro.obs at module level without
    # a circular import through the package __init__
    target = _LAZY.get(name)
    if target is None:
        raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")
    import importlib

    mod = importlib.import_module(target)
    value = mod if name in ("drift", "report") else getattr(mod, name)
    globals()[name] = value
    return value
