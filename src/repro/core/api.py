"""CollectiveEngine — the MPI-transparency layer.

The paper encapsulates ACiS inside an MPI implementation so applications
accelerate without source changes (§VI.A).  The framework analogue: model /
training code talks to a :class:`CollectiveEngine`; a config flag selects
which transport actually runs.  Engines:

  * ``xla``             — passive-network baseline (XLA built-ins)
  * ``acis``            — explicit ring/log-step schedules (Types 1-4)
  * ``acis_compressed`` — acis + Type 2/3 wire compression with error
                          feedback on the gradient-sync path
  * ``acis_hierarchical`` (+ ``_compressed``) — pod-aware two-level sync

`gradient_sync` operates on *pytrees of gradients* inside a shard_map-manual
region over the DP axes; everything else in the step (model-parallel math)
stays in GSPMD-auto land.  See train/step.py for the integration.

All ``acis*`` gradient syncs are one traced switch program — per leaf a
``reduce(axis="auto")`` (plus error-feedback target/residual maps on the
compressed backends) — compiled once through the Legalize → LowerTopology
→ Coalesce → FuseHops → SelectSchedule → Emit pipeline against the
engine's :class:`~repro.core.compiler.Topology` and cached per pytree
structure.  The hierarchical RS/AR/AG schedule is no longer a call-site
convention: it is what LowerTopology emits for a multi-axis reduce — and
the per-leaf collectives are not what actually runs: the Coalesce pass
buckets compatible leaves into flat-buffer bucket collectives
(``CollectiveConfig.bucket_bytes``), so a many-leaf pytree syncs in a
few streaming buckets executed over an explicit
:class:`~repro.core.executor.ExecutionPlan`.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import collectives, compiler, tracing
from repro.core.lookaside import init_residual
from repro.core.types import ADD
from repro.obs import metrics as _obs

PyTree = Any

BACKENDS = ("xla", "acis", "acis_compressed", "acis_hierarchical",
            "acis_hierarchical_compressed")


def live_axis_sizes(axes, known: Optional[dict] = None) -> dict:
    """Best-effort ``{axis: size}`` for the named mesh axes.

    Sizes are read live via ``lax.axis_size`` — available when called
    inside a shard_map region manual over the axis — so compile paths
    can key their caches and feed the cost model without a mesh in
    hand.  ``known`` entries are kept as-is; axes not bound anywhere
    simply stay absent.
    """
    sizes = dict(known) if known else {}
    for ax in axes:
        if ax is None or ax in sizes:
            continue
        try:
            sizes[ax] = lax.axis_size(ax)
        except Exception:            # not under shard_map over this axis
            pass
    return sizes


@dataclasses.dataclass(frozen=True)
class RecompileReport:
    """What :meth:`CollectiveEngine.recompile` reused vs rebuilt.

    Shape-preserving topology deltas (rank dropout absorbed by the alive
    mask, ×k link degradation) must report 100% reuse: the mask is a
    runtime program input, so membership flips never retrace, and the
    arenas are keyed by compiled-program identity.
    """

    programs_reused: int = 0
    programs_rebuilt: int = 0
    arenas_reused: int = 0
    arenas_rebuilt: int = 0
    shape_preserving: bool = True

    @property
    def full_recompile(self) -> bool:
        return self.programs_rebuilt > 0

    @property
    def reuse_frac(self) -> float:
        total = (self.programs_reused + self.programs_rebuilt
                 + self.arenas_reused + self.arenas_rebuilt)
        if total == 0:
            return 1.0
        return (self.programs_reused + self.arenas_reused) / total


@dataclasses.dataclass(frozen=True)
class CollectiveConfig:
    backend: str = "xla"
    # wire codec for the compressed paths: int8 | bf16 | fp8
    codec: str = "int8"
    # compressor for error-feedback sync: int8 | topk
    compressor: str = "int8"
    topk_ratio: float = 0.01
    latency_optimal_below: int = 16384  # bytes; ring-vs-latency crossover
    # Coalesce bucket size (bytes): per-leaf reductions sharing an
    # axis/monoid/codec are concatenated into flat buckets of this many
    # bytes, one collective per bucket.  None = derive from the cost
    # model's crossover for the axis traversed
    # (repro.core.netmodel.bucket_bytes); 0 = disable bucketing.
    bucket_bytes: Optional[int] = None
    # switch CGRA the PlaceCGRA pass maps stage bodies onto; None = the
    # paper's Table II device (repro.cgra.device.PAPER_CGRA)
    cgra_device: Optional[Any] = None
    # overlapped wave dispatch (repro.core.executor.execute): same-axis
    # stages of a wave are chained with explicit optimization_barrier
    # edges, different-axis stages issue with no ordering edges so XLA
    # may run their collectives concurrently.  False = strict
    # stage-ordered serial emission (the pre-overlap runtime, kept for
    # A/B measurement).
    overlap_dispatch: bool = True
    # hoist a bucket's shared elementwise epilogue (the gradient mean)
    # to one bucket-sized kernel; False keeps per-leaf epilogues.  A
    # tunable: the hoist trades kernel count against wave-level overlap.
    epilogue_hoist: bool = True
    # route the bulk data path through the Pallas kernels (switchops
    # registry): the Coalesce bucket pack becomes one fused arena-aliased
    # launch and ring hop combines run the registered kernels.  Whether a
    # kernel compiles (Mosaic on TPU) or interprets (CPU — tier-1 numerics
    # validation) is decided per call by kernels/ops._interpret_default,
    # overridable via $ACIS_KERNEL_INTERPRET.  Default comes from
    # $ACIS_USE_KERNELS (the CI kernels leg sets it).
    use_kernels: bool = dataclasses.field(
        default_factory=lambda: os.environ.get(
            "ACIS_USE_KERNELS", "") not in ("", "0"))
    # merge a wave's independent same-axis allreduces (plain elementwise
    # monoid, identity codec) into ONE ring over a chunk-aligned stacked
    # buffer — k ring launches collapse to one, amortizing the per-launch
    # hop latency.  Bit-compatible with per-program launches (each lane
    # keeps its chunk index, hence its fold order).  A tunable.
    batch_rings: bool = False
    # per-merged-launch payload cap in bytes for batch_rings; None =
    # the compiler default (a few MB), 0 = uncapped.  Bounds the
    # synchronization/cache cost of one giant stacked buffer while
    # still amortizing launches across small rings.
    batch_rings_bytes: Optional[int] = None
    # consult (and on a miss, populate) the on-disk tuning DB
    # (repro.tune.search) at compile: the stored winning overrides for
    # this (program structure, topology) are applied transparently.
    autotune: bool = False
    # tuning-DB path; None = $ACIS_TUNE_DB, else ./.acis_tune.json
    tune_db: Optional[str] = None

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(
                f"backend {self.backend!r} not in {BACKENDS}")

    def cache_key(self) -> tuple:
        """Every config field a compiled program's structure depends on.

        Compiled-program caches must include this in their keys: the
        autotuner varies ``bucket_bytes``/``overlap_dispatch``/
        ``epilogue_hoist``/``latency_optimal_below``, so a tuned
        program must not collide with the default config's cache entry
        for the same pytree structure.
        """
        return (self.backend, self.codec, self.compressor,
                self.topk_ratio, self.latency_optimal_below,
                self.bucket_bytes, self.overlap_dispatch,
                self.epilogue_hoist, self.use_kernels,
                self.batch_rings, self.batch_rings_bytes)


class CollectiveEngine:
    """Rank-local collective transport with backend dispatch."""

    def __init__(self, config: CollectiveConfig,
                 inner_axis: str = "data",
                 outer_axis: Optional[str] = None):
        self.config = config
        self.inner_axis = inner_axis
        self.outer_axis = outer_axis
        self._sync_cache: dict = {}   # pytree structure → CompiledProgram
        self._arena_cache: dict = {}  # CompiledProgram → bucket arenas
        self._tune_cache: dict = {}   # pytree structure → tuned config
        self._last_sync = None        # most recently built/fetched program

    # -- properties ---------------------------------------------------------

    @property
    def compressed(self) -> bool:
        return "compressed" in self.config.backend

    @property
    def hierarchical(self) -> bool:
        return "hierarchical" in self.config.backend

    @property
    def base_backend(self) -> str:
        return "xla" if self.config.backend == "xla" else "acis"

    def needs_residual(self) -> bool:
        return self.compressed

    def init_state(self, grads_like: PyTree) -> Optional[PyTree]:
        """Look-aside state (Type 3): error-feedback residuals, or None.

        Uncompressed backends are stateless — returning None (instead of a
        pytree of dead zero scalars) keeps checkpoints and donated buffers
        free of fake state."""
        if self.compressed:
            return init_residual(grads_like, jnp.float32)
        return None

    # -- topology (the compiler's view of this engine's DP axes) -------------

    def topology(self, mesh: Optional[jax.sharding.Mesh] = None, *,
                 axis_size=None) -> compiler.Topology:
        """The engine's DP axes as a compile :class:`~repro.core.compiler.
        Topology`: inner axis on the fast intra-pod tier, outer axis (when
        configured and present on the mesh) on the thin inter-pod tier.

        ``axis_size`` may be an int (the inner axis) or an {axis: size}
        mapping — pass the outer size too so SelectSchedule can cost the
        inter-pod stage against the thin DCI tier on mesh-less compiles.
        """
        sizes: dict = {}
        if isinstance(axis_size, dict):
            sizes.update(axis_size)
        elif axis_size is not None:
            sizes[self.inner_axis] = axis_size
        if mesh is not None:         # the mesh is authoritative
            sizes.update(zip(mesh.axis_names, mesh.devices.shape))
        axes = [compiler.AxisSpec(self.inner_axis,
                                  sizes.get(self.inner_axis), "ici")]
        if self.outer_axis is not None and \
                (mesh is None or self.outer_axis in mesh.axis_names):
            axes.append(compiler.AxisSpec(self.outer_axis,
                                          sizes.get(self.outer_axis), "dci"))
        return compiler.Topology(tuple(axes))

    # -- the gradient-sync transport -----------------------------------------

    def _local_alive(self, membership) -> jax.Array:
        """This rank's liveness flag (float32 scalar) from a membership
        view — a :class:`repro.elastic.Membership`, a length-``n_ranks``
        mask array (rank = ``outer_index * |inner| + inner_index``), or
        an already-rank-local scalar.  Indexed live via ``axis_index``,
        so the mask is runtime data: membership flips never retrace."""
        if hasattr(membership, "mask_array"):
            mask = jnp.asarray(membership.mask_array(jnp.float32))
        else:
            mask = jnp.asarray(membership, jnp.float32)
        if mask.ndim == 0:
            return mask.astype(jnp.float32)
        idx = lax.axis_index(self.inner_axis)
        if self.outer_axis is not None:
            try:
                idx = idx + lax.axis_size(self.inner_axis) \
                    * lax.axis_index(self.outer_axis)
            except Exception:    # outer axis configured but not on the mesh
                pass
        return mask.reshape(-1)[idx].astype(jnp.float32)

    def gradient_sync(self, grads: PyTree, state: PyTree,
                      n_total: Optional[int] = None, *,
                      arenas: Optional[tuple] = None,
                      membership=None):
        """Mean-all-reduce a gradient pytree over the DP axes.

        Returns (synced_grads, new_state) — or (synced_grads, new_state,
        new_arenas) when ``arenas`` is passed.  Must run inside a
        shard_map region that is manual over `inner_axis` (and
        `outer_axis` if set).

        Every ``acis*`` backend routes through one compiled switch
        program (cached per pytree structure): per leaf, a mean-reduce
        over ``axis="auto"`` — with an error-feedback target/residual
        around it on the compressed backends.  The LowerTopology pass
        turns the multi-axis reduce into the hierarchical RS/AR/AG
        schedule when an outer axis exists.

        ``membership`` switches to bounded-staleness sync: dead ranks'
        contributions are masked to the monoid identity and the mean is
        renormalized by the live count, which rides in the *same* flat
        ring buffer as the payload (``tracing.masked_reduce`` — one
        collective launch, not two).  Accepts a
        :class:`repro.elastic.Membership`, a per-rank mask array, or a
        rank-local scalar; the mask is a runtime input, so changing it
        never recompiles.  ``n_total`` is ignored on the masked path —
        the live count is the divisor.

        ``arenas`` are the persistent bucket buffers from
        :meth:`init_arenas`: the Coalesce bucket packs then write leaves
        into them in place instead of concatenating into fresh buffers.
        Thread the returned ``new_arenas`` into the next step and donate
        them at your jit boundary (``donate_argnums``) so XLA aliases
        the buffers — the pack transient drops from 2× to ~1× bucket
        size.
        """
        if self.config.backend == "xla":
            inner, outer = self.inner_axis, self.outer_axis
            axes = (inner,) if outer is None else (inner, outer)
            if membership is not None:
                # passive-network reference: two launches (payload +
                # count) — the analytic baseline the compiled one-ring
                # masked path is oracled against
                alive = self._local_alive(membership)
                count = jnp.maximum(lax.psum(alive, axes), 1.0)
                synced = jax.tree.map(
                    lambda g: lax.psum(
                        jnp.where(alive != 0, g, jnp.zeros_like(g)), axes)
                    / count.astype(g.dtype), grads)
            elif n_total is None:
                synced = jax.tree.map(
                    lambda g: lax.pmean(g, axes), grads)
            else:   # same divisor override the acis paths honor
                synced = jax.tree.map(
                    lambda g: lax.psum(g, axes) / n_total, grads)
            return (synced, state, arenas) if arenas is not None \
                else (synced, state)

        leaves, treedef = jax.tree_util.tree_flatten(grads)
        if not leaves:                 # nothing to sync (e.g. frozen subtree)
            return (grads, state, arenas) if arenas is not None \
                else (grads, state)
        avals = tuple(jax.ShapeDtypeStruct(l.shape, l.dtype) for l in leaves)
        compiled = self._sync_program(treedef, avals, n_total,
                                      masked=membership is not None)
        args = tuple(leaves)
        if self.compressed:
            args = args + tuple(treedef.flatten_up_to(state))
        if membership is not None:
            args = args + (self._local_alive(membership),)
        if arenas is not None:
            # the donation round-trip: buffers out through the step's
            # state, back in on the next sync
            _obs.RECORDER.count("arena.roundtrip")
            outs, new_arenas = compiled(*args, arenas=tuple(arenas))
        else:
            outs, new_arenas = compiled(*args), None
        synced = jax.tree_util.tree_unflatten(treedef, outs[:len(leaves)])
        new_state = state
        if self.compressed:
            new_state = jax.tree_util.tree_unflatten(
                treedef, outs[len(leaves):])
        if arenas is not None:
            return synced, new_state, new_arenas
        return synced, new_state

    def init_arenas(self, grads_like: PyTree, *,
                    axis_sizes: Optional[dict] = None,
                    n_total: Optional[int] = None,
                    masked: bool = False) -> Optional[tuple]:
        """Persistent bucket arenas for :meth:`gradient_sync` on this
        gradient pytree structure — allocated once per structure and
        cached, so repeated calls return the *same* buffers (donating
        callers get fresh ones from the sync's returned ``new_arenas``).

        Call OUTSIDE any trace (the buffers must be concrete to persist
        across steps), passing ``axis_sizes`` (``{axis: size}``) when no
        shard_map region is active — bucket boundaries depend on the DP
        ring sizes.  Returns None when the program has no bucket stages
        (xla backend, bucketing disabled, single-leaf trees).
        """
        if self.config.backend == "xla":
            return None
        leaves = jax.tree_util.tree_leaves(grads_like)
        if not leaves:
            return None
        treedef = jax.tree_util.tree_structure(grads_like)
        avals = tuple(jax.ShapeDtypeStruct(l.shape, l.dtype) for l in leaves)
        compiled = self._sync_program(treedef, avals, n_total,
                                      axis_sizes=axis_sizes, masked=masked)
        # keyed by the compiled program itself (identity): two configs
        # producing different bucket layouts for the same pytree — e.g.
        # tuned vs default bucket_bytes — must not share arenas
        hit = self._arena_cache.get(compiled)
        fresh_reason = "arena.alloc" if hit is None else None
        if hit is not None and any(
                getattr(a, "is_deleted", lambda: False)() for a in hit):
            # a donating caller consumed the cached buffers (the step
            # owns the live ones as state now) — hand out fresh arenas
            # instead of deleted arrays
            hit, fresh_reason = None, "arena.realloc"
        if hit is None:
            hit = self._arena_cache[compiled] = compiled.make_arenas()
            if hit is not None:
                _obs.RECORDER.count(fresh_reason)
        return hit

    def recompile(self, delta, grads_like: PyTree, *,
                  axis_sizes: Optional[dict] = None,
                  n_total: Optional[int] = None,
                  masked: bool = True) -> RecompileReport:
        """Re-resolve the compiled sync program and arenas after a
        topology change (a :class:`repro.elastic.TopologyDelta` or any
        object with ``shape_preserving`` / ``axis_sizes`` attributes).

        Shape-preserving deltas — rank dropout absorbed by the alive
        mask, ×k link-tier degradation — MUST hit the existing caches:
        the mask is a runtime input (not part of any compile key) and
        arenas are keyed by compiled-program identity, so both report
        100% reuse.  Only a delta that moves rank-local shapes
        (``axis_sizes`` set — e.g. a rank permanently leaving the ring)
        compiles a fresh program and allocates fresh arenas.

        The returned :class:`RecompileReport` carries the reuse/rebuild
        counters; they are also emitted to ``obs``
        (``recompile.programs_reused`` etc.) for the CI gate.
        """
        leaves = jax.tree_util.tree_leaves(grads_like)
        if not leaves or self.config.backend == "xla":
            return RecompileReport()
        treedef = jax.tree_util.tree_structure(grads_like)
        avals = tuple(jax.ShapeDtypeStruct(l.shape, l.dtype)
                      for l in leaves)
        sizes = dict(axis_sizes or {})
        shape_preserving = bool(getattr(delta, "shape_preserving", True))
        if not shape_preserving:
            sizes.update(dict(getattr(delta, "axis_sizes", None) or {}))
        with _obs.recording() as rec:
            compiled = self._sync_program(
                treedef, avals, n_total, axis_sizes=sizes or None,
                masked=masked)
            arenas = self.init_arenas(
                grads_like, axis_sizes=sizes or None, n_total=n_total,
                masked=masked)
        prog_rebuilt = int(rec.counter("compile.cache_miss") > 0)
        arena_rebuilt = 0 if arenas is None else int(
            rec.counter("arena.alloc") + rec.counter("arena.realloc") > 0)
        report = RecompileReport(
            programs_reused=1 - prog_rebuilt,
            programs_rebuilt=prog_rebuilt,
            arenas_reused=0 if arenas is None else 1 - arena_rebuilt,
            arenas_rebuilt=arena_rebuilt,
            shape_preserving=shape_preserving)
        _obs.RECORDER.count("recompile.programs_reused",
                            report.programs_reused)
        _obs.RECORDER.count("recompile.programs_rebuilt",
                            report.programs_rebuilt)
        _obs.RECORDER.count("recompile.arenas_reused",
                            report.arenas_reused)
        _obs.RECORDER.count("recompile.arenas_rebuilt",
                            report.arenas_rebuilt)
        _obs.RECORDER.event("engine.recompile",
                            shape_preserving=shape_preserving,
                            full=report.full_recompile)
        self._last_sync = compiled
        return report

    def _sync_program(self, treedef, avals: tuple,
                      n_total: Optional[int] = None, *,
                      axis_sizes: Optional[dict] = None,
                      masked: bool = False):
        """Build (or fetch) the compiled gradient-sync switch program for
        one pytree structure.

        ``avals`` (one per leaf) give SelectSchedule per-leaf payload
        sizes; axis sizes are read live via ``lax.axis_size`` — we are
        inside the caller's shard_map region at trace time — unless
        ``axis_sizes`` supplies them explicitly (the outside-trace
        spelling :meth:`init_arenas` uses), so the per-tier ring
        crossover is reachable without a mesh in hand.
        """
        cfg = self.config
        inner, outer = self.inner_axis, self.outer_axis
        compressed = self.compressed
        n_leaves = len(avals)
        sizes = live_axis_sizes((inner, outer), axis_sizes)
        # the sizes are part of the key: the same engine may serve meshes
        # of different DP size, and the schedule choice depends on them.
        # The config's cache_key is too — the autotuner hands back
        # configs differing only in tuned fields, and those must compile
        # to distinct programs, not collide with the default's entry.
        key0 = (treedef, avals, n_total, tuple(sorted(sizes.items())),
                masked)
        cfg_eff = cfg
        if cfg.autotune and sizes.get(inner):
            cfg_eff = self._tune_cache.get(key0)
            if cfg_eff is None:
                cfg_eff = self._tuned_sync_config(
                    avals, n_total, sizes)
                self._tune_cache[key0] = cfg_eff
        key = key0 + (cfg_eff.cache_key(),)
        hit = self._sync_cache.get(key)
        if hit is not None:
            _obs.RECORDER.count("compile.cache_hit")
            self._last_sync = hit
            return hit
        _obs.RECORDER.count("compile.cache_miss")
        compiled = self._build_sync(cfg_eff, avals, n_total, sizes,
                                    masked=masked)
        self._sync_cache[key] = compiled
        self._last_sync = compiled
        return compiled

    def _tuned_sync_config(self, avals, n_total, sizes):
        """Resolve the effective config through the tuning DB: a stored
        winner for this (pytree structure, topology) applies directly; a
        miss searches the tunable space offline (analytic replay over
        recompiled candidates) and persists the winner."""
        from repro import tune

        cfg = self.config
        topo = self.topology(axis_size=sizes)
        in_avals = avals + (avals if self.compressed else ())
        tkey = tune.plan_key(
            f"gradient_sync[{cfg.backend}x{len(avals)}]",
            in_avals, topo, cfg)
        return tune.tuned_config(
            cfg,
            lambda c: self._build_sync(c, avals, n_total, sizes),
            key=tkey, db_path=cfg.tune_db)

    def _build_sync(self, cfg, avals, n_total, sizes, *,
                    masked: bool = False):
        """Trace + compile the gradient-sync program under ``cfg`` (also
        the candidate builder the autotune search recompiles with).

        ``masked=True`` builds the bounded-staleness variant: one extra
        scalar input (this rank's alive flag), per-leaf
        ``masked_reduce`` with renormalization — the live count travels
        in the payload's flat bucket, so the program has the same ring
        structure (and the same stage count) as the unmasked one.  On
        the compressed backends the masked target feeds the usual EF
        triple and one tiny exact scalar reduce carries the live count.
        """
        inner, outer = self.inner_axis, self.outer_axis
        compressed = self.compressed
        n_leaves = len(avals)

        def _mean(y):
            n = n_total
            if n is None:
                n = lax.axis_size(inner)
                if outer is not None:
                    n = n * lax.axis_size(outer)
            return y / n

        def _ef_target(g, r):
            return g + r.astype(g.dtype)

        def _masked_ef_target(g, r, a):
            t = g + r.astype(g.dtype)
            return jnp.where(a != 0, t, jnp.zeros_like(t))

        def _ef_residual(t, delivered, r):
            return (t.astype(jnp.float32) - delivered).astype(r.dtype)

        def _masked_mean(y, c):
            return y / jnp.maximum(c, 1).astype(y.dtype)

        def sync(*args):
            if masked:
                alive = args[-1]
                args = args[:-1]
            gs, rs = args[:n_leaves], args[n_leaves:]
            outs, news = [], []
            cnt = None
            if masked and compressed:
                # the EF wire is lossy; the divisor must not be — one
                # exact scalar ring carries the live count for all leaves
                cnt = tracing.reduce(alive, ADD, axis="auto")
            for i in range(n_leaves):
                if compressed:
                    if masked:
                        t = tracing.map(_masked_ef_target, gs[i], rs[i],
                                        alive, name="masked_ef_target")
                    else:
                        t = tracing.map(_ef_target, gs[i], rs[i],
                                        name="ef_target")
                    red, dlv = tracing.ef_reduce(
                        t, compressor=cfg.compressor,
                        topk_ratio=cfg.topk_ratio, axis="auto")
                    if masked:
                        outs.append(tracing.map(_masked_mean, red, cnt,
                                                name="masked_mean"))
                    else:
                        outs.append(tracing.map(_mean, red, name="mean",
                                                elementwise=True))
                    news.append(tracing.map(_ef_residual, t, dlv, rs[i],
                                            name="ef_residual"))
                elif masked:
                    red, _ = tracing.masked_reduce(gs[i], alive, ADD,
                                                   axis="auto")
                    outs.append(red)
                else:
                    red = tracing.reduce(gs[i], ADD, axis="auto")
                    outs.append(tracing.map(_mean, red, name="mean",
                                            elementwise=True))
            return tuple(outs) + tuple(news)

        tag = "masked," if masked else ""
        prog = tracing.trace(
            sync, name=f"gradient_sync[{tag}{cfg.backend}x{n_leaves}]",
            num_inputs=n_leaves * (2 if compressed else 1) + int(masked))
        in_avals = avals + (avals if compressed else ()) \
            + ((jax.ShapeDtypeStruct((), jnp.float32),) if masked else ())
        return compiler.compile_rank_local(
            prog, inner, axis_size=sizes.get(inner), config=cfg,
            in_avals=in_avals, topology=self.topology(axis_size=sizes))

    def last_sync_program(self):
        """The most recently compiled (or cache-hit) gradient-sync
        :class:`~repro.core.compiler.CompiledProgram`, or None before the
        first sync — the stable way for drivers to print ``explain()`` /
        ``program_time()`` for the program that actually ran."""
        return self._last_sync

    # -- generic ops (used by MoE dispatch, GCN, examples) -------------------

    def all_reduce(self, x, axis_name=None, monoid=ADD):
        return collectives.all_reduce(
            x, axis_name or self.inner_axis, monoid,
            backend=self.base_backend)

    def all_gather(self, x, axis_name=None):
        return collectives.all_gather(
            x, axis_name or self.inner_axis, backend=self.base_backend)

    def reduce_scatter(self, x, axis_name=None, monoid=ADD):
        return collectives.reduce_scatter(
            x, axis_name or self.inner_axis, monoid,
            backend=self.base_backend)

    def all_to_all(self, x, axis_name=None):
        return collectives.all_to_all(
            x, axis_name or self.inner_axis, backend=self.base_backend)

    # -- switch-program compilation (the one entry point) --------------------

    def compile(self, prog, mesh=None, in_specs=None, out_specs=None, *,
                axis_name: Optional[str] = None, in_avals=None,
                axis_size=None, jit: bool = True):
        """Compile a switch program through the pass pipeline.

        ``prog`` may be a plain Python function over traced values (see
        :mod:`repro.core.tracing`), a traced :class:`DagProgram`, or a
        legacy chain :class:`SwitchProgram`.  With ``mesh`` (plus
        in/out specs) the result is the jitted shard_map "CGRA binary";
        without it, a rank-local :class:`CompiledProgram` for use inside an
        existing shard_map region.  The engine's
        :class:`CollectiveConfig` drives the SelectSchedule pass
        (``latency_optimal_below`` ring crossover); pass ``in_avals``
        (rank-local ShapeDtypeStructs or arrays, one per program input) to
        give the scheduler payload sizes.  The engine's DP axes form the
        compile :class:`~repro.core.compiler.Topology`, so ops written
        with ``axis="auto"`` lower hierarchically across inner and outer.
        """
        ax = axis_name or self.inner_axis
        topo = self.topology(mesh, axis_size=axis_size)
        if isinstance(axis_size, dict):
            axis_size = axis_size.get(ax)
        cfg = self.config
        if cfg.autotune and in_avals is not None:
            # candidates are scored on rank-local plans (cheap analytic
            # replay); the winning config then drives the real compile,
            # mesh-wrapped or not
            from repro import tune
            from repro.core import program as _program
            from repro.core import tracing

            name = getattr(prog, "name", None) \
                or getattr(prog, "__name__", "program")
            if not isinstance(prog, (_program.DagProgram,
                                     _program.SwitchProgram)):
                # trace once, not once per search candidate — and in_avals
                # fixes the arity for *args-signature programs, which
                # trace() alone cannot infer
                prog = tracing.trace(prog, num_inputs=len(in_avals))
            cfg = tune.tuned_config(
                cfg,
                lambda c: compiler.compile_rank_local(
                    prog, ax, axis_size=axis_size, config=c,
                    in_avals=in_avals, topology=topo),
                key=tune.plan_key(name, in_avals, topo, cfg),
                db_path=cfg.tune_db)
        if mesh is None:
            return compiler.compile_rank_local(
                prog, ax, axis_size=axis_size, config=cfg,
                in_avals=in_avals, topology=topo)
        if in_specs is None or out_specs is None:
            raise ValueError("mesh compilation needs in_specs and out_specs")
        return compiler.compile_program(
            prog, mesh, ax, in_specs, out_specs, jit=jit,
            config=cfg, in_avals=in_avals, topology=topo)


def make_engine(backend: str = "xla", *, inner_axis: str = "data",
                outer_axis: Optional[str] = None, **kw) -> CollectiveEngine:
    return CollectiveEngine(CollectiveConfig(backend=backend, **kw),
                            inner_axis=inner_axis, outer_axis=outer_axis)
