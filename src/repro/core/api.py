"""CollectiveEngine — the MPI-transparency layer.

The paper encapsulates ACiS inside an MPI implementation so applications
accelerate without source changes (§VI.A).  The framework analogue: model /
training code talks to a :class:`CollectiveEngine`; a config flag selects
which transport actually runs.  Engines:

  * ``xla``             — passive-network baseline (XLA built-ins)
  * ``acis``            — explicit ring/log-step schedules (Types 1-4)
  * ``acis_compressed`` — acis + Type 2/3 wire compression with error
                          feedback on the gradient-sync path
  * ``acis_hierarchical`` (+ ``_compressed``) — pod-aware two-level sync

`gradient_sync` operates on *pytrees of gradients* inside a shard_map-manual
region over the DP axes; everything else in the step (model-parallel math)
stays in GSPMD-auto land.  See train/step.py for the integration.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import collectives, topology
from repro.core.lookaside import error_feedback_all_reduce, init_residual
from repro.core.types import ADD
from repro.core.wire import CODECS, IDENTITY, int8_codec

PyTree = Any

BACKENDS = ("xla", "acis", "acis_compressed", "acis_hierarchical",
            "acis_hierarchical_compressed")


@dataclasses.dataclass(frozen=True)
class CollectiveConfig:
    backend: str = "xla"
    # wire codec for the compressed paths: int8 | bf16 | fp8
    codec: str = "int8"
    # compressor for error-feedback sync: int8 | topk
    compressor: str = "int8"
    topk_ratio: float = 0.01
    latency_optimal_below: int = 16384  # bytes; ring-vs-latency crossover

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(
                f"backend {self.backend!r} not in {BACKENDS}")


class CollectiveEngine:
    """Rank-local collective transport with backend dispatch."""

    def __init__(self, config: CollectiveConfig,
                 inner_axis: str = "data",
                 outer_axis: Optional[str] = None):
        self.config = config
        self.inner_axis = inner_axis
        self.outer_axis = outer_axis

    # -- properties ---------------------------------------------------------

    @property
    def compressed(self) -> bool:
        return "compressed" in self.config.backend

    @property
    def hierarchical(self) -> bool:
        return "hierarchical" in self.config.backend

    @property
    def base_backend(self) -> str:
        return "xla" if self.config.backend == "xla" else "acis"

    def needs_residual(self) -> bool:
        return self.compressed

    def init_state(self, grads_like: PyTree) -> Optional[PyTree]:
        """Look-aside state (Type 3): error-feedback residuals, or None.

        Uncompressed backends are stateless — returning None (instead of a
        pytree of dead zero scalars) keeps checkpoints and donated buffers
        free of fake state."""
        if self.compressed:
            return init_residual(grads_like, jnp.float32)
        return None

    # -- the gradient-sync transport -----------------------------------------

    def gradient_sync(self, grads: PyTree, state: PyTree,
                      n_total: Optional[int] = None) -> tuple[PyTree, PyTree]:
        """Mean-all-reduce a gradient pytree over the DP axes.

        Returns (synced_grads, new_state).  Must run inside a shard_map
        region that is manual over `inner_axis` (and `outer_axis` if set).
        """
        inner, outer = self.inner_axis, self.outer_axis
        n = lax.axis_size(inner)
        if outer is not None:
            n = n * lax.axis_size(outer)

        if self.config.backend == "xla":
            axes = (inner,) if outer is None else (inner, outer)
            synced = jax.tree.map(
                lambda g: lax.pmean(g, axes), grads)
            return synced, state

        if self.compressed:
            def sync_leaf(g, r):
                red, new_r = error_feedback_all_reduce(
                    g, r, inner,
                    compressor=self.config.compressor,
                    topk_ratio=self.config.topk_ratio, mean=False)
                if outer is not None:
                    red = collectives.all_reduce(red, outer, ADD)
                return red / n, new_r

            pairs = jax.tree.map(sync_leaf, grads, state)
            synced = jax.tree.map(lambda p: p[0], pairs,
                                  is_leaf=lambda p: isinstance(p, tuple))
            new_state = jax.tree.map(lambda p: p[1], pairs,
                                     is_leaf=lambda p: isinstance(p, tuple))
            return synced, new_state

        if self.hierarchical:
            synced = jax.tree.map(
                lambda g: topology.hierarchical_all_reduce(
                    g, inner_axis=inner, outer_axis=outer, mean=True),
                grads)
            return synced, state

        # plain acis ring all-reduce (Type 1 on the explicit schedule)
        def sync_leaf(g):
            red = collectives.all_reduce(g, inner, ADD)
            if outer is not None:
                red = collectives.all_reduce(red, outer, ADD)
            return red / n

        return jax.tree.map(sync_leaf, grads), state

    # -- generic ops (used by MoE dispatch, GCN, examples) -------------------

    def all_reduce(self, x, axis_name=None, monoid=ADD):
        return collectives.all_reduce(
            x, axis_name or self.inner_axis, monoid,
            backend=self.base_backend)

    def all_gather(self, x, axis_name=None):
        return collectives.all_gather(
            x, axis_name or self.inner_axis, backend=self.base_backend)

    def reduce_scatter(self, x, axis_name=None, monoid=ADD):
        return collectives.reduce_scatter(
            x, axis_name or self.inner_axis, monoid,
            backend=self.base_backend)

    def all_to_all(self, x, axis_name=None):
        return collectives.all_to_all(
            x, axis_name or self.inner_axis, backend=self.base_backend)

    # -- switch-program compilation (the one entry point) --------------------

    def compile(self, prog, mesh=None, in_specs=None, out_specs=None, *,
                axis_name: Optional[str] = None, in_avals=None,
                axis_size: Optional[int] = None, jit: bool = True):
        """Compile a switch program through the pass pipeline.

        ``prog`` may be a plain Python function over traced values (see
        :mod:`repro.core.tracing`), a traced :class:`DagProgram`, or a
        legacy chain :class:`SwitchProgram`.  With ``mesh`` (plus
        in/out specs) the result is the jitted shard_map "CGRA binary";
        without it, a rank-local :class:`CompiledProgram` for use inside an
        existing shard_map region.  The engine's
        :class:`CollectiveConfig` drives the SelectSchedule pass
        (``latency_optimal_below`` ring crossover); pass ``in_avals``
        (rank-local ShapeDtypeStructs or arrays, one per program input) to
        give the scheduler payload sizes.
        """
        from repro.core import compiler
        ax = axis_name or self.inner_axis
        if mesh is None:
            return compiler.compile_rank_local(
                prog, ax, axis_size=axis_size, config=self.config,
                in_avals=in_avals)
        if in_specs is None or out_specs is None:
            raise ValueError("mesh compilation needs in_specs and out_specs")
        return compiler.compile_program(
            prog, mesh, ax, in_specs, out_specs, jit=jit,
            config=self.config, in_avals=in_avals)


def make_engine(backend: str = "xla", *, inner_axis: str = "data",
                outer_axis: Optional[str] = None, **kw) -> CollectiveEngine:
    return CollectiveEngine(CollectiveConfig(backend=backend, **kw),
                            inner_axis=inner_axis, outer_axis=outer_axis)
