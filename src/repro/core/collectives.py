"""ACiS Type 1 & 2 collectives — public rank-local API with backend selection.

Two backends:
  * ``"xla"``  — XLA's built-in collectives (`lax.psum` etc.).  This is the
    *non-ACiS baseline*: the network is a passive conduit, compute stays at
    the endpoints, and the op/dtype set is whatever XLA reduction supports.
  * ``"acis"`` — explicit ring/log-step schedules from :mod:`repro.core.ring`
    with per-hop compute: arbitrary monoids (Type 2 user-defined ops),
    arbitrary wire codecs (Type 0/2 wire dtypes), and hop-fused maps
    (substrate for Type 4).

Everything here is rank-local (call inside `jax.shard_map`).  The
whole-array wrappers used by training live in :mod:`repro.core.api`.
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import ring
from repro.core.types import ADD, MAX, MIN, Monoid
from repro.core.wire import IDENTITY, WireCodec

PyTree = Any

_XLA_REDUCERS = {"add": lax.psum, "max": lax.pmax, "min": lax.pmin}


def all_reduce(
    x: jax.Array,
    axis_name: str,
    monoid: Monoid = ADD,
    *,
    backend: str = "acis",
    codec: WireCodec = IDENTITY,
    latency_optimal: bool = False,
    hop_combine: Optional[Callable] = None,
) -> jax.Array:
    """All-reduce ``x`` over ``axis_name`` with an arbitrary monoid & codec.

    Type 1 when ``monoid`` ∈ {add, max, min} and ``codec`` is identity;
    Type 2 otherwise.  The ``xla`` backend only supports the Type 1 subset —
    requesting more on it raises, which is precisely the limitation of
    fixed-function switch collectives the paper targets.
    """
    if backend == "xla":
        if monoid.name not in _XLA_REDUCERS:
            raise ValueError(
                f"xla backend supports only {sorted(_XLA_REDUCERS)} "
                f"(the Type 1 fixed-op limitation); got {monoid.name!r}. "
                "Use backend='acis' for user-defined (Type 2) ops.")
        if codec is not IDENTITY:
            raise ValueError("xla backend cannot apply wire codecs in-flight")
        return _XLA_REDUCERS[monoid.name](x, axis_name)

    if codec is IDENTITY:
        return ring.ring_all_reduce(x, axis_name, monoid,
                                    hop_combine=hop_combine,
                                    latency_optimal=latency_optimal)

    # Wire-coded path: encode once, combine in the encoded domain per hop
    # (the switch never sees the decoded stream), decode once at the end.
    if codec.combine_encoded is not None:
        enc = codec.encode(x)
        out = _tree_all_reduce_encoded(enc, axis_name, codec.combine_encoded)
        return codec.decode(out)
    # Fallback: cast-style codec (bf16/fp8) — encode before hops, decode after.
    enc = codec.encode(x)
    red = ring.ring_all_reduce(enc, axis_name, monoid,
                               hop_combine=hop_combine,
                               latency_optimal=latency_optimal)
    return codec.decode(red).astype(x.dtype)


def _tree_all_reduce_encoded(enc: PyTree, axis_name: str,
                             combine: Callable[[PyTree, PyTree], PyTree]) -> PyTree:
    """RS∘AG ring all-reduce over an encoded pytree payload.

    The reduce-scatter form matters for *lossy* encoded-domain combines
    (quantized): each chunk is folded along a chunk-determined rank walk, so
    every rank decodes the *identical* result after the all-gather —
    a rank-relative fold order would let replicas diverge.  It is also
    bandwidth-optimal: 2(n-1)/n · encoded-size on the wire.

    Requires all leaves to share their leading ("block") dimension.
    """
    n = lax.axis_size(axis_name)
    if n == 1:
        return enc
    i = lax.axis_index(axis_name)
    leading = {leaf.shape[0] for leaf in jax.tree.leaves(enc)}
    if len(leading) != 1:
        raise ValueError(f"encoded leaves must share leading dim, got {leading}")
    (nblocks,) = leading
    pad = (-nblocks) % n

    def pad_leaf(leaf):
        if not pad:
            return leaf
        fill = jnp.zeros((pad,) + leaf.shape[1:], leaf.dtype)
        return jnp.concatenate([leaf, fill])

    padded = jax.tree.map(pad_leaf, enc)
    chunked = jax.tree.map(
        lambda l: l.reshape((n, l.shape[0] // n) + l.shape[1:]), padded)
    perm = [(j, (j + 1) % n) for j in range(n)]

    def chunk_at(idx):
        return jax.tree.map(
            lambda l: lax.dynamic_index_in_dim(l, idx, 0, keepdims=False),
            chunked)

    buf = chunk_at((i - 1) % n)

    def body(buf, s):
        incoming = ring.ppermute_tree(buf, axis_name, perm)
        local = chunk_at((i - 2 - s) % n)
        return combine(incoming, local), ()

    buf, _ = lax.scan(body, buf, jnp.arange(n - 1))
    gathered = jax.tree.map(lambda l: ring.ring_all_gather(l, axis_name), buf)
    return jax.tree.map(lambda l: l[:nblocks], gathered)


def reduce_scatter(
    x: jax.Array,
    axis_name: str,
    monoid: Monoid = ADD,
    *,
    backend: str = "acis",
    hop_combine: Optional[Callable] = None,
    codec: WireCodec = IDENTITY,
) -> jax.Array:
    if backend == "xla":
        if monoid.name != "add":
            raise ValueError("xla psum_scatter is add-only (Type 1 limitation)")
        if codec is not IDENTITY:
            raise ValueError("xla backend cannot apply wire codecs in-flight")
        return lax.psum_scatter(x, axis_name, tiled=True)
    if codec is IDENTITY:
        return ring.ring_reduce_scatter(x, axis_name, monoid,
                                        hop_combine=hop_combine)
    if codec.combine_encoded is not None:
        # structured payloads (quantized pytrees) change the chunk layout;
        # only the full RS∘AG all-reduce schedule implements that walk
        raise ValueError(
            f"wire codec {codec.name!r} (encoded-domain combine) is not "
            "supported on a standalone reduce-scatter — use all_reduce, or "
            "drop the wire() declaration")
    # cast-style codec: hops and combines run in the wire dtype
    enc = codec.encode(x)
    red = ring.ring_reduce_scatter(enc, axis_name, monoid,
                                   hop_combine=hop_combine)
    return codec.decode(red).astype(x.dtype)


def all_gather(
    x: jax.Array,
    axis_name: str,
    *,
    backend: str = "acis",
    hop_map: Optional[Callable] = None,
) -> jax.Array:
    if backend == "xla":
        if hop_map is not None:
            raise ValueError("xla backend cannot fuse maps into the gather")
        return lax.all_gather(x, axis_name, tiled=True)
    return ring.ring_all_gather(x, axis_name, hop_map=hop_map)


def broadcast(x: jax.Array, axis_name: str, root: int = 0, *,
              backend: str = "acis", tree: bool = True) -> jax.Array:
    if backend == "xla":
        # XLA has no direct bcast primitive at this level; emulate by
        # masking + psum (what a fixed-function endpoint stack would do).
        i = lax.axis_index(axis_name)
        return lax.psum(jnp.where(i == root, x, jnp.zeros_like(x)), axis_name)
    if tree:
        return ring.tree_broadcast(x, axis_name, root)
    return ring.ring_broadcast(x, axis_name, root)


def all_to_all(x: jax.Array, axis_name: str, *, backend: str = "acis") -> jax.Array:
    """[n*chunk, ...] -> [n*chunk, ...] with chunk j delivered to rank j."""
    if backend == "xla":
        n = lax.axis_size(axis_name)
        xs = x.reshape((n, x.shape[0] // n) + x.shape[1:])
        out = lax.all_to_all(xs, axis_name, split_axis=0, concat_axis=0,
                             tiled=False)
        return out.reshape(x.shape)
    return ring.ring_all_to_all(x, axis_name)


def prefix_scan(x: PyTree, axis_name: str, monoid: Monoid = ADD, *,
                exclusive: bool = False) -> PyTree:
    """Cross-rank prefix scan (Type 3 look-aside carry). acis-only: XLA has
    no scan collective — this op *only exists* because the network computes."""
    return ring.rank_prefix_scan(x, axis_name, monoid, exclusive=exclusive)
