"""Ring / log-step collective schedules with per-hop compute.

These are the TPU-native embodiment of ACiS "in-switch" processing: a
collective is a sequence of `lax.ppermute` hops executed under
`jax.shard_map`, and arbitrary compute (the paper's aggregation unit / CGRA
program) is attached to every hop.  All functions in this module are *rank
local*: they must be called inside a `shard_map`-manual region and take the
mesh ``axis_name`` they communicate over.

Schedules provided:
  * ``ring_reduce_scatter``    — bandwidth-optimal ring RS, per-hop combine
  * ``ring_all_gather``        — bandwidth-optimal ring AG, optional per-hop map
  * ``ring_all_reduce``        — RS∘AG (bandwidth) or unchunked (latency)
  * ``ring_broadcast``         — ring multicast (the paper's replication engine)
  * ``tree_broadcast``         — log-step multicast (beyond-paper option)
  * ``rank_prefix_scan``       — log-step (Hillis-Steele) scan across ranks;
                                 the carry is Type-3 "look-aside" state
  * ``ring_all_to_all``        — shifted-ppermute A2A
Axis size 1 degenerates to identity for every schedule.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.types import ADD, Monoid

PyTree = Any


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------

def _shift_perm(n: int, shift: int) -> list[tuple[int, int]]:
    """Cyclic shift: rank j sends to rank (j + shift) % n."""
    return [(j, (j + shift) % n) for j in range(n)]


def _partial_shift_perm(n: int, shift: int) -> list[tuple[int, int]]:
    """Non-cyclic shift used by log-step scans (ranks >= n - shift send nothing).

    Receivers with no sender get zeros from ``ppermute``; callers mask.
    """
    return [(j, j + shift) for j in range(n - shift)]


def ppermute_tree(x: PyTree, axis_name: str, perm: Sequence[tuple[int, int]]) -> PyTree:
    return jax.tree.map(lambda leaf: lax.ppermute(leaf, axis_name, perm), x)


def _split_chunks(x: jax.Array, n: int) -> jax.Array:
    """Reshape leading axis into [n, chunk, ...]; requires divisibility."""
    if x.shape[0] % n:
        raise ValueError(f"leading dim {x.shape[0]} not divisible by axis size {n}")
    return x.reshape((n, x.shape[0] // n) + x.shape[1:])


def _dyn_chunk(xs: jax.Array, idx: jax.Array) -> jax.Array:
    return lax.dynamic_index_in_dim(xs, idx, axis=0, keepdims=False)


def pad_to_multiple(
    x: jax.Array, n: int, fill=0, *, monoid: Optional[Monoid] = None,
) -> tuple[jax.Array, int]:
    """Pad flat array to a multiple of ``n``; returns (padded, original_len).

    ``monoid`` overrides ``fill`` with the monoid's identity element so the
    pad lanes are invisible to per-hop combines (a literal ``0`` corrupts
    non-add monoids: ``min`` over zeros clamps negative data, ``prod``
    annihilates).
    """
    size = x.shape[0]
    rem = (-size) % n
    if rem:
        if monoid is not None:
            fill = monoid.identity(jax.ShapeDtypeStruct((), x.dtype))
        x = jnp.concatenate([x, jnp.full((rem,) + x.shape[1:], fill, x.dtype)])
    return x, size


# ---------------------------------------------------------------------------
# Reduce-scatter  (rank i ends owning the fully-reduced chunk i)
# ---------------------------------------------------------------------------

def ring_reduce_scatter(
    x: jax.Array,
    axis_name: str,
    monoid: Monoid = ADD,
    *,
    hop_combine: Optional[Callable[[jax.Array, jax.Array], jax.Array]] = None,
) -> jax.Array:
    """Bandwidth-optimal ring reduce-scatter with a per-hop combine.

    ``hop_combine(incoming, local)`` is the in-switch aggregation program; it
    defaults to ``monoid.combine`` and may be any user function (ACiS Type 2)
    including a Pallas kernel.  ``x`` has shape [n * chunk, ...]; the return
    value is the fully reduced chunk ``i`` of shape [chunk, ...].
    """
    n = lax.axis_size(axis_name)
    combine = hop_combine or monoid.combine
    if n == 1:
        return x
    i = lax.axis_index(axis_name)
    xs = _split_chunks(x, n)
    perm = _shift_perm(n, 1)

    buf = _dyn_chunk(xs, (i - 1) % n)

    def body(buf, s):
        incoming = lax.ppermute(buf, axis_name, perm)
        local = _dyn_chunk(xs, (i - 2 - s) % n)
        return combine(incoming, local), ()

    buf, _ = lax.scan(body, buf, jnp.arange(n - 1))
    return buf


# ---------------------------------------------------------------------------
# All-gather  (rank i contributes chunk i; result is [n * chunk, ...])
# ---------------------------------------------------------------------------

def ring_all_gather(
    x: jax.Array,
    axis_name: str,
    *,
    hop_map: Optional[Callable[[jax.Array], jax.Array]] = None,
) -> jax.Array:
    """Bandwidth-optimal ring all-gather.

    ``hop_map`` (ACiS Type 4 "map" fused into the collective) is applied to
    every chunk exactly once as it is *forwarded* — i.e. the transformation
    happens in the network, not at the endpoints.  With ``hop_map`` the
    result at every rank is ``concat([map(chunk_0), ..., map(chunk_{n-1})])``.
    """
    n = lax.axis_size(axis_name)
    if hop_map is None:
        hop_map = lambda c: c
    if n == 1:
        out = hop_map(x)
        return out
    i = lax.axis_index(axis_name)
    perm = _shift_perm(n, 1)

    first = hop_map(x)
    out = jnp.zeros((n,) + first.shape, first.dtype)
    out = lax.dynamic_update_index_in_dim(out, first, i, axis=0)

    def body(carry, s):
        out, buf = carry
        buf = lax.ppermute(buf, axis_name, perm)
        out = lax.dynamic_update_index_in_dim(out, buf, (i - 1 - s) % n, axis=0)
        return (out, buf), ()

    (out, _), _ = lax.scan(body, (out, first), jnp.arange(n - 1))
    return out.reshape((n * first.shape[0],) + first.shape[1:])


# ---------------------------------------------------------------------------
# All-reduce
# ---------------------------------------------------------------------------

def ring_all_reduce(
    x: jax.Array,
    axis_name: str,
    monoid: Monoid = ADD,
    *,
    hop_combine: Optional[Callable[[jax.Array, jax.Array], jax.Array]] = None,
    latency_optimal: bool = False,
) -> jax.Array:
    """All-reduce with per-hop combine.

    ``latency_optimal=False`` (default): reduce-scatter ∘ all-gather — 2(n-1)
    hops of ``size/n`` bytes each (bandwidth-optimal; right for large
    messages).  ``latency_optimal=True``: n-1 hops of full-size messages with
    a combine at every hop — fewer sequential hops for tiny messages (the
    paper's Fig. 3 small-message regime; see repro/core/netmodel.py for the
    crossover).
    """
    n = lax.axis_size(axis_name)
    if n == 1:
        return x
    combine = hop_combine or monoid.combine
    if latency_optimal:
        perm = _shift_perm(n, 1)

        # Rotate each rank's *original* contribution around the ring and
        # fold it into a local accumulator — n-1 hops, full-size messages,
        # one combine per hop.  (Folding running partials instead would
        # double-count.)  Requires a commutative monoid.
        def body(carry, _):
            acc, msg = carry
            msg = lax.ppermute(msg, axis_name, perm)
            return (combine(acc, msg), msg), ()

        (out, _), _ = lax.scan(body, (x, x), jnp.arange(n - 1))
        return out

    shape = x.shape
    flat = x.reshape(-1)
    padded, size = pad_to_multiple(flat, n, monoid=monoid)
    red = ring_reduce_scatter(padded, axis_name, monoid, hop_combine=hop_combine)
    full = ring_all_gather(red, axis_name)
    return full[:size].reshape(shape)


# ---------------------------------------------------------------------------
# Broadcast (multicast engine)
# ---------------------------------------------------------------------------

def ring_broadcast(x: jax.Array, axis_name: str, root: int = 0) -> jax.Array:
    """Ring multicast: the value is replicated hop-by-hop along the ring,
    mirroring the paper's packet-replication engine in the switch pipeline."""
    n = lax.axis_size(axis_name)
    if n == 1:
        return x
    i = lax.axis_index(axis_name)
    d = (i - root) % n  # ring distance from root
    perm = _shift_perm(n, 1)
    buf = jnp.where(d == 0, x, jnp.zeros_like(x))

    def body(buf, s):
        incoming = lax.ppermute(buf, axis_name, perm)
        keep = (d == s + 1)
        return jnp.where(keep, incoming, buf), ()

    buf, _ = lax.scan(body, buf, jnp.arange(n - 1))
    return buf


def tree_broadcast(x: jax.Array, axis_name: str, root: int = 0) -> jax.Array:
    """Log-step (binomial-tree) multicast — beyond-paper latency option."""
    n = lax.axis_size(axis_name)
    if n == 1:
        return x
    i = lax.axis_index(axis_name)
    d = (i - root) % n
    buf = jnp.where(d == 0, x, jnp.zeros_like(x))
    k = 1
    while k < n:
        # ranks with d < k hold the value; they send to d + k
        perm = [(j, (j + k) % n) for j in range(n)]
        incoming = lax.ppermute(buf, axis_name, perm)
        take = (d >= k) & (d < 2 * k)
        buf = jnp.where(take, incoming, buf)
        k *= 2
    return buf


# ---------------------------------------------------------------------------
# Rank prefix scan — the Type 3 look-aside carry walking the network.
# ---------------------------------------------------------------------------

def rank_prefix_scan(
    x: PyTree,
    axis_name: str,
    monoid: Monoid = ADD,
    *,
    exclusive: bool = False,
) -> PyTree:
    """Prefix scan *across ranks* (per-rank pytrees combined in rank order).

    Log-step Hillis-Steele: ceil(log2 n) ppermute rounds.  The partial
    prefixes are exactly the "state within the operation" of ACiS Type 3 —
    carried through the network rather than stored at an endpoint.  Works
    for any (possibly non-commutative) associative monoid and any axis size.
    """
    n = lax.axis_size(axis_name)
    i = lax.axis_index(axis_name)
    acc = x
    k = 1
    while k < n:
        perm = _partial_shift_perm(n, k)
        shifted = ppermute_tree(acc, axis_name, perm)
        valid = i >= k
        combined = monoid.combine(shifted, acc)
        acc = jax.tree.map(
            lambda c, a: jnp.where(valid, c, a), combined, acc)
        k *= 2
    if not exclusive:
        return acc
    # exclusive_i = inclusive_{i-1};  rank 0 takes the identity.
    prev = ppermute_tree(acc, axis_name, _partial_shift_perm(n, 1))
    ident = monoid.identity(jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), x))
    return jax.tree.map(
        lambda p, e: jnp.where(i == 0, e, p), prev, ident)


# ---------------------------------------------------------------------------
# All-to-all (shifted ppermutes) — substrate for fused AR+A2A (NAS IS).
# ---------------------------------------------------------------------------

def ring_all_to_all(x: jax.Array, axis_name: str) -> jax.Array:
    """All-to-all: ``x`` is [n * chunk, ...]; chunk j goes to rank j.

    Implemented as n-1 shifted ppermutes of one chunk each, so that per-hop
    compute can be interleaved by callers (see core/fused.py).
    """
    n = lax.axis_size(axis_name)
    if n == 1:
        return x
    i = lax.axis_index(axis_name)
    xs = _split_chunks(x, n)
    out = jnp.zeros_like(xs)
    # local chunk stays
    out = lax.dynamic_update_index_in_dim(
        out, _dyn_chunk(xs, i), i, axis=0)
    for s in range(1, n):
        perm = _shift_perm(n, s)
        # chunk destined for rank (i + s): send it now, receive the one
        # destined for us from rank (i - s).
        send = _dyn_chunk(xs, (i + s) % n)
        recv = lax.ppermute(send, axis_name, perm)
        out = lax.dynamic_update_index_in_dim(out, recv, (i - s) % n, axis=0)
    return out.reshape(x.shape)


# ---------------------------------------------------------------------------
# Gather (SPMD note: every rank computes the gathered value; "root" semantics
# are realized by callers discarding non-root outputs).
# ---------------------------------------------------------------------------

def ring_gather(x: jax.Array, axis_name: str) -> jax.Array:
    return ring_all_gather(x, axis_name)
