"""Core taxonomy types for ACiS (Advanced Computing in the Switch).

The paper classifies in-switch computing into progressively complex types
(Table I of the paper). On the TPU substrate every chip is a hop of the
ring/torus collective, so "in-switch" compute becomes per-hop compute
attached to a `lax.ppermute` schedule executed under `jax.shard_map`.

This module defines:
  * :class:`AcisType` — the taxonomy levels (0-4).
  * :class:`Monoid`   — a combine operation with identity, the algebraic
    object a reduction/scan collective is parameterized by.  Type 1 uses the
    fixed builtin monoids; Type 2 permits arbitrary user monoids over
    arbitrary pytree "wire dtypes".
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


class AcisType(enum.IntEnum):
    """The ACiS taxonomy (paper Table I)."""

    STREAM = 0        # stream transforms (dtype change, checksum)
    COLLECTIVE = 1    # collectives on primitive types, fixed ops
    USER_DEFINED = 2  # user-defined ops / dtypes / communicators
    LOOK_ASIDE = 3    # state + loops + off-chip (HBM) memory
    FUSED = 4         # fused collectives and map functions


@dataclasses.dataclass(frozen=True)
class Monoid:
    """An associative combine with identity.

    ``combine`` must be associative (commutative too for reduction
    collectives whose hop order is rank-dependent).  ``identity`` takes a
    ShapeDtypeStruct-like and returns the identity element of that shape.
    """

    name: str
    combine: Callable[[PyTree, PyTree], PyTree]
    identity: Callable[[Any], PyTree]
    commutative: bool = True

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"Monoid({self.name})"


def _full_like_struct(x: Any, val) -> Array:
    return jnp.full(x.shape, val, dtype=x.dtype)


# ---------------------------------------------------------------------------
# Type 1 fixed monoids (the BlueGene/SHArP-class op set).
# ---------------------------------------------------------------------------

ADD = Monoid("add", lambda a, b: a + b, lambda x: jnp.zeros(x.shape, x.dtype))
MAX = Monoid(
    "max", jnp.maximum, lambda x: _full_like_struct(x, jnp.finfo(x.dtype).min
                                                    if jnp.issubdtype(x.dtype, jnp.floating)
                                                    else jnp.iinfo(x.dtype).min)
)
MIN = Monoid(
    "min", jnp.minimum, lambda x: _full_like_struct(x, jnp.finfo(x.dtype).max
                                                    if jnp.issubdtype(x.dtype, jnp.floating)
                                                    else jnp.iinfo(x.dtype).max)
)
PROD = Monoid("prod", lambda a, b: a * b, lambda x: jnp.ones(x.shape, x.dtype))

TYPE1_MONOIDS = {m.name: m for m in (ADD, MAX, MIN, PROD)}


def tree_monoid(leaf_monoid: Monoid) -> Monoid:
    """Lift a leaf monoid to pytrees (Type 2 'user-defined datatypes')."""

    def combine(a: PyTree, b: PyTree) -> PyTree:
        return jax.tree.map(leaf_monoid.combine, a, b)

    def identity(struct: PyTree) -> PyTree:
        return jax.tree.map(leaf_monoid.identity, struct)

    return Monoid(f"tree_{leaf_monoid.name}", combine, identity,
                  leaf_monoid.commutative)


# ---------------------------------------------------------------------------
# Example Type 2 user-defined monoids (paper §II: "e.g. dot product",
# sparse/matrix datatypes).  These are *data points* showing the engine is
# genuinely op/dtype-polymorphic; users supply their own.
# ---------------------------------------------------------------------------


def _argmax_combine(a, b):
    """(value, payload) argmax-with-payload: keeps payload of the max."""
    av, ap = a
    bv, bp = b
    take_a = av >= bv
    return jnp.where(take_a, av, bv), jnp.where(take_a, ap, bp)


ARGMAX_WITH_PAYLOAD = Monoid(
    "argmax_payload",
    _argmax_combine,
    lambda s: (jnp.full(s[0].shape, -jnp.inf, s[0].dtype),
               jnp.zeros(s[1].shape, s[1].dtype)),
)


def _welford_combine(a, b):
    """Parallel Welford mean/variance merge — a stateful 'matrix-like' dtype."""
    na, ma, sa = a
    nb, mb, sb = b
    n = na + nb
    safe_n = jnp.where(n > 0, n, 1)
    delta = mb - ma
    m = ma + delta * (nb / safe_n)
    s = sa + sb + delta * delta * (na * nb / safe_n)
    return n, m, s


WELFORD = Monoid(
    "welford",
    _welford_combine,
    lambda s: (jnp.zeros(s[0].shape, s[0].dtype),
               jnp.zeros(s[1].shape, s[1].dtype),
               jnp.zeros(s[2].shape, s[2].dtype)),
)
