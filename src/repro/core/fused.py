"""ACiS Type 4 — fused collectives and collective∘map fusion.

The paper's Type 4 builds new operations by fusing chains of collectives
("recirculate interface") or sandwiching map computation between them (the
CGRA program).  The value: intermediate communications are bypassed and the
sandwiched compute happens *in the network*, not at the endpoints.

Implemented fusions (each with its unfused endpoint-compute baseline so
benchmarks/tests can compare like-for-like):

  * allgather_op_allgather   — paper Fig. 5 (op = prefix sum, FEM pattern)
  * fused_allreduce_alltoall — NAS IS pattern (paper §II Type 4 example)
  * map_reduce_scatter / allgather_map — MapReduce pattern
  * allgather_matmul / matmul_reduce_scatter — "collective matmul":
    the map is a matmul shard and each hop's compute hides the next hop's
    communication (the production-relevant Type 4 for tensor parallelism).

All functions are rank-local (inside shard_map).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import ring
from repro.core.types import ADD, Monoid
from repro.core import collectives
from repro.core.lookaside import distributed_prefix_sum
from repro.core.wire import IDENTITY, WireCodec

PyTree = Any


# ---------------------------------------------------------------------------
# Fig. 5: Allgather_op_Allgather  (op = prefix sum)
# ---------------------------------------------------------------------------

def allgather_op_allgather_baseline(x: jax.Array, axis_name: str) -> jax.Array:
    """Endpoint-compute baseline (the MPI4py pattern of paper Fig. 5):
    allgather the blocks, compute the op at every endpoint, allgather the
    (locally relevant slice of the) result again.  Two full collective
    rounds + redundant endpoint compute."""
    n = lax.axis_size(axis_name)
    i = lax.axis_index(axis_name)
    gathered = collectives.all_gather(x, axis_name, backend="xla")
    scanned = jnp.cumsum(gathered, axis=0)
    # second round: each rank re-shares "its" slice of the result —
    # the redundant communication the fusion deletes.
    mine = lax.dynamic_slice_in_dim(scanned, i * x.shape[0], x.shape[0], 0)
    return collectives.all_gather(mine, axis_name, backend="xla")


def allgather_op_allgather(x: jax.Array, axis_name: str) -> jax.Array:
    """Fused version: the prefix-sum carry is computed *in the network*
    (log-step rank scan) and only the finished blocks are gathered — one
    gather round instead of two, no redundant endpoint compute."""
    scanned_local = distributed_prefix_sum(x, axis_name)
    return ring.ring_all_gather(scanned_local, axis_name)


def scan_then_allgather(x: jax.Array, axis_name: str, monoid: Monoid = ADD,
                        *, exclusive: bool = False) -> jax.Array:
    """Generalized Fig. 5 fusion: cross-rank ``monoid`` prefix scan with the
    finished blocks gathered in the same program — one gather round for any
    user-defined (Type 2) scan op, not just the prefix-sum special case."""
    scanned = collectives.prefix_scan(x, axis_name, monoid,
                                      exclusive=exclusive)
    return ring.ring_all_gather(scanned, axis_name)


# ---------------------------------------------------------------------------
# NAS IS: AllReduce (histogram) + AlltoAll (keys), fused on one schedule
# ---------------------------------------------------------------------------

def allreduce_alltoall_baseline(hist: jax.Array, keys: jax.Array,
                                axis_name: str) -> tuple[jax.Array, jax.Array]:
    """Sequential baseline: finish the allreduce, then start the alltoall."""
    h = collectives.all_reduce(hist, axis_name, ADD, backend="xla")
    k = collectives.all_to_all(keys, axis_name, backend="xla")
    return h, k


def fused_allreduce_alltoall(hist: jax.Array, keys: jax.Array,
                             axis_name: str) -> tuple[jax.Array, jax.Array]:
    """Fused schedule: the histogram reduction hops ride the same loop as
    the key-chunk exchange, so the (small) histogram combine hides behind
    the (large) key transfer at every hop — one traversal of the ring does
    both jobs (the paper's IS observation: "ACiS can take advantage of
    communication-computation overlap and in-network data reduction")."""
    n = lax.axis_size(axis_name)
    if n == 1:
        return hist, keys
    i = lax.axis_index(axis_name)
    chunk = keys.shape[0] // n
    ks = keys.reshape((n, chunk) + keys.shape[1:])
    out = jnp.zeros_like(ks)
    out = lax.dynamic_update_index_in_dim(
        out, lax.dynamic_index_in_dim(ks, i, 0, keepdims=False), i, axis=0)

    hacc, hmsg = hist, hist
    perm1 = [(j, (j + 1) % n) for j in range(n)]
    for s in range(1, n):
        perm_s = [(j, (j + s) % n) for j in range(n)]
        send = lax.dynamic_index_in_dim(ks, (i + s) % n, 0, keepdims=False)
        recv = lax.ppermute(send, axis_name, perm_s)          # key chunk hop
        out = lax.dynamic_update_index_in_dim(out, recv, (i - s) % n, axis=0)
        # histogram combine hop rides the same loop iteration (n-1 hops
        # total): rotate original contributions, fold into accumulator.
        hmsg = lax.ppermute(hmsg, axis_name, perm1)
        hacc = hacc + hmsg
    # after n-1 latency-ring hops every rank has the full histogram sum
    return hacc, out.reshape(keys.shape)


# ---------------------------------------------------------------------------
# MapReduce fusions
# ---------------------------------------------------------------------------

def map_reduce_scatter(x: jax.Array, axis_name: str,
                       map_fn: Callable[[jax.Array], jax.Array],
                       monoid: Monoid = ADD,
                       codec: WireCodec = IDENTITY) -> jax.Array:
    """map ∘ reduce-scatter in one schedule: the map is applied to each
    chunk right before it enters the ring (no full-size intermediate)."""
    mapped = map_fn(x)  # chunk-wise map fused by XLA into the hop loop
    return collectives.reduce_scatter(mapped, axis_name, monoid, codec=codec)


def allgather_map(x: jax.Array, axis_name: str,
                  map_fn: Callable[[jax.Array], jax.Array]) -> jax.Array:
    """all-gather ∘ map with the map applied in-flight (once per chunk, at
    the forwarding hop) instead of n times at every endpoint."""
    return ring.ring_all_gather(x, axis_name, hop_map=map_fn)


# ---------------------------------------------------------------------------
# Collective matmul (overlapped TP matmuls — the production Type 4)
# ---------------------------------------------------------------------------

def allgather_matmul(x_local: jax.Array, w_local: jax.Array,
                     axis_name: str) -> jax.Array:
    """y = allgather(x) @ w_local, overlapped.

    x_local: [m_loc, k] (row shard), w_local: [k, n_loc] (col shard of W).
    Result: [m_loc * n_ranks, n_loc].  Each hop's matmul hides the next
    block's rotation — the matmul happens "in the network".
    """
    n = lax.axis_size(axis_name)
    i = lax.axis_index(axis_name)
    m_loc = x_local.shape[0]
    out = jnp.zeros((n * m_loc, w_local.shape[1]), x_local.dtype)
    perm = [(j, (j + 1) % n) for j in range(n)]

    def body(carry, s):
        out, blk = carry
        owner = (i - s) % n
        y = blk @ w_local                     # compute current block...
        blk = lax.ppermute(blk, axis_name, perm)   # ...while rotating
        out = lax.dynamic_update_slice_in_dim(out, y, owner * m_loc, axis=0)
        return (out, blk), ()

    (out, last), _ = lax.scan(body, (out, x_local), jnp.arange(n - 1))
    owner = (i - (n - 1)) % n
    out = lax.dynamic_update_slice_in_dim(
        out, last @ w_local, owner * m_loc, axis=0)
    return out


def matmul_reduce_scatter(x_local: jax.Array, w_local: jax.Array,
                          axis_name: str) -> jax.Array:
    """y = reduce_scatter(x_local @ w_local), overlapped.

    x_local: [m, k_loc], w_local: [k_loc, N] with N divisible by n_ranks.
    Result: [m, N / n_ranks] — rank i owns column block i, fully reduced.
    The partial matmul for each column block is computed just-in-time as
    the accumulating buffer arrives (compute hides communication).
    """
    n = lax.axis_size(axis_name)
    i = lax.axis_index(axis_name)
    if n == 1:
        return x_local @ w_local
    nc = w_local.shape[1] // n
    perm = [(j, (j + 1) % n) for j in range(n)]

    def partial(c):
        wcols = lax.dynamic_slice_in_dim(w_local, c * nc, nc, axis=1)
        return x_local @ wcols

    buf = partial((i - 1) % n)

    def body(buf, s):
        incoming = lax.ppermute(buf, axis_name, perm)
        c = (i - 2 - s) % n
        return incoming + partial(c), ()

    buf, _ = lax.scan(body, buf, jnp.arange(n - 1))
    return buf


def allgather_matmul_baseline(x_local: jax.Array, w_local: jax.Array,
                              axis_name: str) -> jax.Array:
    x = collectives.all_gather(x_local, axis_name, backend="xla")
    return x @ w_local


def matmul_reduce_scatter_baseline(x_local: jax.Array, w_local: jax.Array,
                                   axis_name: str) -> jax.Array:
    """Unfused baseline: full partial matmul, then a separate reduce-scatter."""
    y = x_local @ w_local
    return _rs_cols(y, axis_name)


def _rs_cols(y: jax.Array, axis_name: str) -> jax.Array:
    """reduce-scatter over column blocks via psum_scatter."""
    n = lax.axis_size(axis_name)
    m, N = y.shape
    nc = N // n
    # [m, n, nc] -> scatter over axis 'n'
    yb = y.reshape(m, n, nc).swapaxes(0, 1)          # [n, m, nc]
    out = lax.psum_scatter(yb, axis_name, tiled=False)
    return out.reshape(m, nc)
