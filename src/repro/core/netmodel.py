"""Analytic network emulator — the paper's own evaluation methodology.

The paper's direct-network results (§V.A) come from an emulation with
"(1) the same volume of traffic in the network links, (2) an identical
number of network hops, and (3) an accurate overhead of the accelerator",
parameterized by Table II.  This module rebuilds that emulator so the
benchmark suite can reproduce the paper's figures (3-6) and so the
framework can *predict* collective latency when choosing schedules
(latency-vs-bandwidth crossover, Type 2/3 compression payoff).

Table II constants (measured by the authors on their testbed):
    MPI overhead        14.8 µs      (per software message)
    max network BW      95.9 Gb/s    (11.99 GB/s)
    PCIe latency        0.9 µs
    FPGA-FPGA link      0.44 µs      (Aurora)
    min port-to-port    52 ns
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.cgra.device import CGRADevice, PAPER_CGRA, placement_rate


@dataclasses.dataclass(frozen=True)
class NetParams:
    mpi_overhead: float = 14.8e-6
    bw: float = 95.9e9 / 8            # bytes/s
    pcie: float = 0.9e-6
    fpga_link: float = 0.44e-6
    port: float = 52e-9
    host_bw: float = 6e9 # endpoint compute stream (B/s)
    py_overhead: float = 15e-6        # MPI4py per-collective python cost
    # The in-switch accelerator is a *device*, not a rate constant: the
    # old accel_clock/accel_width pair is now the device's line rate at
    # II = 1, and a mapped stage's placement (repro.cgra.mapper) derives
    # the rate it actually sustains.
    device: CGRADevice = PAPER_CGRA

    @property
    def accel_clock(self) -> float:   # back-compat spelling
        return self.device.clock_hz

    @property
    def accel_width(self) -> int:     # back-compat spelling
        return self.device.lane_bytes


PAPER = NetParams()


def accel_rate(p: NetParams, placement=None) -> float:
    """In-switch compute throughput (bytes/s) for a stage.

    With a :class:`~repro.cgra.device.Placement` this is what the mapped
    op-graph sustains (``line_rate / II``); without one it is the
    device's line rate — the bare Type-1 fixed-function combine, the only
    compute allowed to be costed without a placement.  A host-fallback
    placement raises (cost the detour via :func:`host_fallback_time`).
    """
    return placement_rate(placement, p.device)


def host_fallback_time(m: int, p: NetParams = PAPER) -> float:
    """Cost of bouncing a stage's compute to the host NIC-side CPU.

    The payload detours over PCIe (out and back), pays one software
    message injection, and streams through the endpoint at ``host_bw`` —
    what a stage costs when its body does not fit the switch CGRA.
    """
    return 2 * p.pcie + p.mpi_overhead + m / p.host_bw

# ---------------------------------------------------------------------------
# Two-tier link parameters (multi-pod topologies).
#
# The intra-pod tier ("ici") is the paper's testbed fabric.  The inter-pod
# tier ("dci") models the thin links where a multi-pod system's flows
# converge: ~10× less bandwidth than ICI (the usual DCI/ICI provisioning
# ratio on pod-scale systems) and longer-reach links with ~5× the per-hop
# latency.  SelectSchedule costs each compiled stage against the tier of
# the axis it traverses; LowerTopology places wire compression on the DCI
# hop only, where those bytes are the bottleneck.
# ---------------------------------------------------------------------------

DCI_BW_RATIO = 0.1        # inter-pod bandwidth as a fraction of intra-pod
DCI_HOP_RATIO = 5.0       # inter-pod per-hop latency multiplier

ICI = PAPER
DCI = dataclasses.replace(
    PAPER,
    bw=PAPER.bw * DCI_BW_RATIO,
    fpga_link=PAPER.fpga_link * DCI_HOP_RATIO,
)

TIERS = {"ici": ICI, "dci": DCI}


def torus_hops(n: int) -> int:
    """Average hop count of a 3D-torus of n nodes (paper emulates 3D torus)."""
    side = max(round(n ** (1 / 3)), 1)
    return max(3 * (side // 2), 1)


# ---------------------------------------------------------------------------
# baseline MPI collectives (endpoint compute, passive network)
# ---------------------------------------------------------------------------

def mpi_allgather(n: int, m: int, p: NetParams = PAPER) -> float:
    """Bruck-style latency term + ring bandwidth term."""
    return math.ceil(math.log2(max(n, 2))) * p.mpi_overhead \
        + (n - 1) * m / p.bw


def mpi_allreduce(n: int, m: int, p: NetParams = PAPER) -> float:
    """Recursive-doubling latency + ring RS/AG bandwidth (MPI hybrid)."""
    return 2 * math.ceil(math.log2(max(n, 2))) * p.mpi_overhead \
        + 2 * (n - 1) / n * m / p.bw \
        + m / p.host_bw                       # endpoint reduction compute


def mpi_bcast(n: int, m: int, p: NetParams = PAPER) -> float:
    """Binomial tree."""
    return math.ceil(math.log2(max(n, 2))) * (p.mpi_overhead + m / p.bw)


def mpi_gather(n: int, m: int, p: NetParams = PAPER) -> float:
    """Binomial tree latency; root link carries all (n-1) payloads."""
    return math.ceil(math.log2(max(n, 2))) * p.mpi_overhead \
        + (n - 1) * m / p.bw


def mpi_alltoall(n: int, m: int, p: NetParams = PAPER) -> float:
    return (n - 1) * (p.mpi_overhead + (m / n) / p.bw)


# ---------------------------------------------------------------------------
# ACiS collectives (in-switch processing)
# ---------------------------------------------------------------------------

def _acis_base(n: int, p: NetParams) -> float:
    """Fixed path cost: host→NIC→fabric→…→host, once per collective."""
    return 2 * p.pcie + torus_hops(n) * (p.fpga_link + p.port) \
        + p.mpi_overhead  # one software injection (ExaMPI transport)


def acis_allgather(n: int, m: int, p: NetParams = PAPER) -> float:
    # replication happens in the fabric; each link still carries (n-1)m/n·…
    return _acis_base(n, p) + (n - 1) * m / p.bw \
        + (n - 1) * (p.fpga_link + p.port)


def acis_allreduce(n: int, m: int, p: NetParams = PAPER, *,
                   placement=None) -> float:
    """In-network reduction: messages merge as they travel — each link
    carries each byte once; combine runs at the placed rate in the CGRA
    (line rate when the combine is the bare Type-1 adder)."""
    stream = m / p.bw + m / accel_rate(p, placement)
    return _acis_base(n, p) + stream + math.ceil(
        math.log2(max(n, 2))) * (p.fpga_link + p.port)


def acis_bcast(n: int, m: int, p: NetParams = PAPER) -> float:
    return _acis_base(n, p) + m / p.bw + math.ceil(
        math.log2(max(n, 2))) * (p.fpga_link + p.port)


def acis_gather(n: int, m: int, p: NetParams = PAPER) -> float:
    return _acis_base(n, p) + (n - 1) * m / p.bw


def acis_alltoall(n: int, m: int, p: NetParams = PAPER) -> float:
    return _acis_base(n, p) + (n - 1) * (m / n) / p.bw \
        + (n - 1) * (p.fpga_link + p.port)


# ---------------------------------------------------------------------------
# fused chains (Type 4): intermediate communication is bypassed
# ---------------------------------------------------------------------------

def mpi4py_allgather_op_allgather(n: int, m: int,
                                  p: NetParams = PAPER) -> float:
    """Paper Fig. 5 baseline: AG → host prefix-sum → AG(v), plus python."""
    ag = mpi_allgather(n, m, p) + p.py_overhead
    op = (n * m) / p.host_bw + p.py_overhead
    return 2 * ag + op


def acis_allgather_op_allgather(n: int, m: int, p: NetParams = PAPER, *,
                                placement=None) -> float:
    """Fused: one traversal; the op streams through the CGRA in-flight.
    The paper's runtime is itself Python-based (§V: "the runtime and MPI
    support are based on Python"), so the fixed software cost appears once
    on this path too."""
    return _acis_base(n, p) + p.py_overhead + 2 * p.mpi_overhead \
        + (n - 1) * m / p.bw \
        + (n * m) / accel_rate(p, placement) \
        + (n - 1) * (p.fpga_link + p.port)


def mpi_allreduce_then_alltoall(n: int, m_hist: int, m_keys: int,
                                p: NetParams = PAPER) -> float:
    return mpi_allreduce(n, m_hist, p) + mpi_alltoall(n, m_keys, p)


# ---------------------------------------------------------------------------
# ring-schedule cost model (consumed by the compiler's SelectSchedule pass)
# ---------------------------------------------------------------------------

def ring_allreduce_time(n: int, m: int, p: NetParams = PAPER, *,
                        latency_optimal: bool = False,
                        placement=None) -> float:
    """Predicted wall time of one ring all-reduce of ``m`` bytes per rank.

    ``latency_optimal=True``: n-1 hops of full-size messages (one combine
    per hop) — few sequential hops, each carrying the whole payload.
    ``latency_optimal=False``: RS∘AG — 2(n-1) hops of m/n bytes each
    (bandwidth-optimal; right for large payloads).  ``placement`` is the
    stage's CGRA placement; the per-hop combine runs at its sustained
    rate (line rate for the bare Type-1 adder).
    """
    if n <= 1:
        return 0.0
    hop = p.fpga_link + p.port
    rate = accel_rate(p, placement)
    if latency_optimal:
        return (n - 1) * (m / p.bw + hop) + (n - 1) * m / rate
    return 2 * (n - 1) * ((m / n) / p.bw + hop) \
        + (n - 1) * (m / n) / rate


def ring_crossover_bytes(n: int, p: NetParams = PAPER) -> float:
    """Payload size at which the latency- and bandwidth-optimal rings tie.

    Below this, the (n-1)-hop full-message ring wins (per-hop latency
    dominates); above it, the chunked RS∘AG ring wins (wire bytes dominate).
    Derived from :func:`ring_allreduce_time` with the combine term dropped:
    t_lat < t_bw  ⇔  m (1 - 2/n) / bw < hop  for n > 2.

    Pass the link tier actually traversed (``ICI`` vs ``DCI``): a thin
    inter-pod wire pushes the crossover an order of magnitude lower.
    """
    if n <= 2:
        return float("inf")  # schedules move identical bytes; latency ties
    hop = p.fpga_link + p.port
    return hop * p.bw / (1.0 - 2.0 / n)


# Fraction of a bucket collective's time the fixed per-collective cost
# (the hop walk) is allowed to be: the Coalesce pass sizes its flat-buffer
# gradient buckets so the 2(n-1) ring hops are amortized down to this
# share of the bandwidth term.
BUCKET_OVERHEAD_FRACTION = 0.05

# Floor/ceiling for derived bucket sizes (unknown topologies get the
# floor — roughly the classic DDP bucket scale).
MIN_BUCKET_BYTES = 1 << 20
MAX_BUCKET_BYTES = 64 << 20


def bucket_bytes(n: Optional[int], p: NetParams = PAPER, *,
                 overhead_fraction: float = BUCKET_OVERHEAD_FRACTION) -> int:
    """Coalesce bucket size for an ``n``-rank ring on link tier ``p``.

    Solves ``2(n-1)·hop ≤ f · 2(n-1)/n · m/bw`` for ``m``: the payload at
    which the fixed hop walk of one more collective costs at most
    ``overhead_fraction`` of its streaming time.  Sits well above
    :func:`ring_crossover_bytes`, so bucketized stages are always in the
    bandwidth-optimal regime.  Unknown ``n`` falls back to the floor.
    """
    if n is None or n <= 1:
        return MIN_BUCKET_BYTES
    hop = p.fpga_link + p.port
    m = n * hop * p.bw / overhead_fraction
    return int(min(max(m, MIN_BUCKET_BYTES), MAX_BUCKET_BYTES))


def ring_reduce_scatter_time(n: int, m: int, p: NetParams = PAPER, *,
                             placement=None) -> float:
    """Chunked ring RS: n-1 hops of m/n bytes, one combine per hop."""
    if n <= 1:
        return 0.0
    hop = p.fpga_link + p.port
    return (n - 1) * ((m / n) / p.bw + hop) \
        + (n - 1) * (m / n) / accel_rate(p, placement)


def ring_all_gather_time(n: int, m: int, p: NetParams = PAPER) -> float:
    """Chunked ring AG: n-1 hops of m/n bytes, no combine."""
    if n <= 1:
        return 0.0
    hop = p.fpga_link + p.port
    return (n - 1) * ((m / n) / p.bw + hop)


def batched_ring_times(n: int, sizes, p: NetParams = PAPER, *,
                       latency_optimal: bool = False
                       ) -> tuple[float, float]:
    """(separate, batched) wall time of k same-axis ring all-reduces.

    ``separate`` launches one ring per payload — k full hop walks;
    ``batched`` is ONE ring over the stacked payload (the Coalesce
    ``batch_rings`` rewrite), paying the walk once.  The gap is the
    launch amortization: ``(k-1) · hops · (fpga_link + port)`` plus the
    per-launch bandwidth remainder of ragged chunking.
    """
    sizes = [float(m) for m in sizes]
    separate = sum(ring_allreduce_time(n, m, p,
                                       latency_optimal=latency_optimal)
                   for m in sizes)
    batched = ring_allreduce_time(n, sum(sizes), p,
                                  latency_optimal=latency_optimal)
    return separate, batched


def bucketed_collective_times(kind: str, n: int, sizes,
                              p: NetParams = PAPER) -> tuple[float, float]:
    """(separate, bucketed) wall time of k same-axis RS or AG leaves.

    ``kind`` ∈ {"reduce_scatter", "allgather"}.  The Coalesce RS/AG
    bucket runs one collective over the concatenated payload; like the
    allreduce buckets, the saving is the k-1 amortized hop walks.  For
    AG, ``sizes`` are per-rank *input* shard bytes (the model's AG kind
    convention: the gathered payload is ``n · m``).
    """
    sizes = [float(m) for m in sizes]
    if kind == "reduce_scatter":
        sep = sum(ring_reduce_scatter_time(n, m, p) for m in sizes)
        tot = ring_reduce_scatter_time(n, sum(sizes), p)
    elif kind == "allgather":
        sep = sum(ring_all_gather_time(n, n * m, p) for m in sizes)
        tot = ring_all_gather_time(n, n * sum(sizes), p)
    else:
        raise ValueError(f"bucketed_collective_times: unknown {kind!r}")
    return sep, tot


def hierarchical_allreduce_time(d: int, pods: int, m: int, *,
                                inner: NetParams = ICI,
                                outer: NetParams = DCI) -> float:
    """RS(inner, d ranks) → AR(outer, pods ranks, m/d shard) → AG(inner).

    The compiled LowerTopology schedule: the thin inter-pod tier only ever
    carries 1/d of the payload, vs a flat AR over d·pods ranks pushing
    2·(dp-1)/dp of every byte through the DCI links too.
    """
    shard = m / max(d, 1)
    return ring_reduce_scatter_time(d, m, inner) \
        + ring_allreduce_time(pods, shard, outer) \
        + ring_all_gather_time(d, m, inner)


# Fraction of the histogram reduction left exposed past the key exchange
# in the fused AR+A2A schedule: the shared ring cannot start combining
# until the first key chunk lands (pipeline fill), which the emulation
# charges as a 10% un-overlapped remainder of the reduction time.
FUSED_EXPOSED_FRACTION = 0.1


def acis_fused_allreduce_alltoall(n: int, m_hist: int, m_keys: int,
                                  p: NetParams = PAPER, *,
                                  placement=None) -> float:
    """Shared schedule: the histogram hops ride the key exchange; the
    reduction is free behind the (larger) key traffic.

    This is the *application-level* emulator term (one per-collective
    software/PCIe base cost included), paired against the MPI baseline in
    the paper figures.  The per-stage compiled-plan model —
    :func:`stage_time` / the dataplane simulator — uses
    :func:`fused_ar_a2a_ring_time`, the bare shared-traversal walk.
    """
    keys = acis_alltoall(n, m_keys, p)
    hist_exposed = max(0.0, acis_allreduce(n, m_hist, p,
                                           placement=placement) - keys)
    return keys + FUSED_EXPOSED_FRACTION * hist_exposed \
        + m_hist / accel_rate(p, placement)


def fused_ar_a2a_ring_time(n: int, m_hist: int, m_keys: int,
                           p: NetParams = PAPER, *,
                           placement=None) -> float:
    """Shared-ring traversal of the fused AR+A2A stage, hop-exact.

    Mirrors the dataplane simulator's walk (one traversal, n-1 hops):
    every hop forwards one key chunk (``m_keys/n``) *plus* the whole
    histogram (the reduction rides every hop), and combines the
    histogram at the placed rate.  No per-collective software base cost
    — per-stage models are composed by :func:`program_time`, which is
    also what the simulator validates.
    """
    if n <= 1:
        return 0.0
    hop = p.fpga_link + p.port
    chunk = m_keys / n + m_hist
    return (n - 1) * (hop + chunk / p.bw
                      + m_hist / accel_rate(p, placement))


# ---------------------------------------------------------------------------
# per-stage analytic model (PlaceCGRA / dataplane-simulator comparison)
# ---------------------------------------------------------------------------

# stage kinds whose pipe runs a fused MAP body: costing them needs a real
# placement — there is deliberately no constant-rate default for MAP work.
_MAP_KINDS = {"map", "map+allreduce", "map+reduce_scatter",
              "allgather+map"}


def stage_time(kind: str, n: int, m: int, p: NetParams = PAPER, *,
               placement=None, schedule: str = "",
               codec_ratio: float = 1.0,
               m_parts: Optional[tuple] = None) -> float:
    """Predicted wall time of one emitted stage.

    ``kind`` is a :class:`~repro.core.compiler.Stage` kind, ``n`` the
    size of the axis it traverses, ``m`` the per-rank payload bytes
    *before* wire coding (``codec_ratio`` scales what actually travels).
    ``m_parts`` splits ``m`` per operand for multi-input stages whose
    traversal treats the operands asymmetrically (the fused AR+A2A pair:
    ``(m_hist, m_keys)``); without it an even split is assumed.

    ``placement`` is the stage's CGRA mapping.  Stages that stream a
    fused MAP body **require** one — the old flat ``accel_clock *
    accel_width`` constant is gone, and asking for a MAP-stage time
    without saying where the map runs raises instead of silently
    assuming line rate.  A :class:`~repro.cgra.device.HostFallback`
    placement is costed as the PCIe + MPI host detour.
    """
    if kind in _MAP_KINDS and placement is None:
        raise ValueError(
            f"stage kind {kind!r} streams a fused map: pass its CGRA "
            "placement (or HostFallback) — there is no constant-rate "
            "default for MAP compute")
    fallback = placement is not None and not getattr(placement, "fits",
                                                     True)
    wire = m * codec_ratio
    hop = p.fpga_link + p.port
    lat = schedule == "latency"
    pl = None if fallback else placement

    if kind == "map":
        return host_fallback_time(m, p) if fallback \
            else m / accel_rate(p, pl)
    if kind in ("allreduce", "map+allreduce", "batched_allreduce"):
        # a batched_allreduce IS one ring over the stacked payload — the
        # amortization (k-1 launch walks saved) is already in m being the
        # sum of the merged payloads
        if fallback:
            return host_fallback_time(m, p) + mpi_allreduce(n, wire, p)
        return ring_allreduce_time(n, wire, p, latency_optimal=lat,
                                   placement=pl)
    if kind in ("reduce_scatter", "map+reduce_scatter"):
        if fallback:
            return host_fallback_time(m, p) \
                + ring_reduce_scatter_time(n, wire, p)
        return ring_reduce_scatter_time(n, wire, p, placement=pl)
    if kind == "allgather+map":
        # m is the per-rank *input* shard; each of the n-1 hops forwards
        # one full shard (the gathered payload is n*m), and the hop map
        # runs once per forwarded shard
        gather = ring_all_gather_time(n, n * m, p)
        if fallback:
            return host_fallback_time(m, p) + gather
        return gather + (n - 1) * m / accel_rate(p, pl)
    if kind == "allgather":
        return ring_all_gather_time(n, n * m, p)
    if kind == "alltoall":
        return (n - 1) * ((m / n) / p.bw + hop) if n > 1 else 0.0
    if kind == "bcast":
        return math.ceil(math.log2(max(n, 2))) * (m / p.bw + hop)
    if kind == "scan":
        rounds = math.ceil(math.log2(max(n, 2)))
        if fallback:
            return host_fallback_time(m, p) + rounds * (m / p.bw + hop)
        return rounds * (m / p.bw + hop + m / accel_rate(p, pl))
    if kind == "scan+allgather":
        t = stage_time("scan", n, m, p, placement=placement)
        return t + ring_all_gather_time(n, n * m, p)
    if kind == "delivered":
        # purely local: what the lossy wire delivered of this rank's own
        # contribution — no collective happens
        return host_fallback_time(m, p) if fallback \
            else m / accel_rate(p, pl)
    if kind == "ef_allreduce":
        # shared-scale path: a tiny latency-ring scale exchange plus the
        # quantized (≈ half-width) payload on the RS∘AG walk
        if fallback:
            return host_fallback_time(m, p) + mpi_allreduce(n, m, p)
        compress = m / accel_rate(p, pl)
        scale = ring_allreduce_time(n, max(m // 256, 4), p,
                                    latency_optimal=True)
        return compress + scale + ring_allreduce_time(n, m // 2, p)
    if kind == "allreduce+alltoall":
        # the pair's per-rank payloads: the stamped per-operand split
        # (hist, keys), or an even split of the summed m as a fallback
        m_hist, m_keys = (m_parts if m_parts and len(m_parts) == 2
                          else (m // 2, m // 2))
        if fallback:
            return host_fallback_time(m, p) \
                + mpi_allreduce_then_alltoall(n, m_hist, m_keys, p)
        return fused_ar_a2a_ring_time(n, m_hist, m_keys, p, placement=pl)
    raise ValueError(f"unknown stage kind {kind!r}")


# ---------------------------------------------------------------------------
# per-stage linear decomposition (repro.tune.fit least-squares design)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StageTerms:
    """:func:`stage_time` decomposed over the fittable unknowns.

    For the ring-schedule kinds the stage time is linear in the link
    parameters::

        t = hops · (fpga_link + port)
          + wire_bytes / bw
          + compute_bytes / accel_rate(p, placement)
          + detours · (2·pcie + mpi_overhead)
          + host_bytes / host_bw
          + mpi_msgs · mpi_overhead

    which is what lets :mod:`repro.tune.fit` recover per-tier latency and
    bandwidth (and the host-fallback detour) from recorded traces by
    least squares.  ``compute_bytes`` and ``mpi_msgs`` are charged at
    their prior rates by the fit (the CGRA device is not a wire).
    """

    hops: float = 0.0
    wire_bytes: float = 0.0
    compute_bytes: float = 0.0
    detours: float = 0.0
    host_bytes: float = 0.0
    mpi_msgs: float = 0.0

    def __add__(self, other: "StageTerms") -> "StageTerms":
        return StageTerms(*(a + b for a, b in
                            zip(dataclasses.astuple(self),
                                dataclasses.astuple(other))))

    def time(self, p: NetParams, placement=None) -> float:
        """Re-assemble the stage time under ``p`` — matches
        :func:`stage_time` exactly for every decomposable kind."""
        hop = p.fpga_link + p.port
        return self.hops * hop + self.wire_bytes / p.bw \
            + (self.compute_bytes / accel_rate(p, placement)
               if self.compute_bytes else 0.0) \
            + self.detours * (2 * p.pcie + p.mpi_overhead) \
            + self.host_bytes / p.host_bw \
            + self.mpi_msgs * p.mpi_overhead


def stage_time_terms(kind: str, n: int, m: int, *, schedule: str = "",
                     codec_ratio: float = 1.0, fallback: bool = False,
                     m_parts: Optional[tuple] = None
                     ) -> Optional[StageTerms]:
    """The :class:`StageTerms` decomposition of :func:`stage_time`.

    Mirrors the per-kind formulas above term by term (a unit test pins
    the two against each other); returns None for kinds with no linear
    form.  ``fallback=True`` selects the host-detour branch of the kind.
    """
    T = StageTerms
    wire = m * codec_ratio
    L = math.ceil(math.log2(max(n, 2)))
    ring = max(n - 1, 0)

    def host(extra_host: float = 0.0) -> StageTerms:
        # host_fallback_time: 2·pcie + mpi + m/host_bw
        return T(detours=1.0, host_bytes=m + extra_host)

    def mpi_ar(mm: float) -> StageTerms:
        # mpi_allreduce: 2L software messages + ring RS/AG wire + endpoint
        return T(wire_bytes=2 * ring / max(n, 1) * mm, host_bytes=mm,
                 mpi_msgs=2 * L)

    if kind == "map":
        return host() if fallback else T(compute_bytes=m)
    if kind in ("allreduce", "map+allreduce", "batched_allreduce"):
        if fallback:
            return host() + mpi_ar(wire)
        if n <= 1:
            return T()
        if schedule == "latency":
            return T(hops=ring, wire_bytes=ring * wire,
                     compute_bytes=ring * wire)
        return T(hops=2 * ring, wire_bytes=2 * ring * (wire / n),
                 compute_bytes=ring * (wire / n))
    if kind in ("reduce_scatter", "map+reduce_scatter"):
        rs = T() if n <= 1 else T(hops=ring, wire_bytes=ring * (wire / n),
                                  compute_bytes=ring * (wire / n))
        return host() + rs if fallback else rs
    if kind == "allgather":
        return T() if n <= 1 else T(hops=ring, wire_bytes=ring * m)
    if kind == "allgather+map":
        ag = T() if n <= 1 else T(hops=ring, wire_bytes=ring * m)
        return host() + ag if fallback else ag + T(compute_bytes=ring * m)
    if kind == "alltoall":
        return T() if n <= 1 else T(hops=ring, wire_bytes=ring * (m / n))
    if kind == "bcast":
        return T(hops=L, wire_bytes=L * m)
    if kind == "scan":
        base = T(hops=L, wire_bytes=L * m)
        return host() + base if fallback \
            else base + T(compute_bytes=L * m)
    if kind == "scan+allgather":
        sc = stage_time_terms("scan", n, m, fallback=fallback)
        return sc + stage_time_terms("allgather", n, m)
    if kind == "delivered":
        return host() if fallback else T(compute_bytes=m)
    if kind == "ef_allreduce":
        if fallback:
            return host() + mpi_ar(m)
        s = max(m // 256, 4)
        half = m // 2
        scale = T() if n <= 1 else T(hops=ring, wire_bytes=ring * s,
                                     compute_bytes=ring * s)
        rs_ag = T() if n <= 1 else T(hops=2 * ring,
                                     wire_bytes=2 * ring * (half / n),
                                     compute_bytes=ring * (half / n))
        return T(compute_bytes=m) + scale + rs_ag
    if kind == "allreduce+alltoall":
        m_hist, m_keys = (m_parts if m_parts and len(m_parts) == 2
                          else (m // 2, m // 2))
        if fallback:
            # mpi_allreduce(hist) + mpi_alltoall(keys)
            return host() + mpi_ar(m_hist) \
                + T(wire_bytes=ring * (m_keys / n), mpi_msgs=ring)
        return T() if n <= 1 else T(
            hops=ring, wire_bytes=ring * (m_keys / n + m_hist),
            compute_bytes=ring * m_hist)
    return None


def plan_stage_terms(st, topo=None) -> Optional[tuple]:
    """``(tier, terms, placement)`` for one emitted plan stage, or None.

    The per-stage analogue of :func:`plan_stage_time` that
    :mod:`repro.tune.fit` builds its least-squares design rows from:
    ``tier`` names the link whose (hop, 1/bw) columns the stage loads,
    ``placement`` fixes the compute rate the fit charges at its prior.
    """
    ir = getattr(st, "ir", None)
    m = getattr(ir, "bytes_in", None)
    if m is None:
        return None
    n = 1
    if st.axis:
        if topo is None or topo.size(st.axis) is None:
            return None
        n = topo.size(st.axis)
    placement = st.placement
    if st.kind in _MAP_KINDS and placement is None:
        return None
    fallback = placement is not None and not getattr(placement, "fits",
                                                     True)
    ratio = 1.0
    for nd in getattr(ir, "nodes", ()):
        codec = nd.op.codec
        if getattr(codec, "wire_ratio", 1.0) != 1.0:
            ratio = float(codec.wire_ratio)
    terms = stage_time_terms(st.kind, n, m, schedule=st.schedule,
                             codec_ratio=ratio, fallback=fallback,
                             m_parts=getattr(ir, "bytes_parts", None))
    if terms is None:
        return None
    return _tier_of(st.axis, topo), terms, (None if fallback else placement)


# ---------------------------------------------------------------------------
# program-level cost (ExecutionPlan critical path with per-tier overlap)
# ---------------------------------------------------------------------------

# How much of a *non-critical* concurrent stage's time the fabric hides
# when independent stages of one wave run together.  Keyed by the link
# tier of the stage being overlapped: different-axis rings use disjoint
# links, but every rank *injects* into all of its rings through one
# port, so the wire-serialization share of a concurrent stage stays
# exposed while propagation and in-switch compute hide.  Purely local
# (axis-less) compute streams behind whatever communication is in
# flight.  1.0 = the stage is entirely hidden behind the wave's critical
# path, 0.0 = it serializes (the old sum-of-stages model).
#
# The ici/dci fractions are CALIBRATED, not priors: fitted by
# :func:`fit_tier_overlap` against the dataplane simulator's overlapped
# ``SimReport.t_end`` (which charges injection contention at the shared
# port) across the cross-axis points of ``benchmarks/execplan.py``
# (`python -m benchmarks.run` prints the current fit as
# ``execplan_tier_overlap_calibration``).  The pre-calibration priors
# were ici 0.9 / dci 0.6 — far too optimistic for bandwidth-bound
# stages, whose time is mostly injection serialization the shared port
# cannot hide.
TIER_OVERLAP = {"ici": 0.29, "dci": 0.13, "local": 1.0}


def plan_stage_time(st, topo=None, p: NetParams = PAPER) -> Optional[float]:
    """:func:`stage_time` for one emitted stage of an ExecutionPlan.

    ``st`` duck-types :class:`repro.core.compiler.Stage` (``kind``,
    ``axis``, ``schedule``, ``placement``, and an ``ir`` carrying
    ``bytes_in`` — raw per-rank payload bytes — plus the fused nodes'
    wire codec).  ``topo`` duck-types :class:`repro.core.compiler.
    Topology` for per-axis ring sizes and link tiers.  Returns None when
    the payload or the axis size is unknown.
    """
    ir = getattr(st, "ir", None)
    m = getattr(ir, "bytes_in", None)
    if m is None:
        return None
    n = 1
    net = p
    if st.axis:
        if topo is None or topo.size(st.axis) is None:
            return None
        n = topo.size(st.axis)
        net = topo.net(st.axis)
    ratio = 1.0
    for nd in getattr(ir, "nodes", ()):
        codec = nd.op.codec
        if getattr(codec, "wire_ratio", 1.0) != 1.0:
            ratio = float(codec.wire_ratio)
    try:
        return stage_time(st.kind, n, m, net, placement=st.placement,
                          schedule=st.schedule, codec_ratio=ratio,
                          m_parts=getattr(ir, "bytes_parts", None))
    except ValueError:
        return None


def _tier_of(axis: str, topo) -> str:
    if not axis:
        return "local"
    spec = topo.spec(axis) if topo is not None else None
    return spec.tier if spec is not None else "ici"


def _wave_terms(plan, topo=None, p: NetParams = PAPER):
    """Per wave: ``(base, exposed)`` — the longest per-axis serialized
    chain, and every *other* axis's chain keyed by its link tier (the
    part a tier's overlap fraction can hide).  The shared decomposition
    under :func:`program_time` and :func:`fit_tier_overlap`."""
    terms = []
    for wave in plan.waves:
        per_axis: dict[str, float] = {}
        for i in wave:
            st = plan.stages[i]
            t = plan_stage_time(st, topo, p)
            if t:
                per_axis[st.axis] = per_axis.get(st.axis, 0.0) + t
        if not per_axis:
            continue
        longest_axis = max(per_axis, key=per_axis.get)
        exposed: dict[str, float] = {}
        for ax, t in per_axis.items():
            if ax != longest_axis:
                tier = _tier_of(ax, topo)
                exposed[tier] = exposed.get(tier, 0.0) + t
        terms.append((per_axis[longest_axis], exposed))
    return terms


def program_time(plan, topo=None, p: NetParams = PAPER, *,
                 overlap: Optional[dict] = None) -> float:
    """Predicted wall time of a whole compiled program's ExecutionPlan.

    Within each wave, stages traversing *different* axes use disjoint
    links and overlap; stages sharing an axis serialize on its ring.
    The wave costs its longest per-axis chain plus, for every other
    axis, the un-hidden ``(1 - TIER_OVERLAP[tier])`` remainder of that
    axis's chain.  Summed over waves this is a critical-path cost:
    always ≥ the longest single stage and ≤ the plain sum of stage
    times (the pre-plan model).

    Stages whose payload or axis size is unknown contribute zero — cost
    what the model can see rather than refusing the whole program.
    """
    ov = dict(TIER_OVERLAP)
    if overlap:
        ov.update(overlap)
    total = 0.0
    for base, exposed in _wave_terms(plan, topo, p):
        total += base
        for tier, t in exposed.items():
            total += (1.0 - ov.get(tier, 1.0)) * t
    return total


def fit_tier_overlap(samples, *, tiers=("ici", "dci"),
                     p: NetParams = PAPER) -> dict:
    """Least-squares calibration of :data:`TIER_OVERLAP` from measured
    overlapped end-to-end latencies.

    ``samples`` is an iterable of ``(plan, topo, t_measured)`` — e.g. the
    dataplane simulator's ``SimReport.t_end`` for programs whose waves
    hold cross-axis stages.  :func:`program_time` is linear in the
    per-tier exposure ``x_t = 1 - overlap_t``::

        t = Σ_w base_w + Σ_t x_t · B_t ,  B_t = Σ_w exposed_w[t]

    so the fit solves the normal equations of ``Σ_i (Σ_t B_it x_t -
    (t_i - A_i))²`` over the requested tiers, clamping each overlap into
    [0, 1].  Tiers with no exposure in any sample keep their current
    :data:`TIER_OVERLAP` value.  Returns ``{tier: fitted_overlap}``
    (does not mutate the module constant).
    """
    samples = [(plan, topo, t_meas,
                list(_wave_terms(plan, topo, p)))
               for plan, topo, t_meas in samples]
    live = list(tiers)
    while True:
        # assemble the normal equations over the currently fittable
        # tiers; any other tier's exposure is charged at its current
        # TIER_OVERLAP value and folded into the base
        k = len(live)
        gram = [[0.0] * k for _ in range(k)]
        rhs = [0.0] * k
        for _, _, t_meas, terms in samples:
            base = 0.0
            b = [0.0] * k
            for wave_base, exposed in terms:
                base += wave_base
                for t_name, t_val in exposed.items():
                    if t_name in live:
                        b[live.index(t_name)] += t_val
                    else:
                        base += (1.0 - TIER_OVERLAP.get(t_name, 1.0)) \
                            * t_val
            r = t_meas - base
            for i in range(k):
                rhs[i] += b[i] * r
                for j in range(k):
                    gram[i][j] += b[i] * b[j]
        # a tier with no exposure, or whose column is (nearly) linearly
        # dependent on the others, cannot be identified from these
        # samples: drop it from the fit (it keeps its current value)
        # and RE-solve — silently zeroing its variable while reporting
        # the old constant would make the returned fit inconsistent
        # with the equations it was solved from
        dead = next((t for i, t in enumerate(live)
                     if gram[i][i] <= 0.0), None)
        if dead is None:
            a = [row[:] + [rhs[i]] for i, row in enumerate(gram)]
            singular = None
            for col in range(k):
                piv = max(range(col, k), key=lambda r_: abs(a[r_][col]))
                scale = max(abs(gram[col][col]), 1e-30)
                if abs(a[piv][col]) < 1e-9 * scale:
                    singular = live[col]
                    break
                a[col], a[piv] = a[piv], a[col]
                for r_ in range(k):
                    if r_ != col and a[r_][col]:
                        f = a[r_][col] / a[col][col]
                        a[r_] = [x - f * y for x, y in zip(a[r_], a[col])]
            dead = singular
        if dead is not None:
            live.remove(dead)
            if live:
                continue
            return {t: TIER_OVERLAP[t] for t in tiers
                    if t in TIER_OVERLAP}
        fitted = dict(TIER_OVERLAP)
        for i, t in enumerate(live):
            x = a[i][-1] / a[i][i]
            fitted[t] = min(max(1.0 - x, 0.0), 1.0)
        return {t: fitted[t] for t in tiers if t in fitted}
