"""Traced-DAG frontend — write switch programs as plain Python functions.

The paper's toolchain starts from user source and recovers a dataflow
graph; here the user writes an ordinary function over symbolic
:class:`Value` handles and :func:`trace` records the graph directly:

    from repro import core as acis

    def histogram_shuffle(hist, keys):
        h = acis.reduce(acis.map(jnp.square, hist))
        k = acis.all_to_all(keys)
        return h, k

    prog = acis.trace(histogram_shuffle)          # -> DagProgram
    fn = engine.compile(prog, mesh, in_specs, out_specs)

Every op below accepts and returns :class:`Value` handles and may only be
called on values of the trace in progress.  Multiple inputs and multiple
outputs are natural — no tuple hacks.  Node creation order is the DAG's
topological order.
"""

from __future__ import annotations

import builtins
import inspect
from typing import Callable, Optional, Union

import jax.numpy as jnp
from jax import lax

from repro.core.program import (Axis, DagNode, DagProgram, ErrorFeedback,
                                Node, OpKind)
from repro.core.types import ADD, Monoid
from repro.core.wire import WireCodec


class Value:
    """Symbolic handle to one tensor flowing through a traced program."""

    __slots__ = ("_tracer", "vid")

    def __init__(self, tracer: "_Tracer", vid: int):
        self._tracer = tracer
        self.vid = vid

    def __repr__(self):  # pragma: no cover
        return f"Value({self.vid})"


class _Tracer:
    def __init__(self, num_inputs: int):
        self.num_inputs = num_inputs
        self.nodes: list[DagNode] = []
        self._next_vid = num_inputs

    def emit(self, op: Node, inputs: tuple[Value, ...]) -> Value:
        for v in inputs:
            if not isinstance(v, Value):
                raise TypeError(
                    f"{op.kind.value} expects traced Values, got "
                    f"{type(v).__name__}; switch ops only run under trace()")
            if v._tracer is not self:
                raise ValueError(
                    f"{op.kind.value} received a Value from a different "
                    "trace — values cannot cross trace boundaries")
        out = self._next_vid
        self._next_vid += 1
        self.nodes.append(DagNode(op, tuple(v.vid for v in inputs), out))
        return Value(self, out)


_ACTIVE: list[_Tracer] = []


def _current(op_name: str) -> _Tracer:
    if not _ACTIVE:
        raise RuntimeError(
            f"acis.{op_name} called outside trace(); wrap the program in "
            "a function and pass it to trace() / engine.compile()")
    return _ACTIVE[-1]


def trace(fn: Callable, *, name: Optional[str] = None,
          num_inputs: Optional[int] = None) -> DagProgram:
    """Trace ``fn`` (a function of Value handles) into a :class:`DagProgram`.

    The program's input arity is the function's positional arity, not
    counting parameters with defaults (override with ``num_inputs`` for
    ``*args`` signatures); its outputs are whatever the function returns —
    a Value or a tuple/list of Values.
    """
    if num_inputs is None:
        sig = inspect.signature(fn)
        if any(p.kind == p.VAR_POSITIONAL for p in sig.parameters.values()):
            raise ValueError("pass num_inputs= for *args signatures")
        # parameters with defaults are configuration, not program inputs —
        # feeding them Values would smuggle symbols into e.g. `exclusive=`
        num_inputs = len([
            p for p in sig.parameters.values()
            if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)
            and p.default is p.empty])
    if num_inputs < 1:
        raise ValueError("traced programs need at least one input")
    tracer = _Tracer(num_inputs)
    args = [Value(tracer, i) for i in range(num_inputs)]
    _ACTIVE.append(tracer)
    try:
        result = fn(*args)
    finally:
        _ACTIVE.pop()
    outs = result if isinstance(result, (tuple, builtins.list)) else (result,)
    for v in outs:
        if not isinstance(v, Value) or v._tracer is not tracer:
            raise TypeError(
                "traced function must return Value(s) from this trace, got "
                f"{type(v).__name__}")
    return DagProgram(num_inputs, tuple(tracer.nodes),
                      tuple(v.vid for v in outs),
                      name or getattr(fn, "__name__", "traced"))


# -- traced ops (the user-facing program vocabulary) -------------------------

def map(fn: Callable, *xs: Value, name: str = "",  # noqa: A001
        fusable: bool = True, elementwise: bool = False) -> Value:
    """Apply ``fn`` elementwise/locally; fusable into adjacent hops.

    ``fn`` must be *chunk-local* (elementwise or otherwise independent of
    how the tensor is split across ranks) unless ``fusable=False``: when
    the compiler fuses it into a collective's hop loop it runs once per
    in-flight chunk, so a function that mixes values across positions
    (e.g. ``cumsum``) would compute something different fused vs unfused.
    That is the IR's MAP contract, not a compiler quirk — use ``scan``
    for cross-rank ops, or mark the map ``fusable=False`` to keep it a
    standalone whole-payload stage.

    Accepts multiple inputs (``fn`` is called as ``fn(*tensors)``) — the
    only op that may, which is what lets one program combine tensors.

    ``elementwise=True`` is a stronger promise than chunk-locality: the
    body is strictly per-element (``fn(concat(xs)) == concat(fn(x) for
    x)``), which lets the Coalesce pass hoist the map off every bucketed
    leaf and run it once on the flat bucket instead.
    """
    if not xs:
        raise TypeError("map needs at least one input value")
    return _current("map").emit(
        Node(OpKind.MAP, fn=fn, fusable=fusable, elementwise=elementwise,
             name=name or getattr(fn, "__name__", "")), xs)


def _unary(op_name: str, op: Node, x: Value) -> Value:
    # always emit into the *active* trace — going through the Value's own
    # tracer would let a handle stashed from a finished trace silently
    # append nodes to a dead graph
    return _current(op_name).emit(op, (x,))


def reduce(x: Value, monoid: Monoid = ADD, *,  # noqa: A001
           axis: Axis = None) -> Value:
    """All-reduce over ``axis`` — ``None`` = the engine default axis,
    ``"auto"`` = every data-parallel axis of the compile topology (the
    LowerTopology pass then emits the hierarchical RS/AR/AG schedule)."""
    return _unary("reduce", Node(OpKind.REDUCE, monoid=monoid, axis=axis), x)


def reduce_scatter(x: Value, monoid: Monoid = ADD, *,
                   axis: Axis = None) -> Value:
    return _unary("reduce_scatter",
                  Node(OpKind.REDUCE_SCATTER, monoid=monoid, axis=axis), x)


def all_gather(x: Value, *, axis: Axis = None) -> Value:
    return _unary("all_gather", Node(OpKind.ALLGATHER, axis=axis), x)


def all_to_all(x: Value, *, axis: Axis = None) -> Value:
    return _unary("all_to_all", Node(OpKind.ALLTOALL, axis=axis), x)


def scan(x: Value, monoid: Monoid = ADD, *, exclusive: bool = False,
         axis: Axis = None) -> Value:
    return _unary("scan",
                  Node(OpKind.SCAN, monoid=monoid, exclusive=exclusive,
                       axis=axis), x)


def bcast(x: Value, root: int = 0, *, axis: Axis = None) -> Value:
    return _unary("bcast", Node(OpKind.BCAST, root=root, axis=axis), x)


def ef_reduce(x: Value, *, compressor: str = "int8",
              topk_ratio: float = 0.01,
              axis: Axis = None) -> tuple[Value, Value]:
    """Error-feedback compressed all-reduce (Type 3 look-aside).

    Returns ``(reduced, delivered)``: the lossily-reduced total, and what
    the lossy wire delivered of *this rank's* contribution — the caller
    forms the residual as ``target - delivered``.  The two values are
    sibling DAG nodes sharing one input; the compiler pairs them back into
    a single look-aside stage so the compression runs once.  If the
    program drops ``delivered``, DCE removes the sibling and only the
    reduction is emitted.
    """
    ef = ErrorFeedback(compressor=compressor, topk_ratio=topk_ratio)
    red = _unary("ef_reduce", Node(OpKind.REDUCE, ef=ef, axis=axis), x)
    dlv = _unary("ef_reduce", Node(OpKind.DELIVERED, ef=ef, axis=axis), x)
    return red, dlv


def _masked_renorm_fn(renormalize: bool) -> Callable:
    """Unpack the ``[size+1]`` masked-reduce buffer back to the payload
    shape, dividing by the live count when renormalizing.  ``orig`` is the
    pre-reduce value — the runtime shape donor, like the compiler's
    ``_unpad_like``."""

    def masked_renorm(packed, orig):
        # static slices, not int indexing — packed[-1] lowers to a
        # gather the switch CGRA cannot place
        n = packed.shape[-1] - 1
        body = lax.slice_in_dim(packed, 0, n, axis=-1)
        if renormalize:
            cnt = jnp.maximum(
                lax.slice_in_dim(packed, n, n + 1, axis=-1), 1)
            body = body / cnt.astype(body.dtype)
        return body.reshape(orig.shape)
    masked_renorm.masked_renormalize = renormalize
    return masked_renorm


def _masked_count_fn():
    def masked_count(packed):
        # clamped so a (transient) all-dead view cannot divide by zero —
        # parity with the deprecated topology.masked_all_reduce contract
        n = packed.shape[-1] - 1
        cnt = lax.slice_in_dim(packed, n, n + 1, axis=-1)
        return jnp.maximum(cnt, jnp.asarray(1, packed.dtype)).reshape(
            packed.shape[:-1])
    return masked_count


def masked_reduce(x: Value, alive: Value, monoid: Monoid = ADD, *,
                  axis: Axis = None,
                  renormalize: bool = True) -> tuple[Value, Value]:
    """Bounded-staleness all-reduce: ranks with ``alive == 0`` contribute
    the monoid identity, and the live count travels in the *same* flat
    ring buffer as the payload — one collective launch, not two.

    ``alive`` is this rank's liveness flag (scalar, nonzero = alive), a
    runtime input — changing the mask never retraces or recompiles.
    Returns ``(value, count)``: the masked reduction (renormalized by the
    live count when ``renormalize=True``, which requires the ``add``
    monoid — masked-mean semantics) and the clamped live count
    ``max(sum(alive), 1)``.  The count lane folds under the same monoid
    as the payload (it shares the ring), so for non-``add`` monoids it
    degrades to a clamped any-alive flag rather than a sum.  Drop
    ``count`` and DCE removes its node.

    The compiler expands this into a ``masked_pack`` map feeding a
    standard REDUCE over ``axis``, so it buckets in Coalesce, overlaps in
    the executor, and places on the CGRA like every other reduce.
    """
    if renormalize and monoid.name != "add":
        raise ValueError(
            "renormalize=True divides the total by the live count, which "
            f"is only meaningful for the add monoid, got {monoid.name!r}")
    t = _current("masked_reduce")
    packed = t.emit(
        Node(OpKind.MASKED_REDUCE, monoid=monoid, axis=axis), (x, alive))
    value = t.emit(
        Node(OpKind.MAP, fn=_masked_renorm_fn(renormalize),
             name="masked_renorm", fusable=False), (packed, x))
    count = t.emit(
        Node(OpKind.MAP, fn=_masked_count_fn(),
             name="masked_count", fusable=False), (packed,))
    return value, count


def wire(codec: WireCodec, x: Value) -> Value:
    """Declare the wire format for the collective this value feeds."""
    return _unary("wire", Node(OpKind.WIRE, codec=codec), x)
