"""ACiS Type 3 — look-aside operators: state + loops + off-chip memory.

The paper's Type 3 gives the data plane direct access to off-chip memory so
operations can be *stateful* and contain *loops*.  On TPU the analogue is
HBM-resident state threaded through the collective:

  * :func:`error_feedback_all_reduce` — compressed gradient sync whose
    residual memory persists across steps (state lives "beside" the op).
  * :func:`powersgd_all_reduce` — an iterative low-rank loop *inside* the
    collective (power iteration), with the Q factor as persistent state.
  * :func:`distributed_prefix_sum` — the scan carry walks the network.
  * :func:`gcn_aggregate` — the paper's own Type 3 case study (FLASH, ICS'23):
    neighbor aggregation where remote feature blocks stream past a
    HBM-resident accumulator, hop by hop (never materializing the full
    feature matrix — the in-network memory win).

All functions are rank-local (inside shard_map).
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import ring
from repro.core.compression import TopK, sparse_all_reduce_payloads
from repro.core.types import ADD, MAX as MAX_MONOID, Monoid
from repro.core.wire import WireCodec, int8_codec
from repro.core import collectives

PyTree = Any


# ---------------------------------------------------------------------------
# Shared-scale integer quantized all-reduce (SwitchML/SHArP-style).
#
# Per-hop *re*-quantization (wire.int8_codec) loses precision that no rank's
# error-feedback memory can account for.  The in-switch aggregators that ship
# (SwitchML, SHArP streaming-aggregation) instead agree on a scale up front
# and accumulate integers exactly.  We do the same: a tiny max-allreduce
# fixes a shared per-block scale, contributions are int8-granular, and the
# ring carries int16 partials (exact for axis sizes <= 256).  The only loss
# is each rank's own initial rounding — exactly what EF captures.
# ---------------------------------------------------------------------------

QBLOCK = 256


def shared_scale_quant_all_reduce(
    x: jax.Array, axis_name: str, *, block: int = QBLOCK,
) -> tuple[jax.Array, jax.Array]:
    """Returns (sum_over_ranks(round(x)), delivered_self) — both decoded."""
    flat = x.reshape(-1).astype(jnp.float32)
    size = flat.shape[0]
    pad = (-size) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    blocks = flat.reshape(-1, block)
    absmax = jnp.max(jnp.abs(blocks), axis=1)
    # shared scale: small latency-optimal max-allreduce (1/block of payload)
    absmax = collectives.all_reduce(absmax, axis_name, MAX_MONOID,
                                    latency_optimal=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int16)
    delivered_self = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:size]

    # exact integer ring RS∘AG: combine = int16 add (no loss at any hop)
    qsum = collectives._tree_all_reduce_encoded(
        (q,), axis_name, lambda a, b: (a[0] + b[0],))[0]
    total = (qsum.astype(jnp.float32) * scale[:, None]).reshape(-1)[:size]
    return total.reshape(x.shape), delivered_self.reshape(x.shape)


def compressed_all_reduce(
    target: jax.Array,
    axis_name: str,
    *,
    compressor: str = "int8",
    topk_ratio: float = 0.01,
) -> tuple[jax.Array, jax.Array]:
    """One lossy all-reduce: returns ``(total, delivered)``.

    ``total`` is the (sum, not mean) reduction in ``target``'s dtype;
    ``delivered`` is what the lossy wire delivered of *this rank's*
    contribution, in f32 and ``target``'s shape — the caller forms the
    error-feedback residual as ``target - delivered``.  This is the
    primitive behind both :func:`error_feedback_all_reduce` and the
    compiler's ``ef_allreduce`` stage (the REDUCE+DELIVERED pair).

    Compressors:
      * ``int8``          — shared-scale exact-integer accumulation (default;
                            EF identity exact; wire ≈ 0.5x of f32)
      * ``int8_hopquant`` — per-hop dequant-add-requant (wire ≈ 0.25x; adds
                            bounded, EF-invisible hop noise)
      * ``topk``          — sparse (idx, val) payloads, in-network
                            scatter-accumulate
    """
    tf = target.astype(jnp.float32)
    if compressor == "int8":
        total, delivered = shared_scale_quant_all_reduce(tf, axis_name)
    elif compressor == "int8_hopquant":
        codec = int8_codec()
        total = collectives.all_reduce(tf, axis_name, ADD, codec=codec)
        # what the wire actually delivered for *our* contribution:
        delivered = codec.decode(codec.encode(tf))
    elif compressor == "topk":
        flat = tf.reshape(-1)
        k = max(1, int(flat.shape[0] * topk_ratio))
        tk = TopK(k)
        idx, vals = tk.compress(flat)
        total = sparse_all_reduce_payloads(
            idx, vals, axis_name, flat.shape[0],
            dtype=jnp.float32).reshape(target.shape)
        delivered = tk.decompress((idx, vals), flat.shape,
                                  jnp.float32).reshape(target.shape)
    else:
        raise ValueError(f"unknown compressor {compressor!r}")
    return total.astype(target.dtype), delivered


def error_feedback_all_reduce(
    x: jax.Array,
    residual: jax.Array,
    axis_name: str,
    *,
    compressor: str = "int8",
    topk_ratio: float = 0.01,
    mean: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """All-reduce ``x`` through a lossy wire format with error feedback.

    Returns ``(reduced, new_residual)``.  The residual is the Type 3
    look-aside memory: it must be carried by the caller across invocations
    (the training loop stores it next to the optimizer state).  Thin
    wrapper over :func:`compressed_all_reduce`.
    """
    n = lax.axis_size(axis_name)
    target = x + residual.astype(x.dtype)
    reduced, delivered = compressed_all_reduce(
        target, axis_name, compressor=compressor, topk_ratio=topk_ratio)
    new_residual = (target.astype(jnp.float32) - delivered).astype(
        residual.dtype)
    if mean:
        reduced = reduced / n
    return reduced, new_residual


def init_residual(params: PyTree, dtype=jnp.float32) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, dtype), params)


# ---------------------------------------------------------------------------
# PowerSGD — the loop lives inside the collective (Type 3 "can have loops")
# ---------------------------------------------------------------------------

def powersgd_all_reduce(
    m: jax.Array,
    q: jax.Array,
    residual: jax.Array,
    axis_name: str,
    *,
    mean: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Rank-r all-reduce of a matrix ``m`` [rows, cols] via power iteration.

    ``q`` [cols, r] is the persistent warm-start factor (look-aside state),
    ``residual`` the error-feedback memory.  Two small all-reduces of the
    factors replace one big all-reduce of the matrix:
    wire bytes r·(rows+cols) vs rows·cols.

    Returns (reduced_mean, new_q, new_residual).
    """
    from repro.core.compression import orthonormalize

    n = lax.axis_size(axis_name)
    target = (m + residual.astype(m.dtype)).astype(jnp.float32)

    # -- the in-collective loop (power iteration) --
    p = target @ q                                     # [rows, r]
    p = collectives.all_reduce(p, axis_name, ADD)      # small wire
    p = orthonormalize(p)
    new_q = target.T @ p                               # [cols, r]
    new_q = collectives.all_reduce(new_q, axis_name, ADD)
    approx = p @ new_q.T                               # decoded mean*n
    reduced = approx / n if mean else approx

    delivered_local = p @ (target.T @ p).T             # our contribution as seen
    new_residual = (target - delivered_local).astype(residual.dtype)
    return reduced.astype(m.dtype), new_q, new_residual


def powersgd_init(shape, rank: int, key: jax.Array) -> jax.Array:
    cols = shape[1]
    return jax.random.normal(key, (cols, rank), jnp.float32)


# ---------------------------------------------------------------------------
# Distributed prefix sum (the FEM op of paper Fig. 5)
# ---------------------------------------------------------------------------

def distributed_prefix_sum(x: jax.Array, axis_name: str, *,
                           exclusive: bool = False) -> jax.Array:
    """Global prefix sum over the rank-major concatenation of local blocks.

    Local inclusive scan + cross-rank exclusive scan of block totals (the
    carry walks the network log-step).  Sub-block of the fused
    allgather_op_allgather (core/fused.py).
    """
    local = jnp.cumsum(x, axis=0)
    total = local[-1] if x.shape[0] else jnp.zeros(x.shape[1:], x.dtype)
    carry = ring.rank_prefix_scan(total, axis_name, ADD, exclusive=True)
    inc = local + carry
    if not exclusive:
        return inc
    shifted = jnp.concatenate([carry[None], inc[:-1]], axis=0) if x.ndim == 1 \
        else jnp.concatenate([carry[None], inc[:-1]], axis=0)
    return shifted


# ---------------------------------------------------------------------------
# GCN neighbor aggregation (paper Fig. 4 case study)
# ---------------------------------------------------------------------------

def gcn_aggregate(
    adj_blocks: jax.Array,
    x_local: jax.Array,
    axis_name: str,
    *,
    in_network: bool = True,
    backend: str = "acis",
) -> jax.Array:
    """Aggregate neighbor features  out = Â @ X  with X row-sharded.

    ``adj_blocks`` [n_ranks, rows_local, cols_block] — the local rows of the
    (normalized) adjacency, blocked by owner of the corresponding X rows.
    ``x_local`` [cols_block, d] — this rank's feature rows.

    in_network=True: ring-rotate the feature block; each hop performs a
    block-MAC against the HBM-resident accumulator (look-aside memory) —
    full X is never materialized, and compute overlaps the rotation.
    in_network=False (baseline): all-gather X, then one big SpMM — the
    endpoint-compute pattern of a passive network.
    """
    n = lax.axis_size(axis_name)
    i = lax.axis_index(axis_name)

    if not in_network:
        full_x = collectives.all_gather(x_local, axis_name, backend=backend)
        full_x = full_x.reshape(n, x_local.shape[0], x_local.shape[1])
        # out = sum_b adj_blocks[b] @ full_x[b]
        return jnp.einsum("brc,bcd->rd", adj_blocks, full_x)

    perm = [(j, (j + 1) % n) for j in range(n)]
    acc = jnp.zeros((adj_blocks.shape[1], x_local.shape[1]), x_local.dtype)

    def body(carry, s):
        acc, blk = carry
        owner = (i - s) % n          # whose X block we currently hold
        a = lax.dynamic_index_in_dim(adj_blocks, owner, axis=0, keepdims=False)
        acc = acc + a @ blk          # per-hop MAC against look-aside memory
        blk = lax.ppermute(blk, axis_name, perm)
        return (acc, blk), ()

    (acc, last), _ = lax.scan(body, (acc, x_local), jnp.arange(n - 1))
    owner = (i - (n - 1)) % n
    a = lax.dynamic_index_in_dim(adj_blocks, owner, axis=0, keepdims=False)
    return acc + a @ last
