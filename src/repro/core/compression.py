"""Gradient/payload compression — the ACiS Type 2 "user-defined datatypes".

Three wire datatypes beyond primitives:
  * top-k sparse        — (indices, values) pairs; the sparse-accumulation
                          datatype the paper calls out P4 switches for
                          lacking (§III: "no sparse data types").
  * blockwise int8      — payload+scales (see core/wire.py).
  * low-rank (PowerSGD) — rank-r factor pair; used by the Type 3 iterative
                          loop in core/lookaside.py.

All compressors expose ``compress/decompress`` plus a ``wire_bytes`` account
used by the network emulator and the roofline collective-bytes model.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


# ---------------------------------------------------------------------------
# Top-k sparsification
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TopK:
    """Keep the k largest-magnitude entries of a flat tensor."""

    k: int

    def compress(self, x: jax.Array) -> tuple[jax.Array, jax.Array]:
        flat = x.reshape(-1)
        k = min(self.k, flat.shape[0])
        vals, idx = jax.lax.top_k(jnp.abs(flat), k)
        del vals
        return idx.astype(jnp.int32), flat[idx]

    def decompress(self, payload: tuple[jax.Array, jax.Array],
                   shape, dtype) -> jax.Array:
        idx, vals = payload
        size = 1
        for s in shape:
            size *= s
        dense = jnp.zeros((size,), dtype)
        dense = dense.at[idx].add(vals.astype(dtype))
        return dense.reshape(shape)

    def wire_bytes(self, shape) -> int:
        k = self.k
        return k * (4 + 4)  # int32 idx + f32 val


def sparse_accumulate(dense: jax.Array, idx: jax.Array,
                      vals: jax.Array) -> jax.Array:
    """Scatter-add a sparse (idx, vals) payload into a dense accumulator —
    the per-hop combine of the sparse all-reduce (Pallas-backed: see
    kernels/topk_accum)."""
    return dense.at[idx].add(vals.astype(dense.dtype))


def sparse_all_reduce_payloads(idx: jax.Array, vals: jax.Array,
                               axis_name: str, dense_size: int,
                               dtype=jnp.float32) -> jax.Array:
    """All-reduce of top-k sparse payloads: ring-rotate the (idx, val) pairs
    and scatter-accumulate at every hop into a dense HBM accumulator.

    Bytes on the wire: (n-1) hops × 8k bytes, vs (n-1)/n × 4·size for a dense
    ring all-reduce — the win is size/(2k·n/(n-1)).
    """
    from jax import lax

    n = lax.axis_size(axis_name)
    acc = jnp.zeros((dense_size,), dtype)
    acc = sparse_accumulate(acc, idx, vals)
    if n == 1:
        return acc
    perm = [(j, (j + 1) % n) for j in range(n)]

    def body(carry, _):
        acc, (i, v) = carry
        i = lax.ppermute(i, axis_name, perm)
        v = lax.ppermute(v, axis_name, perm)
        acc = sparse_accumulate(acc, i, v)   # in-network accumulate
        return (acc, (i, v)), ()

    (acc, _), _ = lax.scan(body, (acc, (idx, vals)), jnp.arange(n - 1))
    return acc


# ---------------------------------------------------------------------------
# PowerSGD low-rank factors (used by lookaside.powersgd_all_reduce)
# ---------------------------------------------------------------------------

def orthonormalize(p: jax.Array, eps: float = 1e-8) -> jax.Array:
    """Gram-Schmidt columns of p [n, r] (r small)."""
    def body(i, p):
        col = p[:, i]
        prev = p[:, :] * (jnp.arange(p.shape[1]) < i)[None, :]
        proj = prev @ (prev.T @ col)
        col = col - proj
        col = col / (jnp.linalg.norm(col) + eps)
        return p.at[:, i].set(col)

    return jax.lax.fori_loop(0, p.shape[1], body, p)


def powersgd_wire_bytes(shape, rank: int) -> int:
    n, m = shape
    return 4 * rank * (n + m)
