"""On-wire codecs — ACiS Type 0 stream transforms + Type 2 wire datatypes.

A :class:`WireCodec` describes what actually travels over a link.  The
paper's switch parses payloads, transforms streams (dtype changes, CRC) and
supports user-defined wire datatypes (sparse, quantized).  Here a codec is a
pair ``encode/decode`` plus, optionally, an *encoded-domain combine* — the
in-switch aggregation that merges two encoded payloads without a round-trip
through the decoded domain (e.g. dequant-add-requant in one fused kernel).

Codecs compose with every schedule in :mod:`repro.core.ring` via
:mod:`repro.core.collectives`.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class WireCodec:
    name: str
    encode: Callable[[jax.Array], PyTree]
    decode: Callable[[PyTree], jax.Array]
    # Optional encoded-domain combine (incoming, local) -> encoded.
    combine_encoded: Optional[Callable[[PyTree, PyTree], PyTree]] = None
    # Bytes-on-wire multiplier vs f32 (for the roofline/emulator accounting).
    wire_ratio: float = 1.0

    def __repr__(self) -> str:  # pragma: no cover
        return f"WireCodec({self.name})"


# ---------------------------------------------------------------------------
# Type 0: pure stream transforms.
# ---------------------------------------------------------------------------

IDENTITY = WireCodec("identity", lambda x: x, lambda x: x, wire_ratio=1.0)


def _cast_codec(name: str, wire_dtype, ratio: float) -> WireCodec:
    def encode(x):
        return (x.astype(wire_dtype), jnp.asarray(x.dtype.name == "float32"))

    def decode(p):
        y, was_f32 = p
        del was_f32
        return y.astype(jnp.float32)

    return WireCodec(name, lambda x: x.astype(wire_dtype),
                     lambda y: y.astype(jnp.float32), wire_ratio=ratio)


BF16 = _cast_codec("bf16", jnp.bfloat16, 0.5)
FP8 = _cast_codec("fp8_e4m3", jnp.float8_e4m3fn, 0.25)


def checksum_tag(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Type 0 'append a CRC' analogue: fletcher-style checksum sidecar.

    The checksum travels with the payload; ``checksum_verify`` recomputes and
    compares (used by the fault-tolerance tests to detect corrupt shards).
    """
    flat = x.reshape(-1).astype(jnp.float32)
    bits = lax_bitcast(flat)
    s = jnp.cumsum(bits.astype(jnp.uint32) & jnp.uint32(0xFFFF))
    return x, (jnp.sum(bits, dtype=jnp.uint32), s[-1] if s.size else jnp.uint32(0))


def lax_bitcast(x: jax.Array) -> jax.Array:
    return jax.lax.bitcast_convert_type(x, jnp.uint32)


def checksum_verify(x: jax.Array, tag) -> jax.Array:
    _, fresh = checksum_tag(x)
    return (fresh[0] == tag[0]) & (fresh[1] == tag[1])


# ---------------------------------------------------------------------------
# Type 2 wire datatype: blockwise-int8 quantized tensors (payload + scales).
# ---------------------------------------------------------------------------

QBLOCK = 256  # elements per quantization block (VPU-lane friendly)


def quantize_int8(x: jax.Array, block: int = QBLOCK) -> tuple[jax.Array, jax.Array, Any]:
    """Blockwise symmetric int8 quantization of a flat f32/bf16 array.

    Returns (q[int8, padded], scales[f32, nblocks], orig_size).
    """
    flat = x.reshape(-1).astype(jnp.float32)
    size = flat.shape[0]
    pad = (-size) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    blocks = flat.reshape(-1, block)
    absmax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0], size


def dequantize_int8(q: jax.Array, scale: jax.Array, size: int,
                    shape=None, dtype=jnp.float32) -> jax.Array:
    out = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:size]
    if shape is not None:
        out = out.reshape(shape).astype(dtype)
    return out


def _int8_combine(incoming, local):
    """Encoded-domain combine: dequant both, add, requant — the in-switch
    aggregation-unit program for the quantized wire format (Pallas-kernel
    backed when kernels are enabled; see kernels/quant_combine)."""
    qi, si = incoming
    ql, sl = local
    s = jnp.maximum(si, sl)  # conservative joint scale
    acc = qi.astype(jnp.float32) * si[:, None] + ql.astype(jnp.float32) * sl[:, None]
    absmax = jnp.max(jnp.abs(acc), axis=1)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(acc / scale[:, None]), -127, 127).astype(jnp.int8)
    del s
    return q, scale


def int8_codec(block: int = QBLOCK) -> WireCodec:
    """int8-blockwise codec with encoded-domain combine.

    NOTE: quantized combine is lossy and (mildly) order-dependent; use with
    error-feedback (core/lookaside.py) for training-grade gradient sync.
    Encode assumes a fixed flat f32 payload shape per call site.
    """
    shape_box = {}

    def encode(x):
        shape_box["shape"] = x.shape
        shape_box["dtype"] = x.dtype
        q, s, size = quantize_int8(x, block)
        shape_box["size"] = size
        return q, s

    def decode(p):
        q, s = p
        return dequantize_int8(q, s, shape_box["size"],
                               shape_box["shape"], shape_box["dtype"])

    # wire_ratio: 1 byte payload + 4/block scales vs 4 bytes f32
    ratio = (1.0 + 4.0 / block) / 4.0
    return WireCodec(f"int8_b{block}", encode, decode,
                     combine_encoded=_int8_combine, wire_ratio=ratio)


CODECS = {
    "identity": IDENTITY,
    "bf16": BF16,
    "fp8": FP8,
}


def resolve_codec(name: str) -> WireCodec:
    """Codec by config name.  ``"int8"`` builds a *fresh* instance — its
    encode/decode pair carries per-call-site shape state and must not be
    shared between compiled programs."""
    if name in CODECS:
        return CODECS[name]
    if name == "int8":
        return int8_codec()
    raise ValueError(f"unknown wire codec {name!r}; "
                     f"expected one of {sorted(CODECS) + ['int8']}")
