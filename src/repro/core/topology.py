"""Topology-aware (hierarchical, multi-pod) collective schedules.

The paper places compute at the *center* of the network because that is
where flows converge.  On a multi-pod TPU system the converging point is the
inter-pod fabric (DCI), which is an order of magnitude thinner than intra-pod
ICI.  The hierarchical schedule below is the ACiS story mapped onto that
asymmetry:

    1. intra-pod reduce-scatter over the fast `data` axis,
    2. inter-pod exchange over the thin `pod` axis on 1/|data|-size shards —
       optionally through a lossy wire codec with error feedback (Type 2/3:
       compress exactly where the wire is thin),
    3. intra-pod all-gather.

This is also where straggler tolerance is implemented: the inter-pod stage
can mask out contributions that miss the deadline (bounded staleness) and
renormalize — see `masked_all_reduce`.

Since the compiler grew the LowerTopology pass, the hierarchical schedule
is no longer hand-written here: :func:`hierarchical_all_reduce` is a thin
wrapper that traces ``reduce(x, axis="auto")`` and compiles it through
``engine.compile`` — the RS/AR/AG triple (with the codec riding the outer
hop) is what the pass pipeline emits for a multi-axis reduce.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import collectives
from repro.core.types import ADD, Monoid
from repro.core.wire import IDENTITY, WireCodec

PyTree = Any

# (inner, outer, monoid.name, codec.name, mean) → CompiledProgram.
# Compiling is trace-time-only Python, but a train step may call this per
# gradient leaf on every retrace — don't re-run the 5-pass pipeline each
# time.  Keyed by *names* so per-call codec instances (int8_codec() is
# deliberately fresh per call) still hit; two distinct codecs sharing a
# name would collide, which no current codec constructor allows for
# different behaviour.
_COMPILE_CACHE: dict = {}


def hierarchical_all_reduce(
    x: jax.Array,
    *,
    inner_axis: str = "data",
    outer_axis: Optional[str] = "pod",
    monoid: Monoid = ADD,
    outer_codec: WireCodec = IDENTITY,
    backend: str = "acis",
    mean: bool = False,
) -> jax.Array:
    """RS(inner) → AR(outer, coded) → AG(inner), via the compiled pipeline.

    Wire accounting per element: 2·(d-1)/d intra-pod + 2·(p-1)/p·ratio/d
    inter-pod, vs a flat AR over d·p ranks pushing 2·(dp-1)/dp through the
    *thin* links too.  The inter-pod bytes drop by d× (and by codec ratio).

    ``backend`` is kept for signature compatibility; the emitted stages
    always run the explicit acis ring schedules (the xla baseline has no
    per-hop compute to place).
    """
    from repro.core import api, tracing

    del backend
    # the rank-local aval keys the cache too: SelectSchedule and Coalesce
    # size the schedule from it, and the per-axis ring sizes are read
    # live (we are inside the caller's shard_map region at trace time)
    sizes = api.live_axis_sizes((inner_axis, outer_axis))
    engine = api.make_engine("acis", inner_axis=inner_axis,
                             outer_axis=outer_axis)
    # the config fields the compiled structure depends on key the cache
    # too (engine.compile may apply tuned overrides — bucket sizes,
    # dispatch mode — and a tuned program must not collide with the
    # default's entry)
    key = (inner_axis, outer_axis, monoid.name, outer_codec.name, mean,
           tuple(x.shape), str(x.dtype), tuple(sorted(sizes.items())),
           engine.config.cache_key())
    compiled = _COMPILE_CACHE.get(key)
    if compiled is None:

        def _mean(y):
            n = lax.axis_size(inner_axis)
            if outer_axis is not None:
                n = n * lax.axis_size(outer_axis)
            return y / n

        def prog(v):
            if outer_codec is not IDENTITY and outer_axis is not None:
                # the codec rides the thin outer hop only (and there is no
                # outer hop to compress on a single-pod topology)
                v = tracing.wire(outer_codec, v)
            r = tracing.reduce(v, monoid, axis="auto")
            return tracing.map(_mean, r, name="mean") if mean else r

        compiled = _COMPILE_CACHE[key] = engine.compile(
            prog, in_avals=(jax.ShapeDtypeStruct(x.shape, x.dtype),),
            axis_size=sizes or None)
    return compiled(x)[0]


def masked_all_reduce(
    x: jax.Array,
    alive: jax.Array,
    axis_name: str,
    *,
    renormalize: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Straggler-tolerant mean-reduce: ranks with ``alive == False`` are
    treated as missing (their contribution masked to the identity) and the
    mean is renormalized by the live count.

    This is the algorithmic half of bounded-staleness sync: on real
    hardware the runtime flags ranks that missed the deadline; here `alive`
    is injected by the fault-injection tests.  Returns (mean, live_count).
    """
    contrib = jnp.where(alive, x, jnp.zeros_like(x))
    total = collectives.all_reduce(contrib, axis_name, ADD)
    count = collectives.all_reduce(
        alive.astype(jnp.float32).reshape(()), axis_name, ADD)
    count = jnp.maximum(count, 1.0)
    if renormalize:
        total = total / count.astype(total.dtype)
    return total, count


def pod_aware_axes(mesh: jax.sharding.Mesh) -> tuple[str, Optional[str]]:
    """(inner, outer) DP axes for a mesh — outer is None on single-pod."""
    names = mesh.axis_names
    outer = "pod" if "pod" in names else None
    return "data", outer
