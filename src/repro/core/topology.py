"""Topology-aware (hierarchical, multi-pod) collective schedules.

The paper places compute at the *center* of the network because that is
where flows converge.  On a multi-pod TPU system the converging point is the
inter-pod fabric (DCI), which is an order of magnitude thinner than intra-pod
ICI.  The hierarchical schedule below is the ACiS story mapped onto that
asymmetry:

    1. intra-pod reduce-scatter over the fast `data` axis,
    2. inter-pod exchange over the thin `pod` axis on 1/|data|-size shards —
       optionally through a lossy wire codec with error feedback (Type 2/3:
       compress exactly where the wire is thin),
    3. intra-pod all-gather.

This is also where straggler tolerance is implemented: the inter-pod stage
can mask out contributions that miss the deadline (bounded staleness) and
renormalize — see `masked_all_reduce`.

Since the compiler grew the LowerTopology pass, the hierarchical schedule
is no longer hand-written here: :func:`hierarchical_all_reduce` is a thin
wrapper that traces ``reduce(x, axis="auto")`` and compiles it through
``engine.compile`` — the RS/AR/AG triple (with the codec riding the outer
hop) is what the pass pipeline emits for a multi-axis reduce.
"""

from __future__ import annotations

import collections
import os
import warnings
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.types import ADD, Monoid
from repro.core.wire import IDENTITY, WireCodec
from repro.obs import metrics as _obs

PyTree = Any

# (inner, outer, monoid.name, codec.name, mean, shapes, …) → CompiledProgram.
# Compiling is trace-time-only Python, but a train step may call this per
# gradient leaf on every retrace — don't re-run the 5-pass pipeline each
# time.  Keyed by *names* so per-call codec instances (int8_codec() is
# deliberately fresh per call) still hit; two distinct codecs sharing a
# name would collide, which no current codec constructor allows for
# different behaviour.
#
# Bounded LRU: a long-running serving process sees an open-ended stream of
# (shape, dtype, mesh-size) keys, and each entry pins a jitted executable —
# unbounded growth is a slow leak.  Least-recently-used entries are evicted
# past the size knob; evictions are counted so the leak is observable
# (``topology.compile_cache_evicted``).
_COMPILE_CACHE: "collections.OrderedDict" = collections.OrderedDict()

_COMPILE_CACHE_SIZE = int(os.environ.get("ACIS_TOPOLOGY_CACHE_SIZE", "128"))


def compile_cache_size() -> int:
    return _COMPILE_CACHE_SIZE


def set_compile_cache_size(n: int) -> int:
    """Set the LRU capacity (``$ACIS_TOPOLOGY_CACHE_SIZE`` seeds the
    default); returns the previous value.  Shrinking evicts immediately."""
    global _COMPILE_CACHE_SIZE
    prev, _COMPILE_CACHE_SIZE = _COMPILE_CACHE_SIZE, int(n)
    _cache_trim()
    return prev


def _cache_get(key):
    hit = _COMPILE_CACHE.get(key)
    if hit is not None:
        _COMPILE_CACHE.move_to_end(key)
    return hit


def _cache_put(key, compiled):
    _COMPILE_CACHE[key] = compiled
    _COMPILE_CACHE.move_to_end(key)
    _cache_trim()
    return compiled


def _cache_trim():
    while len(_COMPILE_CACHE) > max(_COMPILE_CACHE_SIZE, 0):
        _COMPILE_CACHE.popitem(last=False)
        _obs.RECORDER.count("topology.compile_cache_evicted")


def hierarchical_all_reduce(
    x: jax.Array,
    *,
    inner_axis: str = "data",
    outer_axis: Optional[str] = "pod",
    monoid: Monoid = ADD,
    outer_codec: WireCodec = IDENTITY,
    backend: str = "acis",
    mean: bool = False,
) -> jax.Array:
    """RS(inner) → AR(outer, coded) → AG(inner), via the compiled pipeline.

    Wire accounting per element: 2·(d-1)/d intra-pod + 2·(p-1)/p·ratio/d
    inter-pod, vs a flat AR over d·p ranks pushing 2·(dp-1)/dp through the
    *thin* links too.  The inter-pod bytes drop by d× (and by codec ratio).

    ``backend`` is kept for signature compatibility; the emitted stages
    always run the explicit acis ring schedules (the xla baseline has no
    per-hop compute to place).
    """
    from repro.core import api, tracing

    del backend
    # the rank-local aval keys the cache too: SelectSchedule and Coalesce
    # size the schedule from it, and the per-axis ring sizes are read
    # live (we are inside the caller's shard_map region at trace time)
    sizes = api.live_axis_sizes((inner_axis, outer_axis))
    engine = api.make_engine("acis", inner_axis=inner_axis,
                             outer_axis=outer_axis)
    # the config fields the compiled structure depends on key the cache
    # too (engine.compile may apply tuned overrides — bucket sizes,
    # dispatch mode — and a tuned program must not collide with the
    # default's entry)
    key = (inner_axis, outer_axis, monoid.name, outer_codec.name, mean,
           tuple(x.shape), str(x.dtype), tuple(sorted(sizes.items())),
           engine.config.cache_key())
    compiled = _cache_get(key)
    if compiled is None:

        def _mean(y):
            n = lax.axis_size(inner_axis)
            if outer_axis is not None:
                n = n * lax.axis_size(outer_axis)
            return y / n

        def prog(v):
            if outer_codec is not IDENTITY and outer_axis is not None:
                # the codec rides the thin outer hop only (and there is no
                # outer hop to compress on a single-pod topology)
                v = tracing.wire(outer_codec, v)
            r = tracing.reduce(v, monoid, axis="auto")
            return tracing.map(_mean, r, name="mean") if mean else r

        compiled = _cache_put(key, engine.compile(
            prog, in_avals=(jax.ShapeDtypeStruct(x.shape, x.dtype),),
            axis_size=sizes or None))
    return compiled(x)[0]


def masked_all_reduce(
    x: jax.Array,
    alive: jax.Array,
    axis_name: str,
    *,
    renormalize: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Straggler-tolerant mean-reduce: ranks with ``alive == False`` are
    treated as missing (their contribution masked to the identity) and the
    mean is renormalized by the live count.

    .. deprecated::
        Thin wrapper over the compiled :func:`repro.core.tracing.
        masked_reduce` path — the live count now rides in the payload's
        flat ring buffer (one collective launch; the old spelling issued a
        second scalar all-reduce for the count).  New code should call
        ``tracing.masked_reduce`` inside a traced program, or
        ``engine.gradient_sync(..., membership=)`` for the sync path.

    Returns (mean, live_count); the count is clamped to ≥1 so a transient
    all-dead view cannot divide by zero.
    """
    warnings.warn(
        "topology.masked_all_reduce is deprecated: use tracing."
        "masked_reduce (compiled, one launch) or gradient_sync("
        "membership=...)", DeprecationWarning, stacklevel=2)
    from repro.core import api, tracing

    sizes = api.live_axis_sizes((axis_name,))
    engine = api.make_engine("acis", inner_axis=axis_name)
    key = ("masked", axis_name, renormalize, tuple(x.shape), str(x.dtype),
           tuple(sorted(sizes.items())), engine.config.cache_key())
    compiled = _cache_get(key)
    if compiled is None:

        def prog(v, a):
            return tracing.masked_reduce(v, a, ADD, axis=axis_name,
                                         renormalize=renormalize)

        compiled = _cache_put(key, engine.compile(
            prog,
            in_avals=(jax.ShapeDtypeStruct(x.shape, x.dtype),
                      jax.ShapeDtypeStruct((), jnp.float32)),
            axis_size=sizes or None))
    total, count = compiled(x, jnp.asarray(alive, jnp.float32).reshape(()))
    return total, count


def pod_aware_axes(mesh: jax.sharding.Mesh) -> tuple[str, Optional[str]]:
    """(inner, outer) DP axes for a mesh — outer is None on single-pod."""
    names = mesh.axis_names
    outer = "pod" if "pod" in names else None
    return "data", outer
