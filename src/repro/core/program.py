"""SwitchProgram IR — the ACiS software-support analogue.

The paper's toolchain (§VI.B): parse MPI source → LLVM IR → dataflow graph →
schedule/register-allocate onto the CGRA → binary carried as an argument of
the fused-collective routine.

The IR here is a true dataflow **DAG** (:class:`DagProgram`): nodes with
explicit inputs and outputs over numbered values, multiple program inputs
and multiple program outputs.  Users normally do not build it by hand —
they write a plain Python function over symbolic values and call
:func:`repro.core.tracing.trace`; the compiler (core/compiler.py) runs a
pass pipeline (Legalize → LowerTopology → FuseHops → SelectSchedule →
PlaceCGRA → Emit) over the DAG and emits a single JAX callable executing
under one `shard_map` — the "CGRA binary" is the jitted HLO, and every
stage carries the CGRA placement (or explicit host fallback) the
:mod:`repro.cgra` mapper assigned its compute body.  This is the mechanism by which arbitrary
*graphs* of collectives and maps become one in-network program (Type 4)
rather than a sequence of endpoint round-trips.

:class:`SwitchProgram` — the original linear chain-of-nodes spelling — is
kept as a thin front-end shim; :meth:`SwitchProgram.to_dag` builds the
degenerate single-input chain DAG.

Node vocabulary (the "SPU instruction set" at graph granularity):
  MAP(fn)              — elementwise/user map, fusable into adjacent hops
  REDUCE(monoid)       — all-reduce (``ef`` set: error-feedback compressed)
  REDUCE_SCATTER(m)    — reduce-scatter
  ALLGATHER            — all-gather
  ALLTOALL             — all-to-all
  SCAN(monoid)         — cross-rank prefix scan (Type 3)
  BCAST(root)          — broadcast
  WIRE(codec)          — wire-format change for downstream links (Type 0/2)
  DELIVERED            — what the lossy wire delivered of *this rank's*
                         contribution (the error-feedback sibling of an
                         ``ef`` REDUCE; pairs into one look-aside stage)
  MASKED_REDUCE(m)     — bounded-staleness all-reduce of ``(x, alive)``:
                         ranks whose alive flag is 0 contribute the monoid
                         identity, and the live count rides in the *same*
                         flat buffer as the payload (one ring, not two).
                         Legalize expands it to masked_pack → REDUCE, so
                         downstream passes bucket/overlap/place it like
                         any other reduce.

Every collective op additionally carries an ``axis``: ``None`` means "the
engine's default axis", ``"auto"`` means "all data-parallel axes of the
compile topology", a string names one mesh axis, and a tuple names a
compound axis (innermost first).  Compound/auto axes are resolved by the
compiler's LowerTopology pass — see :mod:`repro.core.compiler`.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Optional, Sequence, Union

from repro.core.types import ADD, Monoid
from repro.core.wire import IDENTITY, WireCodec


class OpKind(enum.Enum):
    MAP = "map"
    REDUCE = "reduce"
    REDUCE_SCATTER = "reduce_scatter"
    ALLGATHER = "allgather"
    ALLTOALL = "alltoall"
    SCAN = "scan"
    BCAST = "bcast"
    WIRE = "wire"
    DELIVERED = "delivered"
    MASKED_REDUCE = "masked_reduce"


COLLECTIVE_KINDS = {
    OpKind.REDUCE, OpKind.REDUCE_SCATTER, OpKind.ALLGATHER,
    OpKind.ALLTOALL, OpKind.SCAN, OpKind.BCAST, OpKind.DELIVERED,
    OpKind.MASKED_REDUCE,
}

# axis field: None (engine default), "auto" (all DP axes of the topology),
# one mesh-axis name, or a tuple of names (compound axis, innermost first)
Axis = Union[None, str, tuple]

AUTO_AXIS = "auto"


@dataclasses.dataclass(frozen=True)
class ErrorFeedback:
    """Error-feedback compression spec riding on a REDUCE/DELIVERED pair.

    ``compressor`` selects the Type 3 look-aside implementation
    (see :func:`repro.core.lookaside.compressed_all_reduce`).
    """

    compressor: str = "int8"
    topk_ratio: float = 0.01


@dataclasses.dataclass(frozen=True)
class Node:
    kind: OpKind
    fn: Optional[Callable] = None          # MAP payload
    monoid: Monoid = ADD                   # REDUCE/RS/SCAN payload
    codec: WireCodec = IDENTITY            # WIRE payload
    root: int = 0                          # BCAST payload
    exclusive: bool = False                # SCAN payload
    axis: Axis = None                      # collective axis (see module doc)
    ef: Optional[ErrorFeedback] = None     # REDUCE/DELIVERED payload
    fusable: bool = True                   # MAP: may be hop-fused (must be
    #                                        chunk-local; shape transforms
    #                                        such as the compiler's pad/unpad
    #                                        bookkeeping maps are not)
    elementwise: bool = False              # MAP: fn is strictly per-element
    #                                        (f(concat(xs)) == concat(f(x))),
    #                                        so Coalesce may hoist it from
    #                                        per-leaf split outputs onto the
    #                                        flat bucket — a caller promise,
    #                                        declared at trace time
    name: str = ""

    def label(self) -> str:
        base = self.kind.value
        if self.kind == OpKind.MAP and self.name:
            base = f"map:{self.name}"
        elif self.kind in (OpKind.REDUCE, OpKind.REDUCE_SCATTER, OpKind.SCAN,
                           OpKind.MASKED_REDUCE):
            base = f"{base}:{self.monoid.name}"
            if self.ef is not None:
                base += f"+ef[{self.ef.compressor}]"
        elif self.kind == OpKind.WIRE:
            base = f"wire:{self.codec.name}"
        elif self.kind == OpKind.DELIVERED and self.ef is not None:
            base = f"delivered[{self.ef.compressor}]"
        if self.axis is not None and self.kind not in (OpKind.MAP,
                                                       OpKind.WIRE):
            base += f"@{self.axis}"
        return base


# -- user-facing constructors ------------------------------------------------

def Map(fn: Callable, name: str = "", fusable: bool = True,
        elementwise: bool = False) -> Node:
    """``fusable=False`` marks a map whose body is *not* chunk-local
    (e.g. a cumsum or other cross-position transform): the compiler will
    never hop-fuse it into a collective's chunk loop, and the CGRA
    mapper still places it as a whole-payload pipeline stage.
    ``elementwise=True`` additionally promises the body is strictly
    per-element, letting Coalesce run it once on a flat bucket instead of
    once per leaf."""
    return Node(OpKind.MAP, fn=fn, name=name, fusable=fusable,
                elementwise=elementwise)


def Reduce(monoid: Monoid = ADD, axis: Axis = None) -> Node:
    return Node(OpKind.REDUCE, monoid=monoid, axis=axis)


def ReduceScatter(monoid: Monoid = ADD, axis: Axis = None) -> Node:
    return Node(OpKind.REDUCE_SCATTER, monoid=monoid, axis=axis)


def AllGather(axis: Axis = None) -> Node:
    return Node(OpKind.ALLGATHER, axis=axis)


def AllToAll(axis: Axis = None) -> Node:
    return Node(OpKind.ALLTOALL, axis=axis)


def Scan(monoid: Monoid = ADD, exclusive: bool = False,
         axis: Axis = None) -> Node:
    return Node(OpKind.SCAN, monoid=monoid, exclusive=exclusive, axis=axis)


def Bcast(root: int = 0, axis: Axis = None) -> Node:
    return Node(OpKind.BCAST, root=root, axis=axis)


def Wire(codec: WireCodec) -> Node:
    return Node(OpKind.WIRE, codec=codec)


# ---------------------------------------------------------------------------
# DAG IR — the compiler's native program form
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DagNode:
    """One op applied to numbered values.

    Value ids 0..num_inputs-1 are the program inputs; every node defines one
    fresh value (``out``).  Only MAP may take more than one input.
    """

    op: Node
    inputs: tuple[int, ...]
    out: int

    def label(self) -> str:
        return self.op.label()


@dataclasses.dataclass
class DagProgram:
    """A multi-input, multi-output dataflow graph of switch ops.

    ``nodes`` is in value-definition order, which is always a valid
    topological order (a node can only consume already-defined values —
    enforced by :meth:`validate`).
    """

    num_inputs: int
    nodes: Sequence[DagNode]
    outputs: tuple[int, ...]
    name: str = "program"

    def __post_init__(self):
        self.nodes = tuple(self.nodes)
        self.outputs = tuple(self.outputs)
        self.validate()

    def validate(self) -> None:
        defined = set(range(self.num_inputs))
        for nd in self.nodes:
            for vid in nd.inputs:
                if vid not in defined:
                    raise ValueError(
                        f"node {nd.label()} consumes undefined value {vid}")
            if nd.out in defined:
                raise ValueError(f"value {nd.out} defined twice")
            if nd.op.kind == OpKind.MAP:
                if not nd.inputs:
                    raise ValueError("map takes at least one input, got 0")
            elif nd.op.kind == OpKind.MASKED_REDUCE:
                if len(nd.inputs) != 2:
                    raise ValueError(
                        "masked_reduce takes exactly (x, alive), got "
                        f"{len(nd.inputs)} inputs")
            elif len(nd.inputs) != 1:
                raise ValueError(
                    f"{nd.op.kind.value} takes exactly one input, "
                    f"got {len(nd.inputs)}")
            defined.add(nd.out)
        for vid in self.outputs:
            if vid not in defined:
                raise ValueError(f"program output {vid} is undefined")
        if not self.outputs:
            raise ValueError("program has no outputs")

    def users(self) -> dict[int, list[DagNode]]:
        """value id → nodes consuming it (program outputs not included)."""
        out: dict[int, list[DagNode]] = {}
        for nd in self.nodes:
            for vid in nd.inputs:
                out.setdefault(vid, []).append(nd)
        return out

    def labels(self) -> list[str]:
        return [nd.label() for nd in self.nodes]


@dataclasses.dataclass
class SwitchProgram:
    """A linear dataflow chain — kept as a thin shim over the DAG IR.

    The paper's examples (Allgather_op_Allgather, MapReduce) are chains;
    :meth:`to_dag` converts to the compiler's native :class:`DagProgram`.
    Prefer :func:`repro.core.tracing.trace` for new programs.
    """

    nodes: Sequence[Node]
    name: str = "program"

    def __post_init__(self):
        self.nodes = tuple(self.nodes)

    def labels(self) -> list[str]:
        return [n.label() for n in self.nodes]

    def to_dag(self) -> DagProgram:
        """Build the degenerate chain DAG: one input, each node consuming
        the previous node's value.

        Exception (the historical "tuple hack"): the exact chain
        ``[Reduce(m), AllToAll()]`` meant *two independent tensors* — an
        all-reduced histogram plus an all-to-all'd key array — flowing as a
        tuple.  That spelling converts to the true two-input, two-output
        DAG the fusion pattern expects.
        """
        if (len(self.nodes) == 2
                and self.nodes[0].kind == OpKind.REDUCE
                and self.nodes[1].kind == OpKind.ALLTOALL):
            red = DagNode(self.nodes[0], (0,), 2)
            a2a = DagNode(self.nodes[1], (1,), 3)
            return DagProgram(2, (red, a2a), (red.out, a2a.out), self.name)
        dag_nodes: list[DagNode] = []
        vid = 0
        next_vid = 1
        for n in self.nodes:
            dag_nodes.append(DagNode(n, (vid,), next_vid))
            vid = next_vid
            next_vid += 1
        return DagProgram(1, tuple(dag_nodes), (vid,), self.name)
