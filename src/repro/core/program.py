"""SwitchProgram IR — the ACiS software-support analogue.

The paper's toolchain (§VI.B): parse MPI source → LLVM IR → dataflow graph →
schedule/register-allocate onto the CGRA → binary carried as an argument of
the fused-collective routine.

Here the user builds a small dataflow graph of collective and map nodes; the
compiler (core/compiler.py) legalizes it, applies fusion rules, and emits a
single JAX callable executing under one `shard_map` — the "CGRA binary" is
the jitted HLO.  This is the mechanism by which arbitrary *chains* of
collectives and maps become one in-network program (Type 4) rather than a
sequence of endpoint round-trips.

Node vocabulary (the "SPU instruction set" at graph granularity):
  MAP(fn)              — elementwise/user map, fusable into adjacent hops
  REDUCE(monoid)       — all-reduce
  REDUCE_SCATTER(m)    — reduce-scatter
  ALLGATHER            — all-gather
  ALLTOALL             — all-to-all
  SCAN(monoid)         — cross-rank prefix scan (Type 3)
  BCAST(root)          — broadcast
  WIRE(codec)          — wire-format change for downstream links (Type 0/2)
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Optional, Sequence

from repro.core.types import ADD, Monoid
from repro.core.wire import IDENTITY, WireCodec


class OpKind(enum.Enum):
    MAP = "map"
    REDUCE = "reduce"
    REDUCE_SCATTER = "reduce_scatter"
    ALLGATHER = "allgather"
    ALLTOALL = "alltoall"
    SCAN = "scan"
    BCAST = "bcast"
    WIRE = "wire"


COLLECTIVE_KINDS = {
    OpKind.REDUCE, OpKind.REDUCE_SCATTER, OpKind.ALLGATHER,
    OpKind.ALLTOALL, OpKind.SCAN, OpKind.BCAST,
}


@dataclasses.dataclass(frozen=True)
class Node:
    kind: OpKind
    fn: Optional[Callable] = None          # MAP payload
    monoid: Monoid = ADD                   # REDUCE/RS/SCAN payload
    codec: WireCodec = IDENTITY            # WIRE payload
    root: int = 0                          # BCAST payload
    exclusive: bool = False                # SCAN payload
    name: str = ""

    def label(self) -> str:
        base = self.kind.value
        if self.kind == OpKind.MAP and self.name:
            return f"map:{self.name}"
        if self.kind in (OpKind.REDUCE, OpKind.REDUCE_SCATTER, OpKind.SCAN):
            return f"{base}:{self.monoid.name}"
        if self.kind == OpKind.WIRE:
            return f"wire:{self.codec.name}"
        return base


# -- user-facing constructors ------------------------------------------------

def Map(fn: Callable, name: str = "") -> Node:
    return Node(OpKind.MAP, fn=fn, name=name)


def Reduce(monoid: Monoid = ADD) -> Node:
    return Node(OpKind.REDUCE, monoid=monoid)


def ReduceScatter(monoid: Monoid = ADD) -> Node:
    return Node(OpKind.REDUCE_SCATTER, monoid=monoid)


def AllGather() -> Node:
    return Node(OpKind.ALLGATHER)


def AllToAll() -> Node:
    return Node(OpKind.ALLTOALL)


def Scan(monoid: Monoid = ADD, exclusive: bool = False) -> Node:
    return Node(OpKind.SCAN, monoid=monoid, exclusive=exclusive)


def Bcast(root: int = 0) -> Node:
    return Node(OpKind.BCAST, root=root)


def Wire(codec: WireCodec) -> Node:
    return Node(OpKind.WIRE, codec=codec)


@dataclasses.dataclass
class SwitchProgram:
    """A linear dataflow chain (the common fused-collective shape).

    The paper's examples (Allgather_op_Allgather, AllReduce+AlltoAll,
    MapReduce) are all chains; richer DAGs reduce to chains per-tensor.
    """

    nodes: Sequence[Node]
    name: str = "program"

    def __post_init__(self):
        self.nodes = tuple(self.nodes)

    def labels(self) -> list[str]:
        return [n.label() for n in self.nodes]
