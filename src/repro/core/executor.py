"""ExecutionPlan IR — the compiled program's runtime schedule.

The paper's end goal is transparent acceleration of whole *programs*
encapsulated behind an MPI implementation (§VI.A), not of single
collectives.  A program-level runtime therefore needs more than an eager
stage chain: it needs to know which stages *depend* on each other and
which are free to overlap — SwitchML-style aggregation and ACCL+ both
win by streaming independent transfers through the fabric concurrently.

This module is that layer.  :func:`build_plan` derives explicit
dependency edges between emitted stages from the DAG's value ids and
groups independent stages into concurrent **waves** (Kahn levels):
every stage in wave *w* depends only on stages in waves < *w*, so a
runtime may launch a whole wave at once.  Within a wave the plan further
partitions stages into per-axis **dispatch groups** (``wave_groups``):
stages sharing a mesh axis contend for that axis's rings and must
serialize; stages on different axes traverse disjoint links and are free
to run concurrently.  Three consumers share the IR:

  * :meth:`repro.core.compiler.CompiledProgram.__call__` executes the
    plan wave by wave through :func:`execute`.  In overlapped mode the
    wave's dispatch groups are issued round-robin into one merged
    region: same-axis stages are tied together with explicit
    ``lax.optimization_barrier`` edges (pinning the ring order in the
    emitted HLO, so every rank issues the axis's collectives
    identically), while cross-axis stages carry **no** ordering edges —
    XLA's async scheduler may start their collectives concurrently.
    Serial mode (``overlapped=False``) reproduces the strict
    stage-ordered emission for A/B measurement.
  * :func:`repro.core.netmodel.program_time` costs the plan as a
    critical path with a per-tier overlap fraction instead of a
    sum of stage times,
  * :class:`repro.cgra.simulate.SwitchSim` advances its per-rank clocks
    wave by wave, overlapping stages that traverse *different* mesh
    axes (disjoint links, shared injection ports) and serializing
    stages that share one — the measurement that calibrates the
    analytic overlap model.

:func:`execute` also threads persistent **bucket arenas** through the
plan: a stage carrying an ``arena_slot`` (the Coalesce bucket packs)
receives its pre-allocated flat buffer and writes leaves into it in
place; the written buffers are returned alongside the program outputs so
a caller can donate them back on the next step
(``jax.jit(..., donate_argnums=...)``), dropping the pack transient from
2× to ~1× bucket size.

The plan is deliberately dumb data (stage indices + edges + waves): it
duck-types against anything carrying ``in_vids``/``out_vids``, so the
cost model can consume it without importing the compiler.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

from repro.obs import metrics as _metrics
from repro.obs import spans as _spans

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Dependency-explicit schedule over a compiled program's stages.

    ``deps[i]`` are the stage indices stage *i* consumes values from;
    ``waves`` partitions ``range(len(stages))`` into concurrency groups
    in topological order; ``wave_groups[w]`` splits wave ``w`` into
    per-axis dispatch groups ``(axis, stage_indices)`` — stages within a
    group share a mesh axis (or are axis-less local compute) and
    serialize, groups are mutually independent.  ``stages`` is the same
    sequence the owning ``CompiledProgram`` holds (kept here so the cost
    model and the simulator can walk the plan alone).
    """

    stages: tuple
    num_inputs: int
    outputs: tuple[int, ...]
    deps: tuple[tuple[int, ...], ...]
    waves: tuple[tuple[int, ...], ...]
    wave_groups: tuple[tuple[tuple[str, tuple[int, ...]], ...], ...] = ()

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    @property
    def n_waves(self) -> int:
        return len(self.waves)

    def wave_of(self, stage_index: int) -> int:
        for w, group in enumerate(self.waves):
            if stage_index in group:
                return w
        raise IndexError(stage_index)

    def validate(self) -> None:
        """Every stage appears in exactly one wave, strictly after all of
        its dependencies' waves; wave_groups re-partition each wave."""
        seen: dict[int, int] = {}
        for w, group in enumerate(self.waves):
            for i in group:
                if i in seen:
                    raise ValueError(f"stage {i} scheduled twice")
                seen[i] = w
        if len(seen) != len(self.stages):
            raise ValueError("waves do not cover every stage")
        for i, ds in enumerate(self.deps):
            for d in ds:
                if seen[d] >= seen[i]:
                    raise ValueError(
                        f"stage {i} (wave {seen[i]}) depends on stage {d} "
                        f"(wave {seen[d]}) — waves are not topological")
        for wave, groups in zip(self.waves, self.dispatch_groups()):
            flat = sorted(i for _, idxs in groups for i in idxs)
            if flat != sorted(wave):
                raise ValueError(
                    f"wave_groups {groups} do not partition wave {wave}")

    def dispatch_groups(self) -> tuple:
        """The per-wave axis dispatch groups — the stored ``wave_groups``
        when present, else derived on the fly (a plan built by hand with
        just stages/waves still dispatches correctly instead of silently
        running nothing)."""
        if len(self.wave_groups) == len(self.waves):
            return self.wave_groups
        return tuple(_axis_groups(self.stages, w) for w in self.waves)


def _axis_groups(stages: Sequence,
                 wave: tuple[int, ...]) -> tuple[tuple[str, tuple[int, ...]],
                                                 ...]:
    """Partition one wave into per-axis dispatch groups.

    Stages sharing a (non-empty) axis contend for that axis's rings and
    form one serialized group, in plan order.  Axis-less stages (local
    maps) are each their own singleton group — nothing serializes free
    compute.

    Within an axis group, batched ring launches (``batched_allreduce``)
    are issued first: the merged ring is the group's long pole, and
    leading with it lets the leftover per-program launches hide behind
    it.  Stages within one wave are mutually independent (same Kahn
    level), so the stable reorder cannot break a dependency.
    """
    by_axis: dict[str, list[int]] = {}
    groups: list[tuple[str, tuple[int, ...]]] = []
    for i in wave:
        ax = getattr(stages[i], "axis", "")
        if not ax:
            groups.append(("", (i,)))
            continue
        if ax not in by_axis:
            by_axis[ax] = []
            groups.append((ax, by_axis[ax]))  # placeholder; fixed below
        by_axis[ax].append(i)

    def batched_first(idxs):
        return tuple(sorted(
            idxs,
            key=lambda i: getattr(stages[i], "kind", "")
            != "batched_allreduce"))

    return tuple((ax, batched_first(idxs) if isinstance(idxs, list)
                  else idxs)
                 for ax, idxs in groups)


def _pipeline_levels(stages: Sequence, deps: Sequence[tuple[int, ...]],
                     levels: list[int]) -> list[int]:
    """Software-pipeline same-axis collective chains.

    Two topology-preserving refinements over the plain Kahn (ASAP)
    levels — symmetric bucket chains (pack -> ring -> epilogue per
    bucket, all on one axis) otherwise schedule all packs together, all
    rings together and all epilogues together, so no map ever hides
    under a ring:

      * a wave whose collectives all share ONE axis serializes on that
        axis's rings anyway (zero concurrency) — the extras slide to
        later waves, staggering the chains.  Waves holding collectives
        on several axes are left alone: their cross-axis overlap is the
        thing the tier model rewards, and splitting them would forfeit
        it;
      * an axis-less stage (local compute) with a consumer slides down
        to the wave just before its earliest consumer, landing next to
        the staggered collective it can hide under.  Output maps keep
        their ASAP slot.
    """
    n = len(stages)

    def axis(i: int) -> str:
        return getattr(stages[i], "axis", "") or ""

    for _ in range(n):
        # re-settle the dependency floor (stage order is topological)
        for i in range(n):
            if deps[i]:
                levels[i] = max(levels[i],
                                1 + max(levels[d] for d in deps[i]))
        by_wave: dict[int, list[int]] = {}
        for i in range(n):
            if axis(i):
                by_wave.setdefault(levels[i], []).append(i)
        moved = False
        for lv in sorted(by_wave):
            idxs = by_wave[lv]
            if len(idxs) < 2 or len({axis(i) for i in idxs}) != 1:
                continue
            for i in idxs[1:]:
                levels[i] += 1
            moved = True
            break
        if not moved:
            break

    consumers: list[list[int]] = [[] for _ in range(n)]
    for i in range(n):
        for d in deps[i]:
            consumers[d].append(i)
    for i in range(n - 1, -1, -1):
        if axis(i) or not consumers[i]:
            continue
        tgt = min(levels[c] for c in consumers[i]) - 1
        if tgt > levels[i]:
            levels[i] = tgt

    # compress any emptied levels
    remap = {lv: w for w, lv in enumerate(sorted(set(levels)))}
    return [remap[lv] for lv in levels]


def build_plan(stages: Sequence, num_inputs: int,
               outputs: tuple[int, ...]) -> ExecutionPlan:
    """Derive the dependency edges and concurrency waves for ``stages``.

    A stage depends on the stage producing each of its input values;
    values below ``num_inputs`` are program inputs (no producer).  Wave
    assignment starts from the Kahn level (1 + the max level of any
    dependency) and is then refined by :func:`_pipeline_levels` to
    stagger same-axis collective chains.
    """
    producer: dict[int, int] = {}
    for i, st in enumerate(stages):
        for v in st.out_vids:
            if v in producer:
                raise ValueError(
                    f"value {v} produced by stage {producer[v]} and "
                    f"stage {i} — the stage list is not single-assignment")
            producer[v] = i
    deps: list[tuple[int, ...]] = []
    levels: list[int] = []
    for i, st in enumerate(stages):
        ds = sorted({producer[v] for v in st.in_vids if v in producer})
        deps.append(tuple(ds))
        levels.append(1 + max((levels[d] for d in ds), default=-1))
    levels = _pipeline_levels(stages, deps, levels)
    n_waves = (max(levels) + 1) if levels else 0
    waves = tuple(tuple(i for i, l in enumerate(levels) if l == w)
                  for w in range(n_waves))
    wave_groups = tuple(_axis_groups(stages, w) for w in waves)
    plan = ExecutionPlan(tuple(stages), num_inputs, tuple(outputs),
                         tuple(deps), waves, wave_groups)
    plan.validate()
    return plan


def _barrier_tie(prev_outs: tuple, ins: tuple) -> tuple:
    """Tie a stage's inputs to its same-axis predecessor's outputs with an
    ``optimization_barrier`` edge, pinning the axis's collective order in
    the emitted HLO.  Falls back to trace order on jax versions without
    the primitive."""
    from jax import lax

    barrier = getattr(lax, "optimization_barrier", None)
    if barrier is None or not prev_outs:      # pragma: no cover - old jax
        return ins
    tied = barrier(tuple(ins) + tuple(prev_outs))
    return tuple(tied[:len(ins)])


def _issue_order(groups) -> list[int]:
    """Round-robin across a wave's dispatch groups: the k-th stage of
    every axis group is issued before any group's (k+1)-th, so
    different-axis collectives sit adjacent in the merged region and
    XLA's async scheduler can start them together."""
    order: list[int] = []
    cursors = [list(idxs) for _, idxs in groups]
    while any(cursors):
        for c in cursors:
            if c:
                order.append(c.pop(0))
    return order


def execute(plan: ExecutionPlan, args: Sequence[PyTree], *,
            arenas: Optional[Sequence] = None,
            overlapped: bool = True,
            instrument: Optional[list] = None) -> tuple:
    """Run the plan over rank-local values, wave by wave.

    ``overlapped=True`` (the default) issues each wave as one merged
    region: same-axis stages are chained with explicit
    ``optimization_barrier`` edges (they contend for one ring — every
    rank must issue them in the same order), different-axis stages are
    interleaved round-robin with no ordering edges between them, so
    XLA's latency-hiding scheduler may run their collectives
    concurrently.  ``overlapped=False`` reproduces the strict
    stage-ordered serial emission (the pre-overlap runtime) for A/B
    comparison.

    ``arenas`` are the persistent flat buffers for the program's bucket
    packs (one per ``arena_slot``, see
    :meth:`repro.core.compiler.CompiledProgram.make_arenas`); each pack
    writes its leaves into its arena in place rather than concatenating
    into a fresh buffer.  When given, returns ``(outputs, new_arenas)``
    with the written buffers, so the caller can donate them back on the
    next call; otherwise returns just the output tuple.

    ``instrument`` is the stage-trace recorder hook: a list that receives
    one :class:`repro.obs.spans.StageSpan` per executed stage — the
    shared stage-record schema (= ``repro.tune.trace.StageTrace``), with
    ``t_start``/``t_end`` ``perf_counter`` timestamps taken around a
    ``block_until_ready`` on the stage's outputs and the stage's payload
    bytes / placement already attached.  Only
    meaningful when the plan runs eagerly — under ``jit``/``shard_map``
    tracing the timestamps measure trace time, not run time; use the
    interleaved harness in :mod:`repro.tune.trace` for jitted programs.
    Instrumented stages synchronize per stage, so the recorded run is a
    serial measurement even in overlapped dispatch mode.
    """
    env: dict[int, PyTree] = dict(enumerate(args))
    new_arenas = list(arenas) if arenas is not None else None
    wave_of = {i: w for w, ws in enumerate(plan.waves) for i in ws}

    def run_stage(i: int, prev_outs: tuple) -> tuple:
        st = plan.stages[i]
        ins = tuple(env[v] for v in st.in_vids)
        if overlapped and prev_outs:
            ins = _barrier_tie(prev_outs, ins)
        slot = getattr(st, "arena_slot", None)
        if instrument is not None:
            import time

            import jax
            jax.block_until_ready(ins)
            t0 = time.perf_counter()
        if slot is not None and new_arenas is not None:
            outs = st.run(ins, st.axis, arena=new_arenas[slot])
            new_arenas[slot] = outs[0]
        else:
            outs = st.run(ins, st.axis)
        if instrument is not None:
            jax.block_until_ready(outs)
            span = _spans.from_stage(st, i, wave_of.get(i, 0), t0,
                                     time.perf_counter())
            instrument.append(span)
            rec = _metrics.RECORDER
            if rec.enabled:
                rec.count("exec.instrumented_stages")
                rec.observe("exec.stage_s", span.duration)
        for vid, o in zip(st.out_vids, outs):
            env[vid] = o
        return outs

    for wave, groups in zip(plan.waves, plan.dispatch_groups()):
        if not overlapped:
            for i in wave:
                run_stage(i, ())
            continue
        last_outs: dict[str, tuple] = {}
        for i in _issue_order(groups):
            ax = plan.stages[i].axis
            prev = last_outs.get(ax, ()) if ax else ()
            outs = run_stage(i, prev)
            if ax:
                last_outs[ax] = outs
    outs = tuple(env[v] for v in plan.outputs)
    if new_arenas is not None:
        return outs, tuple(new_arenas)
    return outs
