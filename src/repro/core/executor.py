"""ExecutionPlan IR — the compiled program's runtime schedule.

The paper's end goal is transparent acceleration of whole *programs*
encapsulated behind an MPI implementation (§VI.A), not of single
collectives.  A program-level runtime therefore needs more than an eager
stage chain: it needs to know which stages *depend* on each other and
which are free to overlap — SwitchML-style aggregation and ACCL+ both
win by streaming independent transfers through the fabric concurrently.

This module is that layer.  :func:`build_plan` derives explicit
dependency edges between emitted stages from the DAG's value ids and
groups independent stages into concurrent **waves** (Kahn levels):
every stage in wave *w* depends only on stages in waves < *w*, so a
runtime may launch a whole wave at once.  Three consumers share the IR:

  * :meth:`repro.core.compiler.CompiledProgram.__call__` executes the
    plan wave by wave (rank-local JAX issues the stages in plan order;
    the waves document — and bound — the legal overlap),
  * :func:`repro.core.netmodel.program_time` costs the plan as a
    critical path with a per-tier overlap fraction instead of a
    sum of stage times,
  * :class:`repro.cgra.simulate.SwitchSim` advances its per-rank clocks
    wave by wave, overlapping stages that traverse *different* mesh
    axes (disjoint links) and serializing stages that share one — the
    measurement that validates the analytic overlap model.

The plan is deliberately dumb data (stage indices + edges + waves): it
duck-types against anything carrying ``in_vids``/``out_vids``, so the
cost model can consume it without importing the compiler.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Dependency-explicit schedule over a compiled program's stages.

    ``deps[i]`` are the stage indices stage *i* consumes values from;
    ``waves`` partitions ``range(len(stages))`` into concurrency groups
    in topological order.  ``stages`` is the same sequence the owning
    ``CompiledProgram`` holds (kept here so the cost model and the
    simulator can walk the plan alone).
    """

    stages: tuple
    num_inputs: int
    outputs: tuple[int, ...]
    deps: tuple[tuple[int, ...], ...]
    waves: tuple[tuple[int, ...], ...]

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    @property
    def n_waves(self) -> int:
        return len(self.waves)

    def wave_of(self, stage_index: int) -> int:
        for w, group in enumerate(self.waves):
            if stage_index in group:
                return w
        raise IndexError(stage_index)

    def validate(self) -> None:
        """Every stage appears in exactly one wave, strictly after all of
        its dependencies' waves."""
        seen: dict[int, int] = {}
        for w, group in enumerate(self.waves):
            for i in group:
                if i in seen:
                    raise ValueError(f"stage {i} scheduled twice")
                seen[i] = w
        if len(seen) != len(self.stages):
            raise ValueError("waves do not cover every stage")
        for i, ds in enumerate(self.deps):
            for d in ds:
                if seen[d] >= seen[i]:
                    raise ValueError(
                        f"stage {i} (wave {seen[i]}) depends on stage {d} "
                        f"(wave {seen[d]}) — waves are not topological")


def build_plan(stages: Sequence, num_inputs: int,
               outputs: tuple[int, ...]) -> ExecutionPlan:
    """Derive the dependency edges and concurrency waves for ``stages``.

    A stage depends on the stage producing each of its input values;
    values below ``num_inputs`` are program inputs (no producer).  Wave
    assignment is the Kahn level: 1 + the max level of any dependency.
    """
    producer: dict[int, int] = {}
    for i, st in enumerate(stages):
        for v in st.out_vids:
            if v in producer:
                raise ValueError(
                    f"value {v} produced by stage {producer[v]} and "
                    f"stage {i} — the stage list is not single-assignment")
            producer[v] = i
    deps: list[tuple[int, ...]] = []
    levels: list[int] = []
    for i, st in enumerate(stages):
        ds = sorted({producer[v] for v in st.in_vids if v in producer})
        deps.append(tuple(ds))
        levels.append(1 + max((levels[d] for d in ds), default=-1))
    n_waves = (max(levels) + 1) if levels else 0
    waves = tuple(tuple(i for i, l in enumerate(levels) if l == w)
                  for w in range(n_waves))
    plan = ExecutionPlan(tuple(stages), num_inputs, tuple(outputs),
                         tuple(deps), waves)
    plan.validate()
    return plan


def execute(plan: ExecutionPlan, args: Sequence[PyTree]) -> tuple:
    """Run the plan over rank-local values, wave by wave.

    Rank-local JAX execution is sequential either way; walking the plan
    (rather than the flat stage list) keeps the runtime honest about the
    dependency structure the cost model and the dataplane simulator
    reason over, and is where an async transport would launch each wave
    concurrently.  Always returns a tuple, one entry per program output.
    """
    env: dict[int, PyTree] = dict(enumerate(args))
    for wave in plan.waves:
        for i in wave:
            st = plan.stages[i]
            outs = st.run(tuple(env[v] for v in st.in_vids), st.axis)
            for vid, o in zip(st.out_vids, outs):
                env[vid] = o
    return tuple(env[v] for v in plan.outputs)
