"""SwitchProgram compiler — legalize, fuse, schedule, emit.

Pipeline (mirroring the paper's back-end steps: parse IR → DFG →
optimizations → code generation → scheduling):

  1. **Legalize**: canonicalize node chain (REDUCE → RS∘AG split when a
     bandwidth-optimal schedule is requested; WIRE nodes sunk onto the
     collective they feed).
  2. **Fuse**: pattern rules —
       * MAP before/after a collective  → hop-fused map (Type 4)
       * ALLGATHER∘MAP∘ALLGATHER with SCAN-expressible map → fused
         scan+gather (the paper's Fig. 5 op)
       * REDUCE followed by ALLTOALL → fused shared-schedule hop loop
       * RS∘AG adjacency → single all-reduce schedule
  3. **Schedule/emit**: produce one rank-local callable; `compile_program`
     wraps it in `jax.shard_map` + `jax.jit` — the "CGRA binary".

The emitted `CompiledProgram` records its fused stage list so tests (and
the roofline accounting) can verify what was fused, exactly like inspecting
the paper's generated schedule.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import collectives, fused, ring
from repro.core.program import (COLLECTIVE_KINDS, Node, OpKind, SwitchProgram)
from repro.core.types import ADD
from repro.core.wire import IDENTITY

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Stage:
    """One fused in-network stage of the emitted schedule."""

    kind: str                      # e.g. "allreduce", "scan+allgather"
    run: Callable[[PyTree, str], PyTree]
    desc: str = ""

    def __repr__(self):  # pragma: no cover
        return f"Stage({self.kind})"


@dataclasses.dataclass
class CompiledProgram:
    stages: Sequence[Stage]
    source: SwitchProgram
    axis_name: str

    def stage_kinds(self) -> list[str]:
        return [s.kind for s in self.stages]

    def __call__(self, x: PyTree) -> PyTree:
        for st in self.stages:
            x = st.run(x, self.axis_name)
        return x


# ---------------------------------------------------------------------------
# Fusion rules
# ---------------------------------------------------------------------------

def _is_map(n: Node) -> bool:
    return n.kind == OpKind.MAP


def _fuse(nodes: list[Node], axis_name: str) -> list[Stage]:
    stages: list[Stage] = []
    i = 0
    pending_codec = IDENTITY
    while i < len(nodes):
        n = nodes[i]

        if n.kind == OpKind.WIRE:
            # sink the codec onto the next collective
            pending_codec = n.codec
            i += 1
            continue

        # --- rule: AG ∘ SCAN-map ∘ AG → fused scan+gather (paper Fig. 5) ---
        if (n.kind == OpKind.ALLGATHER and i + 2 < len(nodes)
                and nodes[i + 1].kind == OpKind.SCAN
                and nodes[i + 2].kind == OpKind.ALLGATHER):
            mono = nodes[i + 1].monoid
            if mono.name == "add":
                stages.append(Stage(
                    "scan+allgather",
                    lambda x, ax: fused.allgather_op_allgather(x, ax),
                    "fused allgather_op_allgather (in-network prefix scan)"))
            else:
                def run_sg(x, ax, _m=mono, _ex=nodes[i + 1].exclusive):
                    scanned = collectives.prefix_scan(x, ax, _m, exclusive=_ex)
                    return ring.ring_all_gather(scanned, ax)
                stages.append(Stage("scan+allgather", run_sg,
                                    f"fused scan({mono.name})+allgather"))
            i += 3
            continue

        # --- rule: REDUCE ∘ ALLTOALL → shared-schedule fusion (NAS IS) ---
        if (n.kind == OpKind.REDUCE and i + 1 < len(nodes)
                and nodes[i + 1].kind == OpKind.ALLTOALL):
            def run_ra(x, ax, _m=n.monoid):
                hist, keys = x
                return fused.fused_allreduce_alltoall(hist, keys, ax)
            stages.append(Stage("allreduce+alltoall", run_ra,
                                "fused AR+A2A on one ring traversal"))
            i += 2
            continue

        # --- rule: MAP ∘ collective / collective ∘ MAP → hop fusion ---
        if _is_map(n) and i + 1 < len(nodes) and nodes[i + 1].kind in (
                OpKind.REDUCE_SCATTER, OpKind.REDUCE):
            nxt = nodes[i + 1]
            if nxt.kind == OpKind.REDUCE_SCATTER:
                def run_mrs(x, ax, _f=n.fn, _m=nxt.monoid):
                    return fused.map_reduce_scatter(x, ax, _f, _m)
                stages.append(Stage("map+reduce_scatter", run_mrs,
                                    f"map({n.name or 'fn'}) fused into RS hops"))
            else:
                def run_mar(x, ax, _f=n.fn, _m=nxt.monoid, _c=pending_codec):
                    return collectives.all_reduce(_f(x), ax, _m, codec=_c)
                stages.append(Stage("map+allreduce", run_mar,
                                    "map fused ahead of AR schedule"))
                pending_codec = IDENTITY
            i += 2
            continue

        if n.kind == OpKind.ALLGATHER and i + 1 < len(nodes) and \
                _is_map(nodes[i + 1]):
            def run_agm(x, ax, _f=nodes[i + 1].fn):
                return fused.allgather_map(x, ax, _f)
            stages.append(Stage("allgather+map", run_agm,
                                "map applied in-flight at forwarding hop"))
            i += 2
            continue

        # --- rule: RS ∘ AG → one all-reduce schedule ---
        if (n.kind == OpKind.REDUCE_SCATTER and i + 1 < len(nodes)
                and nodes[i + 1].kind == OpKind.ALLGATHER):
            def run_ar(x, ax, _m=n.monoid, _c=pending_codec):
                return collectives.all_reduce(x, ax, _m, codec=_c)
            stages.append(Stage("allreduce", run_ar, "RS∘AG → ring AR"))
            pending_codec = IDENTITY
            i += 2
            continue

        # --- single-node lowerings ---
        stages.append(_lower_single(n, pending_codec))
        if n.kind in COLLECTIVE_KINDS:
            pending_codec = IDENTITY
        i += 1
    return stages


def _lower_single(n: Node, codec) -> Stage:
    if n.kind == OpKind.MAP:
        return Stage("map", lambda x, ax, _f=n.fn: _f(x), n.name or "map")
    if n.kind == OpKind.REDUCE:
        return Stage("allreduce",
                     lambda x, ax, _m=n.monoid, _c=codec:
                     collectives.all_reduce(x, ax, _m, codec=_c),
                     f"ring allreduce({n.monoid.name})")
    if n.kind == OpKind.REDUCE_SCATTER:
        return Stage("reduce_scatter",
                     lambda x, ax, _m=n.monoid:
                     collectives.reduce_scatter(x, ax, _m),
                     f"ring RS({n.monoid.name})")
    if n.kind == OpKind.ALLGATHER:
        return Stage("allgather",
                     lambda x, ax: collectives.all_gather(x, ax),
                     "ring AG")
    if n.kind == OpKind.ALLTOALL:
        return Stage("alltoall",
                     lambda x, ax: collectives.all_to_all(x, ax),
                     "shifted-ppermute A2A")
    if n.kind == OpKind.SCAN:
        return Stage("scan",
                     lambda x, ax, _m=n.monoid, _e=n.exclusive:
                     collectives.prefix_scan(x, ax, _m, exclusive=_e),
                     f"rank scan({n.monoid.name})")
    if n.kind == OpKind.BCAST:
        return Stage("bcast",
                     lambda x, ax, _r=n.root:
                     collectives.broadcast(x, ax, _r),
                     f"tree bcast(root={n.root})")
    raise ValueError(f"cannot lower node {n}")


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def compile_rank_local(prog: SwitchProgram, axis_name: str) -> CompiledProgram:
    """Compile to a rank-local callable (for use inside an existing
    shard_map region, e.g. embedded in a train step)."""
    stages = _fuse(list(prog.nodes), axis_name)
    return CompiledProgram(stages, prog, axis_name)


def compile_program(
    prog: SwitchProgram,
    mesh: jax.sharding.Mesh,
    axis_name: str,
    in_specs,
    out_specs,
    *,
    jit: bool = True,
) -> Callable:
    """Emit the full "CGRA binary": one shard_map-wrapped, jitted callable
    executing every fused stage in a single SPMD program."""
    compiled = compile_rank_local(prog, axis_name)

    def run(x):
        return compiled(x)

    fn = jax.shard_map(run, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    out = jax.jit(fn) if jit else fn
    out.stages = compiled.stage_kinds()  # type: ignore[attr-defined]
    return out
