"""SwitchProgram compiler — a pass pipeline over the DAG IR.

Mirrors the paper's back-end steps (parse IR → DFG → optimizations → code
generation → scheduling) as five composable passes:

  1. :class:`Legalize`   — dead-code-eliminate unused nodes and sink WIRE
     nodes onto the collective they feed (the codec becomes a node
     attribute; non-codec-capable consumers drop it, mirroring a
     fixed-function wire).
  2. :class:`LowerTopology` — resolve every collective's ``axis`` against
     the compile :class:`Topology` ({axis: size} plus per-axis link tier)
     and rewrite a REDUCE over a compound/``"auto"`` axis into the
     hierarchical RS(inner) → REDUCE(outer) → AG(inner) schedule, with
     any sunk wire codec riding the *outer* (thin inter-pod) hop only —
     ACiS processing placed exactly where the flows converge.
  3. :class:`Coalesce`   — execution planning, part one: bucket the
     per-leaf REDUCE / error-feedback REDUCE+DELIVERED units that share
     an axis, monoid and wire codec into flat-buffer **bucket stages**
     (concat the leaves, run one collective per fixed-byte bucket sized
     from the cost model's latency/bandwidth crossover, split the
     results back per leaf), so a many-leaf gradient sync pays the
     per-collective ring latency once per bucket instead of once per
     tensor — the SwitchML/ACCL+ streaming-aggregation shape.
  4. :class:`FuseHops`   — pattern-match fusion opportunities.  Each rule
     is a first-class :class:`FusionPattern` over the DAG (paper Fig. 5
     AG∘scan∘AG, the NAS-IS AR+A2A pair, map-into-hop fusion, RS∘AG →
     one all-reduce schedule, the error-feedback REDUCE+DELIVERED pair);
     matched nodes are grouped into :class:`StageIR` units — same-axis
     only — and topologically ordered.
  5. :class:`SelectSchedule` — pick the latency- vs bandwidth-optimal ring
     for every all-reduce stage by propagating per-rank payload bytes
     through the DAG and consulting ``CollectiveConfig.
     latency_optimal_below`` plus the analytic cost model in
     :mod:`repro.core.netmodel` — evaluated against the link tier of the
     axis the stage actually traverses (fast ICI vs thin DCI).
  6. :class:`PlaceCGRA`  — map every stage's compute body (fused MAPs,
     monoid/codec combines, look-aside compressors) onto the switch CGRA
     grid (:mod:`repro.cgra`): trace to a jaxpr, lower to an op-graph,
     list-schedule + place.  Each stage gets a ``Placement`` (PEs, depth,
     II → sustained rate) or an explicit host-fallback the cost model
     charges as a PCIe + MPI detour.
  7. :class:`Emit`       — lower every stage to a rank-local callable; the
     emitted :class:`CompiledProgram` executes them over a value
     environment (multi-input / multi-output programs are native), each
     stage over its own axis, following an explicit
     :class:`~repro.core.executor.ExecutionPlan` — execution planning,
     part two: stages carry dependency edges derived from the DAG and
     independent stages are grouped into concurrent waves, which is what
     :func:`repro.core.netmodel.program_time` costs as a critical path
     and the dataplane simulator executes with real overlap.

`compile_program` wraps the result in `jax.shard_map` + `jax.jit` — the
"CGRA binary".  The emitted program records its fused stage list, the
chosen schedules, the per-stage axes and the wave structure
(``CompiledProgram.explain()``) so tests (and the roofline accounting)
can verify what was fused and what overlaps, exactly like inspecting the
paper's generated schedule.
"""

from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Any, Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import (collectives, executor, fused, lookaside, netmodel,
                        ring, switchops)
from repro.core.program import (AUTO_AXIS, COLLECTIVE_KINDS, DagNode,
                                DagProgram, Node, OpKind, SwitchProgram)
from repro.core.tracing import trace
from repro.core.types import ADD
from repro.core.wire import IDENTITY, resolve_codec
from repro.obs import metrics as _obs

PyTree = Any
ProgramLike = Union[DagProgram, SwitchProgram, Callable]


def _as_dag(prog: ProgramLike) -> DagProgram:
    if isinstance(prog, DagProgram):
        return prog
    if isinstance(prog, SwitchProgram):
        return prog.to_dag()
    if callable(prog):
        return trace(prog)
    raise TypeError(f"cannot compile {type(prog).__name__}")


# ---------------------------------------------------------------------------
# Topology, compile context & stage forms
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AxisSpec:
    """One data-parallel mesh axis of the compile topology.

    ``tier`` keys into :data:`repro.core.netmodel.TIERS` and tells
    SelectSchedule which link parameters a stage on this axis traverses
    (``"ici"`` fast intra-pod, ``"dci"`` thin inter-pod).  ``size`` may be
    None — collectives then read it at run time via ``lax.axis_size`` and
    the cost model falls back to its bandwidth-optimal default.
    """

    name: str
    size: Optional[int] = None
    tier: str = "ici"


@dataclasses.dataclass(frozen=True)
class Topology:
    """The data-parallel axes a program may communicate over, innermost
    (fastest links) first — the compiler's description of where the
    network is fat and where it is thin."""

    axes: tuple[AxisSpec, ...]

    @classmethod
    def single(cls, name: str, size: Optional[int] = None,
               tier: str = "ici") -> "Topology":
        return cls((AxisSpec(name, size, tier),))

    def names(self) -> tuple[str, ...]:
        return tuple(a.name for a in self.axes)

    def spec(self, name: str) -> Optional[AxisSpec]:
        for a in self.axes:
            if a.name == name:
                return a
        return None

    def size(self, name: str) -> Optional[int]:
        a = self.spec(name)
        return a.size if a is not None else None

    def net(self, name: str) -> netmodel.NetParams:
        a = self.spec(name)
        if a is None:
            return netmodel.PAPER
        return netmodel.TIERS.get(a.tier, netmodel.PAPER)

    @property
    def inner(self) -> AxisSpec:
        return self.axes[0]

    @property
    def outer(self) -> Optional[AxisSpec]:
        return self.axes[-1] if len(self.axes) > 1 else None

    def with_sizes(self, sizes: dict) -> "Topology":
        """Fill (or correct) axis sizes from a mesh's {name: size} map."""
        return Topology(tuple(
            dataclasses.replace(a, size=sizes.get(a.name, a.size))
            for a in self.axes))


@dataclasses.dataclass
class CompileContext:
    """Everything the passes may consult.

    ``config`` duck-types :class:`repro.core.api.CollectiveConfig` (only
    ``latency_optimal_below``, ``backend`` and ``codec`` are read) to avoid
    an api↔compiler import cycle.  ``in_avals`` are rank-local shape/dtype
    structs for the program inputs — optional; without them SelectSchedule
    keeps the bandwidth-optimal default.  ``topology`` defaults to the
    single ``axis_name`` axis on the fast tier.
    """

    axis_name: str
    axis_size: Optional[int] = None
    config: Any = None
    in_avals: Optional[Sequence[Any]] = None
    net: netmodel.NetParams = netmodel.PAPER
    dag: Optional[DagProgram] = None    # current form, updated per pass
    topology: Optional[Topology] = None
    # memo for _propagate_avals: (dag object, aval map).  Coalesce and
    # SelectSchedule both need per-value avals; when Coalesce leaves the
    # DAG untouched (the common case) the eval_shape walk runs once.
    aval_memo: Optional[tuple] = None

    @property
    def latency_optimal_below(self) -> Optional[int]:
        if self.config is None:
            return None
        return getattr(self.config, "latency_optimal_below", None)

    def size_of(self, axis: str) -> Optional[int]:
        if self.topology is not None:
            s = self.topology.size(axis)
            if s is not None:
                return s
        return self.axis_size if axis == self.axis_name else None

    def net_of(self, axis: str) -> netmodel.NetParams:
        if self.topology is not None and self.topology.spec(axis) is not None:
            return self.topology.net(axis)
        return self.net

    def default_wire_codec(self):
        """The codec a compressed engine applies at the thin outer hop when
        the program didn't declare one — compression exactly where the
        wire is thin is a compiler decision, not a call-site convention."""
        if self.config is None:
            return IDENTITY
        if "compressed" not in getattr(self.config, "backend", ""):
            return IDENTITY
        return resolve_codec(getattr(self.config, "codec", "identity"))


@dataclasses.dataclass(frozen=True)
class StageIR:
    """One fused group of DAG nodes, pre-emission."""

    kind: str
    nodes: tuple[DagNode, ...]
    in_vids: tuple[int, ...]
    out_vids: tuple[int, ...]
    schedule: str = ""             # "latency" | "bandwidth" | "" (fixed)
    bytes_in: Optional[int] = None
    # per-operand payload split where the summed bytes_in is not enough
    # (the fused AR+A2A pair: (hist bytes, keys bytes) — the shared ring
    # carries them very differently)
    bytes_parts: Optional[tuple[int, ...]] = None
    desc: str = ""
    axis: str = ""                 # mesh axis the stage communicates over
    placement: Optional[Any] = None  # CGRA Placement | HostFallback


@dataclasses.dataclass(frozen=True)
class Stage:
    """One emitted in-network stage: ``run(args, axis_name) -> outputs``.

    ``placement`` is the CGRA mapping the PlaceCGRA pass attached (a
    :class:`repro.cgra.device.Placement`, or an explicit
    :class:`~repro.cgra.device.HostFallback` when the stage's compute
    body does not fit the switch grid); ``ir`` is the pre-emission
    :class:`StageIR` the stage was lowered from — the dataplane
    simulator interprets it instead of the opaque ``run`` closure.
    """

    kind: str
    run: Callable[[tuple, str], tuple]
    desc: str = ""
    in_vids: tuple[int, ...] = ()
    out_vids: tuple[int, ...] = ()
    schedule: str = ""
    axis: str = ""
    placement: Optional[Any] = None
    ir: Optional[StageIR] = None
    # Coalesce bucket packs: index into the program's arena list (the
    # persistent flat buffer this stage may write in place) and the
    # rank-local aval of that buffer.  None for every other stage.
    arena_slot: Optional[int] = None
    arena_aval: Optional[Any] = None

    def __repr__(self):  # pragma: no cover
        return f"Stage({self.kind}@{self.axis})" if self.axis \
            else f"Stage({self.kind})"


@dataclasses.dataclass(eq=False)
class CompiledProgram:
    """Rank-local executable: stages run over a value environment following
    an explicit :class:`~repro.core.executor.ExecutionPlan`.

    Every stage carries its own communication axis (stamped by
    LowerTopology), so one program may span several mesh axes — there is
    no single program-wide axis any more.  The plan (dependency edges +
    concurrency waves, derived from the DAG at construction) is what the
    analytic cost model prices (:func:`repro.core.netmodel.program_time`)
    and the dataplane simulator executes wave by wave.

    Calling the program always returns a **tuple**, one entry per program
    output — single-output programs return a 1-tuple, not a bare array.

    ``overlap`` selects the dispatch mode (see
    :func:`repro.core.executor.execute`): overlapped wave dispatch by
    default, strict stage-ordered serial emission when False
    (``CollectiveConfig.overlap_dispatch`` at compile time).

    The program's Coalesce bucket packs may additionally write into
    persistent **arenas**: call :meth:`make_arenas` once, thread the
    buffers through every call (``outs, arenas = prog(*xs,
    arenas=arenas)``) and donate them at the jit boundary — the pack
    transient drops from 2× to ~1× bucket size.
    """

    stages: Sequence[Stage]
    source: DagProgram
    topology: Optional[Topology] = None
    plan: Optional[executor.ExecutionPlan] = None
    overlap: bool = True

    def __post_init__(self):
        if self.plan is None:
            self.plan = executor.build_plan(
                self.stages, self.source.num_inputs, self.source.outputs)

    # -- persistent bucket arenas -------------------------------------------

    @property
    def arena_avals(self) -> tuple:
        """Rank-local aval of every bucket-pack arena, slot order."""
        slots = [st for st in self.stages if st.arena_slot is not None]
        return tuple(st.arena_aval
                     for st in sorted(slots, key=lambda s: s.arena_slot))

    def make_arenas(self) -> Optional[tuple]:
        """Freshly allocated arena buffers (one flat zeros per bucket
        pack), or None when the program has no bucket stages.  Allocate
        once per program, outside any trace, and thread the returned
        tuple through every call so the buffers can be donated."""
        avals = self.arena_avals
        if not avals:
            return None
        return tuple(jnp.zeros(a.shape, a.dtype) for a in avals)

    def pack_transient_bytes(self, *, arenas: bool = False) -> int:
        """Peak transient bytes of the bucket packs: each pack holds its
        source leaves alive while materializing the flat bucket, so a
        fresh concat peaks at ~2× the bucket; an in-place arena write
        peaks at ~1× (the persistent buffer is not a transient of this
        step, only the leaves are).  Packs sharing a wave have no
        ordering edges between them — the runtime deliberately lets them
        issue concurrently — so their transients are *summed* per wave
        and the peak is the worst wave, not the largest single bucket.
        """
        wave_of = {i: w for w, grp in enumerate(self.plan.waves)
                   for i in grp}
        per_wave: dict[int, int] = {}
        for i, st in enumerate(self.stages):
            if st.arena_aval is None:
                continue
            bucket = _aval_bytes(st.arena_aval)
            w = wave_of.get(i, -1)
            per_wave[w] = per_wave.get(w, 0) \
                + (bucket if arenas else 2 * bucket)
        return max(per_wave.values(), default=0)

    def stage_kinds(self) -> list[str]:
        return [s.kind for s in self.stages]

    def stage_schedules(self) -> list[str]:
        return [s.schedule for s in self.stages]

    def stage_axes(self) -> list[str]:
        return [s.axis for s in self.stages]

    def stage_placements(self) -> list:
        return [s.placement for s in self.stages]

    def explain(self, trace=None) -> str:
        """Readable per-stage table: what was fused, which wave of the
        execution plan it runs in (stages sharing a wave are independent
        and may overlap), over which axis, on which ring schedule, with
        which wire codec, and where the compute body landed (CGRA
        placement or explicit host fallback).

        With ``trace`` (a :class:`repro.tune.trace.ProgramTrace`, an
        :class:`repro.obs.report.RunReport`, or anything with a
        ``stages`` list of records carrying ``stage`` and ``duration``),
        three more columns compare the recording against the analytic
        model — measured µs, model µs and their ratio — and a footer
        summarizes the mispredict ratio over the priced stages.  Without
        a recording the footer says so explicitly instead of silently
        omitting the columns.
        """
        if trace is not None and not hasattr(trace, "stages") \
                and hasattr(trace, "trace"):
            trace = trace.trace        # a RunReport: unwrap its trace
        wave_of = {i: w for w, grp in enumerate(self.plan.waves)
                   for i in grp}
        measured: dict[int, float] = {}
        if trace is not None:
            for ts in getattr(trace, "stages", trace):
                measured.setdefault(ts.stage, ts.duration)
        header = ("#", "wave", "kind", "axis", "schedule", "codec",
                  "placement")
        if trace is not None:
            header += ("meas_us", "model_us", "ratio")
        rows = [header]
        ratios: list[tuple[float, int]] = []
        for i, st in enumerate(self.stages):
            codec = "-"
            if st.ir is not None:
                for nd in st.ir.nodes:
                    if nd.op.kind in COLLECTIVE_KINDS \
                            and nd.op.codec is not IDENTITY:
                        codec = nd.op.codec.name
                    elif nd.op.ef is not None:
                        codec = f"ef[{nd.op.ef.compressor}]"
            pl = st.placement.describe() if st.placement is not None \
                else "-"
            kind = st.kind
            if kind == "map" and st.ir is not None:
                # named epilogues (masked_pack/renorm/count, hier_pad, ...)
                # would otherwise all print as an anonymous "map"
                name = next((nd.op.name for nd in st.ir.nodes
                             if nd.op.name), "")
                if name:
                    kind = f"map:{name}"
            row = (str(i), str(wave_of.get(i, "-")), kind,
                   st.axis or "-", st.schedule or "-", codec, pl)
            if trace is not None:
                meas = measured.get(i)
                model = netmodel.plan_stage_time(st, self.topology)
                m_s = f"{meas * 1e6:.1f}" if meas is not None else "-"
                t_s = f"{model * 1e6:.1f}" if model is not None else "-"
                r_s = "-"
                if meas is not None and model:
                    r = meas / model
                    ratios.append((r, i))
                    r_s = f"x{r:.2f}"
                row += (m_s, t_s, r_s)
            rows.append(row)
        ncols = len(rows[0]) - 1         # last column stays ragged
        widths = [max(len(r[c]) for r in rows) for c in range(ncols)]
        lines = [f"program {self.source.name!r} "
                 f"({self.source.num_inputs} in, "
                 f"{len(self.source.outputs)} out, "
                 f"{len(self.stages)} stages, "
                 f"{self.plan.n_waves} waves)"]
        for j, r in enumerate(rows):
            lines.append("  " + "  ".join(
                r[c].ljust(widths[c]) for c in range(ncols))
                + "  " + r[ncols])
            if j == 0:
                lines.append("  " + "-" * (sum(widths) + 2 * ncols
                                           + len(r[ncols])))
        if ratios:
            mean = sum(r for r, _ in ratios) / len(ratios)
            worst = max(ratios, key=lambda t: max(t[0], 1.0 / t[0]))
            lines.append(
                f"  mispredict ratio (meas/model): mean x{mean:.2f}, "
                f"worst x{worst[0]:.2f} @ stage {worst[1]} "
                f"({len(ratios)}/{len(self.stages)} stages priced)")
        elif trace is not None:
            lines.append(
                "  mispredict ratio: no stages priced — the recording's "
                "stage indices don't match this plan")
        else:
            lines.append(
                "  (no recording attached — pass trace= a repro.tune "
                "ProgramTrace or repro.obs RunReport to add "
                "measured-vs-model columns)")
        return "\n".join(lines)

    def program_time(self, topology: Optional[Topology] = None) -> float:
        """Analytic wall time of the whole plan (critical path with
        per-tier overlap) — :func:`repro.core.netmodel.program_time`
        against this program's compile topology."""
        topo = topology if topology is not None else self.topology
        return netmodel.program_time(self.plan, topo)

    def axes(self) -> list[str]:
        """Distinct communication axes, in first-use order."""
        seen: list[str] = []
        for s in self.stages:
            if s.axis and s.axis not in seen:
                seen.append(s.axis)
        return seen

    def __call__(self, *xs: PyTree, arenas: Optional[tuple] = None,
                 instrument: Optional[list] = None) -> tuple:
        """Run the plan.  Without ``arenas``: the output tuple.  With
        ``arenas`` (from :meth:`make_arenas`, or the previous call's
        second result): ``(outputs, new_arenas)`` — thread and donate the
        arenas so the bucket packs write in place.  ``instrument`` is the
        stage-trace recorder hook (see
        :func:`repro.core.executor.execute`); only meaningful on eager
        runs."""
        n_in = self.source.num_inputs
        if len(xs) == 1 and n_in > 1 and isinstance(xs[0], (tuple, list)):
            xs = tuple(xs[0])      # chain-shim spelling: one tuple argument
        if len(xs) != n_in:
            raise TypeError(
                f"program {self.source.name!r} takes {n_in} inputs, "
                f"got {len(xs)}")
        if arenas is not None:
            avals = self.arena_avals
            if len(arenas) != len(avals):
                raise TypeError(
                    f"program {self.source.name!r} has {len(avals)} "
                    f"bucket arenas, got {len(arenas)}")
            for i, (a, want) in enumerate(zip(arenas, avals)):
                # shape AND dtype must match: the pack would otherwise
                # silently astype-cast every gradient into the arena's
                # dtype (e.g. f32 grads into a bf16 arena)
                if tuple(a.shape) != tuple(want.shape) \
                        or jnp.dtype(a.dtype) != jnp.dtype(want.dtype):
                    raise TypeError(
                        f"program {self.source.name!r} arena {i} must be "
                        f"{want.shape} {want.dtype}, got {tuple(a.shape)} "
                        f"{a.dtype} — rebuild the arenas for this "
                        "program (make_arenas / engine.init_arenas with "
                        "matching grad dtypes)")
        return executor.execute(self.plan, xs, arenas=arenas,
                                overlapped=self.overlap,
                                instrument=instrument)


# ---------------------------------------------------------------------------
# Pass 1: Legalize
# ---------------------------------------------------------------------------

# consumers that can apply a wire codec in-flight (all lower to an
# all-reduce schedule, which takes `codec=`)
_CODEC_SINKS = {OpKind.REDUCE, OpKind.REDUCE_SCATTER, OpKind.MASKED_REDUCE}


def _masked_pack_fn(monoid) -> Callable:
    """Legalize-side expansion of MASKED_REDUCE: mask the payload with the
    monoid identity (``where``, not multiply — ``0 * NaN`` would poison
    the ring) and append this rank's alive flag as one trailing lane, so
    the live count folds in the *same* flat buffer as the payload.  Under
    ``add`` the trailing lane reduces to the live count; under other
    monoids it is the monoid-fold of the alive flags (renormalization is
    add-only and rejected at trace time otherwise)."""
    def masked_pack(x, alive):
        a = alive.reshape(()).astype(x.dtype)
        fill = monoid.identity(jax.ShapeDtypeStruct((x.size,), x.dtype))
        body = jnp.where(a != 0, x.reshape(-1), fill)
        return jnp.concatenate([body, a.reshape(1)])
    masked_pack.masked_monoid = monoid
    return masked_pack


class Legalize:
    """Canonicalize the DAG: DCE + sink WIRE nodes onto their consumer +
    expand MASKED_REDUCE into masked_pack → REDUCE (the count lane rides
    the payload's flat buffer — one ring, not two launches)."""

    name = "legalize"

    def run(self, dag: DagProgram, ctx: CompileContext) -> DagProgram:
        dag = self._dce(dag)
        dag = self._sink_wires(dag)
        return self._expand_masked(dag)

    @staticmethod
    def _expand_masked(dag: DagProgram) -> DagProgram:
        """MASKED_REDUCE(x, alive) → masked_pack MAP → REDUCE.

        Runs after ``_sink_wires`` so a codec sunk onto the masked reduce
        transfers to the emitted REDUCE (it rides the same hop the
        payload does).  The expansion is total: MASKED_REDUCE must never
        survive Legalize — no later pass can lower it.
        """
        if not any(nd.op.kind == OpKind.MASKED_REDUCE for nd in dag.nodes):
            return dag
        next_vid = max(
            [dag.num_inputs - 1] + [nd.out for nd in dag.nodes]) + 1
        nodes: list[DagNode] = []
        for nd in dag.nodes:
            if nd.op.kind != OpKind.MASKED_REDUCE:
                nodes.append(nd)
                continue
            pack_out = next_vid
            next_vid += 1
            nodes.append(DagNode(
                Node(OpKind.MAP, fn=_masked_pack_fn(nd.op.monoid),
                     name="masked_pack", fusable=False),
                nd.inputs, pack_out))
            nodes.append(DagNode(
                Node(OpKind.REDUCE, monoid=nd.op.monoid,
                     codec=nd.op.codec, axis=nd.op.axis),
                (pack_out,), nd.out))
        return DagProgram(dag.num_inputs, tuple(nodes), dag.outputs,
                          dag.name)

    @staticmethod
    def _dce(dag: DagProgram) -> DagProgram:
        live = set(dag.outputs)
        keep: list[DagNode] = []
        for nd in reversed(dag.nodes):
            if nd.out in live:
                keep.append(nd)
                live.update(nd.inputs)
        keep.reverse()
        if len(keep) == len(dag.nodes):
            return dag
        return DagProgram(dag.num_inputs, tuple(keep), dag.outputs, dag.name)

    @staticmethod
    def _sink_wires(dag: DagProgram) -> DagProgram:
        """Replace WIRE nodes by a ``codec`` attribute on their consumer.

        The codec travels through single-input MAPs (the map runs before
        the payload hits the wire, so the declaration still applies to the
        collective downstream — the old chain compiler's pending-codec
        behaviour).  A WIRE reaching a non-codec-capable op or a program
        output is dropped — the wire format of those links is fixed — and
        the drop is *announced* with a ``UserWarning`` naming the node, so
        a user who declared compression on a link that cannot apply it
        learns the codec was ignored instead of silently paying f32 wire
        bytes they thought they'd saved.
        """
        if not any(nd.op.kind == OpKind.WIRE for nd in dag.nodes):
            return dag
        alias: dict[int, int] = {}       # wire out → its input
        carried: dict[int, Any] = {}     # value id → pending codec

        def resolve(vid: int) -> int:
            while vid in alias:
                vid = alias[vid]
            return vid

        def warn_drop(codec, where: str) -> None:
            warnings.warn(
                f"[{dag.name}] wire codec {codec.name!r} dropped at "
                f"{where} — that link's wire format is fixed, the "
                "declared compression will NOT be applied",
                UserWarning, stacklevel=3)

        nodes: list[DagNode] = []
        applied: set[int] = set()        # carried vids whose codec sank
        for nd in dag.nodes:
            if nd.op.kind == OpKind.WIRE:
                alias[nd.out] = nd.inputs[0]
                carried[nd.out] = nd.op.codec
                continue
            op = nd.op
            ins = tuple(resolve(v) for v in nd.inputs)
            codecs = [carried[v] for v in nd.inputs if v in carried]
            if codecs:
                # an error-feedback reduce is not codec-capable — its wire
                # format is the compressor's, so a WIRE reaching it drops
                # like on any fixed-function link
                if op.kind in _CODEC_SINKS and op.ef is None:
                    op = dataclasses.replace(op, codec=codecs[-1])
                    applied.update(v for v in nd.inputs if v in carried)
                elif op.kind == OpKind.MAP and len(nd.inputs) == 1:
                    carried[nd.out] = codecs[-1]
                elif op.kind in _CODEC_SINKS:
                    warn_drop(codecs[-1],
                              f"error-feedback node {op.label()!r} (its "
                              "wire format is the compressor's)")
                else:
                    warn_drop(codecs[-1],
                              f"non-codec-capable node {op.label()!r}")
            nodes.append(DagNode(op, ins, nd.out))
        for v in dag.outputs:
            # a pending codec that reached an output without ever sinking
            # (directly, or carried through maps) was silently useless
            if v in carried and v not in applied:
                warn_drop(carried[v], "a program output")
        outputs = tuple(resolve(v) for v in dag.outputs)
        return DagProgram(dag.num_inputs, tuple(nodes), outputs, dag.name)


# ---------------------------------------------------------------------------
# Pass 2: LowerTopology — resolve axes, lower compound reductions
# ---------------------------------------------------------------------------

def _flatten_pad(inner_axes: tuple[str, ...],
                 monoid=None, quant_safe: bool = False) -> Callable:
    """Flatten to 1-D and pad to a multiple of the product of the inner
    axis sizes, so the reduce-scatter chain can chunk evenly.  Runs inside
    shard_map, where ``lax.axis_size`` is concrete — no static size needed
    at compile time.

    Pad lanes carry the reduce monoid's identity so per-hop combines never
    see invented values (a literal 0 clamps ``min`` / annihilates ``prod``).
    ``quant_safe`` forces a zero fill instead: a blockwise-quant codec on
    the outer hop shares one scale per block, and a huge identity element
    (e.g. max's -3.4e38) in the tail block would absorb the real lanes'
    resolution — the pad lanes themselves are sliced off by hier_unpad.
    """
    def fn(x):
        n = 1
        for ax in inner_axes:
            n *= lax.axis_size(ax)
        m = None if quant_safe else monoid
        return ring.pad_to_multiple(x.reshape(-1), n, monoid=m)[0]
    # the axis query makes fn opaque to jax.eval_shape; expose the axes
    # so _propagate_avals can compute the padded shape statically
    fn.inner_axes = tuple(inner_axes)
    return fn


def _unpad_like(y, orig):
    """Undo :func:`_flatten_pad` using the original operand for shape."""
    return y[:orig.size].reshape(orig.shape)


class LowerTopology:
    """Make topology a compiler concern.

    Every collective's ``axis`` is resolved against ``ctx.topology``:
    ``None`` → the engine default axis, ``"auto"`` → all DP axes of the
    topology, a tuple → that compound axis (innermost first).  A REDUCE
    over a compound axis is rewritten into the hierarchical schedule

        pad → RS(inner…) → REDUCE(outer, codec) → AG(…inner) → unpad

    so the later passes fuse/schedule/emit *per axis*.  A sunk wire codec
    (or a compressed engine's default codec) rides the outer hop only —
    the payload crossing the thin inter-pod links is already 1/|inner| of
    the gradient, and it is the only place compression pays.  An
    error-feedback REDUCE instead compresses at the innermost tier (where
    its DELIVERED sibling lives) and reduces the outer tiers exactly.
    """

    name = "lower_topology"

    def run(self, dag: DagProgram, ctx: CompileContext) -> DagProgram:
        nodes: list[DagNode] = []
        vmap: dict[int, int] = {i: i for i in range(dag.num_inputs)}
        next_vid = dag.num_inputs

        def emit(op: Node, ins: Sequence[int]) -> int:
            nonlocal next_vid
            vid = next_vid
            next_vid += 1
            nodes.append(DagNode(op, tuple(ins), vid))
            return vid

        for nd in dag.nodes:
            ins = tuple(vmap[v] for v in nd.inputs)
            op = nd.op
            if op.kind not in COLLECTIVE_KINDS:
                vmap[nd.out] = emit(op, ins)
                continue
            axes = self._resolve(op.axis, ctx)
            if len(axes) == 1 or op.kind == OpKind.DELIVERED:
                # DELIVERED is rank-local feedback of the innermost-tier
                # compression — it never spans tiers
                vmap[nd.out] = emit(
                    dataclasses.replace(op, axis=axes[0]), ins)
            elif op.kind == OpKind.REDUCE:
                vmap[nd.out] = self._lower_reduce(op, ins[0], axes, ctx,
                                                  emit)
            else:
                raise NotImplementedError(
                    f"{op.kind.value} over compound axis {axes} has no "
                    "hierarchical lowering (only reduce does)")
        return DagProgram(dag.num_inputs, tuple(nodes),
                          tuple(vmap[v] for v in dag.outputs), dag.name)

    @staticmethod
    def _resolve(axis, ctx: CompileContext) -> tuple[str, ...]:
        if axis is None:
            return (ctx.axis_name,)
        if axis == AUTO_AXIS:
            if ctx.topology is None:
                return (ctx.axis_name,)
            return ctx.topology.names()
        if isinstance(axis, str):
            return (axis,)
        return tuple(axis)

    def _lower_reduce(self, op: Node, vin: int, axes: tuple[str, ...],
                      ctx: CompileContext, emit) -> int:
        if op.ef is not None:
            # error feedback applies at the innermost tier; the outer
            # tiers reduce the (already compressed) partials exactly
            v = emit(dataclasses.replace(op, axis=axes[0]), (vin,))
            for ax in axes[1:]:
                v = emit(Node(OpKind.REDUCE, monoid=op.monoid, axis=ax),
                         (v,))
            return v
        inner, outer = axes[:-1], axes[-1]
        codec = op.codec
        if codec is IDENTITY:
            codec = ctx.default_wire_codec()
        # pad/unpad are shape bookkeeping, not chunk-local compute — they
        # must not be hop-fused into the ring schedules
        quant_safe = codec.combine_encoded is not None
        p = emit(Node(OpKind.MAP,
                      fn=_flatten_pad(inner, monoid=op.monoid,
                                      quant_safe=quant_safe),
                      name="hier_pad", fusable=False), (vin,))
        for ax in inner:
            p = emit(Node(OpKind.REDUCE_SCATTER, monoid=op.monoid, axis=ax),
                     (p,))
        p = emit(Node(OpKind.REDUCE, monoid=op.monoid, codec=codec,
                      axis=outer), (p,))
        for ax in reversed(inner):
            p = emit(Node(OpKind.ALLGATHER, axis=ax), (p,))
        return emit(Node(OpKind.MAP, fn=_unpad_like, name="hier_unpad",
                         fusable=False), (p, vin))


# ---------------------------------------------------------------------------
# Pass 3: Coalesce — bucket per-leaf reductions into flat-buffer stages
# ---------------------------------------------------------------------------

def _propagate_avals(dag: DagProgram,
                     ctx: CompileContext) -> dict[int, jax.ShapeDtypeStruct]:
    """Best-effort rank-local aval for every DAG value.

    Program inputs come from ``ctx.in_avals``; MAP outputs via
    ``jax.eval_shape`` (a map whose body queries ``lax.axis_size`` —
    e.g. the hier pad/mean bookkeeping — simply stays unknown);
    collectives preserve their input aval except AG/RS, which scale the
    leading dim by their axis size when it is known.
    """
    if ctx.in_avals is None:
        return {}
    if ctx.aval_memo is not None and ctx.aval_memo[0] is dag:
        return ctx.aval_memo[1]
    avals: dict[int, jax.ShapeDtypeStruct] = {}
    for i, a in enumerate(ctx.in_avals):
        try:
            avals[i] = jax.ShapeDtypeStruct(tuple(a.shape), a.dtype)
        except Exception:
            pass
    for nd in dag.nodes:
        ins = [avals.get(v) for v in nd.inputs]
        if any(a is None for a in ins):
            continue
        k = nd.op.kind
        if k == OpKind.MAP:
            try:
                out = jax.eval_shape(nd.op.fn, *ins)
            except Exception:
                # hier_pad queries lax.axis_size (opaque to eval_shape)
                # but advertises its axes — compute the pad statically
                inner = getattr(nd.op.fn, "inner_axes", None)
                if inner:
                    n = 1
                    for ax in inner:
                        sz = ctx.size_of(ax)
                        if not sz:
                            n = None
                            break
                        n *= sz
                    if n:
                        flat = int(math.prod(ins[0].shape)) \
                            if ins[0].shape else 1
                        avals[nd.out] = jax.ShapeDtypeStruct(
                            (-(-flat // n) * n,), ins[0].dtype)
                continue
            if hasattr(out, "shape") and hasattr(out, "dtype"):
                avals[nd.out] = jax.ShapeDtypeStruct(tuple(out.shape),
                                                     out.dtype)
        elif k == OpKind.ALLGATHER:
            n = SelectSchedule._axis_size(nd, ctx)
            if n and ins[0].shape:
                avals[nd.out] = jax.ShapeDtypeStruct(
                    (ins[0].shape[0] * n,) + tuple(ins[0].shape[1:]),
                    ins[0].dtype)
        elif k == OpKind.REDUCE_SCATTER:
            n = SelectSchedule._axis_size(nd, ctx)
            if n and ins[0].shape:
                avals[nd.out] = jax.ShapeDtypeStruct(
                    (max(ins[0].shape[0] // n, 1),)
                    + tuple(ins[0].shape[1:]), ins[0].dtype)
        elif k != OpKind.WIRE:
            avals[nd.out] = ins[0]
    ctx.aval_memo = (dag, avals)
    return avals


def _aval_bytes(aval) -> int:
    size = int(math.prod(aval.shape)) if aval.shape else 1
    return size * jnp.dtype(aval.dtype).itemsize


def _pack_fn(sizes: tuple[int, ...], dtype: str = "float32") -> Callable:
    """Emit-side shim: flatten every leaf and concat into one flat bucket.

    The bucket layout (split offsets) was computed from the compile
    ``in_avals`` — if a leaf shows up at run time with a different
    element count, slicing would silently hand every downstream leaf the
    wrong gradient, so the mismatch is rejected at trace time instead.

    The per-leaf sizes and bucket dtype ride on the function as
    ``bucket_sizes`` / ``bucket_dtype``: Emit reads them to lower the
    pack as a donation-aware **arena write** (in-place
    ``dynamic_update_slice`` into a persistent flat buffer) instead of a
    fresh concatenation when the caller threads arenas through the call.
    """
    def pack(*xs):
        _check_pack_sizes(xs, sizes)
        return jnp.concatenate([x.reshape(-1) for x in xs], axis=0)
    pack.bucket_sizes = sizes
    pack.bucket_dtype = dtype
    return pack


def _check_pack_sizes(xs, sizes: tuple[int, ...]) -> None:
    for i, (x, s) in enumerate(zip(xs, sizes)):
        if x.size != s:
            raise ValueError(
                f"Coalesce bucket pack: leaf {i} has {x.size} "
                f"elements at run time but the compile in_avals "
                f"promised {s} — pass in_avals matching the "
                "rank-local shapes (bucket offsets are computed "
                "from them)")


def _split_fn(offset: int, size: int) -> Callable:
    """Emit-side shim: slice one leaf back out of a reduced flat bucket,
    shaped like the original operand (runtime shape, not the aval — a
    rank-local leading dim of 1 survives the round trip)."""
    def split(b, orig):
        return b[offset:offset + size].reshape(orig.shape)
    return split


def _masked_bucket_pack_fn(sizes: tuple[int, ...], dtype: str,
                           monoid) -> Callable:
    """Bucket pack for masked reductions: mask every leaf with the monoid
    identity (``where`` on the shared alive flag — the last argument) and
    append ONE trailing count lane for the whole bucket, so k masked
    leaves still cost one ring with a single extra element.

    ``bucket_sizes`` includes the count lane (size 1); ``masked_monoid``
    tells Emit's arena path to pre-mask the leaves before the in-place
    writes (the arena write is otherwise raw)."""
    def masked_bucket_pack(*args):
        xs, alive = args[:-1], args[-1]
        _check_pack_sizes(xs, sizes)
        a = alive.reshape(()).astype(jnp.dtype(dtype))
        live = a != 0
        parts = []
        for x in xs:
            flat = x.reshape(-1).astype(jnp.dtype(dtype))
            fill = monoid.identity(
                jax.ShapeDtypeStruct(flat.shape, flat.dtype))
            parts.append(jnp.where(live, flat, fill))
        parts.append(a.reshape(1))
        return jnp.concatenate(parts)
    masked_bucket_pack.bucket_sizes = tuple(sizes) + (1,)
    masked_bucket_pack.bucket_dtype = dtype
    masked_bucket_pack.masked_monoid = monoid
    return masked_bucket_pack


def _masked_bucket_renorm_fn() -> Callable:
    """Whole-bucket renormalize epilogue: divide the payload lanes by the
    reduced live count (clamped — a transiently all-dead view must not
    divide by zero) and drop the count lane.  One kernel per bucket, the
    masked analogue of the hoisted mean epilogue."""
    def bucket_masked_renorm(b):
        # static slices, not int indexing: b[-1] lowers to a gather the
        # switch CGRA cannot place (the epilogue must stay on-switch)
        n = b.shape[-1] - 1
        cnt = jnp.maximum(lax.slice_in_dim(b, n, n + 1, axis=-1), 1)
        return lax.slice_in_dim(b, 0, n, axis=-1) / cnt.astype(b.dtype)
    return bucket_masked_renorm


def _masked_bucket_count_fn() -> Callable:
    def bucket_masked_count(b):
        n = b.shape[-1] - 1
        cnt = lax.slice_in_dim(b, n, n + 1, axis=-1)
        return jnp.maximum(cnt, jnp.asarray(1, b.dtype)).reshape(
            b.shape[:-1])
    return bucket_masked_count


def _rs_pack_fn(sizes: tuple[int, ...], n: int) -> Callable:
    """Layout-aware pack for a REDUCE_SCATTER bucket.

    Chunk boundaries must align with the scatter axis: each flat leaf is
    viewed as ``(n, size/n)`` and the leaves are concatenated chunk-wise
    (axis 1), so rank ``j``'s scattered share of the bucket is exactly
    the concatenation of every leaf's own chunk ``j`` — pure data
    movement, bit-identical to the per-leaf scatters."""
    def pack(*xs):
        _check_pack_sizes(xs, sizes)
        return jnp.concatenate([x.reshape(n, -1) for x in xs],
                               axis=1).reshape(-1)
    return pack


def _rs_split_fn(offset: int, chunk: int, n: int) -> Callable:
    """Slice one leaf's scattered chunk back out of a bucket RS result
    (the bucket output is one rank-chunk: ``sum(size_i / n)`` long)."""
    def split(b, orig):
        shp = (orig.shape[0] // n,) + tuple(orig.shape[1:])
        return b[offset:offset + chunk].reshape(shp)
    return split


def _ag_split_fn(offset: int, size: int, n: int) -> Callable:
    """Slice one leaf's gathered result out of a bucket AG output: the
    output is n rank-copies of the flat bucket back to back, so leaf
    ``i`` is column block ``[offset, offset+size)`` of the (n, S) view."""
    def split(b, orig):
        shp = (orig.shape[0] * n,) + tuple(orig.shape[1:])
        return b.reshape(n, -1)[:, offset:offset + size].reshape(shp)
    return split


def _ring_batch_pack_fn(sizes: tuple[int, ...], chunks: tuple[int, ...],
                        n: int, monoid) -> Callable:
    """Pack k independent same-axis allreduce payloads into ONE
    chunk-aligned stacked buffer (the batched ring launch).

    Each flat leaf is padded to ``n * chunk_i`` with the monoid identity
    — the same pad :func:`repro.core.ring.pad_to_multiple` would apply
    inside its own ring — viewed as ``(n, chunk_i)`` and concatenated
    along axis 1.  Every lane therefore keeps its original chunk index,
    hence its exact per-hop fold order: the batched ring is
    *bit-identical* to the k separate rings (for both the bandwidth RS∘AG
    walk, whose fold path is chunk-indexed, and the latency log-step,
    whose fold order is lane-independent)."""
    def pack(*xs):
        _check_pack_sizes(xs, sizes)
        cols = []
        for x, c in zip(xs, chunks):
            flat = x.reshape(-1)
            pad = n * c - flat.shape[0]
            if pad:
                fill = monoid.identity(
                    jax.ShapeDtypeStruct((), flat.dtype))
                flat = jnp.concatenate(
                    [flat, jnp.full((pad,), fill, flat.dtype)])
            cols.append(flat.reshape(n, c))
        return jnp.concatenate(cols, axis=1).reshape(-1)
    return pack


def _ring_batch_split_fn(offset: int, chunk: int, size: int,
                         n: int) -> Callable:
    """Recover one payload from a batched-ring result: take its column
    block of the (n, C) view, drop the identity pad lanes, reshape."""
    def split(b, orig):
        col = b.reshape(n, -1)[:, offset:offset + chunk]
        return col.reshape(-1)[:size].reshape(orig.shape)
    return split


@dataclasses.dataclass
class _ReduceUnit:
    """One bucketable per-leaf reduction — a plain REDUCE, an
    error-feedback REDUCE(+DELIVERED sibling, + trailing outer reduces),
    or a whole LowerTopology hierarchical pad→RS…→AR→…AG→unpad chain.
    All three are elementwise across ranks and shape-preserving end to
    end, which is exactly what makes concat-then-split legal."""

    kind: str           # "reduce" | "ef" | "hier" | "rs" | "ag" | "masked"
    vin: int                        # the leaf value feeding the unit
    out_red: int                    # the unit's reduced output value
    out_dlv: Optional[int]          # DELIVERED sibling output (ef only) —
    #                                 the shared count output for "masked"
    nodes: tuple[DagNode, ...]      # claimed by this unit
    key: tuple                      # bucketing group key
    nbytes: int
    size: int
    shape: tuple
    ops: dict                       # replay ops for the bucket rebuild
    dtype: str = "float32"          # leaf (= bucket) dtype
    aux: tuple = ()                 # extra consumed vids (the masked
    #                                 units' shared alive flag) — part of
    #                                 the bucket's dependency footprint


class Coalesce:
    """Bucket same-axis/monoid/codec per-leaf reductions into flat-buffer
    bucket stages.

    A transformer's gradient sync emits one reduce per pytree leaf —
    hundreds of collectives, each paying the full ring latency.  This
    pass concatenates the leaves of compatible reductions into fixed-byte
    buckets (sized by :func:`repro.core.netmodel.bucket_bytes` from the
    latency/bandwidth crossover of the axis actually traversed, or the
    ``CollectiveConfig.bucket_bytes`` override; ``0`` disables the pass),
    runs **one** collective per bucket, and splits the results back per
    leaf — pack/split are ordinary MAP shims, so the per-leaf API is
    unchanged and `gradient_sync` numerics are preserved: exactly (up to
    summation order) for plain reductions and hierarchical chains, and
    within the compression's own error bars for blockwise error-feedback
    compressors (block boundaries shift across the concat).  Top-k EF is
    deliberately *not* bucketized — global selection over a concat would
    change which gradients ship — and data-dependent reductions never
    share a bucket.

    Runs between LowerTopology and FuseHops: axes are resolved (the
    group key is exact) and the hierarchical RS/AR/AG chains LowerTopology
    emitted are bucketized whole — the bucket replays the same chain
    once.  Leaves whose aval is unknown, groups of one, and buckets of
    one stay untouched.
    """

    name = "coalesce"

    def __init__(self, bucket_bytes: Optional[int] = None):
        self.bucket_bytes = bucket_bytes

    def run(self, dag: DagProgram, ctx: CompileContext) -> DagProgram:
        override = self.bucket_bytes
        if override is None and ctx.config is not None:
            override = getattr(ctx.config, "bucket_bytes", None)
        if ctx.in_avals is None:
            return dag
        if override != 0:
            avals = _propagate_avals(dag, ctx)
            units = self._find_units(dag, avals, ctx)
            buckets = self._form_buckets(units, ctx, override, dag)
            if buckets:
                hoist = True
                if ctx.config is not None:
                    hoist = getattr(ctx.config, "epilogue_hoist", True)
                dag = self._rewrite(dag, buckets, hoist=hoist)
        if ctx.config is not None and getattr(ctx.config, "batch_rings",
                                              False):
            dag = self._batch_rings(dag, ctx)
        return dag

    # -- unit discovery ------------------------------------------------------

    def _find_units(self, dag: DagProgram, avals: dict,
                    ctx: CompileContext) -> list[_ReduceUnit]:
        users = dag.users()
        out_set = set(dag.outputs)
        producer_of = {nd.out: nd for nd in dag.nodes}
        claimed: set[int] = set()

        def sole_user(vid: int) -> Optional[DagNode]:
            us = users.get(vid, [])
            if len(us) == 1 and vid not in out_set \
                    and us[0].out not in claimed:
                return us[0]
            return None

        # DELIVERED siblings indexed once — _match_ef must not rescan the
        # whole DAG per EF reduce (O(leaves²) on big gradient pytrees)
        delivered: dict[tuple, DagNode] = {}
        for nd in dag.nodes:
            if nd.op.kind == OpKind.DELIVERED:
                delivered.setdefault((nd.inputs, nd.op.axis, nd.op.ef), nd)

        units: list[_ReduceUnit] = []
        for nd in dag.nodes:
            if nd.out in claimed or not nd.inputs:
                continue
            aval = avals.get(nd.inputs[0])
            u = None
            if aval is not None:
                if nd.op.kind == OpKind.REDUCE and nd.op.ef is not None:
                    u = self._match_ef(nd, delivered, aval, claimed,
                                       sole_user)
                elif nd.op.kind == OpKind.REDUCE:
                    u = self._match_reduce(nd, aval)
                elif nd.op.kind == OpKind.MAP \
                        and nd.op.name == "masked_pack":
                    u = self._match_masked(nd, aval, users, out_set,
                                           claimed, sole_user)
                elif nd.op.kind == OpKind.MAP and nd.op.name == "hier_pad":
                    u = self._match_hier(nd, aval, sole_user)
                elif nd.op.kind == OpKind.REDUCE_SCATTER:
                    u = self._match_rs(nd, aval, users, ctx)
                elif nd.op.kind == OpKind.ALLGATHER:
                    u = self._match_ag(nd, aval, users, producer_of, ctx)
            if u is not None:
                units.append(u)
                claimed.update(g.out for g in u.nodes)
        return units

    @staticmethod
    def _leaf_meta(aval) -> tuple[int, int, tuple, str]:
        size = int(math.prod(aval.shape)) if aval.shape else 1
        return (_aval_bytes(aval), size, tuple(aval.shape),
                str(jnp.dtype(aval.dtype)))

    def _match_reduce(self, nd: DagNode, aval) -> Optional[_ReduceUnit]:
        nbytes, size, shape, dt = self._leaf_meta(aval)
        key = ("reduce", nd.op.axis, nd.op.monoid.name, nd.op.codec.name,
               dt)
        return _ReduceUnit("reduce", nd.inputs[0], nd.out, None, (nd,),
                           key, nbytes, size, shape, {"red": nd.op}, dt)

    def _match_rs(self, nd: DagNode, aval, users,
                  ctx: CompileContext) -> Optional[_ReduceUnit]:
        """Standalone REDUCE_SCATTER leaf (sharded-optimizer style).

        Bucketizable because the pack is chunk-aligned with the scatter
        axis (see :func:`_rs_pack_fn`) — each rank's share of the bucket
        is the concat of its per-leaf shares.  Requires the leading dim
        divisible by the axis size (otherwise the per-leaf op itself
        defines the ragged split and we leave it alone)."""
        if nd.op.ef is not None:
            return None
        ax = nd.op.axis
        if not isinstance(ax, str) or ax == AUTO_AXIS:
            return None
        n = ctx.size_of(ax)
        if not n or n < 2 or not aval.shape or aval.shape[0] % n:
            return None
        us = users.get(nd.out, [])
        if len(us) == 1 and us[0].op.kind == OpKind.ALLGATHER \
                and us[0].op.axis == ax:
            # RS feeding a same-axis AG is FuseHops' RsAgPattern — the
            # pair rebuilds the bandwidth-optimal allreduce; don't split
            # the pattern across a bucket boundary
            return None
        nbytes, size, shape, dt = self._leaf_meta(aval)
        key = ("rs", ax, nd.op.monoid.name, nd.op.codec.name, dt)
        return _ReduceUnit("rs", nd.inputs[0], nd.out, None, (nd,), key,
                           nbytes, size, shape,
                           {"red": nd.op, "n": n}, dt)

    def _match_ag(self, nd: DagNode, aval, users, producer_of,
                  ctx: CompileContext) -> Optional[_ReduceUnit]:
        """Standalone ALLGATHER leaf — pure data movement, so a plain
        concat bucket gathers once and the splits de-interleave the
        (n, bucket) result per leaf."""
        ax = nd.op.axis
        if not isinstance(ax, str) or ax == AUTO_AXIS:
            return None
        n = ctx.size_of(ax)
        if not n or n < 2 or not aval.shape:
            return None
        prod = producer_of.get(nd.inputs[0])
        if prod is not None and prod.op.kind == OpKind.REDUCE_SCATTER \
                and prod.op.axis == ax:
            return None                     # RsAgPattern territory
        us = users.get(nd.out, [])
        if len(us) == 1 and us[0].op.kind == OpKind.MAP \
                and us[0].op.fusable and len(us[0].inputs) == 1:
            return None                     # GatherMapPattern territory
        nbytes, size, shape, dt = self._leaf_meta(aval)
        key = ("ag", ax, dt)
        return _ReduceUnit("ag", nd.inputs[0], nd.out, None, (nd,), key,
                           nbytes, size, shape,
                           {"red": nd.op, "n": n}, dt)

    def _match_ef(self, nd: DagNode, delivered: dict, aval,
                  claimed: set, sole_user) -> Optional[_ReduceUnit]:
        if nd.op.ef.compressor == "topk":
            # top-k selects globally over its operand: run over a concat
            # bucket it would starve small-magnitude leaves in favor of
            # large ones — a semantic change, not a layout change.  The
            # blockwise compressors (int8 shared-scale: one scale per
            # 256-element block) only shift block boundaries, which stays
            # within the compression's own error bars.
            return None
        dlv = delivered.get((nd.inputs, nd.op.axis, nd.op.ef))
        if dlv is not None and dlv.out in claimed:
            dlv = None
        # trailing plain outer reduces (the hierarchical EF lowering:
        # compress at the innermost tier, reduce the outer tiers exactly)
        outer: list[DagNode] = []
        cur = nd
        while True:
            u = sole_user(cur.out)
            if (u is not None and u.op.kind == OpKind.REDUCE
                    and u.op.ef is None and len(u.inputs) == 1):
                outer.append(u)
                cur = u
            else:
                break
        nbytes, size, shape, dt = self._leaf_meta(aval)
        ef = nd.op.ef
        key = ("ef", nd.op.axis, nd.op.monoid.name, ef.compressor,
               round(ef.topk_ratio, 9),
               tuple((o.op.axis, o.op.monoid.name, o.op.codec.name)
                     for o in outer),
               dlv is not None, dt)
        nodes = (nd,) + tuple(outer) + ((dlv,) if dlv is not None else ())
        return _ReduceUnit("ef", nd.inputs[0], cur.out,
                           dlv.out if dlv is not None else None,
                           nodes, key, nbytes, size, shape,
                           {"red": nd.op,
                            "dlv": dlv.op if dlv is not None else None,
                            "outer": tuple(o.op for o in outer)}, dt)

    def _match_masked(self, pack: DagNode, aval, users, out_set,
                      claimed: set, sole_user) -> Optional[_ReduceUnit]:
        """A whole Legalize masked-reduce chain, bucketized to stage
        parity with the unmasked path:

            masked_pack(x, alive) → [REDUCE | hier pad→RS…→AR→…AG→unpad]
                → masked_renorm(+ masked_count)

        k such units sharing (axes, monoid, codec, dtype, alive flag,
        renormalize) collapse into ONE bucket: one masked pack with a
        single trailing count lane, one ring, one whole-bucket renorm
        epilogue, k splits — the masked sync costs what the unmasked
        bucket costs plus one element.
        """
        x_vid, alive_vid = pack.inputs
        if pack.out in out_set:
            return None
        pus = [u for u in users.get(pack.out, [])]
        if any(u.out in claimed for u in pus):
            return None
        chain: tuple[DagNode, ...]
        ops: dict
        if len(pus) == 1 and pus[0].op.kind == OpKind.REDUCE \
                and pus[0].op.ef is None:
            red = pus[0]
            chain = (red,)
            ops = {"red": red.op}
            red_out = red.out
            axes_sig = (red.op.axis,)
        elif len(pus) == 2:
            # the LowerTopology hierarchical chain: pack.out feeds both
            # hier_pad and (as shape donor) hier_unpad
            pads = [u for u in pus if u.op.name == "hier_pad"]
            unpads = [u for u in pus if u.op.name == "hier_unpad"]
            if len(pads) != 1 or len(unpads) != 1:
                return None
            hu = self._match_hier(pads[0], aval, sole_user)
            if hu is None or hu.nodes[-1] is not unpads[0]:
                return None
            chain = hu.nodes
            ops = dict(hu.ops)
            red_out = hu.out_red
            axes_sig = (tuple(op.axis for op in ops["rs"]),
                        ops["red"].axis)
        else:
            return None
        if red_out in out_set:
            return None
        rus = users.get(red_out, [])
        renorm = count = None
        for u in rus:
            if u.out in claimed:
                return None
            if (u.op.kind == OpKind.MAP and u.op.name == "masked_renorm"
                    and len(u.inputs) == 2 and u.inputs[1] == x_vid
                    and renorm is None):
                renorm = u
            elif (u.op.kind == OpKind.MAP
                    and u.op.name == "masked_count"
                    and len(u.inputs) == 1 and count is None):
                count = u
            else:
                return None
        if renorm is None:
            return None
        nbytes, size, shape, dt = self._leaf_meta(aval)
        renormalize = bool(getattr(renorm.op.fn, "masked_renormalize",
                                   True))
        ops["renormalize"] = renormalize
        ops["alive"] = alive_vid
        red_op = ops["red"]
        key = ("masked", axes_sig, red_op.monoid.name, red_op.codec.name,
               dt, alive_vid, renormalize)
        nodes = (pack,) + chain + (renorm,) \
            + ((count,) if count is not None else ())
        return _ReduceUnit("masked", x_vid, renorm.out,
                           count.out if count is not None else None,
                           nodes, key, nbytes, size, shape, ops, dt,
                           aux=(alive_vid,))

    def _match_hier(self, pad: DagNode, aval,
                    sole_user) -> Optional[_ReduceUnit]:
        rs: list[DagNode] = []
        u = sole_user(pad.out)
        while u is not None and u.op.kind == OpKind.REDUCE_SCATTER:
            rs.append(u)
            u = sole_user(u.out)
        if not rs or u is None or u.op.kind != OpKind.REDUCE \
                or u.op.ef is not None:
            return None
        red = u
        ag: list[DagNode] = []
        u = sole_user(red.out)
        while u is not None and u.op.kind == OpKind.ALLGATHER:
            ag.append(u)
            u = sole_user(u.out)
        unpad = u
        if (unpad is None or unpad.op.kind != OpKind.MAP
                or unpad.op.name != "hier_unpad"
                or len(unpad.inputs) != 2
                or unpad.inputs[1] != pad.inputs[0]
                or len(ag) != len(rs)
                or [n.op.axis for n in ag]
                != [n.op.axis for n in reversed(rs)]):
            return None
        nbytes, size, shape, dt = self._leaf_meta(aval)
        key = ("hier", tuple(n.op.axis for n in rs), red.op.axis,
               red.op.monoid.name, red.op.codec.name, dt)
        nodes = (pad,) + tuple(rs) + (red,) + tuple(ag) + (unpad,)
        return _ReduceUnit("hier", pad.inputs[0], unpad.out, None, nodes,
                           key, nbytes, size, shape,
                           {"pad": pad.op, "rs": tuple(n.op for n in rs),
                            "red": red.op, "ag": tuple(n.op for n in ag),
                            "unpad": unpad.op}, dt)

    # -- bucket formation ----------------------------------------------------

    @staticmethod
    def _primary_axis(u: _ReduceUnit) -> Optional[str]:
        """The first link tier the unit's payload traverses (sizes the
        bucket): the reduce's own axis, or the innermost RS axis of a
        hierarchical chain."""
        hier = u.kind == "hier" or (u.kind == "masked" and u.ops.get("rs"))
        ax = u.ops["rs"][0].axis if hier else u.ops["red"].axis
        return ax if isinstance(ax, str) and ax != AUTO_AXIS else None

    @staticmethod
    def _value_ancestors(dag: DagProgram) -> dict[int, set[int]]:
        anc: dict[int, set[int]] = {}
        for nd in dag.nodes:
            a: set[int] = set()
            for v in nd.inputs:
                a.add(v)
                a |= anc.get(v, set())
            anc[nd.out] = a
        return anc

    def _form_buckets(self, units: list[_ReduceUnit], ctx: CompileContext,
                      override: Optional[int],
                      dag: DagProgram) -> list[list[_ReduceUnit]]:
        """Greedy byte-capped packing, dependency-safe.

        A unit whose input transitively depends on a current bucket
        member's output must not join that bucket (the pack would need a
        value the bucket itself produces); it is deferred to a later
        round and may still bucket with its own level.  A final
        Kahn check over the bucket graph dissolves any bucket whose
        grouping would knot buckets into a cycle through intermediate
        nodes — unbucketed lowering is always legal, just less coalesced
        (same policy as FuseHops' cross-branch fusion).
        """
        anc = self._value_ancestors(dag)
        groups: dict[tuple, list[_ReduceUnit]] = {}
        for u in units:
            groups.setdefault(u.key, []).append(u)
        buckets: list[list[_ReduceUnit]] = []
        for us in groups.values():
            if override:
                cap = override
            else:
                ax = self._primary_axis(us[0])
                cap = netmodel.bucket_bytes(
                    ctx.size_of(ax) if ax else None,
                    ctx.net_of(ax) if ax else netmodel.PAPER)
            pending = us
            while len(pending) >= 2:
                cur: list[_ReduceUnit] = []
                cur_bytes = 0
                cur_outs: set[int] = set()
                deferred: list[_ReduceUnit] = []

                def close():
                    nonlocal cur, cur_bytes, cur_outs
                    if len(cur) >= 2:
                        buckets.append(cur)
                        _obs.RECORDER.observe("coalesce.bucket_fill_frac",
                                              cur_bytes / cap)
                    cur, cur_bytes, cur_outs = [], 0, set()

                for u in pending:       # definition order throughout
                    if any(o in anc.get(v, ())
                           for v in (u.vin,) + u.aux for o in cur_outs):
                        deferred.append(u)      # retry next round
                        continue
                    if cur and cur_bytes + u.nbytes > cap:
                        close()                 # full: start the next one
                    cur.append(u)
                    cur_bytes += u.nbytes
                    cur_outs.add(u.out_red)
                    if u.out_dlv is not None:
                        cur_outs.add(u.out_dlv)
                close()
                if len(deferred) >= len(pending):
                    break       # no progress (unreachable: the first unit
                    #             of a round always enters cur) — safety
                pending = deferred
        return self._drop_cyclic(buckets, anc)

    @staticmethod
    def _drop_cyclic(buckets: list[list[_ReduceUnit]],
                     anc: dict[int, set[int]]) -> list[list[_ReduceUnit]]:
        """Dissolve buckets participating in a bucket-graph cycle.

        Rare shape: two buckets each holding a unit whose input depends
        (through *another* member of the other bucket) on the first —
        individually independent units, knotted only by the grouping.
        """
        while True:
            outs_of = [
                {u.out_red for u in b}
                | {u.out_dlv for u in b if u.out_dlv is not None}
                for b in buckets]
            indeg = [0] * len(buckets)
            succs: list[list[int]] = [[] for _ in buckets]
            for i, b in enumerate(buckets):
                for j, outs in enumerate(outs_of):
                    if i != j and any(o in anc.get(v, ())
                                      for u in b
                                      for v in (u.vin,) + u.aux
                                      for o in outs):
                        succs[j].append(i)
                        indeg[i] += 1
            ready = [i for i, d in enumerate(indeg) if d == 0]
            seen = 0
            while ready:
                i = ready.pop()
                seen += 1
                for s in succs[i]:
                    indeg[s] -= 1
                    if indeg[s] == 0:
                        ready.append(s)
            if seen == len(buckets):
                return buckets
            # dissolve a bucket actually ON a cycle, not one merely
            # downstream of the knot (which Kahn also leaves with
            # residual indegree)
            residual = {i for i, d in enumerate(indeg) if d > 0}

            def on_cycle(start: int) -> bool:
                stack, visited = list(succs[start]), set()
                while stack:
                    i = stack.pop()
                    if i == start:
                        return True
                    if i in visited or i not in residual:
                        continue
                    visited.add(i)
                    stack.extend(succs[i])
                return False

            drop = next(i for i in sorted(residual) if on_cycle(i))
            buckets = buckets[:drop] + buckets[drop + 1:]

    # -- the rewrite ---------------------------------------------------------

    def _find_epilogues(self, dag: DagProgram,
                        buckets: list[list[_ReduceUnit]],
                        claimed_outs: set[int]) -> tuple[dict, dict]:
        """Per-bucket elementwise epilogue hoist.

        When every unit's reduced output feeds exactly one *identical*
        single-input MAP declared ``elementwise`` (the gradient sync's
        shared mean), that map runs once on the flat bucket instead of
        once per leaf — a many-leaf sync then issues one bucket-sized
        kernel rather than N tiny ones.  The hoist is only taken for a
        whole bucket (all units share the fn object), and only on the
        caller's explicit elementwise promise: ``f(concat(xs)) ==
        concat(f(x))`` is what makes running it before the split legal.
        Returns ({bucket idx → hoisted op}, {bucket idx → per-unit map
        out vids}); the hoisted map nodes are added to ``claimed_outs``.
        """
        users = dag.users()
        out_set = set(dag.outputs)
        epilogues: dict[int, Node] = {}
        epi_outs: dict[int, list[int]] = {}
        for bi, b in enumerate(buckets):
            hoisted: list[DagNode] = []
            for u in b:
                us = users.get(u.out_red, [])
                if (len(us) == 1 and u.out_red not in out_set
                        and us[0].op.kind == OpKind.MAP
                        and len(us[0].inputs) == 1
                        and us[0].op.elementwise
                        and us[0].out not in claimed_outs):
                    hoisted.append(us[0])
                else:
                    break
            if len(hoisted) != len(b) \
                    or len({h.op.fn for h in hoisted}) != 1:
                continue
            epilogues[bi] = dataclasses.replace(
                hoisted[0].op, name="bucket_epilogue", fusable=False)
            epi_outs[bi] = [h.out for h in hoisted]
            claimed_outs.update(h.out for h in hoisted)
        return epilogues, epi_outs

    def _rewrite(self, dag: DagProgram,
                 buckets: list[list[_ReduceUnit]], *,
                 hoist: bool = True) -> DagProgram:
        claimed_outs = {nd.out for b in buckets for u in b
                        for nd in u.nodes}
        # epilogue hoist is a tunable (CollectiveConfig.epilogue_hoist):
        # per-leaf epilogues trade one big kernel for wave-level overlap
        epilogues, epi_outs = (
            self._find_epilogues(dag, buckets, claimed_outs)
            if hoist else ({}, {}))
        producers: dict[int, tuple] = {}
        for nd in dag.nodes:
            if nd.out not in claimed_outs:
                producers[nd.out] = ("node", nd)
        for bi, b in enumerate(buckets):
            for u in b:
                producers[u.out_red] = ("bucket", bi)
                if u.out_dlv is not None:
                    producers[u.out_dlv] = ("bucket", bi)
            for v in epi_outs.get(bi, ()):
                producers[v] = ("bucket", bi)

        nodes_out: list[DagNode] = []
        vmap: dict[int, int] = {i: i for i in range(dag.num_inputs)}
        next_vid = [dag.num_inputs]

        def emit(op: Node, ins: Sequence[int]) -> int:
            vid = next_vid[0]
            next_vid[0] += 1
            nodes_out.append(DagNode(op, tuple(ins), vid))
            return vid

        emitted: set[int] = set()

        def get(vid: int) -> int:
            got = vmap.get(vid)
            if got is not None:
                return got
            tag, obj = producers[vid]
            if tag == "node":
                ins = tuple(get(v) for v in obj.inputs)
                vmap[vid] = emit(obj.op, ins)
            else:
                emit_bucket(obj)
            return vmap[vid]

        def emit_bucket(bi: int) -> None:
            if bi in emitted:
                return
            emitted.add(bi)
            us = buckets[bi]
            ins = tuple(get(u.vin) for u in us)
            ops = us[0].ops
            if us[0].kind == "masked":
                # one masked pack over every leaf plus the shared alive
                # flag: a single trailing count lane serves the bucket
                ins = ins + (get(ops["alive"]),)
                pack = emit(Node(OpKind.MAP,
                                 fn=_masked_bucket_pack_fn(
                                     tuple(u.size for u in us),
                                     us[0].dtype, ops["red"].monoid),
                                 name="bucket_pack", fusable=False), ins)
            elif us[0].kind == "rs":
                # scatter-axis-aligned interleave, NOT the arena concat
                # layout — no bucket_sizes attr, so Emit never hands
                # this pack an arena
                pack = emit(Node(OpKind.MAP,
                                 fn=_rs_pack_fn(
                                     tuple(u.size for u in us),
                                     ops["n"]),
                                 name="bucket_pack_rs", fusable=False),
                            ins)
            else:
                pack = emit(Node(OpKind.MAP,
                                 fn=_pack_fn(tuple(u.size for u in us),
                                             us[0].dtype),
                                 name="bucket_pack", fusable=False), ins)
            v_dlv = None
            v_cnt = None
            if us[0].kind == "masked":
                if ops.get("rs"):              # hierarchical masked chain
                    v = emit(ops["pad"], (pack,))
                    for op in ops["rs"]:
                        v = emit(op, (v,))
                    v = emit(ops["red"], (v,))
                    for op in ops["ag"]:
                        v = emit(op, (v,))
                    v_raw = emit(ops["unpad"], (v, pack))
                else:
                    v_raw = emit(ops["red"], (pack,))
                if any(u.out_dlv is not None for u in us):
                    v_cnt = emit(Node(OpKind.MAP,
                                      fn=_masked_bucket_count_fn(),
                                      name="masked_count",
                                      fusable=False), (v_raw,))
                if ops["renormalize"]:
                    # the whole-bucket renorm epilogue — one kernel per
                    # bucket, the masked analogue of the hoisted mean
                    v_red = emit(Node(OpKind.MAP,
                                      fn=_masked_bucket_renorm_fn(),
                                      name="masked_renorm",
                                      fusable=False), (v_raw,))
                else:
                    # splits read the payload lanes straight off the
                    # reduced buffer; the count lane sits past them
                    v_red = v_raw
            elif us[0].kind in ("reduce", "rs", "ag"):
                v_red = emit(ops["red"], (pack,))
            elif us[0].kind == "ef":
                v_red = emit(ops["red"], (pack,))
                if ops["dlv"] is not None:
                    v_dlv = emit(ops["dlv"], (pack,))
                for op in ops["outer"]:
                    v_red = emit(op, (v_red,))
            else:                                        # "hier"
                v = emit(ops["pad"], (pack,))
                for op in ops["rs"]:
                    v = emit(op, (v,))
                v = emit(ops["red"], (v,))
                for op in ops["ag"]:
                    v = emit(op, (v,))
                v_red = emit(ops["unpad"], (v, pack))
            epi = epilogues.get(bi)
            v_epi = emit(epi, (v_red,)) if epi is not None else None
            off = 0
            for k, u in enumerate(us):
                orig = vmap[u.vin]      # runtime shape donor for the slice
                if u.kind == "rs":
                    chunk = u.size // ops["n"]
                    split = Node(OpKind.MAP,
                                 fn=_rs_split_fn(off, chunk, ops["n"]),
                                 name="bucket_split", fusable=False)
                elif u.kind == "ag":
                    split = Node(OpKind.MAP,
                                 fn=_ag_split_fn(off, u.size, ops["n"]),
                                 name="bucket_split", fusable=False)
                else:
                    split = Node(OpKind.MAP, fn=_split_fn(off, u.size),
                                 name="bucket_split", fusable=False)
                if v_epi is not None:
                    # the hoisted epilogue replaced every per-leaf map:
                    # the split of the epilogued bucket IS that map's
                    # output (u.out_red itself had no other consumer)
                    vmap[epi_outs[bi][k]] = emit(split, (v_epi, orig))
                else:
                    vmap[u.out_red] = emit(split, (v_red, orig))
                if u.out_dlv is not None:
                    if u.kind == "masked":
                        # the live count is one shared scalar, not a
                        # per-leaf slice
                        vmap[u.out_dlv] = v_cnt
                    else:
                        dsplit = Node(OpKind.MAP,
                                      fn=_split_fn(off, u.size),
                                      name="bucket_split", fusable=False)
                        vmap[u.out_dlv] = emit(dsplit, (v_dlv, orig))
                # rs split offsets walk the per-rank chunk, not the leaf
                off += u.size // ops["n"] if u.kind == "rs" else u.size

        for nd in dag.nodes:
            p = producers.get(nd.out)
            if p is not None and p[0] == "node":
                get(nd.out)
        for v in dag.outputs:
            get(v)
        return DagProgram(dag.num_inputs, tuple(nodes_out),
                          tuple(vmap[v] for v in dag.outputs), dag.name)

    # -- batched same-axis ring launch ---------------------------------------

    _BATCHABLE_MONOIDS = ("add", "max", "min", "prod")

    # default per-member payload cap for batching.  Merging amortizes
    # the fixed per-launch hop walk, which only matters while a ring is
    # latency-bound; a bandwidth-bound member gains nothing and loses
    # twice — it can no longer pipeline against its siblings, and the
    # stacked buffer spills the per-hop working set out of cache
    # (measured: merging MB-scale bucket rings on the host backend is a
    # slowdown, merging tens-of-KB rings is ~2x).  So: members above the
    # cap keep their own launch, members below it merge, and one merged
    # launch's total payload is bounded at 8x the cap.
    _BATCH_RINGS_BYTES = 256 << 10

    @staticmethod
    def _cap_groups(g: list, cap: Optional[int]) -> list[list]:
        """Partition a batch group under the payload cap: drop members
        above ``cap`` bytes (they stay per-program launches), greedily
        pack the rest smallest-first into sub-groups of at most
        ``8 * cap`` total.  ``cap`` 0/None = merge everything.  Only
        sub-groups of >= 2 survive — a singleton batches nothing."""
        if not cap:
            return [g] if len(g) >= 2 else []
        small = [t for t in g if _aval_bytes(t[2]) <= cap]
        out: list[list] = []
        cur: list = []
        cur_bytes = 0
        for t in sorted(small, key=lambda t: _aval_bytes(t[2])):
            b = _aval_bytes(t[2])
            if cur and cur_bytes + b > 8 * cap:
                out.append(cur)
                cur, cur_bytes = [], 0
            cur.append(t)
            cur_bytes += b
        out.append(cur)
        return [s for s in out if len(s) >= 2]

    @staticmethod
    def _drop_group_cycles(merges: list, anc: dict) -> list:
        """Dissolve batch groups knotted into a cycle through other
        groups' members (same policy as :meth:`_drop_cyclic`): members
        are independent *within* a group, but group A may feed group B
        through intermediates while B feeds A — merging both would
        deadlock; per-program launches stay legal."""
        while len(merges) > 1:
            k = len(merges)
            outs = [{nd.out for nd, _, _ in g} for _, g in merges]
            indeg = [0] * k
            succs: list[list[int]] = [[] for _ in range(k)]
            for i in range(k):
                for j in range(k):
                    if i != j and any(
                            (anc.get(nd.inputs[0], set())
                             | {nd.inputs[0]}) & outs[i]
                            for nd, _, _ in merges[j][1]):
                        succs[i].append(j)
                        indeg[j] += 1
            ready = [i for i, d in enumerate(indeg) if d == 0]
            seen = 0
            while ready:
                i = ready.pop()
                seen += 1
                for s in succs[i]:
                    indeg[s] -= 1
                    if indeg[s] == 0:
                        ready.append(s)
            if seen == k:
                break
            drop = next(i for i, d in enumerate(indeg) if d > 0)
            merges = merges[:drop] + merges[drop + 1:]
        return merges

    def _batch_rings(self, dag: DagProgram,
                     ctx: CompileContext) -> DagProgram:
        """Merge a program's independent same-axis ring collectives —
        allreduces, reduce-scatters, all-gathers — into ONE launch per
        (kind, axis, monoid, dtype) over a chunk-aligned stacked buffer.

        After bucketing, a big sync is a handful of bucket allreduces on
        the same axis — each still a separate ring launch paying the full
        per-hop dispatch latency.  When the combine is a plain
        elementwise Type 1 monoid and the codec is identity, k of them
        collapse into a single launch: pack (chunk-aligned, identity-
        padded — see :func:`_ring_batch_pack_fn`), one REDUCE tagged
        ``batched_ring:k``, k splits.  Bit-compatible with the separate
        launches because every lane keeps its chunk index, hence its
        per-hop fold order.  (When group members would straddle the
        latency/bandwidth crossover, the batched buffer makes one
        schedule decision for all of them — same numerics up to float
        reassociation, which is the usual schedule-choice caveat.)
        """
        avals = _propagate_avals(dag, ctx)
        anc = self._value_ancestors(dag)
        groups: dict[tuple, list] = {}
        for nd in dag.nodes:
            op = nd.op
            if (op.name or "").startswith("batched_ring"):
                continue
            ax = op.axis
            if not isinstance(ax, str) or ax == AUTO_AXIS:
                continue
            n = ctx.size_of(ax)
            if not n or n < 2:
                continue
            aval = avals.get(nd.inputs[0])
            if aval is None:
                continue
            dt = str(jnp.dtype(aval.dtype))
            if op.kind == OpKind.REDUCE:
                if (op.ef is not None or op.codec.name != "identity"
                        or op.monoid.name not in self._BATCHABLE_MONOIDS):
                    continue
                key = ("red", ax, op.monoid.name, dt)
            elif op.kind == OpKind.REDUCE_SCATTER:
                # same chunk-aligned layout as the RS bucket pack; the
                # merged op needs every leading dim divisible by n
                if (op.ef is not None or op.codec.name != "identity"
                        or op.monoid.name not in self._BATCHABLE_MONOIDS
                        or not aval.shape or aval.shape[0] % n):
                    continue
                key = ("rs", ax, op.monoid.name, dt)
            elif op.kind == OpKind.ALLGATHER:
                if not aval.shape:
                    continue
                key = ("ag", ax, dt)
            else:
                continue
            groups.setdefault(key, []).append((nd, n, aval))

        cap = getattr(ctx.config, "batch_rings_bytes", None) \
            if ctx.config is not None else None
        if cap is None:
            cap = self._BATCH_RINGS_BYTES
        merges: list[tuple[str, list]] = []
        for key, g in groups.items():
            outs = {nd.out for nd, _, _ in g}
            # keep only mutually independent members: a collective whose
            # input (transitively) needs another member's output cannot
            # share its launch
            indep = [t for t in g
                     if not ((anc.get(t[0].inputs[0], set())
                              | {t[0].inputs[0]}) & outs)]
            for sub in self._cap_groups(indep, cap):
                merges.append((key[0], sub))
        merges = self._drop_group_cycles(merges, anc)
        if not merges:
            return dag

        member: dict[int, int] = {}
        for gi, (_, g) in enumerate(merges):
            for nd, _, _ in g:
                member[nd.out] = gi
        producers: dict[int, tuple] = {}
        for nd in dag.nodes:
            if nd.out in member:
                producers[nd.out] = ("group", member[nd.out])
            else:
                producers[nd.out] = ("node", nd)

        nodes_out: list[DagNode] = []
        vmap: dict[int, int] = {i: i for i in range(dag.num_inputs)}
        next_vid = [dag.num_inputs]
        emitted: set[int] = set()

        def emit(op: Node, ins: Sequence[int]) -> int:
            vid = next_vid[0]
            next_vid[0] += 1
            nodes_out.append(DagNode(op, tuple(ins), vid))
            return vid

        def get(vid: int) -> int:
            got = vmap.get(vid)
            if got is not None:
                return got
            tag, obj = producers[vid]
            if tag == "node":
                ins = tuple(get(v) for v in obj.inputs)
                vmap[vid] = emit(obj.op, ins)
            else:
                emit_group(obj)
            return vmap[vid]

        def emit_group(gi: int) -> None:
            if gi in emitted:
                return
            emitted.add(gi)
            ckind, g = merges[gi]
            n = g[0][1]
            op0 = g[0][0].op
            sizes = tuple(
                int(math.prod(a.shape)) if a.shape else 1
                for _, _, a in g)
            ins = tuple(get(nd.inputs[0]) for nd, _, _ in g)
            if ckind == "red":
                chunks = tuple(-(-s // n) for s in sizes)
                pack = emit(Node(OpKind.MAP,
                                 fn=_ring_batch_pack_fn(sizes, chunks, n,
                                                        op0.monoid),
                                 name="ring_batch_pack", fusable=False),
                            ins)
                red = emit(dataclasses.replace(
                    op0, name=f"batched_ring:{len(g)}"), (pack,))
                off = 0
                for (nd, _, _), s, c in zip(g, sizes, chunks):
                    split = Node(OpKind.MAP,
                                 fn=_ring_batch_split_fn(off, c, s, n),
                                 name="ring_batch_split", fusable=False)
                    vmap[nd.out] = emit(split,
                                        (red, vmap[nd.inputs[0]]))
                    off += c
            elif ckind == "rs":
                # chunk-aligned stacking (the RS bucket layout): rank
                # j's share of the merged buffer is the concat of its
                # per-member shares
                pack = emit(Node(OpKind.MAP, fn=_rs_pack_fn(sizes, n),
                                 name="ring_batch_pack_rs",
                                 fusable=False), ins)
                red = emit(dataclasses.replace(
                    op0, name=f"batched_ring_rs:{len(g)}"), (pack,))
                off = 0
                for (nd, _, _), s in zip(g, sizes):
                    split = Node(OpKind.MAP,
                                 fn=_rs_split_fn(off, s // n, n),
                                 name="ring_batch_split_rs",
                                 fusable=False)
                    vmap[nd.out] = emit(split,
                                        (red, vmap[nd.inputs[0]]))
                    off += s // n
            else:                                      # "ag"
                pack = emit(Node(OpKind.MAP,
                                 fn=lambda *xs: jnp.concatenate(
                                     [x.reshape(-1) for x in xs]),
                                 name="ring_batch_pack_ag",
                                 fusable=False), ins)
                red = emit(dataclasses.replace(
                    op0, name=f"batched_ring_ag:{len(g)}"), (pack,))
                off = 0
                for (nd, _, _), s in zip(g, sizes):
                    split = Node(OpKind.MAP,
                                 fn=_ag_split_fn(off, s, n),
                                 name="ring_batch_split_ag",
                                 fusable=False)
                    vmap[nd.out] = emit(split,
                                        (red, vmap[nd.inputs[0]]))
                    off += s

        for nd in dag.nodes:
            if producers[nd.out][0] == "node":
                get(nd.out)
        for v in dag.outputs:
            get(v)
        return DagProgram(dag.num_inputs, tuple(nodes_out),
                          tuple(vmap[v] for v in dag.outputs), dag.name)


# ---------------------------------------------------------------------------
# Pass 4: FuseHops — first-class fusion patterns
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _MatchState:
    """Shared lookup tables for pattern matching over one DAG."""

    dag: DagProgram
    users: dict[int, list[DagNode]]
    out_set: set[int]
    claimed: set[int]                       # node out-ids already grouped
    ancestors: dict[int, set[int]]          # node out → transitive inputs

    @classmethod
    def build(cls, dag: DagProgram) -> "_MatchState":
        anc: dict[int, set[int]] = {}
        for nd in dag.nodes:
            a: set[int] = set()
            for v in nd.inputs:
                a.add(v)
                a |= anc.get(v, set())
            anc[nd.out] = a
        return cls(dag, dag.users(), set(dag.outputs), set(), anc)

    def sole_user(self, vid: int) -> Optional[DagNode]:
        """The unique consumer of ``vid`` if it isn't also a program
        output (fusion would hide the intermediate value) and hasn't been
        claimed by an earlier match (a cross-branch pattern may grab a
        node defined after the current root)."""
        us = self.users.get(vid, [])
        if len(us) == 1 and vid not in self.out_set \
                and us[0].out not in self.claimed:
            return us[0]
        return None

    def independent(self, a: DagNode, b: DagNode) -> bool:
        return a.out not in self.ancestors[b.out] \
            and b.out not in self.ancestors[a.out]


class FusionPattern:
    """One fusion rule: try to build a :class:`StageIR` rooted at ``nd``."""

    name = "pattern"

    def match(self, nd: DagNode, st: _MatchState) -> Optional[StageIR]:
        raise NotImplementedError


def _stage_axis(*nds: DagNode) -> str:
    """The (shared) communication axis of a fused group — the first
    collective node's axis; MAP nodes are axis-less."""
    for nd in nds:
        if nd.op.kind in COLLECTIVE_KINDS and isinstance(nd.op.axis, str) \
                and nd.op.axis != AUTO_AXIS:
            return nd.op.axis
    return ""


def _same_axis(*nds: DagNode) -> bool:
    """Collectives may only fuse onto one schedule if they traverse the
    same mesh axis (a pod-local ring cannot carry inter-pod hops)."""
    axes = {nd.op.axis for nd in nds if nd.op.kind in COLLECTIVE_KINDS}
    return len(axes) <= 1


class ScanGatherPattern(FusionPattern):
    """AG ∘ SCAN ∘ AG → fused scan+gather (paper Fig. 5)."""

    name = "scan+allgather"

    def match(self, nd, st):
        if nd.op.kind != OpKind.ALLGATHER:
            return None
        scan = st.sole_user(nd.out)
        if scan is None or scan.op.kind != OpKind.SCAN:
            return None
        ag2 = st.sole_user(scan.out)
        if ag2 is None or ag2.op.kind != OpKind.ALLGATHER \
                or not _same_axis(nd, scan, ag2):
            return None
        mono = scan.op.monoid
        return StageIR("scan+allgather", (nd, scan, ag2),
                       nd.inputs, (ag2.out,),
                       axis=_stage_axis(nd),
                       desc=f"fused allgather_op_allgather "
                            f"(in-network {mono.name}-scan)")


class MapIntoReducePattern(FusionPattern):
    """MAP ∘ REDUCE / MAP ∘ REDUCE_SCATTER → hop-fused map (Type 4)."""

    name = "map+reduce"

    def match(self, nd, st):
        if nd.op.kind != OpKind.MAP or len(nd.inputs) != 1 \
                or not nd.op.fusable:
            return None
        red = st.sole_user(nd.out)
        if red is None or red.op.kind not in (OpKind.REDUCE,
                                              OpKind.REDUCE_SCATTER) \
                or red.op.ef is not None:
            return None
        if red.op.kind == OpKind.REDUCE:
            return StageIR("map+allreduce", (nd, red), nd.inputs, (red.out,),
                           axis=_stage_axis(red),
                           desc="map fused ahead of AR schedule")
        return StageIR("map+reduce_scatter", (nd, red), nd.inputs,
                       (red.out,),
                       axis=_stage_axis(red),
                       desc=f"map({nd.op.name or 'fn'}) fused into RS hops")


class GatherMapPattern(FusionPattern):
    """ALLGATHER ∘ MAP → map applied in-flight at the forwarding hop."""

    name = "allgather+map"

    def match(self, nd, st):
        if nd.op.kind != OpKind.ALLGATHER:
            return None
        mp = st.sole_user(nd.out)
        if mp is None or mp.op.kind != OpKind.MAP or len(mp.inputs) != 1 \
                or not mp.op.fusable:
            return None
        return StageIR("allgather+map", (nd, mp), nd.inputs, (mp.out,),
                       axis=_stage_axis(nd),
                       desc="map applied in-flight at forwarding hop")


class ReduceAlltoallPattern(FusionPattern):
    """Independent REDUCE(add) + ALLTOALL pair → one shared ring schedule
    (the NAS IS histogram/keys fusion)."""

    name = "allreduce+alltoall"

    def match(self, nd, st):
        pair = None
        if self._fusable_reduce(nd):
            pair = self._find(nd, OpKind.ALLTOALL, st)
            red, a2a = nd, pair
        elif nd.op.kind == OpKind.ALLTOALL:
            pair = self._find(nd, OpKind.REDUCE, st)
            red, a2a = pair, nd
        if pair is None:
            return None
        return StageIR("allreduce+alltoall", (red, a2a),
                       (red.inputs[0], a2a.inputs[0]),
                       (red.out, a2a.out),
                       schedule="latency",
                       axis=_stage_axis(red),
                       desc="fused AR+A2A on one ring traversal")

    @staticmethod
    def _fusable_reduce(nd: DagNode) -> bool:
        # the shared-schedule kernel implements the add combine on the
        # identity wire only — a sunk codec must go to the unfused AR,
        # and an error-feedback reduce is a look-aside stage of its own
        return (nd.op.kind == OpKind.REDUCE
                and nd.op.monoid.name == "add"
                and nd.op.codec is IDENTITY
                and nd.op.ef is None)

    def _find(self, nd: DagNode, kind: OpKind,
              st: _MatchState) -> Optional[DagNode]:
        for cand in st.dag.nodes:
            if (cand.op.kind == kind and cand.out not in st.claimed
                    and (kind != OpKind.REDUCE
                         or self._fusable_reduce(cand))
                    and _same_axis(nd, cand)
                    and st.independent(nd, cand)):
                return cand
        return None


class RsAgPattern(FusionPattern):
    """REDUCE_SCATTER ∘ ALLGATHER → one all-reduce schedule."""

    name = "allreduce"

    def match(self, nd, st):
        if nd.op.kind != OpKind.REDUCE_SCATTER:
            return None
        ag = st.sole_user(nd.out)
        if ag is None or ag.op.kind != OpKind.ALLGATHER \
                or not _same_axis(nd, ag):
            return None
        return StageIR("allreduce", (nd, ag), nd.inputs, (ag.out,),
                       axis=_stage_axis(nd),
                       desc="RS∘AG → ring AR")


class EfPairPattern(FusionPattern):
    """Error-feedback REDUCE + its DELIVERED sibling → one look-aside
    stage: the compression runs once and yields both the lossy total and
    the locally-delivered contribution (the residual's other half)."""

    name = "ef_allreduce"

    def match(self, nd, st):
        if nd.op.kind != OpKind.REDUCE or nd.op.ef is None:
            return None
        for cand in st.dag.nodes:
            if (cand.op.kind == OpKind.DELIVERED
                    and cand.out not in st.claimed
                    and cand.inputs == nd.inputs
                    and cand.op.axis == nd.op.axis
                    and cand.op.ef == nd.op.ef):
                return StageIR("ef_allreduce", (nd, cand), nd.inputs,
                               (nd.out, cand.out),
                               axis=_stage_axis(nd),
                               desc=f"error-feedback "
                                    f"{nd.op.ef.compressor} all-reduce "
                                    "(Type 3 look-aside)")
        return None     # residual DCE'd — _single emits the lone reduce


DEFAULT_PATTERNS: tuple[FusionPattern, ...] = (
    EfPairPattern(),
    ScanGatherPattern(),
    MapIntoReducePattern(),
    GatherMapPattern(),
    ReduceAlltoallPattern(),
    RsAgPattern(),
)


_SINGLE_KINDS = {
    OpKind.MAP: "map",
    OpKind.REDUCE: "allreduce",
    OpKind.REDUCE_SCATTER: "reduce_scatter",
    OpKind.ALLGATHER: "allgather",
    OpKind.ALLTOALL: "alltoall",
    OpKind.SCAN: "scan",
    OpKind.BCAST: "bcast",
    OpKind.DELIVERED: "delivered",
}


class FuseHops:
    """Greedily apply fusion patterns in definition order, then
    topologically order the resulting stage groups."""

    name = "fuse_hops"

    def __init__(self, patterns: Sequence[FusionPattern] = DEFAULT_PATTERNS):
        self.patterns = tuple(patterns)

    def run(self, dag: DagProgram, ctx: CompileContext) -> list[StageIR]:
        st = _MatchState.build(dag)
        groups: list[StageIR] = []
        for nd in dag.nodes:
            if nd.out in st.claimed:
                continue
            for pat in self.patterns:
                m = pat.match(nd, st)
                if m is not None:
                    groups.append(m)
                    st.claimed.update(g.out for g in m.nodes)
                    break
            else:
                groups.append(self._single(nd))
                st.claimed.add(nd.out)
        # Cross-branch fusions (AR+A2A pairs) can deadlock each other at
        # the group level even though each pair is node-independent: two
        # pairs may each consume a value the other produces.  Dissolve
        # fused groups until the group graph is acyclic — unfused
        # lowering is always legal, just less fused.
        while True:
            cyclic = self._find_cycle_member(groups)
            if cyclic is None:
                break
            groups = [g for g in groups if g is not cyclic] \
                + [self._single(nd) for nd in cyclic.nodes]
        return self._topo(groups)

    @staticmethod
    def _find_cycle_member(groups: list[StageIR]) -> Optional[StageIR]:
        """A multi-node group participating in a group-graph cycle, or
        None if the group graph is already acyclic (Kahn's algorithm)."""
        produced_by = {v: g for g in groups for v in g.out_vids}
        succs: dict[int, list[StageIR]] = {id(g): [] for g in groups}
        indeg = {id(g): 0 for g in groups}
        for g in groups:
            for v in g.in_vids:
                dep = produced_by.get(v)
                if dep is not None and dep is not g:
                    succs[id(dep)].append(g)
                    indeg[id(g)] += 1
        ready = [g for g in groups if indeg[id(g)] == 0]
        seen = 0
        while ready:
            g = ready.pop()
            seen += 1
            for s in succs[id(g)]:
                indeg[id(s)] -= 1
                if indeg[id(s)] == 0:
                    ready.append(s)
        if seen == len(groups):
            return None
        for g in groups:
            if indeg[id(g)] > 0 and len(g.nodes) > 1:
                return g
        raise AssertionError("cycle among single-node groups — invalid DAG")

    @staticmethod
    def _single(nd: DagNode) -> StageIR:
        if nd.op.kind == OpKind.REDUCE and nd.op.ef is not None:
            # lone error-feedback reduce (its DELIVERED sibling was DCE'd)
            return StageIR("ef_allreduce", (nd,), nd.inputs, (nd.out,),
                           axis=_stage_axis(nd))
        kind = _SINGLE_KINDS.get(nd.op.kind)
        if nd.op.kind == OpKind.REDUCE \
                and (nd.op.name or "").startswith("batched_ring"):
            # Coalesce-merged same-axis ring batch: same lowering as a
            # plain allreduce, but a distinct stage kind so the executor
            # can prioritize it and the cost model can amortize launches
            kind = "batched_allreduce"
        if kind is None:
            raise ValueError(f"cannot lower node {nd.op}")
        return StageIR(kind, (nd,), nd.inputs, (nd.out,),
                       axis=_stage_axis(nd))

    @staticmethod
    def _topo(groups: list[StageIR]) -> list[StageIR]:
        """Order groups so every consumed value is produced first (a
        cross-branch fusion like AR+A2A can capture a node defined after
        another group's root)."""
        produced_by = {v: g for g in groups for v in g.out_vids}
        ordered: list[StageIR] = []
        emitted: set[int] = set()

        def visit(g: StageIR):
            if id(g) in emitted:
                return
            emitted.add(id(g))
            for v in g.in_vids:
                dep = produced_by.get(v)
                if dep is not None:
                    visit(dep)
            ordered.append(g)

        for g in groups:
            visit(g)
        return ordered


# ---------------------------------------------------------------------------
# Pass 5: SelectSchedule — latency- vs bandwidth-optimal rings
# ---------------------------------------------------------------------------

_RESCHEDULABLE = {"allreduce", "map+allreduce", "batched_allreduce"}


class SelectSchedule:
    """Annotate all-reduce stages with the ring schedule to emit.

    Per-rank payload bytes are propagated from ``ctx.in_avals`` through the
    DAG; a stage whose payload is below ``CollectiveConfig.
    latency_optimal_below`` gets the (n-1)-hop full-message latency ring,
    larger ones the chunked RS∘AG bandwidth ring.  The analytic model in
    :mod:`repro.core.netmodel` supplies predicted times (recorded in the
    stage desc) and the crossover when no explicit threshold is
    configured — both evaluated against the link tier of the *stage's own
    axis* (fast intra-pod ICI vs thin inter-pod DCI), so an outer-axis
    stage is costed on the wire it actually traverses.
    """

    name = "select_schedule"

    def run(self, groups: list[StageIR],
            ctx: CompileContext) -> list[StageIR]:
        nbytes = self._value_bytes(ctx)
        out: list[StageIR] = []
        for g in groups:
            # every stage records its raw per-rank payload (the program
            # cost model walks the emitted plan stage by stage)
            b = self._group_bytes(g, nbytes)
            if g.kind not in _RESCHEDULABLE:
                parts = None
                if g.kind == "allreduce+alltoall" and nbytes is not None:
                    # the shared ring carries the pair asymmetrically
                    # (histogram rides every hop whole, keys chunked) —
                    # keep the per-operand split for the cost model
                    vals = [nbytes.get(v) for v in g.in_vids]
                    if all(v is not None for v in vals):
                        parts = tuple(vals)
                if b is not None or parts is not None:
                    out.append(dataclasses.replace(g, bytes_in=b,
                                                   bytes_parts=parts))
                else:
                    out.append(g)
                continue
            red = next(nd for nd in g.nodes
                       if nd.op.kind in (OpKind.REDUCE,
                                         OpKind.REDUCE_SCATTER))
            if red.op.codec.combine_encoded is not None:
                # the encoded-domain combine only exists as the chunked
                # RS∘AG walk — there is no latency-ring variant to pick
                out.append(dataclasses.replace(
                    g, bytes_in=b, schedule="bandwidth",
                    desc=f"encoded-domain ({red.op.codec.name}) RS∘AG walk "
                         "(fixed schedule)"))
                continue
            wire = None
            if b is not None:
                # what actually travels: the sunk codec shrinks the wire
                wire = int(b * red.op.codec.wire_ratio)
            out.append(dataclasses.replace(
                g, bytes_in=b,
                **self._decide(wire, ctx, g.axis or ctx.axis_name)))
        return out

    @staticmethod
    def _group_bytes(g: StageIR, nbytes: Optional[dict]) -> Optional[int]:
        if nbytes is None or not g.in_vids:
            return None
        if g.kind == "allreduce+alltoall":
            # the fused-pair model takes the summed per-rank payload
            vals = [nbytes.get(v) for v in g.in_vids]
            return sum(vals) if all(v is not None for v in vals) else None
        if g.kind == "map":
            # a map streams what it *produces* (a Coalesce split is
            # address steering — it reads one slice of the bucket, not
            # the whole buffer; a pack's output is the sum of its inputs)
            b = nbytes.get(g.out_vids[0])
            if b is not None:
                return b
        return nbytes.get(g.in_vids[0])

    def _decide(self, payload: Optional[int], ctx: CompileContext,
                axis: str) -> dict:
        if payload is None:
            return {"schedule": "bandwidth",
                    "desc": "RS∘AG ring (payload unknown; "
                            "bandwidth-optimal default)"}
        n = ctx.size_of(axis)
        if n is None:
            # never cost one axis with another's ring size — without this
            # axis's size the model has nothing to say
            return {"schedule": "bandwidth",
                    "desc": f"[{axis}] RS∘AG ring (axis size unknown; "
                            "bandwidth-optimal default)"}
        net = ctx.net_of(axis)
        threshold = ctx.latency_optimal_below
        if threshold is None:
            threshold = netmodel.ring_crossover_bytes(n, net)
        t_lat = netmodel.ring_allreduce_time(n, payload, net,
                                             latency_optimal=True)
        t_bw = netmodel.ring_allreduce_time(n, payload, net,
                                            latency_optimal=False)
        sched = "latency" if payload < threshold else "bandwidth"
        return {"schedule": sched,
                "desc": f"[{axis}] {payload}B/rank vs threshold "
                        f"{threshold}B → {sched}-optimal ring "
                        f"(model: lat {t_lat * 1e6:.1f}us, "
                        f"bw {t_bw * 1e6:.1f}us)"}

    @staticmethod
    def _value_bytes(ctx: CompileContext) -> Optional[dict[int, int]]:
        """Per-rank payload bytes for every DAG value, or None if unknown.

        Exact where the aval propagation can see (``jax.eval_shape``
        sizes MAP bodies, including the Coalesce pack/split shims, whose
        outputs are nothing like their first input).  Where it cannot
        (a map querying ``lax.axis_size``), a multi-input MAP falls back
        to the max over its *known* input sizes, and stays unknown when
        none are known — sizing it from ``inputs[0]`` alone would let a
        small first operand mis-drive the latency/bandwidth decision
        downstream.  AG/RS scale by the size of their own axis (unknown
        axis size → unknown output).
        """
        if ctx.in_avals is None:
            return None
        avals = _propagate_avals(ctx.dag, ctx)
        nbytes: dict[int, int] = {}
        for i, aval in enumerate(ctx.in_avals):
            size = int(math.prod(aval.shape)) if aval.shape else 1
            nbytes[i] = size * jnp.dtype(aval.dtype).itemsize
        for nd in ctx.dag.nodes:
            a = avals.get(nd.out)
            if a is not None:
                nbytes[nd.out] = _aval_bytes(a)
                continue
            k = nd.op.kind
            if k == OpKind.MAP:
                known = [nbytes[v] for v in nd.inputs if v in nbytes]
                if known:
                    nbytes[nd.out] = max(known)
                continue
            src = nbytes.get(nd.inputs[0])
            if src is None:
                continue
            if k == OpKind.ALLGATHER:
                n = SelectSchedule._axis_size(nd, ctx)
                if n is not None:
                    nbytes[nd.out] = src * n
            elif k == OpKind.REDUCE_SCATTER:
                n = SelectSchedule._axis_size(nd, ctx)
                if n is not None:
                    nbytes[nd.out] = max(src // n, 1)
            else:                       # REDUCE/A2A/SCAN/BCAST/DELIVERED
                nbytes[nd.out] = src    # (WIRE nodes are gone by Legalize)
        return nbytes

    @staticmethod
    def _axis_size(nd: DagNode, ctx: CompileContext) -> Optional[int]:
        """Size of the axis this node communicates over; axis=None means
        the program default (a pipeline without LowerTopology)."""
        ax = nd.op.axis
        if ax is None:
            ax = ctx.axis_name
        if not isinstance(ax, str) or ax == AUTO_AXIS:
            return None
        return ctx.size_of(ax)


# ---------------------------------------------------------------------------
# Pass 6: PlaceCGRA — map stage compute bodies onto the switch grid
# ---------------------------------------------------------------------------

class PlaceCGRA:
    """Attach a CGRA placement (or explicit host fallback) to every stage.

    Runs after SelectSchedule: the ring choice is made, the payloads are
    known, and this pass decides whether the in-switch rate the model
    assumed is *earned* — re-costing the stage with the placement-derived
    throughput (or the PCIe + MPI host detour) in the stage desc.  The
    heavy lifting lives in :mod:`repro.cgra.mapper`; the import is
    deferred so neither package needs the other at import time.
    """

    name = "place_cgra"

    def __init__(self, device=None):
        self.device = device

    def run(self, groups: list, ctx: "CompileContext") -> list:
        from repro.cgra import mapper

        return mapper.place_groups(groups, ctx, self.device)


# ---------------------------------------------------------------------------
# Pass 7: Emit
# ---------------------------------------------------------------------------

def _use_kernels(ctx: CompileContext) -> bool:
    return bool(getattr(ctx.config, "use_kernels", False))


def _hop_combine_kernel(monoid) -> Optional[Callable]:
    """The registered Pallas combine for a Type 1 monoid, as a ring
    ``hop_combine(incoming, local)`` hook; None when the monoid has no
    kernel (the ring then folds with the plain monoid combine)."""
    if monoid.name not in ("add", "max", "min"):
        return None
    sop = switchops.get(monoid.name)

    def hop(incoming, local, _sop=sop):
        return _sop(incoming, local, use_kernel=True)
    return hop


class Emit:
    """Lower every StageIR to a rank-local callable.

    Coalesce bucket packs additionally get an **arena slot**: the
    emitted run accepts an optional persistent flat buffer and writes
    the leaves into it in place (``dynamic_update_slice``) instead of
    concatenating into a fresh one — with the arena donated at the jit
    boundary the pack's transient memory is ~1× the bucket, not 2×.
    """

    name = "emit"

    def run(self, groups: list[StageIR], ctx: CompileContext) -> list[Stage]:
        if _use_kernels(ctx):
            # bind the Pallas implementations onto the registry once so the
            # emitted closures' `use_kernel=True` calls actually hit them
            switchops.load_kernels()
        stages = []
        n_arenas = 0
        for g in groups:
            st = self._emit(g, ctx)
            if st.arena_aval is not None:
                st = dataclasses.replace(st, arena_slot=n_arenas)
                n_arenas += 1
            stages.append(st)
        if stages:
            _obs.RECORDER.count(
                "emit.kernel_stage" if _use_kernels(ctx)
                else "emit.reference_stage", len(stages))
        return stages

    def _emit(self, g: StageIR, ctx: CompileContext) -> Stage:
        run = getattr(self, "_" + g.kind.replace("+", "_"))(g, ctx)
        axis = g.axis
        if not axis:
            coll = [nd.op for nd in g.nodes
                    if nd.op.kind in COLLECTIVE_KINDS]
            if any(op.axis is not None for op in coll):
                # "auto"/tuple survived to Emit — running it over the
                # default axis would silently compute the wrong reduction
                raise ValueError(
                    f"stage {g.kind} has an unresolved compound axis "
                    f"{[op.axis for op in coll]}; include LowerTopology "
                    "in the pipeline")
            if coll:
                # a custom pipeline without LowerTopology leaves axis=None
                # ops unresolved — fall back to the program-wide default
                # axis (pure-map stages legitimately stay axis-less)
                axis = ctx.axis_name
        aval = None
        if g.kind == "map":
            sizes = getattr(g.nodes[0].op.fn, "bucket_sizes", None)
            if sizes is not None:
                aval = jax.ShapeDtypeStruct(
                    (sum(sizes),),
                    jnp.dtype(getattr(g.nodes[0].op.fn, "bucket_dtype",
                                      "float32")))
        return Stage(g.kind, run, g.desc, g.in_vids, g.out_vids, g.schedule,
                     axis, g.placement, g, arena_aval=aval)

    # -- fused stages --------------------------------------------------------

    @staticmethod
    def _scan_allgather(g: StageIR, ctx: CompileContext):
        scan_op = g.nodes[1].op

        def run(args, ax, _m=scan_op.monoid, _ex=scan_op.exclusive):
            (x,) = args
            if _m.name == "add" and not _ex:
                return (fused.allgather_op_allgather(x, ax),)
            return (fused.scan_then_allgather(x, ax, _m, exclusive=_ex),)
        return run

    @staticmethod
    def _allreduce_alltoall(g: StageIR, ctx: CompileContext):
        def run(args, ax):
            hist, keys = args
            return fused.fused_allreduce_alltoall(hist, keys, ax)
        return run

    @staticmethod
    def _map_allreduce(g: StageIR, ctx: CompileContext):
        mp, red = g.nodes[0].op, g.nodes[1].op
        lat = g.schedule == "latency"

        def run(args, ax, _f=mp.fn, _m=red.monoid, _c=red.codec, _l=lat):
            (x,) = args
            return (collectives.all_reduce(_f(x), ax, _m, codec=_c,
                                           latency_optimal=_l),)
        return run

    @staticmethod
    def _map_reduce_scatter(g: StageIR, ctx: CompileContext):
        mp, rs = g.nodes[0].op, g.nodes[1].op

        def run(args, ax, _f=mp.fn, _m=rs.monoid, _c=rs.codec):
            (x,) = args
            return (fused.map_reduce_scatter(x, ax, _f, _m, codec=_c),)
        return run

    @staticmethod
    def _allgather_map(g: StageIR, ctx: CompileContext):
        mp = g.nodes[1].op

        def run(args, ax, _f=mp.fn):
            (x,) = args
            return (fused.allgather_map(x, ax, _f),)
        return run

    @staticmethod
    def _ef_allreduce(g: StageIR, ctx: CompileContext):
        """Error-feedback compressed all-reduce (Type 3 look-aside): one
        compression yields both the lossy total and, when the DELIVERED
        sibling survived DCE, this rank's delivered contribution."""
        ef = g.nodes[0].op.ef
        both = len(g.out_vids) == 2

        def run(args, ax, _c=ef.compressor, _k=ef.topk_ratio, _b=both):
            (t,) = args
            total, delivered = lookaside.compressed_all_reduce(
                t, ax, compressor=_c, topk_ratio=_k)
            return (total, delivered) if _b else (total,)
        return run

    @staticmethod
    def _delivered(g: StageIR, ctx: CompileContext):
        # standalone DELIVERED (its reduce was DCE'd) — rare; reuse the
        # full look-aside op and keep only the local-feedback half
        ef = g.nodes[0].op.ef

        def run(args, ax, _c=ef.compressor, _k=ef.topk_ratio):
            (t,) = args
            return (lookaside.compressed_all_reduce(
                t, ax, compressor=_c, topk_ratio=_k)[1],)
        return run

    # -- single-node lowerings ----------------------------------------------

    @staticmethod
    def _map(g: StageIR, ctx: CompileContext):
        op = g.nodes[0].op
        sizes = getattr(op.fn, "bucket_sizes", None)
        if sizes is None:
            def run(args, ax, _f=op.fn):
                return (_f(*args),)
            return run

        # Coalesce bucket pack: without an arena, the plain concat; with
        # one, flatten every leaf into the persistent buffer in place —
        # the same layout, but the destination is a donated buffer the
        # caller keeps across steps instead of a fresh allocation.  With
        # kernels on, the N per-leaf dynamic_update_slice calls collapse
        # into ONE arena-aliased Pallas launch (switchops "pack_combine").
        # A masked pack (``masked_monoid`` set) masks its leaves with the
        # monoid identity *before* the in-place writes and stores the
        # alive flag in the trailing count lane — same layout, same
        # arena, one extra element.
        uk = _use_kernels(ctx)
        masked = getattr(op.fn, "masked_monoid", None)

        def run(args, ax, arena=None, _f=op.fn, _sizes=sizes, _uk=uk,
                _m=masked):
            if arena is None:
                return (_f(*args),)
            _check_pack_sizes(args, _sizes)
            if _m is not None:
                alive = args[-1].reshape(()).astype(arena.dtype)
                live = alive != 0
                args = tuple(
                    jnp.where(live, x.reshape(-1).astype(arena.dtype),
                              _m.identity(jax.ShapeDtypeStruct(
                                  (x.size,), arena.dtype)))
                    for x in args[:-1]) + (alive.reshape(1),)
            if _uk:
                parts = [x.reshape(-1).astype(arena.dtype) for x in args]
                return (switchops.get("pack_combine")(
                    arena, *parts, use_kernel=True),)
            buf = arena
            off = 0
            for x, s in zip(args, _sizes):
                buf = lax.dynamic_update_slice(
                    buf, x.reshape(-1).astype(buf.dtype), (off,))
                off += s
            return (buf,)
        return run

    @staticmethod
    def _allreduce(g: StageIR, ctx: CompileContext):
        op = g.nodes[-1].op if g.nodes[-1].op.kind == OpKind.REDUCE \
            else g.nodes[0].op           # RS∘AG group: monoid/codec on RS
        lat = g.schedule == "latency"
        hop = _hop_combine_kernel(op.monoid) if _use_kernels(ctx) else None

        def run(args, ax, _m=op.monoid, _c=op.codec, _l=lat, _h=hop):
            (x,) = args
            return (collectives.all_reduce(x, ax, _m, codec=_c,
                                           latency_optimal=_l,
                                           hop_combine=_h),)
        return run

    # batched same-axis ring: k independent allreduces already merged into
    # one chunk-aligned stacked buffer by Coalesce — the lowering is the
    # plain allreduce of that buffer
    _batched_allreduce = _allreduce

    @staticmethod
    def _reduce_scatter(g: StageIR, ctx: CompileContext):
        op = g.nodes[0].op
        hop = _hop_combine_kernel(op.monoid) if _use_kernels(ctx) else None

        def run(args, ax, _m=op.monoid, _c=op.codec, _h=hop):
            (x,) = args
            return (collectives.reduce_scatter(x, ax, _m, codec=_c,
                                               hop_combine=_h),)
        return run

    @staticmethod
    def _allgather(g: StageIR, ctx: CompileContext):
        def run(args, ax):
            (x,) = args
            return (collectives.all_gather(x, ax),)
        return run

    @staticmethod
    def _alltoall(g: StageIR, ctx: CompileContext):
        def run(args, ax):
            (x,) = args
            return (collectives.all_to_all(x, ax),)
        return run

    @staticmethod
    def _scan(g: StageIR, ctx: CompileContext):
        op = g.nodes[0].op

        def run(args, ax, _m=op.monoid, _e=op.exclusive):
            (x,) = args
            return (collectives.prefix_scan(x, ax, _m, exclusive=_e),)
        return run

    @staticmethod
    def _bcast(g: StageIR, ctx: CompileContext):
        op = g.nodes[0].op

        def run(args, ax, _r=op.root):
            (x,) = args
            return (collectives.broadcast(x, ax, _r),)
        return run


# ---------------------------------------------------------------------------
# The pipeline & public entry points
# ---------------------------------------------------------------------------

DEFAULT_PIPELINE = (Legalize(), LowerTopology(), Coalesce(), FuseHops(),
                    SelectSchedule(), PlaceCGRA(), Emit())


def run_pipeline(dag: DagProgram, ctx: CompileContext,
                 pipeline=DEFAULT_PIPELINE):
    ctx.dag = dag                       # Legalize may rewrite; keep current
    unit: Any = dag
    for p in pipeline:
        unit = p.run(unit, ctx)
        if isinstance(unit, DagProgram):
            ctx.dag = unit
    return unit, ctx.dag


def compile_rank_local(
    prog: ProgramLike,
    axis_name: str,
    *,
    axis_size: Optional[int] = None,
    config: Any = None,
    in_avals: Optional[Sequence[Any]] = None,
    topology: Optional[Topology] = None,
    pipeline=DEFAULT_PIPELINE,
) -> CompiledProgram:
    """Compile to a rank-local callable (for use inside an existing
    shard_map region, e.g. embedded in a train step).

    ``prog`` may be a traced :class:`DagProgram`, a legacy chain
    :class:`SwitchProgram`, or a plain function (traced on the fly).
    ``axis_name`` is the default axis for ops that don't name one;
    ``topology`` describes all DP axes (it defaults to the single
    ``axis_name`` axis) and drives the LowerTopology pass.
    """
    dag = _as_dag(prog)
    if topology is None:
        topology = Topology.single(axis_name, axis_size)
    ctx = CompileContext(axis_name=axis_name, axis_size=axis_size,
                         config=config, in_avals=in_avals,
                         topology=topology)
    stages, final_dag = run_pipeline(dag, ctx, pipeline)
    out = CompiledProgram(stages, final_dag, topology=ctx.topology,
                          overlap=getattr(config, "overlap_dispatch",
                                          True))
    rec = _obs.RECORDER
    if rec.enabled:
        rec.count("compile.programs")
        for st in stages:
            nb = getattr(st.ir, "bytes_in", None) if st.ir is not None \
                else None
            if nb:
                rec.observe("plan.stage_bytes", float(nb))
            if st.placement is not None:
                rec.count("cgra.placed" if st.placement.fits
                          else "cgra.host_fallback")
        for grp in out.plan.waves:
            rec.observe("plan.wave_width", float(len(grp)))
    return out


def compile_program(
    prog: ProgramLike,
    mesh: jax.sharding.Mesh,
    axis_name: str,
    in_specs,
    out_specs,
    *,
    jit: bool = True,
    config: Any = None,
    in_avals: Optional[Sequence[Any]] = None,
    topology: Optional[Topology] = None,
) -> Callable:
    """Emit the full "CGRA binary": one shard_map-wrapped, jitted callable
    executing every fused stage in a single SPMD program (stages may span
    several mesh axes — each runs over its own)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    axis_size = sizes[axis_name]
    if topology is not None:
        topology = topology.with_sizes(sizes)
    compiled = compile_rank_local(prog, axis_name, axis_size=axis_size,
                                  config=config, in_avals=in_avals,
                                  topology=topology)

    def run(*xs):
        # the rank-local program always returns a tuple; the shard_map
        # callable mirrors out_specs, so a single spec gets a bare array
        outs = compiled(*xs)
        return outs[0] if len(outs) == 1 else outs

    fn = jax.shard_map(run, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    out = jax.jit(fn) if jit else fn
    out.stages = compiled.stage_kinds()        # type: ignore[attr-defined]
    out.schedules = compiled.stage_schedules()  # type: ignore[attr-defined]
    out.axes = compiled.stage_axes()           # type: ignore[attr-defined]
    out.compiled = compiled                    # type: ignore[attr-defined]
    return out
