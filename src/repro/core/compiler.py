"""SwitchProgram compiler — a pass pipeline over the DAG IR.

Mirrors the paper's back-end steps (parse IR → DFG → optimizations → code
generation → scheduling) as five composable passes:

  1. :class:`Legalize`   — dead-code-eliminate unused nodes and sink WIRE
     nodes onto the collective they feed (the codec becomes a node
     attribute; non-codec-capable consumers drop it, mirroring a
     fixed-function wire).
  2. :class:`LowerTopology` — resolve every collective's ``axis`` against
     the compile :class:`Topology` ({axis: size} plus per-axis link tier)
     and rewrite a REDUCE over a compound/``"auto"`` axis into the
     hierarchical RS(inner) → REDUCE(outer) → AG(inner) schedule, with
     any sunk wire codec riding the *outer* (thin inter-pod) hop only —
     ACiS processing placed exactly where the flows converge.
  3. :class:`FuseHops`   — pattern-match fusion opportunities.  Each rule
     is a first-class :class:`FusionPattern` over the DAG (paper Fig. 5
     AG∘scan∘AG, the NAS-IS AR+A2A pair, map-into-hop fusion, RS∘AG →
     one all-reduce schedule, the error-feedback REDUCE+DELIVERED pair);
     matched nodes are grouped into :class:`StageIR` units — same-axis
     only — and topologically ordered.
  4. :class:`SelectSchedule` — pick the latency- vs bandwidth-optimal ring
     for every all-reduce stage by propagating per-rank payload bytes
     through the DAG and consulting ``CollectiveConfig.
     latency_optimal_below`` plus the analytic cost model in
     :mod:`repro.core.netmodel` — evaluated against the link tier of the
     axis the stage actually traverses (fast ICI vs thin DCI).
  5. :class:`PlaceCGRA`  — map every stage's compute body (fused MAPs,
     monoid/codec combines, look-aside compressors) onto the switch CGRA
     grid (:mod:`repro.cgra`): trace to a jaxpr, lower to an op-graph,
     list-schedule + place.  Each stage gets a ``Placement`` (PEs, depth,
     II → sustained rate) or an explicit host-fallback the cost model
     charges as a PCIe + MPI detour.
  6. :class:`Emit`       — lower every stage to a rank-local callable; the
     emitted :class:`CompiledProgram` executes them over a value
     environment (multi-input / multi-output programs are native), each
     stage over its own axis.

`compile_program` wraps the result in `jax.shard_map` + `jax.jit` — the
"CGRA binary".  The emitted program records its fused stage list, the
chosen schedules, and the per-stage axes so tests (and the roofline
accounting) can verify what was fused, exactly like inspecting the
paper's generated schedule.
"""

from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Any, Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import collectives, fused, lookaside, netmodel, ring
from repro.core.program import (AUTO_AXIS, COLLECTIVE_KINDS, DagNode,
                                DagProgram, Node, OpKind, SwitchProgram)
from repro.core.tracing import trace
from repro.core.types import ADD
from repro.core.wire import IDENTITY, resolve_codec

PyTree = Any
ProgramLike = Union[DagProgram, SwitchProgram, Callable]


def _as_dag(prog: ProgramLike) -> DagProgram:
    if isinstance(prog, DagProgram):
        return prog
    if isinstance(prog, SwitchProgram):
        return prog.to_dag()
    if callable(prog):
        return trace(prog)
    raise TypeError(f"cannot compile {type(prog).__name__}")


# ---------------------------------------------------------------------------
# Topology, compile context & stage forms
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AxisSpec:
    """One data-parallel mesh axis of the compile topology.

    ``tier`` keys into :data:`repro.core.netmodel.TIERS` and tells
    SelectSchedule which link parameters a stage on this axis traverses
    (``"ici"`` fast intra-pod, ``"dci"`` thin inter-pod).  ``size`` may be
    None — collectives then read it at run time via ``lax.axis_size`` and
    the cost model falls back to its bandwidth-optimal default.
    """

    name: str
    size: Optional[int] = None
    tier: str = "ici"


@dataclasses.dataclass(frozen=True)
class Topology:
    """The data-parallel axes a program may communicate over, innermost
    (fastest links) first — the compiler's description of where the
    network is fat and where it is thin."""

    axes: tuple[AxisSpec, ...]

    @classmethod
    def single(cls, name: str, size: Optional[int] = None,
               tier: str = "ici") -> "Topology":
        return cls((AxisSpec(name, size, tier),))

    def names(self) -> tuple[str, ...]:
        return tuple(a.name for a in self.axes)

    def spec(self, name: str) -> Optional[AxisSpec]:
        for a in self.axes:
            if a.name == name:
                return a
        return None

    def size(self, name: str) -> Optional[int]:
        a = self.spec(name)
        return a.size if a is not None else None

    def net(self, name: str) -> netmodel.NetParams:
        a = self.spec(name)
        if a is None:
            return netmodel.PAPER
        return netmodel.TIERS.get(a.tier, netmodel.PAPER)

    @property
    def inner(self) -> AxisSpec:
        return self.axes[0]

    @property
    def outer(self) -> Optional[AxisSpec]:
        return self.axes[-1] if len(self.axes) > 1 else None

    def with_sizes(self, sizes: dict) -> "Topology":
        """Fill (or correct) axis sizes from a mesh's {name: size} map."""
        return Topology(tuple(
            dataclasses.replace(a, size=sizes.get(a.name, a.size))
            for a in self.axes))


@dataclasses.dataclass
class CompileContext:
    """Everything the passes may consult.

    ``config`` duck-types :class:`repro.core.api.CollectiveConfig` (only
    ``latency_optimal_below``, ``backend`` and ``codec`` are read) to avoid
    an api↔compiler import cycle.  ``in_avals`` are rank-local shape/dtype
    structs for the program inputs — optional; without them SelectSchedule
    keeps the bandwidth-optimal default.  ``topology`` defaults to the
    single ``axis_name`` axis on the fast tier.
    """

    axis_name: str
    axis_size: Optional[int] = None
    config: Any = None
    in_avals: Optional[Sequence[Any]] = None
    net: netmodel.NetParams = netmodel.PAPER
    dag: Optional[DagProgram] = None    # current form, updated per pass
    topology: Optional[Topology] = None

    @property
    def latency_optimal_below(self) -> Optional[int]:
        if self.config is None:
            return None
        return getattr(self.config, "latency_optimal_below", None)

    def size_of(self, axis: str) -> Optional[int]:
        if self.topology is not None:
            s = self.topology.size(axis)
            if s is not None:
                return s
        return self.axis_size if axis == self.axis_name else None

    def net_of(self, axis: str) -> netmodel.NetParams:
        if self.topology is not None and self.topology.spec(axis) is not None:
            return self.topology.net(axis)
        return self.net

    def default_wire_codec(self):
        """The codec a compressed engine applies at the thin outer hop when
        the program didn't declare one — compression exactly where the
        wire is thin is a compiler decision, not a call-site convention."""
        if self.config is None:
            return IDENTITY
        if "compressed" not in getattr(self.config, "backend", ""):
            return IDENTITY
        return resolve_codec(getattr(self.config, "codec", "identity"))


@dataclasses.dataclass(frozen=True)
class StageIR:
    """One fused group of DAG nodes, pre-emission."""

    kind: str
    nodes: tuple[DagNode, ...]
    in_vids: tuple[int, ...]
    out_vids: tuple[int, ...]
    schedule: str = ""             # "latency" | "bandwidth" | "" (fixed)
    bytes_in: Optional[int] = None
    desc: str = ""
    axis: str = ""                 # mesh axis the stage communicates over
    placement: Optional[Any] = None  # CGRA Placement | HostFallback


@dataclasses.dataclass(frozen=True)
class Stage:
    """One emitted in-network stage: ``run(args, axis_name) -> outputs``.

    ``placement`` is the CGRA mapping the PlaceCGRA pass attached (a
    :class:`repro.cgra.device.Placement`, or an explicit
    :class:`~repro.cgra.device.HostFallback` when the stage's compute
    body does not fit the switch grid); ``ir`` is the pre-emission
    :class:`StageIR` the stage was lowered from — the dataplane
    simulator interprets it instead of the opaque ``run`` closure.
    """

    kind: str
    run: Callable[[tuple, str], tuple]
    desc: str = ""
    in_vids: tuple[int, ...] = ()
    out_vids: tuple[int, ...] = ()
    schedule: str = ""
    axis: str = ""
    placement: Optional[Any] = None
    ir: Optional[StageIR] = None

    def __repr__(self):  # pragma: no cover
        return f"Stage({self.kind}@{self.axis})" if self.axis \
            else f"Stage({self.kind})"


@dataclasses.dataclass
class CompiledProgram:
    """Rank-local executable: stages run in order over a value environment.

    Every stage carries its own communication axis (stamped by
    LowerTopology), so one program may span several mesh axes — there is
    no single program-wide axis any more.
    """

    stages: Sequence[Stage]
    source: DagProgram

    def stage_kinds(self) -> list[str]:
        return [s.kind for s in self.stages]

    def stage_schedules(self) -> list[str]:
        return [s.schedule for s in self.stages]

    def stage_axes(self) -> list[str]:
        return [s.axis for s in self.stages]

    def stage_placements(self) -> list:
        return [s.placement for s in self.stages]

    def explain(self) -> str:
        """Readable per-stage table: what was fused, over which axis, on
        which ring schedule, with which wire codec, and where the compute
        body landed (CGRA placement or explicit host fallback)."""
        rows = [("#", "kind", "axis", "schedule", "codec", "placement")]
        for i, st in enumerate(self.stages):
            codec = "-"
            if st.ir is not None:
                for nd in st.ir.nodes:
                    if nd.op.kind in COLLECTIVE_KINDS \
                            and nd.op.codec is not IDENTITY:
                        codec = nd.op.codec.name
                    elif nd.op.ef is not None:
                        codec = f"ef[{nd.op.ef.compressor}]"
            pl = st.placement.describe() if st.placement is not None \
                else "-"
            rows.append((str(i), st.kind, st.axis or "-",
                         st.schedule or "-", codec, pl))
        widths = [max(len(r[c]) for r in rows) for c in range(5)]
        lines = [f"program {self.source.name!r} "
                 f"({self.source.num_inputs} in, "
                 f"{len(self.source.outputs)} out, "
                 f"{len(self.stages)} stages)"]
        for j, r in enumerate(rows):
            lines.append("  " + "  ".join(
                r[c].ljust(widths[c]) for c in range(5)) + "  " + r[5])
            if j == 0:
                lines.append("  " + "-" * (sum(widths) + 8 + len(r[5])))
        return "\n".join(lines)

    def axes(self) -> list[str]:
        """Distinct communication axes, in first-use order."""
        seen: list[str] = []
        for s in self.stages:
            if s.axis and s.axis not in seen:
                seen.append(s.axis)
        return seen

    def __call__(self, *xs: PyTree) -> PyTree:
        n_in = self.source.num_inputs
        if len(xs) == 1 and n_in > 1 and isinstance(xs[0], (tuple, list)):
            xs = tuple(xs[0])      # chain-shim spelling: one tuple argument
        if len(xs) != n_in:
            raise TypeError(
                f"program {self.source.name!r} takes {n_in} inputs, "
                f"got {len(xs)}")
        env: dict[int, PyTree] = dict(enumerate(xs))
        for st in self.stages:
            outs = st.run(tuple(env[v] for v in st.in_vids), st.axis)
            for vid, o in zip(st.out_vids, outs):
                env[vid] = o
        outs = tuple(env[v] for v in self.source.outputs)
        return outs[0] if len(outs) == 1 else outs


# ---------------------------------------------------------------------------
# Pass 1: Legalize
# ---------------------------------------------------------------------------

# consumers that can apply a wire codec in-flight (all lower to an
# all-reduce schedule, which takes `codec=`)
_CODEC_SINKS = {OpKind.REDUCE, OpKind.REDUCE_SCATTER}


class Legalize:
    """Canonicalize the DAG: DCE + sink WIRE nodes onto their consumer."""

    name = "legalize"

    def run(self, dag: DagProgram, ctx: CompileContext) -> DagProgram:
        dag = self._dce(dag)
        return self._sink_wires(dag)

    @staticmethod
    def _dce(dag: DagProgram) -> DagProgram:
        live = set(dag.outputs)
        keep: list[DagNode] = []
        for nd in reversed(dag.nodes):
            if nd.out in live:
                keep.append(nd)
                live.update(nd.inputs)
        keep.reverse()
        if len(keep) == len(dag.nodes):
            return dag
        return DagProgram(dag.num_inputs, tuple(keep), dag.outputs, dag.name)

    @staticmethod
    def _sink_wires(dag: DagProgram) -> DagProgram:
        """Replace WIRE nodes by a ``codec`` attribute on their consumer.

        The codec travels through single-input MAPs (the map runs before
        the payload hits the wire, so the declaration still applies to the
        collective downstream — the old chain compiler's pending-codec
        behaviour).  A WIRE reaching a non-codec-capable op or a program
        output is dropped — the wire format of those links is fixed — and
        the drop is *announced* with a ``UserWarning`` naming the node, so
        a user who declared compression on a link that cannot apply it
        learns the codec was ignored instead of silently paying f32 wire
        bytes they thought they'd saved.
        """
        if not any(nd.op.kind == OpKind.WIRE for nd in dag.nodes):
            return dag
        alias: dict[int, int] = {}       # wire out → its input
        carried: dict[int, Any] = {}     # value id → pending codec

        def resolve(vid: int) -> int:
            while vid in alias:
                vid = alias[vid]
            return vid

        def warn_drop(codec, where: str) -> None:
            warnings.warn(
                f"[{dag.name}] wire codec {codec.name!r} dropped at "
                f"{where} — that link's wire format is fixed, the "
                "declared compression will NOT be applied",
                UserWarning, stacklevel=3)

        nodes: list[DagNode] = []
        applied: set[int] = set()        # carried vids whose codec sank
        for nd in dag.nodes:
            if nd.op.kind == OpKind.WIRE:
                alias[nd.out] = nd.inputs[0]
                carried[nd.out] = nd.op.codec
                continue
            op = nd.op
            ins = tuple(resolve(v) for v in nd.inputs)
            codecs = [carried[v] for v in nd.inputs if v in carried]
            if codecs:
                # an error-feedback reduce is not codec-capable — its wire
                # format is the compressor's, so a WIRE reaching it drops
                # like on any fixed-function link
                if op.kind in _CODEC_SINKS and op.ef is None:
                    op = dataclasses.replace(op, codec=codecs[-1])
                    applied.update(v for v in nd.inputs if v in carried)
                elif op.kind == OpKind.MAP and len(nd.inputs) == 1:
                    carried[nd.out] = codecs[-1]
                elif op.kind in _CODEC_SINKS:
                    warn_drop(codecs[-1],
                              f"error-feedback node {op.label()!r} (its "
                              "wire format is the compressor's)")
                else:
                    warn_drop(codecs[-1],
                              f"non-codec-capable node {op.label()!r}")
            nodes.append(DagNode(op, ins, nd.out))
        for v in dag.outputs:
            # a pending codec that reached an output without ever sinking
            # (directly, or carried through maps) was silently useless
            if v in carried and v not in applied:
                warn_drop(carried[v], "a program output")
        outputs = tuple(resolve(v) for v in dag.outputs)
        return DagProgram(dag.num_inputs, tuple(nodes), outputs, dag.name)


# ---------------------------------------------------------------------------
# Pass 2: LowerTopology — resolve axes, lower compound reductions
# ---------------------------------------------------------------------------

def _flatten_pad(inner_axes: tuple[str, ...]) -> Callable:
    """Flatten to 1-D and pad to a multiple of the product of the inner
    axis sizes, so the reduce-scatter chain can chunk evenly.  Runs inside
    shard_map, where ``lax.axis_size`` is concrete — no static size needed
    at compile time."""
    def fn(x):
        n = 1
        for ax in inner_axes:
            n *= lax.axis_size(ax)
        return ring.pad_to_multiple(x.reshape(-1), n)[0]
    return fn


def _unpad_like(y, orig):
    """Undo :func:`_flatten_pad` using the original operand for shape."""
    return y[:orig.size].reshape(orig.shape)


class LowerTopology:
    """Make topology a compiler concern.

    Every collective's ``axis`` is resolved against ``ctx.topology``:
    ``None`` → the engine default axis, ``"auto"`` → all DP axes of the
    topology, a tuple → that compound axis (innermost first).  A REDUCE
    over a compound axis is rewritten into the hierarchical schedule

        pad → RS(inner…) → REDUCE(outer, codec) → AG(…inner) → unpad

    so the later passes fuse/schedule/emit *per axis*.  A sunk wire codec
    (or a compressed engine's default codec) rides the outer hop only —
    the payload crossing the thin inter-pod links is already 1/|inner| of
    the gradient, and it is the only place compression pays.  An
    error-feedback REDUCE instead compresses at the innermost tier (where
    its DELIVERED sibling lives) and reduces the outer tiers exactly.
    """

    name = "lower_topology"

    def run(self, dag: DagProgram, ctx: CompileContext) -> DagProgram:
        nodes: list[DagNode] = []
        vmap: dict[int, int] = {i: i for i in range(dag.num_inputs)}
        next_vid = dag.num_inputs

        def emit(op: Node, ins: Sequence[int]) -> int:
            nonlocal next_vid
            vid = next_vid
            next_vid += 1
            nodes.append(DagNode(op, tuple(ins), vid))
            return vid

        for nd in dag.nodes:
            ins = tuple(vmap[v] for v in nd.inputs)
            op = nd.op
            if op.kind not in COLLECTIVE_KINDS:
                vmap[nd.out] = emit(op, ins)
                continue
            axes = self._resolve(op.axis, ctx)
            if len(axes) == 1 or op.kind == OpKind.DELIVERED:
                # DELIVERED is rank-local feedback of the innermost-tier
                # compression — it never spans tiers
                vmap[nd.out] = emit(
                    dataclasses.replace(op, axis=axes[0]), ins)
            elif op.kind == OpKind.REDUCE:
                vmap[nd.out] = self._lower_reduce(op, ins[0], axes, ctx,
                                                  emit)
            else:
                raise NotImplementedError(
                    f"{op.kind.value} over compound axis {axes} has no "
                    "hierarchical lowering (only reduce does)")
        return DagProgram(dag.num_inputs, tuple(nodes),
                          tuple(vmap[v] for v in dag.outputs), dag.name)

    @staticmethod
    def _resolve(axis, ctx: CompileContext) -> tuple[str, ...]:
        if axis is None:
            return (ctx.axis_name,)
        if axis == AUTO_AXIS:
            if ctx.topology is None:
                return (ctx.axis_name,)
            return ctx.topology.names()
        if isinstance(axis, str):
            return (axis,)
        return tuple(axis)

    def _lower_reduce(self, op: Node, vin: int, axes: tuple[str, ...],
                      ctx: CompileContext, emit) -> int:
        if op.ef is not None:
            # error feedback applies at the innermost tier; the outer
            # tiers reduce the (already compressed) partials exactly
            v = emit(dataclasses.replace(op, axis=axes[0]), (vin,))
            for ax in axes[1:]:
                v = emit(Node(OpKind.REDUCE, monoid=op.monoid, axis=ax),
                         (v,))
            return v
        inner, outer = axes[:-1], axes[-1]
        codec = op.codec
        if codec is IDENTITY:
            codec = ctx.default_wire_codec()
        # pad/unpad are shape bookkeeping, not chunk-local compute — they
        # must not be hop-fused into the ring schedules
        p = emit(Node(OpKind.MAP, fn=_flatten_pad(inner), name="hier_pad",
                      fusable=False), (vin,))
        for ax in inner:
            p = emit(Node(OpKind.REDUCE_SCATTER, monoid=op.monoid, axis=ax),
                     (p,))
        p = emit(Node(OpKind.REDUCE, monoid=op.monoid, codec=codec,
                      axis=outer), (p,))
        for ax in reversed(inner):
            p = emit(Node(OpKind.ALLGATHER, axis=ax), (p,))
        return emit(Node(OpKind.MAP, fn=_unpad_like, name="hier_unpad",
                         fusable=False), (p, vin))


# ---------------------------------------------------------------------------
# Pass 3: FuseHops — first-class fusion patterns
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _MatchState:
    """Shared lookup tables for pattern matching over one DAG."""

    dag: DagProgram
    users: dict[int, list[DagNode]]
    out_set: set[int]
    claimed: set[int]                       # node out-ids already grouped
    ancestors: dict[int, set[int]]          # node out → transitive inputs

    @classmethod
    def build(cls, dag: DagProgram) -> "_MatchState":
        anc: dict[int, set[int]] = {}
        for nd in dag.nodes:
            a: set[int] = set()
            for v in nd.inputs:
                a.add(v)
                a |= anc.get(v, set())
            anc[nd.out] = a
        return cls(dag, dag.users(), set(dag.outputs), set(), anc)

    def sole_user(self, vid: int) -> Optional[DagNode]:
        """The unique consumer of ``vid`` if it isn't also a program
        output (fusion would hide the intermediate value) and hasn't been
        claimed by an earlier match (a cross-branch pattern may grab a
        node defined after the current root)."""
        us = self.users.get(vid, [])
        if len(us) == 1 and vid not in self.out_set \
                and us[0].out not in self.claimed:
            return us[0]
        return None

    def independent(self, a: DagNode, b: DagNode) -> bool:
        return a.out not in self.ancestors[b.out] \
            and b.out not in self.ancestors[a.out]


class FusionPattern:
    """One fusion rule: try to build a :class:`StageIR` rooted at ``nd``."""

    name = "pattern"

    def match(self, nd: DagNode, st: _MatchState) -> Optional[StageIR]:
        raise NotImplementedError


def _stage_axis(*nds: DagNode) -> str:
    """The (shared) communication axis of a fused group — the first
    collective node's axis; MAP nodes are axis-less."""
    for nd in nds:
        if nd.op.kind in COLLECTIVE_KINDS and isinstance(nd.op.axis, str) \
                and nd.op.axis != AUTO_AXIS:
            return nd.op.axis
    return ""


def _same_axis(*nds: DagNode) -> bool:
    """Collectives may only fuse onto one schedule if they traverse the
    same mesh axis (a pod-local ring cannot carry inter-pod hops)."""
    axes = {nd.op.axis for nd in nds if nd.op.kind in COLLECTIVE_KINDS}
    return len(axes) <= 1


class ScanGatherPattern(FusionPattern):
    """AG ∘ SCAN ∘ AG → fused scan+gather (paper Fig. 5)."""

    name = "scan+allgather"

    def match(self, nd, st):
        if nd.op.kind != OpKind.ALLGATHER:
            return None
        scan = st.sole_user(nd.out)
        if scan is None or scan.op.kind != OpKind.SCAN:
            return None
        ag2 = st.sole_user(scan.out)
        if ag2 is None or ag2.op.kind != OpKind.ALLGATHER \
                or not _same_axis(nd, scan, ag2):
            return None
        mono = scan.op.monoid
        return StageIR("scan+allgather", (nd, scan, ag2),
                       nd.inputs, (ag2.out,),
                       axis=_stage_axis(nd),
                       desc=f"fused allgather_op_allgather "
                            f"(in-network {mono.name}-scan)")


class MapIntoReducePattern(FusionPattern):
    """MAP ∘ REDUCE / MAP ∘ REDUCE_SCATTER → hop-fused map (Type 4)."""

    name = "map+reduce"

    def match(self, nd, st):
        if nd.op.kind != OpKind.MAP or len(nd.inputs) != 1 \
                or not nd.op.fusable:
            return None
        red = st.sole_user(nd.out)
        if red is None or red.op.kind not in (OpKind.REDUCE,
                                              OpKind.REDUCE_SCATTER) \
                or red.op.ef is not None:
            return None
        if red.op.kind == OpKind.REDUCE:
            return StageIR("map+allreduce", (nd, red), nd.inputs, (red.out,),
                           axis=_stage_axis(red),
                           desc="map fused ahead of AR schedule")
        return StageIR("map+reduce_scatter", (nd, red), nd.inputs,
                       (red.out,),
                       axis=_stage_axis(red),
                       desc=f"map({nd.op.name or 'fn'}) fused into RS hops")


class GatherMapPattern(FusionPattern):
    """ALLGATHER ∘ MAP → map applied in-flight at the forwarding hop."""

    name = "allgather+map"

    def match(self, nd, st):
        if nd.op.kind != OpKind.ALLGATHER:
            return None
        mp = st.sole_user(nd.out)
        if mp is None or mp.op.kind != OpKind.MAP or len(mp.inputs) != 1 \
                or not mp.op.fusable:
            return None
        return StageIR("allgather+map", (nd, mp), nd.inputs, (mp.out,),
                       axis=_stage_axis(nd),
                       desc="map applied in-flight at forwarding hop")


class ReduceAlltoallPattern(FusionPattern):
    """Independent REDUCE(add) + ALLTOALL pair → one shared ring schedule
    (the NAS IS histogram/keys fusion)."""

    name = "allreduce+alltoall"

    def match(self, nd, st):
        pair = None
        if self._fusable_reduce(nd):
            pair = self._find(nd, OpKind.ALLTOALL, st)
            red, a2a = nd, pair
        elif nd.op.kind == OpKind.ALLTOALL:
            pair = self._find(nd, OpKind.REDUCE, st)
            red, a2a = pair, nd
        if pair is None:
            return None
        return StageIR("allreduce+alltoall", (red, a2a),
                       (red.inputs[0], a2a.inputs[0]),
                       (red.out, a2a.out),
                       schedule="latency",
                       axis=_stage_axis(red),
                       desc="fused AR+A2A on one ring traversal")

    @staticmethod
    def _fusable_reduce(nd: DagNode) -> bool:
        # the shared-schedule kernel implements the add combine on the
        # identity wire only — a sunk codec must go to the unfused AR,
        # and an error-feedback reduce is a look-aside stage of its own
        return (nd.op.kind == OpKind.REDUCE
                and nd.op.monoid.name == "add"
                and nd.op.codec is IDENTITY
                and nd.op.ef is None)

    def _find(self, nd: DagNode, kind: OpKind,
              st: _MatchState) -> Optional[DagNode]:
        for cand in st.dag.nodes:
            if (cand.op.kind == kind and cand.out not in st.claimed
                    and (kind != OpKind.REDUCE
                         or self._fusable_reduce(cand))
                    and _same_axis(nd, cand)
                    and st.independent(nd, cand)):
                return cand
        return None


class RsAgPattern(FusionPattern):
    """REDUCE_SCATTER ∘ ALLGATHER → one all-reduce schedule."""

    name = "allreduce"

    def match(self, nd, st):
        if nd.op.kind != OpKind.REDUCE_SCATTER:
            return None
        ag = st.sole_user(nd.out)
        if ag is None or ag.op.kind != OpKind.ALLGATHER \
                or not _same_axis(nd, ag):
            return None
        return StageIR("allreduce", (nd, ag), nd.inputs, (ag.out,),
                       axis=_stage_axis(nd),
                       desc="RS∘AG → ring AR")


class EfPairPattern(FusionPattern):
    """Error-feedback REDUCE + its DELIVERED sibling → one look-aside
    stage: the compression runs once and yields both the lossy total and
    the locally-delivered contribution (the residual's other half)."""

    name = "ef_allreduce"

    def match(self, nd, st):
        if nd.op.kind != OpKind.REDUCE or nd.op.ef is None:
            return None
        for cand in st.dag.nodes:
            if (cand.op.kind == OpKind.DELIVERED
                    and cand.out not in st.claimed
                    and cand.inputs == nd.inputs
                    and cand.op.axis == nd.op.axis
                    and cand.op.ef == nd.op.ef):
                return StageIR("ef_allreduce", (nd, cand), nd.inputs,
                               (nd.out, cand.out),
                               axis=_stage_axis(nd),
                               desc=f"error-feedback "
                                    f"{nd.op.ef.compressor} all-reduce "
                                    "(Type 3 look-aside)")
        return None     # residual DCE'd — _single emits the lone reduce


DEFAULT_PATTERNS: tuple[FusionPattern, ...] = (
    EfPairPattern(),
    ScanGatherPattern(),
    MapIntoReducePattern(),
    GatherMapPattern(),
    ReduceAlltoallPattern(),
    RsAgPattern(),
)


_SINGLE_KINDS = {
    OpKind.MAP: "map",
    OpKind.REDUCE: "allreduce",
    OpKind.REDUCE_SCATTER: "reduce_scatter",
    OpKind.ALLGATHER: "allgather",
    OpKind.ALLTOALL: "alltoall",
    OpKind.SCAN: "scan",
    OpKind.BCAST: "bcast",
    OpKind.DELIVERED: "delivered",
}


class FuseHops:
    """Greedily apply fusion patterns in definition order, then
    topologically order the resulting stage groups."""

    name = "fuse_hops"

    def __init__(self, patterns: Sequence[FusionPattern] = DEFAULT_PATTERNS):
        self.patterns = tuple(patterns)

    def run(self, dag: DagProgram, ctx: CompileContext) -> list[StageIR]:
        st = _MatchState.build(dag)
        groups: list[StageIR] = []
        for nd in dag.nodes:
            if nd.out in st.claimed:
                continue
            for pat in self.patterns:
                m = pat.match(nd, st)
                if m is not None:
                    groups.append(m)
                    st.claimed.update(g.out for g in m.nodes)
                    break
            else:
                groups.append(self._single(nd))
                st.claimed.add(nd.out)
        # Cross-branch fusions (AR+A2A pairs) can deadlock each other at
        # the group level even though each pair is node-independent: two
        # pairs may each consume a value the other produces.  Dissolve
        # fused groups until the group graph is acyclic — unfused
        # lowering is always legal, just less fused.
        while True:
            cyclic = self._find_cycle_member(groups)
            if cyclic is None:
                break
            groups = [g for g in groups if g is not cyclic] \
                + [self._single(nd) for nd in cyclic.nodes]
        return self._topo(groups)

    @staticmethod
    def _find_cycle_member(groups: list[StageIR]) -> Optional[StageIR]:
        """A multi-node group participating in a group-graph cycle, or
        None if the group graph is already acyclic (Kahn's algorithm)."""
        produced_by = {v: g for g in groups for v in g.out_vids}
        succs: dict[int, list[StageIR]] = {id(g): [] for g in groups}
        indeg = {id(g): 0 for g in groups}
        for g in groups:
            for v in g.in_vids:
                dep = produced_by.get(v)
                if dep is not None and dep is not g:
                    succs[id(dep)].append(g)
                    indeg[id(g)] += 1
        ready = [g for g in groups if indeg[id(g)] == 0]
        seen = 0
        while ready:
            g = ready.pop()
            seen += 1
            for s in succs[id(g)]:
                indeg[id(s)] -= 1
                if indeg[id(s)] == 0:
                    ready.append(s)
        if seen == len(groups):
            return None
        for g in groups:
            if indeg[id(g)] > 0 and len(g.nodes) > 1:
                return g
        raise AssertionError("cycle among single-node groups — invalid DAG")

    @staticmethod
    def _single(nd: DagNode) -> StageIR:
        if nd.op.kind == OpKind.REDUCE and nd.op.ef is not None:
            # lone error-feedback reduce (its DELIVERED sibling was DCE'd)
            return StageIR("ef_allreduce", (nd,), nd.inputs, (nd.out,),
                           axis=_stage_axis(nd))
        kind = _SINGLE_KINDS.get(nd.op.kind)
        if kind is None:
            raise ValueError(f"cannot lower node {nd.op}")
        return StageIR(kind, (nd,), nd.inputs, (nd.out,),
                       axis=_stage_axis(nd))

    @staticmethod
    def _topo(groups: list[StageIR]) -> list[StageIR]:
        """Order groups so every consumed value is produced first (a
        cross-branch fusion like AR+A2A can capture a node defined after
        another group's root)."""
        produced_by = {v: g for g in groups for v in g.out_vids}
        ordered: list[StageIR] = []
        emitted: set[int] = set()

        def visit(g: StageIR):
            if id(g) in emitted:
                return
            emitted.add(id(g))
            for v in g.in_vids:
                dep = produced_by.get(v)
                if dep is not None:
                    visit(dep)
            ordered.append(g)

        for g in groups:
            visit(g)
        return ordered


# ---------------------------------------------------------------------------
# Pass 4: SelectSchedule — latency- vs bandwidth-optimal rings
# ---------------------------------------------------------------------------

_RESCHEDULABLE = {"allreduce", "map+allreduce"}


class SelectSchedule:
    """Annotate all-reduce stages with the ring schedule to emit.

    Per-rank payload bytes are propagated from ``ctx.in_avals`` through the
    DAG; a stage whose payload is below ``CollectiveConfig.
    latency_optimal_below`` gets the (n-1)-hop full-message latency ring,
    larger ones the chunked RS∘AG bandwidth ring.  The analytic model in
    :mod:`repro.core.netmodel` supplies predicted times (recorded in the
    stage desc) and the crossover when no explicit threshold is
    configured — both evaluated against the link tier of the *stage's own
    axis* (fast intra-pod ICI vs thin inter-pod DCI), so an outer-axis
    stage is costed on the wire it actually traverses.
    """

    name = "select_schedule"

    def run(self, groups: list[StageIR],
            ctx: CompileContext) -> list[StageIR]:
        nbytes = self._value_bytes(ctx)
        out: list[StageIR] = []
        for g in groups:
            if g.kind not in _RESCHEDULABLE:
                out.append(g)
                continue
            red = next(nd for nd in g.nodes
                       if nd.op.kind in (OpKind.REDUCE,
                                         OpKind.REDUCE_SCATTER))
            if red.op.codec.combine_encoded is not None:
                # the encoded-domain combine only exists as the chunked
                # RS∘AG walk — there is no latency-ring variant to pick
                out.append(dataclasses.replace(
                    g, schedule="bandwidth",
                    desc=f"encoded-domain ({red.op.codec.name}) RS∘AG walk "
                         "(fixed schedule)"))
                continue
            b = nbytes.get(g.in_vids[0]) if nbytes is not None else None
            if b is not None:
                # what actually travels: the sunk codec shrinks the wire
                b = int(b * red.op.codec.wire_ratio)
            out.append(dataclasses.replace(
                g, bytes_in=b,
                **self._decide(b, ctx, g.axis or ctx.axis_name)))
        return out

    def _decide(self, payload: Optional[int], ctx: CompileContext,
                axis: str) -> dict:
        if payload is None:
            return {"schedule": "bandwidth",
                    "desc": "RS∘AG ring (payload unknown; "
                            "bandwidth-optimal default)"}
        n = ctx.size_of(axis)
        if n is None:
            # never cost one axis with another's ring size — without this
            # axis's size the model has nothing to say
            return {"schedule": "bandwidth",
                    "desc": f"[{axis}] RS∘AG ring (axis size unknown; "
                            "bandwidth-optimal default)"}
        net = ctx.net_of(axis)
        threshold = ctx.latency_optimal_below
        if threshold is None:
            threshold = netmodel.ring_crossover_bytes(n, net)
        t_lat = netmodel.ring_allreduce_time(n, payload, net,
                                             latency_optimal=True)
        t_bw = netmodel.ring_allreduce_time(n, payload, net,
                                            latency_optimal=False)
        sched = "latency" if payload < threshold else "bandwidth"
        return {"schedule": sched,
                "desc": f"[{axis}] {payload}B/rank vs threshold "
                        f"{threshold}B → {sched}-optimal ring "
                        f"(model: lat {t_lat * 1e6:.1f}us, "
                        f"bw {t_bw * 1e6:.1f}us)"}

    @staticmethod
    def _value_bytes(ctx: CompileContext) -> Optional[dict[int, int]]:
        """Per-rank payload bytes for every DAG value, or None if unknown.

        A multi-input MAP is sized as the max over its *known* input
        sizes, and stays unknown when none are known — sizing it from
        ``inputs[0]`` alone would let a small first operand mis-drive the
        latency/bandwidth decision downstream.  AG/RS scale by the size of
        their own axis (unknown axis size → unknown output).
        """
        if ctx.in_avals is None:
            return None
        nbytes: dict[int, int] = {}
        for i, aval in enumerate(ctx.in_avals):
            size = int(math.prod(aval.shape)) if aval.shape else 1
            nbytes[i] = size * jnp.dtype(aval.dtype).itemsize
        for nd in ctx.dag.nodes:
            k = nd.op.kind
            if k == OpKind.MAP:
                known = [nbytes[v] for v in nd.inputs if v in nbytes]
                if known:
                    nbytes[nd.out] = max(known)
                continue
            src = nbytes.get(nd.inputs[0])
            if src is None:
                continue
            if k == OpKind.ALLGATHER:
                n = SelectSchedule._axis_size(nd, ctx)
                if n is not None:
                    nbytes[nd.out] = src * n
            elif k == OpKind.REDUCE_SCATTER:
                n = SelectSchedule._axis_size(nd, ctx)
                if n is not None:
                    nbytes[nd.out] = max(src // n, 1)
            else:                       # REDUCE/A2A/SCAN/BCAST/DELIVERED
                nbytes[nd.out] = src    # (WIRE nodes are gone by Legalize)
        return nbytes

    @staticmethod
    def _axis_size(nd: DagNode, ctx: CompileContext) -> Optional[int]:
        """Size of the axis this node communicates over; axis=None means
        the program default (a pipeline without LowerTopology)."""
        ax = nd.op.axis
        if ax is None:
            ax = ctx.axis_name
        if not isinstance(ax, str) or ax == AUTO_AXIS:
            return None
        return ctx.size_of(ax)


# ---------------------------------------------------------------------------
# Pass 5: PlaceCGRA — map stage compute bodies onto the switch grid
# ---------------------------------------------------------------------------

class PlaceCGRA:
    """Attach a CGRA placement (or explicit host fallback) to every stage.

    Runs after SelectSchedule: the ring choice is made, the payloads are
    known, and this pass decides whether the in-switch rate the model
    assumed is *earned* — re-costing the stage with the placement-derived
    throughput (or the PCIe + MPI host detour) in the stage desc.  The
    heavy lifting lives in :mod:`repro.cgra.mapper`; the import is
    deferred so neither package needs the other at import time.
    """

    name = "place_cgra"

    def __init__(self, device=None):
        self.device = device

    def run(self, groups: list, ctx: "CompileContext") -> list:
        from repro.cgra import mapper

        return mapper.place_groups(groups, ctx, self.device)


# ---------------------------------------------------------------------------
# Pass 6: Emit
# ---------------------------------------------------------------------------

class Emit:
    """Lower every StageIR to a rank-local callable."""

    name = "emit"

    def run(self, groups: list[StageIR], ctx: CompileContext) -> list[Stage]:
        return [self._emit(g, ctx) for g in groups]

    def _emit(self, g: StageIR, ctx: CompileContext) -> Stage:
        run = getattr(self, "_" + g.kind.replace("+", "_"))(g)
        axis = g.axis
        if not axis:
            coll = [nd.op for nd in g.nodes
                    if nd.op.kind in COLLECTIVE_KINDS]
            if any(op.axis is not None for op in coll):
                # "auto"/tuple survived to Emit — running it over the
                # default axis would silently compute the wrong reduction
                raise ValueError(
                    f"stage {g.kind} has an unresolved compound axis "
                    f"{[op.axis for op in coll]}; include LowerTopology "
                    "in the pipeline")
            if coll:
                # a custom pipeline without LowerTopology leaves axis=None
                # ops unresolved — fall back to the program-wide default
                # axis (pure-map stages legitimately stay axis-less)
                axis = ctx.axis_name
        return Stage(g.kind, run, g.desc, g.in_vids, g.out_vids, g.schedule,
                     axis, g.placement, g)

    # -- fused stages --------------------------------------------------------

    @staticmethod
    def _scan_allgather(g: StageIR):
        scan_op = g.nodes[1].op

        def run(args, ax, _m=scan_op.monoid, _ex=scan_op.exclusive):
            (x,) = args
            if _m.name == "add" and not _ex:
                return (fused.allgather_op_allgather(x, ax),)
            return (fused.scan_then_allgather(x, ax, _m, exclusive=_ex),)
        return run

    @staticmethod
    def _allreduce_alltoall(g: StageIR):
        def run(args, ax):
            hist, keys = args
            return fused.fused_allreduce_alltoall(hist, keys, ax)
        return run

    @staticmethod
    def _map_allreduce(g: StageIR):
        mp, red = g.nodes[0].op, g.nodes[1].op
        lat = g.schedule == "latency"

        def run(args, ax, _f=mp.fn, _m=red.monoid, _c=red.codec, _l=lat):
            (x,) = args
            return (collectives.all_reduce(_f(x), ax, _m, codec=_c,
                                           latency_optimal=_l),)
        return run

    @staticmethod
    def _map_reduce_scatter(g: StageIR):
        mp, rs = g.nodes[0].op, g.nodes[1].op

        def run(args, ax, _f=mp.fn, _m=rs.monoid, _c=rs.codec):
            (x,) = args
            return (fused.map_reduce_scatter(x, ax, _f, _m, codec=_c),)
        return run

    @staticmethod
    def _allgather_map(g: StageIR):
        mp = g.nodes[1].op

        def run(args, ax, _f=mp.fn):
            (x,) = args
            return (fused.allgather_map(x, ax, _f),)
        return run

    @staticmethod
    def _ef_allreduce(g: StageIR):
        """Error-feedback compressed all-reduce (Type 3 look-aside): one
        compression yields both the lossy total and, when the DELIVERED
        sibling survived DCE, this rank's delivered contribution."""
        ef = g.nodes[0].op.ef
        both = len(g.out_vids) == 2

        def run(args, ax, _c=ef.compressor, _k=ef.topk_ratio, _b=both):
            (t,) = args
            total, delivered = lookaside.compressed_all_reduce(
                t, ax, compressor=_c, topk_ratio=_k)
            return (total, delivered) if _b else (total,)
        return run

    @staticmethod
    def _delivered(g: StageIR):
        # standalone DELIVERED (its reduce was DCE'd) — rare; reuse the
        # full look-aside op and keep only the local-feedback half
        ef = g.nodes[0].op.ef

        def run(args, ax, _c=ef.compressor, _k=ef.topk_ratio):
            (t,) = args
            return (lookaside.compressed_all_reduce(
                t, ax, compressor=_c, topk_ratio=_k)[1],)
        return run

    # -- single-node lowerings ----------------------------------------------

    @staticmethod
    def _map(g: StageIR):
        op = g.nodes[0].op

        def run(args, ax, _f=op.fn):
            return (_f(*args),)
        return run

    @staticmethod
    def _allreduce(g: StageIR):
        op = g.nodes[-1].op if g.nodes[-1].op.kind == OpKind.REDUCE \
            else g.nodes[0].op           # RS∘AG group: monoid/codec on RS
        lat = g.schedule == "latency"

        def run(args, ax, _m=op.monoid, _c=op.codec, _l=lat):
            (x,) = args
            return (collectives.all_reduce(x, ax, _m, codec=_c,
                                           latency_optimal=_l),)
        return run

    @staticmethod
    def _reduce_scatter(g: StageIR):
        op = g.nodes[0].op

        def run(args, ax, _m=op.monoid, _c=op.codec):
            (x,) = args
            return (collectives.reduce_scatter(x, ax, _m, codec=_c),)
        return run

    @staticmethod
    def _allgather(g: StageIR):
        def run(args, ax):
            (x,) = args
            return (collectives.all_gather(x, ax),)
        return run

    @staticmethod
    def _alltoall(g: StageIR):
        def run(args, ax):
            (x,) = args
            return (collectives.all_to_all(x, ax),)
        return run

    @staticmethod
    def _scan(g: StageIR):
        op = g.nodes[0].op

        def run(args, ax, _m=op.monoid, _e=op.exclusive):
            (x,) = args
            return (collectives.prefix_scan(x, ax, _m, exclusive=_e),)
        return run

    @staticmethod
    def _bcast(g: StageIR):
        op = g.nodes[0].op

        def run(args, ax, _r=op.root):
            (x,) = args
            return (collectives.broadcast(x, ax, _r),)
        return run


# ---------------------------------------------------------------------------
# The pipeline & public entry points
# ---------------------------------------------------------------------------

DEFAULT_PIPELINE = (Legalize(), LowerTopology(), FuseHops(),
                    SelectSchedule(), PlaceCGRA(), Emit())


def run_pipeline(dag: DagProgram, ctx: CompileContext,
                 pipeline=DEFAULT_PIPELINE):
    ctx.dag = dag                       # Legalize may rewrite; keep current
    unit: Any = dag
    for p in pipeline:
        unit = p.run(unit, ctx)
        if isinstance(unit, DagProgram):
            ctx.dag = unit
    return unit, ctx.dag


def compile_rank_local(
    prog: ProgramLike,
    axis_name: str,
    *,
    axis_size: Optional[int] = None,
    config: Any = None,
    in_avals: Optional[Sequence[Any]] = None,
    topology: Optional[Topology] = None,
    pipeline=DEFAULT_PIPELINE,
) -> CompiledProgram:
    """Compile to a rank-local callable (for use inside an existing
    shard_map region, e.g. embedded in a train step).

    ``prog`` may be a traced :class:`DagProgram`, a legacy chain
    :class:`SwitchProgram`, or a plain function (traced on the fly).
    ``axis_name`` is the default axis for ops that don't name one;
    ``topology`` describes all DP axes (it defaults to the single
    ``axis_name`` axis) and drives the LowerTopology pass.
    """
    dag = _as_dag(prog)
    if topology is None:
        topology = Topology.single(axis_name, axis_size)
    ctx = CompileContext(axis_name=axis_name, axis_size=axis_size,
                         config=config, in_avals=in_avals,
                         topology=topology)
    stages, final_dag = run_pipeline(dag, ctx, pipeline)
    return CompiledProgram(stages, final_dag)


def compile_program(
    prog: ProgramLike,
    mesh: jax.sharding.Mesh,
    axis_name: str,
    in_specs,
    out_specs,
    *,
    jit: bool = True,
    config: Any = None,
    in_avals: Optional[Sequence[Any]] = None,
    topology: Optional[Topology] = None,
) -> Callable:
    """Emit the full "CGRA binary": one shard_map-wrapped, jitted callable
    executing every fused stage in a single SPMD program (stages may span
    several mesh axes — each runs over its own)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    axis_size = sizes[axis_name]
    if topology is not None:
        topology = topology.with_sizes(sizes)
    compiled = compile_rank_local(prog, axis_name, axis_size=axis_size,
                                  config=config, in_avals=in_avals,
                                  topology=topology)

    def run(*xs):
        return compiled(*xs)

    fn = jax.shard_map(run, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    out = jax.jit(fn) if jit else fn
    out.stages = compiled.stage_kinds()        # type: ignore[attr-defined]
    out.schedules = compiled.stage_schedules()  # type: ignore[attr-defined]
    out.axes = compiled.stage_axes()           # type: ignore[attr-defined]
    out.compiled = compiled                    # type: ignore[attr-defined]
    return out
