"""repro.core — the ACiS in-network computing engine (paper's contribution).

Layering:
  types       taxonomy + monoids (Type 1/2 algebra)
  ring        ppermute schedules with per-hop compute (the "switch fabric")
  wire        on-wire codecs (Type 0 streams, Type 2 wire dtypes)
  collectives Type 1/2 public collectives, backend = xla | acis
  compression top-k / int8 / low-rank wire datatypes
  lookaside   Type 3 stateful ops (error feedback, PowerSGD, scan, GCN)
  fused       Type 4 fused collectives (+ collective matmul)
  program     DAG IR (DagProgram) + the legacy SwitchProgram chain shim
  tracing     traced frontend: write programs as plain Python functions
              over symbolic Values (trace / map / reduce / all_gather / …)
  compiler    pass pipeline — Legalize (DCE, wire sinking) → FuseHops
              (first-class fusion patterns) → SelectSchedule (latency- vs
              bandwidth-optimal rings via CollectiveConfig.
              latency_optimal_below + the netmodel cost model) → Emit
              (one shard_map program, the "CGRA binary")
  netmodel    analytic network emulator (paper Table II) — feeds both the
              benchmark figures and the SelectSchedule cost model
  topology    hierarchical multi-pod schedules + straggler masking
  switchops   SPU instruction registry (jnp refs + Pallas kernels)
  api         CollectiveEngine — the MPI-transparency layer;
              engine.compile(fn_or_program, ...) is the one entry point

Quick taste of the traced API (usually imported as ``acis``)::

    from repro import core as acis

    def fem(x):
        return acis.all_gather(acis.scan(acis.all_gather(x)))

    fn = acis.make_engine("acis").compile(fem, mesh, P("data"), P(None))
"""

from repro.core.types import (ADD, MAX, MIN, PROD, AcisType, Monoid,
                              TYPE1_MONOIDS, tree_monoid)
from repro.core.api import (BACKENDS, CollectiveConfig, CollectiveEngine,
                            make_engine)
from repro.core.program import (AllGather, AllToAll, Bcast, DagNode,
                                DagProgram, Map, Node, Reduce, ReduceScatter,
                                Scan, SwitchProgram, Wire)
from repro.core.compiler import (CompiledProgram, Stage,
                                 compile_program, compile_rank_local)
from repro.core.tracing import (Value, all_gather, all_to_all, bcast,
                                reduce, reduce_scatter, scan, trace, wire)
from repro.core.tracing import map  # noqa: A004  (traced op, by design)

__all__ = [
    "ADD", "MAX", "MIN", "PROD", "AcisType", "Monoid", "TYPE1_MONOIDS",
    "tree_monoid", "BACKENDS", "CollectiveConfig", "CollectiveEngine",
    "make_engine", "AllGather", "AllToAll", "Bcast", "Map", "Node", "Reduce",
    "ReduceScatter", "Scan", "SwitchProgram", "Wire", "DagNode", "DagProgram",
    "CompiledProgram", "Stage", "compile_program", "compile_rank_local",
    "Value", "trace", "map", "reduce", "reduce_scatter", "all_gather",
    "all_to_all", "scan", "bcast", "wire",
]
