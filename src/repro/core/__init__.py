"""repro.core — the ACiS in-network computing engine (paper's contribution).

Layering:
  types       taxonomy + monoids (Type 1/2 algebra)
  ring        ppermute schedules with per-hop compute (the "switch fabric")
  wire        on-wire codecs (Type 0 streams, Type 2 wire dtypes)
  collectives Type 1/2 public collectives, backend = xla | acis
  compression top-k / int8 / low-rank wire datatypes
  lookaside   Type 3 stateful ops (error feedback, PowerSGD, scan, GCN)
  fused       Type 4 fused collectives (+ collective matmul)
  program     SwitchProgram IR (the S2S translator front-end analogue)
  compiler    fusion compiler emitting one shard_map program (CGRA binary)
  topology    hierarchical multi-pod schedules + straggler masking
  switchops   SPU instruction registry (jnp refs + Pallas kernels)
  api         CollectiveEngine — the MPI-transparency layer
"""

from repro.core.types import (ADD, MAX, MIN, PROD, AcisType, Monoid,
                              TYPE1_MONOIDS, tree_monoid)
from repro.core.api import (BACKENDS, CollectiveConfig, CollectiveEngine,
                            make_engine)
from repro.core.program import (AllGather, AllToAll, Bcast, Map, Node,
                                Reduce, ReduceScatter, Scan, SwitchProgram,
                                Wire)
from repro.core.compiler import compile_program, compile_rank_local

__all__ = [
    "ADD", "MAX", "MIN", "PROD", "AcisType", "Monoid", "TYPE1_MONOIDS",
    "tree_monoid", "BACKENDS", "CollectiveConfig", "CollectiveEngine",
    "make_engine", "AllGather", "AllToAll", "Bcast", "Map", "Node", "Reduce",
    "ReduceScatter", "Scan", "SwitchProgram", "Wire", "compile_program",
    "compile_rank_local",
]
