"""repro.core — the ACiS in-network computing engine (paper's contribution).

Layering:
  types       taxonomy + monoids (Type 1/2 algebra)
  ring        ppermute schedules with per-hop compute (the "switch fabric")
  wire        on-wire codecs (Type 0 streams, Type 2 wire dtypes)
  collectives Type 1/2 public collectives, backend = xla | acis
  compression top-k / int8 / low-rank wire datatypes
  lookaside   Type 3 stateful ops (error feedback, PowerSGD, scan, GCN)
  fused       Type 4 fused collectives (+ collective matmul)
  program     DAG IR (DagProgram) + the legacy SwitchProgram chain shim;
              every collective op carries an ``axis`` (None = engine
              default, "auto" = all DP axes, tuple = compound)
  tracing     traced frontend: write programs as plain Python functions
              over symbolic Values (trace / map / reduce(axis=…) /
              all_gather / ef_reduce / …)
  compiler    pass pipeline — Legalize (DCE, wire sinking) →
              LowerTopology (resolve axes against the compile Topology;
              rewrite a compound/"auto" reduce into RS(inner) →
              AR(outer, coded) → AG(inner), the codec on the thin outer
              hop only) → Coalesce (bucket per-leaf reductions into
              flat-buffer bucket collectives, sized from the netmodel
              crossover) → FuseHops (first-class same-axis fusion
              patterns) → SelectSchedule (latency- vs bandwidth-optimal
              rings via CollectiveConfig.latency_optimal_below + the
              netmodel cost model, per the link tier each stage actually
              traverses) → Emit (one shard_map program, the "CGRA
              binary"; each stage runs over its own axis, scheduled by
              an explicit ExecutionPlan of dependency waves)
  executor    ExecutionPlan IR: per-stage dependency edges + concurrent
              waves — what CompiledProgram runs, netmodel.program_time
              costs, and the dataplane simulator overlaps
  netmodel    analytic network emulator (paper Table II), two link tiers
              (fast intra-pod ICI, ~10× thinner inter-pod DCI) — feeds
              the benchmark figures, the SelectSchedule cost model, and
              program_time (plan critical path with per-tier overlap)
  topology    hierarchical multi-pod sync (thin wrapper over the compiled
              pipeline) + straggler masking
  switchops   SPU instruction registry (jnp refs + Pallas kernels)
  api         CollectiveEngine — the MPI-transparency layer;
              engine.compile(fn_or_program, ...) is the one entry point;
              gradient_sync is itself a compiled switch program
              (reduce over axis="auto" + error-feedback state), cached
              per pytree structure

Quick taste of the traced API (usually imported as ``acis``)::

    from repro import core as acis

    def fem(x):
        return acis.all_gather(acis.scan(acis.all_gather(x)))

    fn = acis.make_engine("acis").compile(fem, mesh, P("data"), P(None))

    # multi-pod: one reduce over every DP axis — the compiler emits the
    # hierarchical schedule and compresses only the thin inter-pod hop
    eng = acis.make_engine("acis_hierarchical_compressed", outer_axis="pod")
    sync = eng.compile(lambda g: acis.reduce(g, axis="auto"), ...)
"""

from repro.core.types import (ADD, MAX, MIN, PROD, AcisType, Monoid,
                              TYPE1_MONOIDS, tree_monoid)
from repro.core.api import (BACKENDS, CollectiveConfig, CollectiveEngine,
                            RecompileReport, make_engine)
from repro.core.program import (AllGather, AllToAll, Bcast, DagNode,
                                DagProgram, ErrorFeedback, Map, Node, Reduce,
                                ReduceScatter, Scan, SwitchProgram, Wire)
from repro.core.compiler import (AxisSpec, CompiledProgram, Stage, Topology,
                                 compile_program, compile_rank_local)
from repro.core.executor import ExecutionPlan, build_plan
from repro.core.tracing import (Value, all_gather, all_to_all, bcast,
                                ef_reduce, masked_reduce, reduce,
                                reduce_scatter, scan, trace, wire)
from repro.core.tracing import map  # noqa: A004  (traced op, by design)

__all__ = [
    "ADD", "MAX", "MIN", "PROD", "AcisType", "Monoid", "TYPE1_MONOIDS",
    "tree_monoid", "BACKENDS", "CollectiveConfig", "CollectiveEngine",
    "RecompileReport",
    "make_engine", "AllGather", "AllToAll", "Bcast", "Map", "Node", "Reduce",
    "ReduceScatter", "Scan", "SwitchProgram", "Wire", "DagNode", "DagProgram",
    "ErrorFeedback", "AxisSpec", "Topology",
    "CompiledProgram", "Stage", "compile_program", "compile_rank_local",
    "ExecutionPlan", "build_plan",
    "Value", "trace", "map", "reduce", "reduce_scatter", "all_gather",
    "all_to_all", "scan", "bcast", "wire", "ef_reduce", "masked_reduce",
]
