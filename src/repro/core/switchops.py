"""SPU instruction registry — the per-hop vector op set of the "CGRA".

The paper's CGRA is a deep pipeline of SIMD Processing Units with wide
vector instructions (Fig. 2).  The registry below is that instruction set at
the JAX level: every op has a pure-jnp reference implementation, and the
compute-hot ones carry a Pallas TPU kernel (see src/repro/kernels) selected
by ``use_kernels=True``.  Collectives look combines up here, so adding a
user op (Type 2) is one `register()` call — the analogue of loading a new
CGRA binary into the switch.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SwitchOp:
    name: str
    ref: Callable          # pure-jnp reference (always available)
    kernel: Optional[Callable] = None  # Pallas-backed implementation

    def __call__(self, *args, use_kernel: bool = False, **kw):
        impl = self.kernel if (use_kernel and self.kernel is not None) else self.ref
        return impl(*args, **kw)


_REGISTRY: Dict[str, SwitchOp] = {}


def register(name: str, ref: Callable,
             kernel: Optional[Callable] = None) -> SwitchOp:
    op = SwitchOp(name, ref, kernel)
    _REGISTRY[name] = op
    return op


def get(name: str) -> SwitchOp:
    return _REGISTRY[name]


def names() -> list[str]:
    return sorted(_REGISTRY)


def attach_kernel(name: str, kernel: Callable) -> None:
    """Late-bind a Pallas kernel to an existing op (kernels import lazily
    so the registry never forces a Pallas dependency at import time)."""
    old = _REGISTRY[name]
    _REGISTRY[name] = SwitchOp(old.name, old.ref, kernel)


# -- the base instruction set -------------------------------------------------

register("add", lambda a, b: a + b)
register("max", jnp.maximum)
register("min", jnp.minimum)
register("mac", lambda acc, x, alpha=1.0: acc + alpha * x)
register("dot_accumulate", lambda acc, a, b: acc + a @ b)
register("prefix_sum", lambda x: jnp.cumsum(x, axis=0))
register("relu2", lambda x: jnp.square(jnp.maximum(x, 0)))


def _ref_scatter_accum(dense, idx, vals):
    return dense.at[idx].add(vals.astype(dense.dtype))


register("topk_accumulate", _ref_scatter_accum)


def _ref_pack_combine(arena, *parts, op=None):
    from repro.kernels import ref

    return ref.pack_combine(arena, *parts, op=op)


register("pack_combine", _ref_pack_combine)


def load_kernels() -> None:
    """Bind the Pallas kernels onto the registry (idempotent)."""
    from repro.kernels import ops as kops  # local import: keep core light

    attach_kernel("add", kops.combine_add)
    attach_kernel("max", kops.combine_max)
    attach_kernel("min", kops.combine_min)
    attach_kernel("mac", kops.combine_mac)
    attach_kernel("prefix_sum", kops.prefix_sum)
    attach_kernel("topk_accumulate", kops.topk_accumulate)
    attach_kernel("pack_combine", kops.pack_combine)
