"""repro — ACiS (complex processing in the switch fabric) on jax.

Importing the package installs the jax forward-compat shims (see
:mod:`repro._jax_compat`) so every submodule can use the current jax API
spelling regardless of the installed jax version.
"""

from repro import _jax_compat

_jax_compat.install()
