"""Batched serving engine: continuous batching with per-slot positions.

Fixed B decode slots; every slot carries its own position (the decode path
takes an int32 [B] index vector — cache writes are per-row scatters, masking
is per-row).  Finished sequences are immediately replaced from the request
queue; new prompts prefill *inside the running batch*: the new slot steps
through its prompt tokens while other slots keep generating — one jitted
decode program for everything, zero recompiles in steady state.

Two decode transports:

  * plain (default) — a bare ``jax.jit`` over ``model.decode_step``; the
    network is free (single host / GSPMD handles it).
  * compiled — pass ``collectives=`` a
    :class:`repro.serve.collectives.ServeCollectives`: decode runs
    rank-local under ``shard_map`` over the ``tp`` mesh with every
    per-layer all-reduce / MoE all-to-all a compiled switch program from
    the process-wide program cache.

Admission is SLO-aware when an :class:`SLOPolicy` is installed: requests
carry deadlines, the prefill-vs-decode cost of admitting is estimated
from measured tick times (falling back to the compiled prefill program's
analytic ``program_time``), and requests that cannot make their deadline
are rejected at admission instead of wasting slot ticks.

This driver is the host-side control loop; it is exercised by
tests/test_serving.py, tests/test_serve_collectives.py and
examples/serve_batched.py.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.obs import metrics as _obs

PyTree = Any


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [t] int32
    max_new_tokens: int = 16
    eos: Optional[int] = None
    # SLO deadline in seconds from submit to last token; None = best-effort
    deadline_s: Optional[float] = None
    # stamped by ServeEngine.submit (time.monotonic)
    t_submit: float = dataclasses.field(default=0.0, compare=False)


@dataclasses.dataclass
class Completion:
    rid: int
    prompt_len: int
    tokens: list[int]


@dataclasses.dataclass
class SLOPolicy:
    """Admission policy for deadline-carrying requests.

    ``decide`` returns one of

      * ``"admit"``  — take the request into the free slot
      * ``"reject"`` — it cannot make its deadline even if admitted now;
        drop it at admission (``serve.slo_rejected``) instead of burning
        decode ticks on a doomed sequence
      * ``"defer"``  — leave it queued this tick
        (``serve.admit_deferred``): too many slots are already
        prefilling, so admitting would stretch everyone's tick

    The per-tick cost estimate prefers the engine's measured tick times
    (p50 over a sliding window); before any tick has run it falls back
    to the analytic ``program_time`` of the compiled decode/prefill
    programs — the prefill-vs-decode decision the compiled path makes
    possible.

    Deadline checks run BEFORE the prefill-cap defer: a request whose
    deadline already expired (or provably cannot be met) is rejected
    even when the cap would defer it — the old order left an expired
    request parked at the queue head, silently re-deferred every tick.

    ``membership`` (a :class:`repro.elastic.Membership`) makes the
    estimate fault-aware: with ranks masked out, the compiled decode
    collectives run on a degraded fabric, so the tick estimate inflates
    by ``n_ranks / n_alive`` — deadlines that only fit a healthy fabric
    reject at admission instead of timing out mid-decode.
    """

    # admit at most this many concurrently-prefilling slots (None = no cap)
    max_concurrent_prefills: Optional[int] = None
    # safety factor on the completion-time estimate (>1 rejects earlier)
    slack: float = 1.0
    # elastic membership view; masked ranks inflate the tick estimate
    membership: Optional[Any] = None

    def _degrade_factor(self) -> float:
        m = self.membership
        if m is None:
            return 1.0
        n = getattr(m, "n_ranks", 0)
        a = getattr(m, "n_alive", n)
        if not n:
            return 1.0
        return float("inf") if a == 0 else n / a

    def decide(self, req: Request, engine: "ServeEngine",
               n_prefilling: int) -> str:
        if req.deadline_s is not None:
            waited = time.monotonic() - req.t_submit
            if waited >= req.deadline_s:
                return "reject"       # expired while queued/deferred
            tick = engine.tick_time_estimate()
            if tick is not None:
                tick = tick * self._degrade_factor()
                # in-batch prefill pays one tick per prompt token; a
                # dedicated batched prefill pass can never beat its
                # compiled program's analytic switch time, so the
                # estimate is the max of the two
                ttft = len(req.prompt) * tick
                sc = engine.collectives
                if sc is not None:
                    ttft = max(ttft, sc.prefill_comm_time(
                        engine.slots, max(len(req.prompt), 1)))
                est = waited + ttft + req.max_new_tokens * tick
                if est * self.slack > req.deadline_s:
                    return "reject"
        if self.max_concurrent_prefills is not None \
                and n_prefilling >= self.max_concurrent_prefills:
            return "defer"
        return "admit"


class ServeEngine:
    def __init__(self, model: Model, params: PyTree, *, slots: int = 4,
                 max_seq: int = 256, recorder: Optional[_obs.Recorder] = None,
                 collectives=None, admission: Optional[SLOPolicy] = None):
        self.model = model
        self.params = params
        # per-engine recorder; defaults to the process-wide one at call
        # time (so ``obs.recording()`` around a serving loop just works)
        self.recorder = recorder
        self.slots = slots
        self.max_seq = max_seq
        self.collectives = collectives
        self.admission = admission
        self.cache = model.init_cache(slots, max_seq)

        # host-side slot state
        self.rid = np.full(slots, -1, np.int64)
        self.pos = np.zeros(slots, np.int32)          # next write position
        self.remaining = np.zeros(slots, np.int32)
        self.eos = np.full(slots, -1, np.int64)
        self.prompt: list[Optional[np.ndarray]] = [None] * slots
        self.prompt_cursor = np.zeros(slots, np.int32)
        self.deadline = np.full(slots, np.inf)
        self.t_submit = np.zeros(slots)
        self.generated: list[list[int]] = [[] for _ in range(slots)]
        self.queue: collections.deque[Request] = collections.deque()
        self.done: list[Completion] = []
        self.rejected: list[Request] = []
        self.ticks = 0
        # per-tick wall times (measured; the decode host sync makes every
        # tick a natural timing boundary) -> p50/p99 gauges + admission
        self._tick_times: collections.deque[float] = collections.deque(
            maxlen=256)

        # the KV cache is persistent, step-threaded state exactly like the
        # train path's bucket arenas: donate it so every decode tick's
        # cache writes alias the previous buffers instead of allocating a
        # full cache copy per token (the engine always rebinds
        # ``self.cache`` to the returned cache, so the donated input is
        # never reused)
        if collectives is not None:
            self._decode = collectives.decode_fn(params, self.cache)
        else:
            self._decode = jax.jit(
                lambda p, tok, cache, idx: model.decode_step(
                    p, tok, cache, idx),
                donate_argnums=(2,))

    def submit(self, req: Request):
        assert len(req.prompt) + req.max_new_tokens < self.max_seq
        req.t_submit = time.monotonic()
        self.queue.append(req)

    def tick_time_estimate(self) -> Optional[float]:
        """Seconds per engine tick: measured p50 when ticks have run,
        else the compiled decode programs' analytic switch time, else
        None (plain transport, nothing measured yet)."""
        if self._tick_times:
            return float(np.median(self._tick_times))
        if self.collectives is not None:
            return self.collectives.decode_comm_time(self.slots)
        return None

    # -- slot management -------------------------------------------------------

    def _reset_slot_caches(self, slot_ids: list[int]):
        """Zero the cache rows of every slot admitted this tick in ONE
        tree traversal (a full ``jax.tree.map`` per slot was O(admits ×
        leaves) dispatches per tick)."""
        idx = jnp.asarray(np.asarray(slot_ids, np.int32))

        def reset(leaf):
            if leaf.ndim >= 1 and leaf.shape[0] == self.slots:
                fill = -1 if leaf.dtype == jnp.int32 and leaf.ndim == 2 \
                    else 0       # window 'pos' buffers use -1 = invalid
                return leaf.at[idx].set(fill)
            return leaf
        self.cache = jax.tree.map(reset, self.cache)

    def _admit(self, s: int, req: Request):
        """Host-side slot bookkeeping; the cache rows are cleared by the
        caller's batched :meth:`_reset_slot_caches`."""
        self.rid[s] = req.rid
        self.pos[s] = 0
        self.remaining[s] = req.max_new_tokens
        self.eos[s] = -1 if req.eos is None else req.eos
        self.prompt[s] = np.asarray(req.prompt, np.int32)
        self.prompt_cursor[s] = 0
        self.deadline[s] = np.inf if req.deadline_s is None else req.deadline_s
        self.t_submit[s] = req.t_submit
        self.generated[s] = []

    def _retire(self, s: int):
        self.done.append(Completion(int(self.rid[s]),
                                    len(self.prompt[s]),
                                    self.generated[s]))
        self.rid[s] = -1

    # -- one engine tick ---------------------------------------------------------

    def step(self) -> int:
        rec = self.recorder if self.recorder is not None else _obs.RECORDER
        rec.count("serve.ticks")
        rec.gauge("serve.queue_depth", len(self.queue))
        admitted_slots: list[int] = []
        n_prefilling = sum(
            1 for s in range(self.slots)
            if self.rid[s] >= 0
            and self.prompt_cursor[s] < len(self.prompt[s]))
        deferred = False
        for s in range(self.slots):
            if self.rid[s] >= 0 or deferred:
                continue
            while self.queue:
                req = self.queue[0]
                verdict = "admit" if self.admission is None else \
                    self.admission.decide(req, self, n_prefilling)
                if verdict == "reject":
                    self.queue.popleft()
                    self.rejected.append(req)
                    rec.count("serve.slo_rejected")
                    continue
                if verdict == "defer":
                    rec.count("serve.admit_deferred")
                    deferred = True
                    break
                self.queue.popleft()
                self._admit(s, req)
                admitted_slots.append(s)
                n_prefilling += 1
                break
        if admitted_slots:
            self._reset_slot_caches(admitted_slots)
            rec.count("serve.admitted", len(admitted_slots))
        active = np.flatnonzero(self.rid >= 0)
        rec.gauge("serve.active", int(active.size))
        if active.size == 0:
            return 0

        # token each active slot feeds this tick: next prompt token while
        # prefilling, else its last generated token
        tok = np.zeros(self.slots, np.int32)
        in_prefill = np.zeros(self.slots, bool)
        for s in active:
            cur = self.prompt_cursor[s]
            if cur < len(self.prompt[s]):
                tok[s] = self.prompt[s][cur]
                in_prefill[s] = True
            else:
                tok[s] = self.generated[s][-1] if self.generated[s] \
                    else self.prompt[s][-1]

        idx = jnp.asarray(self.pos)
        t0 = time.perf_counter()
        lg, self.cache = self._decode(self.params, jnp.asarray(tok),
                                      self.cache, idx)
        # the tick's ONE host sync: greedy sampling below needs the logits
        # on the host whether or not recording is on — an explicit
        # device->host block here, not a side effect of instrumentation
        lg = np.asarray(lg)
        dt = time.perf_counter() - t0
        self._tick_times.append(dt)
        if rec.enabled:
            rec.count("serve.host_sync")
            rec.observe("serve.decode_s", dt)
            order = sorted(self._tick_times)
            rec.gauge("serve.decode_p50_s", order[len(order) // 2])
            rec.gauge("serve.decode_p99_s",
                      order[min(len(order) - 1, int(len(order) * 0.99))])
            live = self.deadline[active]
            if np.isfinite(live).any():
                now = time.monotonic()
                headroom = (live - (now - self.t_submit[active]))
                rec.gauge("serve.deadline_headroom_s",
                          float(headroom[np.isfinite(live)].min()))
        self.ticks += 1

        retired = 0
        for s in active:
            self.pos[s] += 1
            if in_prefill[s]:
                self.prompt_cursor[s] += 1
                if self.prompt_cursor[s] < len(self.prompt[s]):
                    continue               # still prefilling
                # prompt finished: this tick's logits predict token 1
            nxt = int(lg[s].argmax())
            self.generated[s].append(nxt)
            self.remaining[s] -= 1
            if (self.remaining[s] <= 0 or nxt == self.eos[s]
                    or self.pos[s] >= self.max_seq - 1):
                self._retire(s)
                retired += 1
        if retired:
            rec.count("serve.retired", retired)
        return int(active.size)

    def run_to_completion(self, max_ticks: int = 100000) -> list[Completion]:
        for _ in range(max_ticks):
            if self.step() == 0 and not self.queue:
                break
        return sorted(self.done, key=lambda c: c.rid)
