"""Batched serving engine: continuous batching with per-slot positions.

Fixed B decode slots; every slot carries its own position (the decode path
takes an int32 [B] index vector — cache writes are per-row scatters, masking
is per-row).  Finished sequences are immediately replaced from the request
queue; new prompts prefill *inside the running batch*: the new slot steps
through its prompt tokens while other slots keep generating — one jitted
decode program for everything, zero recompiles in steady state.

On a real pod the decode program is SPMD over the mesh (cache sharded per
sharding/rules.py); this driver is the host-side control loop and is
exercised by tests/test_serving.py and examples/serve_batched.py.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.obs import metrics as _obs

PyTree = Any


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [t] int32
    max_new_tokens: int = 16
    eos: Optional[int] = None


@dataclasses.dataclass
class Completion:
    rid: int
    prompt_len: int
    tokens: list[int]


class ServeEngine:
    def __init__(self, model: Model, params: PyTree, *, slots: int = 4,
                 max_seq: int = 256, recorder: Optional[_obs.Recorder] = None):
        self.model = model
        self.params = params
        # per-engine recorder; defaults to the process-wide one at call
        # time (so ``obs.recording()`` around a serving loop just works)
        self.recorder = recorder
        self.slots = slots
        self.max_seq = max_seq
        self.cache = model.init_cache(slots, max_seq)

        # host-side slot state
        self.rid = np.full(slots, -1, np.int64)
        self.pos = np.zeros(slots, np.int32)          # next write position
        self.remaining = np.zeros(slots, np.int32)
        self.eos = np.full(slots, -1, np.int64)
        self.prompt: list[Optional[np.ndarray]] = [None] * slots
        self.prompt_cursor = np.zeros(slots, np.int32)
        self.generated: list[list[int]] = [[] for _ in range(slots)]
        self.queue: list[Request] = []
        self.done: list[Completion] = []
        self.ticks = 0

        # the KV cache is persistent, step-threaded state exactly like the
        # train path's bucket arenas: donate it so every decode tick's
        # cache writes alias the previous buffers instead of allocating a
        # full cache copy per token (the engine always rebinds
        # ``self.cache`` to the returned cache, so the donated input is
        # never reused)
        self._decode = jax.jit(
            lambda p, tok, cache, idx: model.decode_step(p, tok, cache, idx),
            donate_argnums=(2,))

    def submit(self, req: Request):
        assert len(req.prompt) + req.max_new_tokens < self.max_seq
        self.queue.append(req)

    # -- slot management -------------------------------------------------------

    def _reset_slot_cache(self, s: int):
        def reset(leaf):
            if leaf.ndim >= 1 and leaf.shape[0] == self.slots:
                fill = -1 if leaf.dtype == jnp.int32 and leaf.ndim == 2 \
                    else 0       # window 'pos' buffers use -1 = invalid
                return leaf.at[s].set(fill)
            return leaf
        self.cache = jax.tree.map(reset, self.cache)

    def _admit(self, s: int, req: Request):
        self._reset_slot_cache(s)
        self.rid[s] = req.rid
        self.pos[s] = 0
        self.remaining[s] = req.max_new_tokens
        self.eos[s] = -1 if req.eos is None else req.eos
        self.prompt[s] = np.asarray(req.prompt, np.int32)
        self.prompt_cursor[s] = 0
        self.generated[s] = []

    def _retire(self, s: int):
        self.done.append(Completion(int(self.rid[s]),
                                    len(self.prompt[s]),
                                    self.generated[s]))
        self.rid[s] = -1

    # -- one engine tick ---------------------------------------------------------

    def step(self) -> int:
        rec = self.recorder if self.recorder is not None else _obs.RECORDER
        rec.count("serve.ticks")
        admitted = 0
        for s in range(self.slots):
            if self.rid[s] < 0 and self.queue:
                self._admit(s, self.queue.pop(0))
                admitted += 1
        if admitted:
            rec.count("serve.admitted", admitted)
        active = np.flatnonzero(self.rid >= 0)
        rec.gauge("serve.active", int(active.size))
        if active.size == 0:
            return 0

        # token each active slot feeds this tick: next prompt token while
        # prefilling, else its last generated token
        tok = np.zeros(self.slots, np.int32)
        in_prefill = np.zeros(self.slots, bool)
        for s in active:
            cur = self.prompt_cursor[s]
            if cur < len(self.prompt[s]):
                tok[s] = self.prompt[s][cur]
                in_prefill[s] = True
            else:
                tok[s] = self.generated[s][-1] if self.generated[s] \
                    else self.prompt[s][-1]

        idx = jnp.asarray(self.pos)
        t0 = time.perf_counter() if rec.enabled else 0.0
        lg, self.cache = self._decode(self.params, jnp.asarray(tok),
                                      self.cache, idx)
        lg = np.asarray(lg)        # blocks on the decode result
        if rec.enabled:
            rec.observe("serve.decode_s", time.perf_counter() - t0)
        self.ticks += 1

        retired = 0
        for s in active:
            self.pos[s] += 1
            if in_prefill[s]:
                self.prompt_cursor[s] += 1
                if self.prompt_cursor[s] < len(self.prompt[s]):
                    continue               # still prefilling
                # prompt finished: this tick's logits predict token 1
            nxt = int(lg[s].argmax())
            self.generated[s].append(nxt)
            self.remaining[s] -= 1
            if (self.remaining[s] <= 0 or nxt == self.eos[s]
                    or self.pos[s] >= self.max_seq - 1):
                self._retire(s)
                retired += 1
        if retired:
            rec.count("serve.retired", retired)
        return int(active.size)

    def run_to_completion(self, max_ticks: int = 100000) -> list[Completion]:
        for _ in range(max_ticks):
            if self.step() == 0 and not self.queue:
                break
        return sorted(self.done, key=lambda c: c.rid)
