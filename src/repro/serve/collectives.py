"""Compiled serving data path — decode/prefill collectives as switch programs.

Tensor-parallel serving splits every layer's matmuls across a ``tp`` mesh
axis, which turns the decode hot loop into a *communication* loop: one
all-reduce of attention partials and one of FFN partials per layer, plus
the MoE group->expert all-to-all dispatch/combine.  This module expresses
those as traced :mod:`repro.core` programs compiled through
``engine.compile`` — the same Legalize → … → Emit pipeline (and the same
bucketing / batched-ring / Pallas-kernel / autotune machinery) the
training sync path uses — and installs them into the models via the
:class:`repro.models.parallel.TensorParallel` hook.

Three hook transports, selected by ``mode``:

  * ``xla``      — ``lax.psum`` / XLA all_to_all (passive-network baseline)
  * ``direct``   — per-op acis ring collectives, no compiler (the
                   "uncompiled" acis path the benchmark beats)
  * ``compiled`` — switch programs from :meth:`ServeCollectives.program`:
                   sub-crossover decode payloads get the log-step
                   latency-optimal schedule, the MoE combine all-to-all
                   fuses with the shared-expert all-reduce into one
                   Type-4 ``allreduce+alltoall`` stage (FuseHops), and
                   ``use_kernels`` / ``batch_rings`` / ``autotune`` apply
                   exactly as in training.

Programs are cached in a process-wide :class:`SwitchProgramCache` shared
by every engine replica — N replicas serving the same model compile each
decode-shape program once (``serve.program_cache_hit/miss`` counters).
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import collectives as C
from repro.core import tracing
from repro.core.api import CollectiveConfig, CollectiveEngine
from repro.core.types import ADD
from repro.models import moe as MOE
from repro.models import parallel as TP
from repro.models.config import ModelConfig
from repro.models.transformer import layer_schedule
from repro.obs import metrics as _obs
from repro.tune.search import plan_key

PyTree = Any


# ---------------------------------------------------------------------------
# the shared program cache
# ---------------------------------------------------------------------------

class SwitchProgramCache:
    """Process-wide compiled-program store shared across serving replicas.

    Keyed by a :func:`repro.tune.search.plan_key`-style hash of (program
    name, rank-local input avals, topology) plus the config's
    ``cache_key()`` — the same identity the tuning DB uses, so two
    replicas of the same model at the same batch shape share every
    program, while a replica running a tuned or kernel-enabled config
    compiles its own.  Hits and misses land on the process recorder
    (``serve.program_cache_hit`` / ``serve.program_cache_miss``).
    """

    def __init__(self):
        self._programs: dict = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get_or_build(self, key, build: Callable[[], Any]):
        with self._lock:
            hit = self._programs.get(key)
            if hit is not None:
                self.hits += 1
                _obs.RECORDER.count("serve.program_cache_hit")
                return hit
        # compile outside the lock (compiles can nest cache lookups via
        # autotune); last writer wins on a racing double-compile
        _obs.RECORDER.count("serve.program_cache_miss")
        prog = build()
        with self._lock:
            self._programs[key] = prog
            self.misses += 1
        return prog

    def __len__(self) -> int:
        return len(self._programs)

    def stats(self) -> dict:
        return {"programs": len(self._programs),
                "hits": self.hits, "misses": self.misses}

    def clear(self):
        with self._lock:
            self._programs.clear()
            self.hits = self.misses = 0


#: Default cache — every :class:`ServeCollectives` that is not handed an
#: explicit cache shares this one, so replicas co-located in a process
#: compile each program once.
PROGRAM_CACHE = SwitchProgramCache()


# ---------------------------------------------------------------------------
# hook transports
# ---------------------------------------------------------------------------

class _TPBase(TP.TensorParallel):
    """Shared dispatch/combine plumbing; subclasses supply the transport.

    MoE resharding with replicated tokens (serving keeps activations
    replicated across tp; only weights are sliced):

      dispatch: all ranks hold the identical slot tensor [E, S, D]; the
        all-to-all hands rank r the rows of *its* E/tp experts — chunk r
        of every peer's (identical) input — so we keep block 0 of the
        [tp, E/tp, ...] output.
      combine: rank r tiles its local expert outputs [E/tp, S, D] tp
        times so every destination receives them; the all-to-all output
        is then the full [E, S, D] in expert order on every rank.

    Both are pure data movement — bit-exact against the unhooked path.
    """

    def __init__(self, axis: str, tp: int):
        self.axis = axis
        self.tp = tp

    # transport primitives -------------------------------------------------
    def _all_reduce(self, x):
        raise NotImplementedError

    def _all_to_all(self, x):
        raise NotImplementedError

    def _fused_combine(self, shared, tiled):
        """(all_reduce(shared), all_to_all(tiled)) — overridden where the
        pair can fuse into one switch stage."""
        return self._all_reduce(shared), self._all_to_all(tiled)

    # the model-facing hook ------------------------------------------------
    def attn_reduce(self, h):
        return self._all_reduce(h)

    def ffn_reduce(self, f):
        return self._all_reduce(f)

    def moe_dispatch(self, xem):
        e = xem.shape[0]
        el = e // self.tp
        out = self._all_to_all(xem)
        return out.reshape((self.tp, el) + xem.shape[1:])[0]

    def moe_combine(self, yem, shared_partial=None):
        tiled = jnp.broadcast_to(
            yem[None], (self.tp,) + yem.shape).reshape(
                (self.tp * yem.shape[0],) + yem.shape[1:])
        if shared_partial is None:
            return self._all_to_all(tiled), None
        reduced, full = self._fused_combine(shared_partial, tiled)
        return full, reduced


class XlaTPHook(_TPBase):
    """Passive-network baseline: XLA built-ins."""

    def _all_reduce(self, x):
        return lax.psum(x, self.axis)

    def _all_to_all(self, x):
        return C.all_to_all(x, self.axis, backend="xla")


class DirectTPHook(_TPBase):
    """Per-op acis ring collectives — the uncompiled acis path.  Every
    call is its own bandwidth-optimal ring (2(n-1) hops); nothing is
    scheduled, fused, or batched.  The A/B baseline ``benchmarks/serve.py``
    measures the compiler against."""

    def _all_reduce(self, x):
        return C.all_reduce(x, self.axis, ADD, backend="acis")

    def _all_to_all(self, x):
        return C.all_to_all(x, self.axis, backend="acis")


class CompiledTPHook(_TPBase):
    """Switch programs from the shared cache, built on first use per
    rank-local aval (decode and prefill shapes get distinct programs)."""

    def __init__(self, sc: "ServeCollectives"):
        super().__init__(sc.axis, sc.tp)
        self.sc = sc

    @staticmethod
    def _aval(x):
        return jax.ShapeDtypeStruct(x.shape, x.dtype)

    def _all_reduce(self, x):
        prog = self.sc.program("serve_tp_allreduce",
                               self.sc._trace_allreduce, (self._aval(x),))
        return prog(x)[0]

    def _all_to_all(self, x):
        prog = self.sc.program("serve_moe_alltoall",
                               self.sc._trace_alltoall, (self._aval(x),))
        return prog(x)[0]

    def _fused_combine(self, shared, tiled):
        prog = self.sc.program(
            "serve_moe_combine", self.sc._trace_combine,
            (self._aval(shared), self._aval(tiled)))
        return prog(shared, tiled)


_MODES = ("compiled", "direct", "xla")


# ---------------------------------------------------------------------------
# ServeCollectives — sharding rules + program factory for one model config
# ---------------------------------------------------------------------------

class ServeCollectives:
    """Tensor-parallel serving plan for one :class:`ModelConfig`.

    Owns the ``tp`` mesh, the per-leaf parameter/cache
    :class:`PartitionSpec` rules, the rank-local decode wrapper
    (:meth:`decode_fn` — a drop-in for ``ServeEngine``'s jitted decode),
    and the switch-program factory backed by a shared
    :class:`SwitchProgramCache`.

    Supported families: ``dense`` and ``moe`` (GQA attention; MLA caches
    are 57× smaller and latent-projected — slicing them is a different
    PR).  ``tp`` must divide ``n_heads``, ``n_kv_heads``, every FFN
    hidden dim, and (moe) ``n_experts``.
    """

    def __init__(self, cfg: ModelConfig, tp: int, *, axis: str = "tp",
                 config: Optional[CollectiveConfig] = None,
                 cache: Optional[SwitchProgramCache] = None,
                 devices=None):
        if cfg.family not in ("dense", "moe"):
            raise NotImplementedError(
                f"tensor-parallel serving supports dense/moe stacks, "
                f"not family={cfg.family!r}")
        if cfg.family == "moe" and cfg.mla is not None:
            raise NotImplementedError("MLA cache slicing not supported")

        def div(what, n):
            if n % tp:
                raise ValueError(f"tp={tp} must divide {what}={n}")
        div("n_heads", cfg.n_heads)
        div("n_kv_heads", cfg.n_kv_heads)
        div("d_ff", cfg.d_ff)
        if cfg.family == "moe":
            div("moe.n_experts", cfg.moe.n_experts)
            div("moe.d_ff_dense", cfg.moe.d_ff_dense or cfg.d_ff)
            if cfg.moe.n_shared:
                div("moe.d_ff_shared", cfg.moe.d_ff_shared
                    or cfg.moe.n_shared * cfg.moe.d_ff_expert)

        self.cfg = cfg
        self.tp = tp
        self.axis = axis
        self.config = config if config is not None \
            else CollectiveConfig(backend="acis")
        if self.config.backend == "xla":
            raise ValueError("compiled serving needs an acis backend; "
                             "use mode='xla' for the XLA baseline")
        self.cache = cache if cache is not None else PROGRAM_CACHE
        self.engine = CollectiveEngine(self.config, inner_axis=axis)
        if devices is None:
            devices = jax.devices()[:tp]
        if len(devices) != tp:
            raise ValueError(f"need {tp} devices, got {len(devices)}")
        self.mesh = jax.sharding.Mesh(devices, (axis,))
        # rank-local view: each rank runs the same decode math over its
        # head/expert slice; head counts shrink, everything else (incl.
        # moe.n_experts — routing is replicated, expert compute reads the
        # sliced param shapes) stays the model's.
        self.cfg_local = dataclasses.replace(
            cfg, n_heads=cfg.n_heads // tp, n_kv_heads=cfg.n_kv_heads // tp,
            d_head=cfg.head_dim)   # pin: head_dim derives from n_heads

    # -- traced program bodies (named methods so benchmarks can reuse) ------

    def _trace_allreduce(self, v):
        return tracing.reduce(v, ADD, axis=self.axis)

    def _trace_alltoall(self, v):
        return tracing.all_to_all(v, axis=self.axis)

    def _trace_combine(self, s, t):
        # independent same-axis REDUCE + ALLTOALL: FuseHops merges them
        # into one Type-4 allreduce+alltoall stage
        return (tracing.reduce(s, ADD, axis=self.axis),
                tracing.all_to_all(t, axis=self.axis))

    # -- program factory ----------------------------------------------------

    def program(self, name: str, fn, avals: tuple):
        """Compiled switch program for ``fn`` at ``avals``, from the
        shared cache.  The key is the tune-DB :func:`plan_key` identity
        plus the full config ``cache_key()`` (tuned/kernel variants must
        not collide)."""
        topo = self.engine.topology(axis_size={self.axis: self.tp})
        key = (plan_key(name, avals, topo, self.config),
               self.config.cache_key())
        return self.cache.get_or_build(
            key, lambda: self.engine.compile(
                fn, in_avals=avals, axis_size={self.axis: self.tp}))

    def hook(self, mode: str = "compiled") -> _TPBase:
        if mode == "compiled":
            return CompiledTPHook(self)
        if mode == "direct":
            return DirectTPHook(self.axis, self.tp)
        if mode == "xla":
            return XlaTPHook(self.axis, self.tp)
        raise ValueError(f"mode {mode!r} not in {_MODES}")

    # -- per-leaf sharding rules -------------------------------------------

    def _param_spec(self, path, leaf) -> P:
        keys = [k.key for k in path
                if isinstance(k, jax.tree_util.DictKey)]
        name = keys[-1] if keys else ""
        nd = leaf.ndim
        ax = self.axis
        if "experts" in keys:
            # stacked expert weights [..., E, d_in, d_out]: slice E
            return P(*(None,) * (nd - 3), ax, None, None)
        if name in ("wq", "wk", "wv", "wi", "wi_gate", "wi_up"):
            return P(*(None,) * (nd - 1), ax)      # column (head/ff) slice
        if name == "wo":
            return P(*(None,) * (nd - 2), ax, None)  # row slice -> partials
        return P()      # norms, router, embed, lm_head, gates: replicated

    def _cache_spec(self, path, leaf) -> P:
        keys = [k.key for k in path
                if isinstance(k, jax.tree_util.DictKey)]
        name = keys[-1] if keys else ""
        if name in ("k", "v"):
            # [..., B, S, Hkv, dh]: slice the kv-head dim
            return P(*(None,) * (leaf.ndim - 2), self.axis, None)
        raise ValueError(f"unsupported cache leaf {'/'.join(keys)}")

    def param_specs(self, params: PyTree) -> PyTree:
        return jax.tree_util.tree_map_with_path(self._param_spec, params)

    def cache_specs(self, cache: PyTree) -> PyTree:
        return jax.tree_util.tree_map_with_path(self._cache_spec, cache)

    # -- the decode program -------------------------------------------------

    def decode_fn(self, params: PyTree, cache: PyTree, *,
                  mode: str = "compiled", donate: bool = True):
        """Jitted ``(params, token, cache, index) -> (logits, cache)``
        with the same contract as ``ServeEngine``'s plain decode: full
        (unsharded) trees in, full logits out — jit reshards per the TP
        specs at dispatch, the KV cache stays device-resident and
        donated across ticks.

        ``params``/``cache`` are exemplars for spec-tree construction
        only; any same-structure trees may be passed at call time.
        """
        from repro.models import decode as D

        hook = self.hook(mode)
        if mode == "compiled":
            # build the tick's programs eagerly (outside any trace): the
            # hook's trace-time lookups then hit the shared cache
            self.decode_programs(self._batch_of(cache))
        cfg_local = self.cfg_local
        pspecs = self.param_specs(params)
        cspecs = self.cache_specs(cache)

        def run(p, tok, c, idx):
            with TP.tensor_parallel(hook):
                return D.decode_step(p, cfg_local, tok, c, idx)

        fn = jax.shard_map(run, mesh=self.mesh,
                           in_specs=(pspecs, P(), cspecs, P()),
                           out_specs=(P(), cspecs), check_vma=False)
        return jax.jit(fn, donate_argnums=(2,) if donate else ())

    @staticmethod
    def _batch_of(cache: PyTree) -> int:
        leaf = jax.tree.leaves(cache)[0]
        # stacked layer caches are [P, B, S, H, dh]; unstacked [B, S, H, dh]
        return leaf.shape[1] if leaf.ndim >= 5 else leaf.shape[0]

    # -- analytic costs (SLO admission, benchmarks) -------------------------

    def decode_programs(self, batch: int) -> list[tuple[str, Any, int]]:
        """The switch programs one decode tick runs, as
        ``(name, CompiledProgram, calls-per-tick)`` — built (or fetched)
        from the shared cache with the exact avals the hook will use."""
        return self._tick_programs(batch, 1)

    def prefill_programs(self, batch: int, t: int):
        """Programs of one *batched* prefill pass over a [batch, t]
        prompt (the ``model.prefill`` formulation — ``ServeEngine``'s
        in-batch prefill instead pays ``t`` decode ticks)."""
        return self._tick_programs(batch, t)

    def _tick_programs(self, b: int, t: int):
        cfg = self.cfg
        dt = jnp.bfloat16
        d = cfg.d_model
        sds = jax.ShapeDtypeStruct
        counts: dict[str, list] = {}

        def add(name, fn, avals):
            prog = self.program(name, fn, avals)
            ent = counts.setdefault(name, [prog, 0])
            ent[1] += 1

        n_tok = b * t
        g = MOE._n_groups(n_tok)
        ng = n_tok // g
        for kind in layer_schedule(cfg):
            add("serve_tp_allreduce", self._trace_allreduce,
                (sds((b, t, d), dt),))               # attention partials
            if kind != "moe_self":
                add("serve_tp_allreduce", self._trace_allreduce,
                    (sds((b, t, d), dt),))           # dense-FFN partials
                continue
            m = cfg.moe
            cap = ng if t == 1 else max(
                1, int(ng * m.top_k * m.capacity_factor / m.n_experts))
            slot = (m.n_experts, g * cap, d)
            add("serve_moe_alltoall", self._trace_alltoall, (sds(slot, dt),))
            if m.n_shared:
                add("serve_moe_combine", self._trace_combine,
                    (sds((g, ng, d), dt), sds(slot, dt)))
            else:
                add("serve_moe_alltoall", self._trace_alltoall,
                    (sds(slot, dt),))
        return [(name, prog, n) for name, (prog, n) in counts.items()]

    def decode_comm_time(self, batch: int) -> float:
        """Analytic switch time (seconds) of one decode tick's
        communication — ``program_time`` over the tick's programs."""
        return sum(prog.program_time() * n
                   for _, prog, n in self.decode_programs(batch))

    def prefill_comm_time(self, batch: int, t: int) -> float:
        """Analytic switch time (seconds) of one batched prefill pass."""
        return sum(prog.program_time() * n
                   for _, prog, n in self.prefill_programs(batch, t))
