"""repro.serve — continuous-batching serving engine.

``engine`` is the host-side control loop (slots, admission, SLO policy);
``collectives`` is the compiled tensor-parallel data path — decode/prefill
communication as switch programs from a process-wide
:class:`~repro.serve.collectives.SwitchProgramCache`.
"""

from repro.serve.collectives import (PROGRAM_CACHE, ServeCollectives,
                                     SwitchProgramCache)
from repro.serve.engine import Completion, Request, ServeEngine, SLOPolicy

__all__ = ["Completion", "PROGRAM_CACHE", "Request", "SLOPolicy",
           "ServeCollectives", "ServeEngine", "SwitchProgramCache"]
