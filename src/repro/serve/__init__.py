"""repro.serve — continuous-batching serving engine."""
