"""Forward-compat shims so the codebase runs on older jax (0.4.x).

The repo is written against the current jax API surface:

  * ``jax.shard_map(f, mesh=..., in_specs=..., out_specs=..., check_vma=...,
    axis_names=...)``
  * ``jax.sharding.AxisType`` + ``jax.make_mesh(..., axis_types=...)``
  * ``jax.set_mesh(mesh)`` as a context manager

On jax 0.4.x those live under ``jax.experimental.shard_map`` (with
``check_rep``/``auto`` spellings) or do not exist at all.  ``install()``
bridges the gap in one place instead of sprinkling version checks through
every module; it is a no-op on a jax new enough to provide the real APIs.

Imported for its side effect from ``repro/__init__.py`` — anything that
imports any ``repro`` module gets the shims before touching jax.
"""

from __future__ import annotations

import contextlib
import enum
import functools
import inspect

import jax
import jax.sharding


class _AxisTypeShim(enum.Enum):
    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


def _install_axis_type() -> None:
    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = _AxisTypeShim


def _install_make_mesh() -> None:
    if "axis_types" in inspect.signature(jax.make_mesh).parameters:
        return
    orig = jax.make_mesh

    @functools.wraps(orig)
    def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
        del axis_types  # 0.4.x meshes are implicitly Auto everywhere
        return orig(axis_shapes, axis_names, devices=devices)

    jax.make_mesh = make_mesh


def _install_shard_map() -> None:
    if hasattr(jax, "shard_map"):
        return
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True,
                  axis_names=None):
        # Partial-manual (axis_names ⊂ mesh axes) is miscompiled by the
        # 0.4.x SPMD partitioner (PartitionId / IsManualSubgroup failures)
        # as soon as the body runs explicit schedules, so run fully manual
        # instead.  This is semantically identical whenever in/out specs and
        # body collectives only reference the manual axes — the auto axes
        # then just replicate the same block computation.
        del axis_names
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=bool(check_vma))

    jax.shard_map = shard_map


def _install_set_mesh() -> None:
    if hasattr(jax, "set_mesh"):
        return

    @contextlib.contextmanager
    def set_mesh(mesh):
        with mesh:
            yield mesh

    jax.set_mesh = set_mesh


def _install_axis_size() -> None:
    from jax import lax
    if hasattr(lax, "axis_size"):
        return

    def axis_size(axis_name):
        # psum of a python scalar over a named axis is evaluated statically.
        return lax.psum(1, axis_name)

    lax.axis_size = axis_size


def _install_cost_analysis() -> None:
    # jax 0.4.x returns [dict] (one per program); current jax returns dict.
    import jax.stages

    orig = jax.stages.Compiled.cost_analysis

    @functools.wraps(orig)
    def cost_analysis(self):
        out = orig(self)
        if isinstance(out, list):
            return out[0] if out else {}
        return out

    jax.stages.Compiled.cost_analysis = cost_analysis


def install() -> None:
    _install_axis_type()
    _install_make_mesh()
    _install_shard_map()
    _install_set_mesh()
    _install_axis_size()
    _install_cost_analysis()
