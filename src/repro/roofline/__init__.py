"""repro.roofline — cost-analysis + HLO collective-bytes roofline model."""
