"""Roofline report generator: results/dryrun/*.json → markdown tables.

    PYTHONPATH=src python -m repro.roofline.report results/dryrun

Produces the §Dry-run and §Roofline tables for EXPERIMENTS.md.
"""

from __future__ import annotations

import json
import os
import sys

ARCH_ORDER = ["nemotron-4-15b", "granite-8b", "qwen3-8b", "granite-3-8b",
              "qwen2-moe-a2.7b", "deepseek-v2-236b", "recurrentgemma-9b",
              "rwkv6-1.6b", "whisper-small", "llama-3.2-vision-11b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(out_dir: str) -> list[dict]:
    rows = []
    summary = os.path.join(out_dir, "summary.json")
    seen = set()
    for f in sorted(os.listdir(out_dir)):
        if not f.endswith(".json") or f == "summary.json":
            continue
        with open(os.path.join(out_dir, f)) as fh:
            r = json.load(fh)
        rows.append(r)
        seen.add((r["arch"], r["shape"], r.get("multi_pod", False)))
    if os.path.exists(summary):
        with open(summary) as fh:
            for r in json.load(fh):
                key = (r.get("arch"), r.get("shape"), r.get("multi_pod"))
                if key not in seen and r.get("status") != "ok":
                    rows.append(r)
                    seen.add(key)
    return rows


def fmt_bytes(b):
    if b is None:
        return "—"
    return f"{b / 2 ** 30:.2f}"


def fmt_t(t):
    if t is None:
        return "—"
    if t >= 1:
        return f"{t:.2f}s"
    return f"{t * 1e3:.1f}ms"


def dryrun_table(rows: list[dict]) -> str:
    out = ["| arch | shape | mesh | status | per-dev temp GiB | compile s |",
           "|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mp in (False, True):
                r = _find(rows, arch, shape, mp)
                if r is None:
                    continue
                mesh = "2×16×16" if mp else "16×16"
                st = r.get("status", "?")
                mem = r.get("memory_analysis", {}).get("temp_bytes") \
                    if st == "ok" else None
                out.append(
                    f"| {arch} | {shape} | {mesh} | {st} | "
                    f"{fmt_bytes(mem)} | {r.get('compile_s', '—')} |")
    return "\n".join(out)


def roofline_table(rows: list[dict]) -> str:
    out = ["| arch | shape | t_comp | t_mem⁺ | t_coll | dominant | "
           "useful | frac(cc) | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = _find(rows, arch, shape, False)
            if r is None:
                continue
            st = r.get("status", "?")
            if st != "ok" or "t_compute_s" not in r:
                out.append(f"| {arch} | {shape} | — | — | — | — | — | — "
                           f"| {st} |")
                continue
            fcc = r.get("roofline_fraction_cc")
            if fcc is None:
                fcc = r["roofline_fraction"]
            bcc = r.get("bottleneck_cc") or r["bottleneck"]
            out.append(
                f"| {arch} | {shape} | {fmt_t(r['t_compute_s'])} | "
                f"{fmt_t(r['t_memory_s'])} | {fmt_t(r['t_collective_s'])} | "
                f"{bcc} | {r['useful_flops_ratio']:.2f} | "
                f"{fcc:.3f} | |")
    return "\n".join(out)


def _find(rows, arch, shape, mp):
    for r in rows:
        if r.get("arch") == arch and r.get("shape") == shape \
                and bool(r.get("multi_pod", False)) == mp:
            return r
    return None


def main():
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    rows = load(out_dir)
    print("## §Dry-run\n")
    print(dryrun_table(rows))
    print("\n## §Roofline (single-pod 16×16, 256 chips)\n")
    print(roofline_table(rows))
    ok = sum(1 for r in rows if r.get("status") == "ok")
    print(f"\n{ok} ok / {len(rows)} records")


if __name__ == "__main__":
    main()
