"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds-per-step on the
TARGET hardware (TPU v5e):

    compute    = HLO_FLOPs(per device)      / 197e12  FLOP/s  (bf16 MXU)
    memory     = HLO_bytes(per device)      / 819e9   B/s     (HBM)
    collective = wire_bytes(per device)     / 50e9    B/s     (one ICI link)

``cost_analysis`` supplies FLOPs/bytes of the *partitioned per-device*
module.  Collective bytes are NOT in cost_analysis: we parse the optimized
HLO and sum the result-shape bytes of every collective op (all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, sync or
async-start form; `-done` twins are skipped to avoid double counting).

The dominant term is the bottleneck; MODEL_FLOPS/HLO_FLOPs measures how
much compiled compute is algorithmically useful (remat & padding waste).
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Any, Optional

PEAK_FLOPS = 197e12          # bf16 / chip (v5e)
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^=]*?\)|[a-z0-9\[\],{}:#* ]+?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by each collective kind (result shapes)."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        # result type precedes the op name
        prefix = line[:m.end(1) - len(kind)]
        total = sum(_shape_bytes(dt, dims)
                    for dt, dims in _SHAPE_RE.findall(prefix))
        out[kind] += total
        counts[kind] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops: float                 # per device
    hbm_bytes: float             # per device
    coll_bytes: float            # per device
    coll_detail: dict
    model_flops: float           # global, algorithmic
    per_device_bytes: Optional[float] = None   # peak memory (fits check)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bottleneck_cc(self) -> str:
        """Compute-vs-collective bottleneck.  The memory term from the
        CPU-backend cost_analysis is an operand-traffic UPPER BOUND (CPU
        fusion is far weaker than TPU's), so comm/compute comparisons are
        the reliable signal for schedule decisions."""
        return "compute" if self.t_compute >= self.t_collective \
            else "collective"

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the per-step time budget spent at the dominant
        hardware limit doing *useful* work: t_model_compute / t_step where
        t_step = max(terms) (perfect overlap assumption)."""
        t_step = max(self.t_compute, self.t_memory, self.t_collective)
        t_useful = (self.model_flops / self.chips) / PEAK_FLOPS
        return t_useful / t_step if t_step else 0.0

    @property
    def roofline_fraction_cc(self) -> float:
        """Useful-compute fraction against max(compute, collective) — the
        memory-term-free score used for hillclimbing (see bottleneck_cc)."""
        t_step = max(self.t_compute, self.t_collective)
        t_useful = (self.model_flops / self.chips) / PEAK_FLOPS
        return t_useful / t_step if t_step else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips, "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_bytes_per_device": self.coll_bytes,
            "collective_detail": self.coll_detail,
            "model_flops": self.model_flops,
            "per_device_peak_bytes": self.per_device_bytes,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "bottleneck_cc": self.bottleneck_cc,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "roofline_fraction_cc": self.roofline_fraction_cc,
        }


def model_flops_for(arch: str, shape_name: str) -> float:
    """Algorithmic FLOPs per step: 6·N·D train (N = active params for MoE),
    2·N·tokens for forward-only (prefill/decode)."""
    from repro import configs
    from repro.launch.shapes import SHAPES
    cfg = configs.get(arch)
    cell = SHAPES[shape_name]
    n = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * cell.global_batch       # one token per sequence


def analyze(lowered_cell, compiled) -> Roofline:
    """Build the roofline record from a compiled dry-run cell."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):       # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    mesh_desc = lowered_cell.mesh_desc
    chips = 1
    for part in re.findall(r"(\d+)[a-z]", mesh_desc):
        chips *= int(part)
    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = float(getattr(ma, "temp_size_in_bytes", 0)
                    + getattr(ma, "argument_size_in_bytes", 0)
                    + getattr(ma, "output_size_in_bytes", 0)
                    - getattr(ma, "alias_size_in_bytes", 0))
    except Exception:
        pass
    return Roofline(
        arch=lowered_cell.arch, shape=lowered_cell.shape, mesh=mesh_desc,
        chips=chips, flops=flops, hbm_bytes=hbm,
        coll_bytes=float(coll["total_bytes"]), coll_detail=coll,
        model_flops=model_flops_for(lowered_cell.arch, lowered_cell.shape),
        per_device_bytes=mem)
