"""Dry-run "profiler": rank collective/HBM-heavy ops in a cell's HLO.

    PYTHONPATH=src python -m repro.roofline.profile --arch rwkv6-1.6b \
        --shape train_4k [--probe] [--extra '{"parallelism":"pure_dp"}']

This is the profile the perf loop reads (no real hardware): the lowered
IR's collective ops ranked by bytes, with op provenance (forward/backward,
which dot_general), plus duplicate-op counts as a remat/redundancy signal.
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import re
from collections import Counter


def profile_hlo(hlo: str, top: int = 15) -> dict:
    from repro.roofline.analysis import _OP_RE, _SHAPE_RE, _shape_bytes

    rows = []
    for line in hlo.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        prefix = line[:m.end(1) - len(kind)]
        b = sum(_shape_bytes(dt, dims)
                for dt, dims in _SHAPE_RE.findall(prefix))
        mm = re.search(r'op_name="([^"]+)"', line)
        meta = mm.group(1) if mm else ""
        shapes = _SHAPE_RE.findall(prefix)
        rows.append((b, kind, shapes[:2], meta[-80:]))
    rows.sort(key=lambda r: -r[0])
    total = sum(r[0] for r in rows)
    # remat signal: identical op_name stems appearing many times
    stems = Counter(re.sub(r"\d+", "", r[3]) for r in rows)
    return {"total_bytes": total, "count": len(rows), "top": rows[:top],
            "dup_stems": stems.most_common(5)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--probe", action="store_true",
                    help="profile the (1,1) probe instead of the full cell")
    ap.add_argument("--extra", default=None)
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()

    from repro.launch import cells
    from repro.launch.mesh import make_production_mesh

    extra = json.loads(args.extra) if args.extra else None
    mesh = make_production_mesh()
    if args.probe:
        built = cells.build_probe(args.arch, args.shape, mesh, periods=1,
                                  microbatches=1, extra_config=extra)
    else:
        built = cells.build_cell(args.arch, args.shape, mesh,
                                 extra_config=extra)
    hlo = built.lowered.compile().as_text()
    prof = profile_hlo(hlo, args.top)
    print(f"collective ops: {prof['count']}, total "
          f"{prof['total_bytes'] / 2**30:.3f} GiB/device")
    for b, kind, shapes, meta in prof["top"]:
        print(f"{b / 2**20:9.1f}MiB {kind:18s} {shapes} {meta}")


if __name__ == "__main__":
    main()
