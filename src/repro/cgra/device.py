"""CGRA device model — the paper's §IV modular switch extension.

The switch's processing module is a coarse-grained reconfigurable array:
a small grid of processing elements (PEs) on the data path between the
ingress parser and the egress scheduler.  Payload words stream through
the array at line granularity; the mapped op-graph is a *spatial
pipeline* (one PE per op, level by level), so throughput is one input
word-group per initiation interval (II) once the pipe is full.

This module is deliberately standalone (no imports from ``repro.core``):
:mod:`repro.core.netmodel` derives its in-switch compute rates from a
:class:`CGRADevice` + :class:`Placement` instead of the old
``accel_clock``/``accel_width`` magic constants, and the mapper
(:mod:`repro.cgra.mapper`) produces the placements.

Feasibility is the point: an op-graph that needs more PE slots, more
pipeline depth, or primitives the array doesn't implement gets an
explicit :class:`HostFallback` — the framework then *costs that stage as
a PCIe + MPI host detour* rather than silently pretending the switch ran
it (the honesty ACCL+/FPsPIN-style device models buy).
"""

from __future__ import annotations

import dataclasses
from typing import Optional


# Primitive vocabulary of one PE's ALU.  Names are jax primitive names —
# the mapper lowers a stage's compute body to a jaxpr and classifies
# every equation against these sets.
ALU_PRIMS = frozenset({
    "add", "sub", "mul", "div", "rem", "neg", "max", "min",
    "abs", "sign", "floor", "ceil", "round", "clamp", "nextafter",
    "exp", "exp2", "log", "log1p", "expm1", "logistic", "tanh",
    "sqrt", "rsqrt", "cbrt", "square", "integer_pow", "pow",
    "sin", "cos", "erf", "erfc", "erf_inv",
    "lt", "le", "gt", "ge", "eq", "ne", "select_n",
    "and", "or", "xor", "not",
    "shift_left", "shift_right_logical", "shift_right_arithmetic",
    "convert_element_type", "bitcast_convert_type", "is_finite",
    "stop_gradient", "real", "imag",
})

# Single-PE accumulator / scan ops: one PE with a feedback register; the
# pipeline depth grows with log2 of the reduced extent (a balanced tree
# of the same ALU op), the slot cost stays one PE.
ACCUM_PRIMS = frozenset({
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
    "reduce_and", "reduce_or", "reduce_xor",
    "cumsum", "cumprod", "cummax", "cummin", "cumlogsumexp",
    "argmax", "argmin",
})

# Pure data-steering absorbed by the interconnect / address generators:
# no ALU slot, but each consumes routing budget.
ROUTE_PRIMS = frozenset({
    "reshape", "broadcast_in_dim", "broadcast", "concatenate", "slice",
    "squeeze", "expand_dims", "transpose", "rev", "pad", "iota",
    "dynamic_slice", "dynamic_update_slice", "copy", "split",
    "device_put",
})

# Call-like primitives the mapper recurses through rather than placing.
CALL_PRIMS = frozenset({
    "pjit", "closed_call", "core_call", "xla_call", "custom_jvp_call",
    "custom_vjp_call", "custom_jvp_call_jaxpr", "remat", "checkpoint",
    "custom_vjp_call_jaxpr", "name",
})


@dataclasses.dataclass(frozen=True)
class CGRADevice:
    """One switch's CGRA extension, parameterized like the paper's build.

    The defaults mirror the paper's Table II accelerator: a 250 MHz
    fabric clock moving 64 B per cycle through the processing pipe
    (the old ``NetParams.accel_clock * accel_width`` line rate is
    exactly ``line_rate`` of this device at II = 1).
    """

    name: str = "acis_switch_v1"
    rows: int = 4                 # PE grid: one row per pipeline level
    cols: int = 4
    ops_per_pe: int = 2           # time-multiplexed ALU slots per PE
    lane_bytes: int = 64          # payload bytes entering the array/cycle
    clock_hz: float = 250e6       # fabric clock (Vitis build, 250 MHz)
    max_depth: int = 32           # pipeline registers along one path
    #   (registers are cheap; 32 admits the blockwise-int8 quantize
    #   pipeline — absmax tree over a 256 block is 8 levels by itself —
    #   while PEs/op-slots stay the binding resource)
    route_budget: int = 64        # steering ops the interconnect absorbs
    supported: frozenset = ALU_PRIMS

    @property
    def n_pes(self) -> int:
        return self.rows * self.cols

    @property
    def op_slots(self) -> int:
        return self.n_pes * self.ops_per_pe

    @property
    def line_rate(self) -> float:
        """Bytes/s through the array at II = 1 (a bare Type-1 combine)."""
        return self.clock_hz * self.lane_bytes


PAPER_CGRA = CGRADevice()


@dataclasses.dataclass(frozen=True)
class Placement:
    """A mapped stage: where the op-graph sits and what it sustains.

    ``pes`` holds (row, col) coordinates of occupied PEs (level-major —
    the list-scheduler places level ``l`` ops on row ``l % rows``).
    ``ii`` > 1 means the graph needed more ALU slots than PEs in one
    wave, so PEs are time-multiplexed and throughput drops to
    ``line_rate / ii``.
    """

    device: CGRADevice
    n_ops: int                        # ALU + accumulator ops placed
    n_route: int                      # steering ops absorbed by routing
    depth: int                        # pipeline latency in levels
    ii: int                           # initiation interval (cycles/input)
    pes: tuple = ()                   # occupied (row, col) coordinates
    ops: tuple = ()                   # primitive names, level order
    note: str = ""

    fits: bool = dataclasses.field(default=True, init=False, repr=False)

    @property
    def pes_used(self) -> int:
        return len(self.pes)

    @property
    def bytes_per_s(self) -> float:
        """Sustained throughput of the mapped pipeline."""
        return self.device.line_rate / max(self.ii, 1)

    @property
    def cycles_per_element(self) -> float:
        """Cycles per ``lane_bytes`` input word-group."""
        return float(max(self.ii, 1))

    def describe(self) -> str:
        if self.n_ops == 0:
            return f"route-through ({self.n_route} steer ops, 0 PEs)"
        return (f"{self.pes_used}/{self.device.n_pes} PEs, "
                f"depth {self.depth}, II {self.ii}, "
                f"{self.bytes_per_s / 1e9:.1f} GB/s")


def route_through(device: CGRADevice, n_route: int = 0,
                  note: str = "") -> Placement:
    """A stage with no ALU work: pure forwarding / source-rank reformat.

    Shape bookkeeping (pad/unpad), replication, and plain store-and-
    forward movement occupy zero PEs and stream at the full line rate.
    """
    return Placement(device=device, n_ops=0, n_route=n_route, depth=0,
                     ii=1, pes=(), ops=(), note=note or "pure data movement")


@dataclasses.dataclass(frozen=True)
class HostFallback:
    """The stage's compute body does not fit the switch CGRA.

    Execution is unchanged (the emitted shard_map program still runs the
    op at the endpoint — that is exactly what "fallback" means); the
    *cost model* charges the stage a PCIe + MPI host detour instead of
    the in-switch rate, so schedules and benchmarks stop pretending.
    """

    reason: str

    fits: bool = dataclasses.field(default=False, init=False, repr=False)

    def describe(self) -> str:
        return f"host-fallback: {self.reason}"


PlacementLike = "Placement | HostFallback"


def placement_rate(placement: Optional[object],
                   device: CGRADevice = PAPER_CGRA) -> float:
    """In-switch compute throughput (bytes/s) of a stage.

    ``None`` (no mapper ran — e.g. a hand-built pipeline without
    PlaceCGRA) and route-through placements stream at the device line
    rate; a mapped graph sustains ``line_rate / II``.  Host fallbacks
    have *no* in-switch rate — callers must cost the detour explicitly
    (see :func:`repro.core.netmodel.host_fallback_time`); asking for a
    rate anyway is a modeling bug, so it raises.
    """
    if placement is None:
        return device.line_rate
    if not getattr(placement, "fits", True):
        raise ValueError(
            f"host-fallback stage has no in-switch rate "
            f"({placement.describe()}); cost it as a host detour")
    return placement.bytes_per_s
