"""Cycle-approximate switch dataplane simulator.

Executes a compiled switch program (:class:`repro.core.compiler.
CompiledProgram`) across N *simulated* ranks in a single process: every
rank's port buffer is a numpy/jax array, every collective stage is
interpreted hop by hop with exactly the chunk walk and per-hop combine
order of the real :mod:`repro.core.ring` schedules, and a discrete-event
clock per rank advances by link latency + serialization + in-switch (or
host-detour) compute per hop.

Two outputs per run:

  * the program's results for every rank — bit-comparable (allclose)
    against executing the same ``CompiledProgram`` under ``jax.shard_map``
    on a real device mesh, which is how the tests validate the dataplane;
  * a :class:`SimReport` putting the *simulated* per-stage latency next
    to the :func:`repro.core.netmodel.stage_time` analytic prediction —
    the emulator's cross-check, stage by stage, with the CGRA placement
    (or host fallback) that produced the compute rate.  Stages execute
    in :class:`~repro.core.executor.ExecutionPlan` wave order: within a
    wave, stages on different mesh axes overlap on disjoint clock
    branches, so ``report.t_end`` (overlapped end-to-end) validates the
    :func:`repro.core.netmodel.program_time` overlap model while the
    per-stage sum ``report.t_sim`` remains the serial cost.

The simulator needs no mesh and no shard_map: multi-axis programs
(hierarchical RS/AR/AG) run over a simulated rank *grid*, each stage
over its own axis, with the stage's link tier (ICI/DCI) taken from the
compile topology.  MAP bodies execute under nested ``jax.vmap`` frames
(one per grid axis, names bound) so the compiler's pad/unpad bookkeeping
— which queries ``lax.axis_size`` — runs unmodified.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.cgra.device import HostFallback, PAPER_CGRA
from repro.core import netmodel
from repro.obs import metrics as _obs
from repro.core.program import OpKind
from repro.core.wire import IDENTITY, int8_codec

Array = np.ndarray


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Injected faults for a :class:`SwitchSim` run.

    ``dead`` ranks are *endpoint-dead*: the switch port still forwards
    (the fabric is alive, so the data path and every buffer shape are
    unchanged — a masked program zeroes their stale contribution via the
    alive input), but the rank is spliced out of ring timing — it never
    injects, never delays a hop, and each ring contracts to its live
    members.  Live ranks pay ``detect_timeout_s`` per dead rank once at
    run start (the deadline the runtime waits before masking), which is
    what makes the sync-time-vs-dead-fraction curve a *line* — detection
    cost in, hop savings out — instead of a cliff.

    ``straggler_s`` maps rank → extra seconds that rank adds to every
    hop it receives (the mean of its delay distribution).
    ``degraded_links`` maps axis → k: links on that axis run at 1/k
    bandwidth with k× link latency.
    """

    dead: frozenset = frozenset()
    straggler_s: tuple = ()            # ((rank, seconds), ...)
    degraded_links: tuple = ()         # ((axis, k), ...)
    detect_timeout_s: float = 0.0

    def __post_init__(self):
        object.__setattr__(self, "dead", frozenset(self.dead))
        object.__setattr__(self, "straggler_s",
                           tuple(sorted(dict(self.straggler_s).items())))
        object.__setattr__(self, "degraded_links",
                           tuple(sorted(dict(self.degraded_links).items())))
        for ax, k in self.degraded_links:
            if k < 1:
                raise ValueError(
                    f"degraded link on {ax!r}: k must be ≥1, got {k}")

    def __bool__(self) -> bool:
        return bool(self.dead or self.straggler_s or self.degraded_links)


@dataclasses.dataclass(frozen=True)
class SimStage:
    kind: str
    axis: str
    schedule: str
    t_sim: float                  # simulated wall time of the stage (s)
    t_model: Optional[float]      # netmodel.stage_time prediction (s)
    placement: Any = None
    wave: int = 0                 # ExecutionPlan wave the stage ran in
    # global start timestamp of the stage on its wave branch (s) and the
    # stage's injection-serialization share (the part of t_sim the shared
    # port stays busy — what the wave merge re-exposes for non-critical
    # branches).  Together with t_sim these are exactly the fields
    # repro.tune.trace.StageTrace records, so simulated traces drive the
    # record → fit → replay → search loop without hardware.
    t_start: float = 0.0
    t_ser: float = 0.0

    @property
    def deviation(self) -> Optional[float]:
        if not self.t_model:
            return None
        return self.t_sim / self.t_model


@dataclasses.dataclass
class SimReport:
    stages: list[SimStage]
    axes: dict                    # axis name -> size
    # end-to-end simulated latency with wave overlap (stages of one wave
    # on different axes run concurrently); ≤ t_sim, the serial stage sum
    t_end: float = 0.0
    # netmodel.program_time of the same plan — the analytic overlap
    # model's prediction for t_end (None without a compile topology)
    t_program_model: Optional[float] = None
    # per-rank completion timestamps (s) — what deadline verdicts and the
    # drift watchdog's per-rank span pools read; dead ranks report their
    # frozen clock
    rank_t_end: tuple = ()

    @property
    def t_sim(self) -> float:
        return sum(s.t_sim for s in self.stages)

    @property
    def t_model(self) -> float:
        return sum(s.t_model or 0.0 for s in self.stages)

    def table(self) -> str:
        rows = [("wv", "kind", "axis", "sched", "sim_us", "model_us",
                 "placement")]
        for s in self.stages:
            pl = s.placement.describe() if s.placement is not None else "-"
            rows.append((str(s.wave), s.kind, s.axis or "-",
                         s.schedule or "-",
                         f"{s.t_sim * 1e6:9.2f}",
                         f"{(s.t_model or 0.0) * 1e6:9.2f}", pl))
        rows.append(("", "TOTAL", "", "", f"{self.t_sim * 1e6:9.2f}",
                     f"{self.t_model * 1e6:9.2f}", ""))
        rows.append(("", "END-TO-END", "", "", f"{self.t_end * 1e6:9.2f}",
                     f"{(self.t_program_model or 0.0) * 1e6:9.2f}",
                     "(waves overlapped)"))
        w = [max(len(r[c]) for r in rows) for c in range(6)]
        return "\n".join(
            "  ".join(r[c].ljust(w[c]) for c in range(6)) + "  " + r[6]
            for r in rows)


# ---------------------------------------------------------------------------
# the simulator
# ---------------------------------------------------------------------------

class SwitchSim:
    """A multi-port switch fabric simulated at rank granularity.

    ``topology`` is either a :class:`repro.core.compiler.Topology` (axis
    order = leading-dim order of the inputs; per-axis link tiers are
    honored) or a ``{axis: size}`` mapping (all axes on the fast tier).
    """

    def __init__(self, topology, *, device=PAPER_CGRA,
                 faults: Optional[FaultPlan] = None):
        if hasattr(topology, "axes"):          # compiler.Topology
            self.axis_names = [a.name for a in topology.axes]
            self.sizes = {a.name: int(a.size) for a in topology.axes}
            self.nets = {a.name: topology.net(a.name)
                         for a in topology.axes}
        else:
            self.axis_names = list(topology)
            self.sizes = {a: int(n) for a, n in dict(topology).items()}
            self.nets = {a: netmodel.PAPER for a in self.axis_names}
        if any(n <= 0 for n in self.sizes.values()):
            raise ValueError(f"axis sizes must be concrete: {self.sizes}")
        self.grid = tuple(self.sizes[a] for a in self.axis_names)
        self.n_ranks = int(np.prod(self.grid))
        self.device = device
        # healthy-fabric params, frozen before fault injection: t_model
        # predictions price against these, so a degraded link shows up as
        # sim/model drift the watchdog can attribute to the axis instead
        # of silently re-baselining the prediction onto the fault
        self.model_nets = dict(self.nets)
        self.faults = faults if faults else None
        self._alive = np.ones((self.n_ranks,), bool)
        self._straggler = np.zeros((self.n_ranks,), np.float64)
        if self.faults is not None:
            bad = [r for r in self.faults.dead
                   if not 0 <= r < self.n_ranks]
            bad += [r for r, _ in self.faults.straggler_s
                    if not 0 <= r < self.n_ranks]
            if bad:
                raise ValueError(
                    f"fault ranks {sorted(set(bad))} out of range "
                    f"0..{self.n_ranks - 1}")
            for r in self.faults.dead:
                self._alive[r] = False
            for r, s in self.faults.straggler_s:
                self._straggler[r] = float(s)
            for ax, k in self.faults.degraded_links:
                if ax not in self.nets:
                    raise ValueError(f"degraded link on unknown axis {ax!r}")
                p = self.nets[ax]
                self.nets[ax] = dataclasses.replace(
                    p, bw=p.bw / k, fpga_link=p.fpga_link * k)
        # per-rank injection-serialization account of the wave branch
        # currently executing (set by run() around each stage)
        self._cur_ser: Optional[Array] = None

    # -- rank bookkeeping ---------------------------------------------------

    def _rings(self, axis: str) -> list[np.ndarray]:
        """Flat rank index groups forming independent rings along ``axis``."""
        ids = np.arange(self.n_ranks).reshape(self.grid)
        k = self.axis_names.index(axis)
        moved = np.moveaxis(ids, k, -1)
        return [g for g in moved.reshape(-1, self.grid[k])]

    def _vmap_all(self, fn: Callable) -> Callable:
        for ax in reversed(self.axis_names):
            fn = jax.vmap(fn, axis_name=ax)
        return fn

    # -- timing -------------------------------------------------------------

    @staticmethod
    def _hop_time(p, chunk_bytes: float, compute_bytes: float,
                  placement) -> float:
        """One ring hop: link + serialization + per-hop compute.

        A fitting placement streams the compute at its sustained rate; a
        host fallback detours the chunk over PCIe and computes at the
        endpoint (the per-stage MPI injection is charged separately).
        """
        t = p.fpga_link + p.port + chunk_bytes / p.bw
        if compute_bytes:
            if placement is not None and not placement.fits:
                t += 2 * p.pcie + compute_bytes / p.host_bw
            else:
                t += compute_bytes / netmodel.accel_rate(p, placement)
        return t

    def _advance_ring(self, clock: Array, axis: str, steps: int,
                      t_hop: float, ser_hop: float = 0.0) -> None:
        """Discrete-event update: each step, every rank's clock becomes
        max(own, upstream neighbour) + hop time, per ring of the axis.

        ``ser_hop`` is the *injection-serialization* share of the hop
        (chunk bytes / link bw): the time the rank's shared port is
        busy pushing this branch's bytes.  It accrues into the current
        wave branch's serialization account — concurrent branches of one
        wave overlap their propagation and compute, but their injection
        contends at the port, so the wave merge re-exposes the
        non-critical branches' serialization (see :meth:`run`).

        Under a :class:`FaultPlan`, each ring contracts to its live
        members (dead ports are cut through, so a lap needs fewer hops:
        the step count caps at live−1), stragglers add their per-hop
        delay to every hop they receive, and dead ranks neither inject
        nor advance.
        """
        faulty = self.faults is not None
        for g in self._rings(axis):
            gl = g[self._alive[g]] if faulty else g
            n_live = len(gl)
            if n_live < 2:
                continue
            eff = min(max(steps, 0), n_live - 1) if faulty else max(steps, 0)
            extra = self._straggler[gl] if faulty else 0.0
            for _ in range(eff):
                vals = clock[gl]
                clock[gl] = np.maximum(vals, np.roll(vals, 1)) \
                    + t_hop + extra
            if ser_hop and eff > 0 and self._cur_ser is not None:
                self._cur_ser[gl] += eff * ser_hop

    def _advance_local(self, clock: Array, t: float) -> None:
        clock += t

    # -- public entry -------------------------------------------------------

    def run(self, compiled, *inputs) -> tuple[Any, SimReport]:
        """Execute ``compiled`` over per-rank inputs, wave by wave.

        Every input is shaped ``grid + local_shape`` (leading dims in
        topology-axis order).  Returns ``(outputs, report)`` with outputs
        in the same convention.

        Stages are walked in :class:`~repro.core.executor.ExecutionPlan`
        wave order.  Within one wave, stages traversing *different* mesh
        axes occupy disjoint links and advance independent clock branches
        from the wave-start snapshot (true overlap); stages sharing an
        axis serialize on that axis's rings.  The wave ends at the
        element-wise max of its branches — so ``report.t_end`` measures
        the overlapped end-to-end latency the analytic
        :func:`repro.core.netmodel.program_time` predicts, while the
        per-stage ``t_sim`` entries still sum to the serial cost.
        """
        src = compiled.source
        if len(inputs) != src.num_inputs:
            raise TypeError(f"program takes {src.num_inputs} inputs, "
                            f"got {len(inputs)}")
        env: dict[int, Array] = {}
        for i, x in enumerate(inputs):
            x = np.asarray(x)
            if tuple(x.shape[:len(self.grid)]) != self.grid:
                raise ValueError(
                    f"input {i} must lead with the rank grid {self.grid}, "
                    f"got shape {x.shape}")
            env[i] = x.reshape((self.n_ranks,) + x.shape[len(self.grid):])

        plan = getattr(compiled, "plan", None)
        waves = plan.waves if plan is not None \
            else tuple((i,) for i in range(len(compiled.stages)))
        clock = np.zeros((self.n_ranks,), np.float64)
        if self.faults is not None and self.faults.dead:
            # every live rank waits out the detection deadline once per
            # dead peer before masking it — the linear term of the
            # degradation curve
            n_dead = len(self.faults.dead)
            clock[self._alive] += n_dead * self.faults.detect_timeout_s
            _obs.RECORDER.count("sim.dead_ranks", n_dead)
        rows: dict[int, SimStage] = {}
        for wi, wave in enumerate(waves):
            branch: dict[str, Array] = {}
            branch_ser: dict[str, Array] = {}
            for si in wave:
                st = compiled.stages[si]
                if st.ir is None:
                    raise ValueError(
                        f"stage {st.kind!r} carries no StageIR — the "
                        "program was compiled by a pipeline the simulator "
                        "cannot interpret (use the default pipeline)")
                c = branch.get(st.axis)
                if c is None:
                    c = branch[st.axis] = clock.copy()
                    branch_ser[st.axis] = np.zeros_like(clock)
                self._cur_ser = branch_ser[st.axis]
                t0 = float(c.max())
                s0 = float(branch_ser[st.axis].max())
                args = [env[v] for v in st.in_vids]
                try:
                    outs = self._exec(st, args, c)
                finally:
                    self._cur_ser = None
                for vid, o in zip(st.out_vids, outs):
                    env[vid] = np.asarray(o)
                t_sim = float(c.max()) - t0
                rows[si] = SimStage(
                    st.kind, st.axis, st.schedule, t_sim,
                    self._model_time(st, args), st.placement, wi,
                    t_start=t0,
                    t_ser=float(branch_ser[st.axis].max()) - s0)
            if branch:
                # concurrent branches overlap propagation and compute,
                # but every rank injects into all of its rings through
                # one shared port: the wave ends at the per-rank max
                # branch plus the *other* branches' injection-
                # serialization time (the contention the calibrated
                # netmodel.TIER_OVERLAP fractions price)
                clocks = np.stack(list(branch.values()))
                sers = np.stack([branch_ser[a] for a in branch])
                arg = np.argmax(clocks, axis=0)
                exposed = sers.sum(axis=0) \
                    - np.take_along_axis(sers, arg[None], axis=0)[0]
                clock = clocks.max(axis=0) + exposed

        outs = tuple(env[v].reshape(self.grid + env[v].shape[1:])
                     for v in src.outputs)
        t_prog = None
        topo = getattr(compiled, "topology", None)
        if plan is not None and topo is not None:
            t_prog = netmodel.program_time(plan, topo)
        t_end = float(clock[self._alive].max()) \
            if self._alive.any() else float(clock.max())
        report = SimReport([rows[i] for i in sorted(rows)],
                           dict(self.sizes), t_end, t_prog,
                           rank_t_end=tuple(float(t) for t in clock))
        rec = _obs.RECORDER
        if rec.enabled:
            rec.count("sim.runs")
            rec.count("sim.stages", len(report.stages))
        return (outs[0] if len(outs) == 1 else outs), report

    # -- per-stage analytic prediction --------------------------------------

    def _model_time(self, st, args: list) -> Optional[float]:
        m = int(args[0].nbytes // self.n_ranks) if args else 0
        m_parts = None
        if st.kind == "allreduce+alltoall" and len(args) == 2:
            m_parts = (int(args[0].nbytes // self.n_ranks),
                       int(args[1].nbytes // self.n_ranks))
            m = sum(m_parts)
        elif st.kind == "map" and st.ir.bytes_in is not None:
            # the plan-consistent map payload: what the stage produces
            # (pack = sum of operands, split = one slice of the bucket)
            m = int(st.ir.bytes_in)
        axis = st.axis
        n = self.sizes.get(axis, 1)
        p = self.model_nets.get(axis, netmodel.PAPER)
        ratio = 1.0
        for nd in st.ir.nodes:
            if nd.op.codec is not IDENTITY:
                ratio = float(nd.op.codec.wire_ratio)
        try:
            return netmodel.stage_time(st.kind, n, m, p,
                                       placement=st.placement,
                                       schedule=st.schedule,
                                       codec_ratio=ratio,
                                       m_parts=m_parts)
        except ValueError:
            return None

    # -- stage interpreters --------------------------------------------------

    def _exec(self, st, args: list, clock: Array) -> tuple:
        kind = st.kind.replace("+", "_")
        return getattr(self, "_run_" + kind)(st, args, clock)

    # .. local map ..........................................................

    def _apply_map(self, fn: Callable, args: list) -> Array:
        grid_args = [a.reshape(self.grid + a.shape[1:]) for a in args]
        out = self._vmap_all(fn)(*[jnp.asarray(a) for a in grid_args])
        out = np.asarray(out)
        return out.reshape((self.n_ranks,) + out.shape[len(self.grid):])

    def _run_map(self, st, args, clock):
        fn = st.ir.nodes[0].op.fn
        out = self._apply_map(fn, args)
        p = netmodel.PAPER
        pl = st.placement
        # a map streams what it produces: a Coalesce bucket pack emits the
        # sum of its operands, a bucket split only its own slice
        m = int(out.nbytes // self.n_ranks)
        if pl is not None and not pl.fits:
            self._advance_local(clock, netmodel.host_fallback_time(m, p))
        else:
            self._advance_local(clock, m / netmodel.accel_rate(p, pl))
        return (out,)

    # .. ring all-reduce family .............................................

    def _ring_rs(self, blocks: list, combine: Callable) -> list:
        """Ring reduce-scatter over one ring, exact hop/fold order of
        :func:`repro.core.ring.ring_reduce_scatter`; ``blocks[i]`` is
        rank i's [n*chunk, ...] payload, the result rank i's chunk i."""
        n = len(blocks)
        xs = [np.asarray(jnp.asarray(b)) for b in blocks]
        chunks = [np.split(x, n, axis=0) for x in xs]
        buf = [chunks[i][(i - 1) % n] for i in range(n)]
        for s in range(n - 1):
            incoming = [buf[(i - 1) % n] for i in range(n)]
            buf = [np.asarray(combine(jnp.asarray(incoming[i]),
                                      jnp.asarray(chunks[i][(i - 2 - s) % n])))
                   for i in range(n)]
        return buf

    @staticmethod
    def _ring_ag(blocks: list, hop_map: Optional[Callable] = None) -> list:
        mapped = [np.asarray(hop_map(jnp.asarray(b))) if hop_map else b
                  for b in blocks]
        full = np.concatenate(mapped, axis=0)
        return [full for _ in blocks]

    def _allreduce_ring(self, blocks: list, monoid, codec,
                        latency: bool) -> list:
        n = len(blocks)
        if n == 1:
            return list(blocks)
        if codec is not IDENTITY and codec.combine_encoded is not None:
            return self._allreduce_encoded(blocks, codec)
        combine = monoid.combine
        if codec is not IDENTITY:            # cast-style codec
            enc = [np.asarray(codec.encode(jnp.asarray(b))) for b in blocks]
            red = self._allreduce_ring(enc, monoid, IDENTITY, latency)
            return [np.asarray(codec.decode(jnp.asarray(r))
                               .astype(blocks[i].dtype))
                    for i, r in enumerate(red)]
        if latency:
            acc = [jnp.asarray(b) for b in blocks]
            for s in range(1, n):
                acc = [combine(acc[i], jnp.asarray(blocks[(i - s) % n]))
                       for i in range(n)]
            return [np.asarray(a) for a in acc]
        shape = blocks[0].shape
        flat = [b.reshape(-1) for b in blocks]
        size = flat[0].shape[0]
        pad = (-size) % n
        if pad:
            # mirror ring.pad_to_multiple(..., monoid=): pad lanes carry
            # the monoid identity, not literal zeros
            fill = np.asarray(monoid.identity(
                jax.ShapeDtypeStruct((), flat[0].dtype)))
            flat = [np.concatenate([f, np.full((pad,), fill, f.dtype)])
                    for f in flat]
        red = self._ring_rs(flat, combine)
        full = self._ring_ag(red)
        return [f[:size].reshape(shape) for f in full]

    def _allreduce_encoded(self, blocks: list, codec) -> list:
        """Mirror of ``collectives._tree_all_reduce_encoded``: encode once,
        chunked RS walk with the encoded-domain combine, gather, decode."""
        n = len(blocks)
        encs = [codec.encode(jnp.asarray(b)) for b in blocks]
        leaves = [jax.tree_util.tree_flatten(e) for e in encs]
        treedef = leaves[0][1]
        nblocks = int(leaves[0][0][0].shape[0])
        pad = (-nblocks) % n

        def pad_leaf(leaf):
            leaf = np.asarray(leaf)
            if pad:
                fill = np.zeros((pad,) + leaf.shape[1:], leaf.dtype)
                leaf = np.concatenate([leaf, fill])
            return leaf

        chunks = [[np.split(pad_leaf(l), n, axis=0) for l in ls]
                  for ls, _ in leaves]     # chunks[rank][leaf][chunk_idx]

        def combine(a_leaves, b_leaves):
            a = jax.tree_util.tree_unflatten(
                treedef, [jnp.asarray(x) for x in a_leaves])
            b = jax.tree_util.tree_unflatten(
                treedef, [jnp.asarray(x) for x in b_leaves])
            return [np.asarray(l) for l in
                    jax.tree_util.tree_leaves(codec.combine_encoded(a, b))]

        buf = [[chunks[i][l][(i - 1) % n]
                for l in range(len(chunks[i]))] for i in range(n)]
        for s in range(n - 1):
            incoming = [buf[(i - 1) % n] for i in range(n)]
            buf = [combine(incoming[i],
                           [chunks[i][l][(i - 2 - s) % n]
                            for l in range(len(chunks[i]))])
                   for i in range(n)]
        # all-gather each leaf: contributor rank r supplies chunk r
        gathered = [np.concatenate([buf[r][l] for r in range(n)], axis=0)
                    [:nblocks]
                    for l in range(len(buf[0]))]
        full = jax.tree_util.tree_unflatten(
            treedef, [jnp.asarray(g) for g in gathered])
        out = np.asarray(codec.decode(full))
        return [out for _ in range(n)]

    def _per_ring(self, axis: str, args: list,
                  fn: Callable[[list], list]) -> list:
        """Apply a ring interpreter along ``axis``; other grid coords are
        independent switch ports."""
        results: dict[int, Array] = {}
        for g in self._rings(axis):
            blocks = [args[0][r] for r in g]
            for r, o in zip(g, fn(blocks)):
                results[int(r)] = o
        first = results[0]
        out = np.empty((self.n_ranks,) + first.shape, first.dtype)
        for r, o in results.items():
            out[r] = o
        return [out]

    def _stage_net(self, st):
        return self.nets.get(st.axis, netmodel.PAPER), \
            self.sizes.get(st.axis, 1)

    def _charge_ring(self, st, clock, per_rank_bytes: float, *,
                     steps: Optional[int] = None, chunked: bool = True,
                     compute: bool = True) -> None:
        p, n = self._stage_net(st)
        if n <= 1:
            return
        steps = steps if steps is not None else n - 1
        chunk = per_rank_bytes / n if chunked else per_rank_bytes
        pl = st.placement
        if pl is not None and not pl.fits and compute:
            # one software injection for the stage's host detour — only
            # on the half that actually computes (an RS∘AG walk must not
            # charge it twice)
            self._advance_local(clock, p.mpi_overhead)
        t_hop = self._hop_time(p, chunk, chunk if compute else 0.0, pl)
        self._advance_ring(clock, st.axis, steps, t_hop,
                           ser_hop=chunk / p.bw)

    # .. stage handlers ......................................................

    def _wire_ratio(self, st) -> float:
        for nd in st.ir.nodes:
            if nd.op.codec is not IDENTITY:
                return float(nd.op.codec.wire_ratio)
        return 1.0

    def _run_allreduce(self, st, args, clock):
        op = next(nd.op for nd in st.ir.nodes
                  if nd.op.kind in (OpKind.REDUCE, OpKind.REDUCE_SCATTER))
        latency = st.schedule == "latency"
        out = self._per_ring(
            st.axis, args,
            lambda blocks: self._allreduce_ring(blocks, op.monoid,
                                                op.codec, latency))
        m = args[0].nbytes / self.n_ranks * self._wire_ratio(st)
        if latency:
            self._charge_ring(st, clock, m, chunked=False)
        else:
            self._charge_ring(st, clock, m)                  # RS half
            self._charge_ring(st, clock, m, compute=False)   # AG half
        return tuple(out)

    # a batched ring (Coalesce batch_rings) is one ring over the stacked
    # payload — identical dataplane walk, so the analytic/simulated
    # agreement for "allreduce" carries over unchanged
    _run_batched_allreduce = _run_allreduce

    def _run_map_allreduce(self, st, args, clock):
        mp = st.ir.nodes[0].op
        mapped = self._apply_map(mp.fn, args)
        return self._run_allreduce(st, [mapped], clock)

    def _run_reduce_scatter(self, st, args, clock):
        op = next(nd.op for nd in st.ir.nodes
                  if nd.op.kind == OpKind.REDUCE_SCATTER)

        def rs(blocks):
            if len(blocks) == 1:
                return list(blocks)
            if op.codec is not IDENTITY:
                enc = [np.asarray(op.codec.encode(jnp.asarray(b)))
                       for b in blocks]
                red = self._ring_rs(enc, op.monoid.combine)
                return [np.asarray(op.codec.decode(jnp.asarray(r))
                                   .astype(blocks[i].dtype))
                        for i, r in enumerate(red)]
            return self._ring_rs(blocks, op.monoid.combine)

        out = self._per_ring(st.axis, args, rs)
        self._charge_ring(st, clock,
                          args[0].nbytes / self.n_ranks
                          * self._wire_ratio(st))
        return tuple(out)

    def _run_map_reduce_scatter(self, st, args, clock):
        mp = st.ir.nodes[0].op
        mapped = self._apply_map(mp.fn, args)
        return self._run_reduce_scatter(st, [mapped], clock)

    def _run_allgather(self, st, args, clock):
        out = self._per_ring(st.axis, args, self._ring_ag)
        self._charge_ring(st, clock, args[0].nbytes / self.n_ranks
                          * (self.sizes.get(st.axis, 1)),
                          compute=False)
        return tuple(out)

    def _run_allgather_map(self, st, args, clock):
        mp = st.ir.nodes[1].op
        out = self._per_ring(
            st.axis, args, lambda blocks: self._ring_ag(blocks, mp.fn))
        self._charge_ring(st, clock, args[0].nbytes / self.n_ranks
                          * (self.sizes.get(st.axis, 1)))
        return tuple(out)

    def _run_alltoall(self, st, args, clock):
        def a2a(blocks):
            n = len(blocks)
            chunks = [np.split(b, n, axis=0) for b in blocks]
            return [np.concatenate([chunks[j][r] for j in range(n)], axis=0)
                    for r in range(n)]

        out = self._per_ring(st.axis, args, a2a)
        self._charge_ring(st, clock, args[0].nbytes / self.n_ranks,
                          compute=False)
        return tuple(out)

    def _run_scan(self, st, args, clock):
        op = next(nd.op for nd in st.ir.nodes if nd.op.kind == OpKind.SCAN)

        def scan(blocks):
            acc = None
            incl = []
            for b in blocks:
                acc = b if acc is None \
                    else np.asarray(op.monoid.combine(jnp.asarray(acc),
                                                      jnp.asarray(b)))
                incl.append(acc)
            if not op.exclusive:
                return incl
            ident = np.asarray(op.monoid.identity(
                jax.ShapeDtypeStruct(blocks[0].shape, blocks[0].dtype)))
            return [ident] + incl[:-1]

        out = self._per_ring(st.axis, args, scan)
        p, n = self._stage_net(st)
        rounds = int(math.ceil(math.log2(max(n, 2)))) if n > 1 else 0
        m = args[0].nbytes / self.n_ranks
        self._advance_ring(clock, st.axis, rounds,
                           self._hop_time(p, m, m, st.placement),
                           ser_hop=m / p.bw)
        return tuple(out)

    def _run_scan_allgather(self, st, args, clock):
        scan_op = st.ir.nodes[1].op

        def fused(blocks):
            if scan_op.monoid.name == "add" and not scan_op.exclusive:
                # allgather_op_allgather: cumsum of the rank-major concat
                full = np.concatenate(blocks, axis=0)
                out = np.cumsum(full, axis=0, dtype=full.dtype)
                return [out for _ in blocks]
            # scan_then_allgather: blockwise rank-prefix scan (exclusive
            # shifts in the monoid identity at rank 0), then gather
            acc = None
            scanned = []
            for b in blocks:
                acc = b if acc is None \
                    else np.asarray(scan_op.monoid.combine(jnp.asarray(acc),
                                                           jnp.asarray(b)))
                scanned.append(acc)
            if scan_op.exclusive:
                ident = np.asarray(scan_op.monoid.identity(
                    jax.ShapeDtypeStruct(blocks[0].shape,
                                         blocks[0].dtype)))
                scanned = [ident] + scanned[:-1]
            full = np.concatenate(scanned, axis=0)
            return [full for _ in blocks]

        out = self._per_ring(st.axis, args, fused)
        p, n = self._stage_net(st)
        m = args[0].nbytes / self.n_ranks
        rounds = int(math.ceil(math.log2(max(n, 2)))) if n > 1 else 0
        self._advance_ring(clock, st.axis, rounds,
                           self._hop_time(p, m, m, st.placement),
                           ser_hop=m / p.bw)
        self._charge_ring(st, clock, m * n, compute=False)   # gather round
        return tuple(out)

    def _run_bcast(self, st, args, clock):
        op = next(nd.op for nd in st.ir.nodes if nd.op.kind == OpKind.BCAST)

        def bc(blocks):
            return [blocks[op.root] for _ in blocks]

        out = self._per_ring(st.axis, args, bc)
        p, n = self._stage_net(st)
        rounds = int(math.ceil(math.log2(max(n, 2)))) if n > 1 else 0
        m = args[0].nbytes / self.n_ranks
        self._advance_ring(clock, st.axis, rounds,
                           self._hop_time(p, m, 0.0, st.placement),
                           ser_hop=m / p.bw)
        return tuple(out)

    def _run_allreduce_alltoall(self, st, args, clock):
        hist_arg, keys_arg = args

        def hist_ring(blocks):
            n = len(blocks)
            acc = [jnp.asarray(b) for b in blocks]
            for s in range(1, n):
                acc = [acc[i] + jnp.asarray(blocks[(i - s) % n])
                       for i in range(n)]
            return [np.asarray(a) for a in acc]

        hist = self._per_ring(st.axis, [hist_arg], hist_ring)[0]

        def a2a(blocks):
            n = len(blocks)
            chunks = [np.split(b, n, axis=0) for b in blocks]
            return [np.concatenate([chunks[j][r] for j in range(n)], axis=0)
                    for r in range(n)]

        keys = self._per_ring(st.axis, [keys_arg], a2a)[0]
        p, n = self._stage_net(st)
        m_keys = keys_arg.nbytes / self.n_ranks
        m_hist = hist_arg.nbytes / self.n_ranks
        # one shared traversal: key chunk + full histogram per hop
        chunk = m_keys / max(n, 1) + m_hist
        self._advance_ring(
            clock, st.axis, max(n - 1, 0),
            self._hop_time(p, chunk, m_hist, st.placement),
            ser_hop=chunk / p.bw)
        return hist, keys

    # .. look-aside (error feedback) ........................................

    def _run_ef_allreduce(self, st, args, clock):
        ef = st.ir.nodes[0].op.ef
        both = len(st.out_vids) == 2
        total, delivered = self._ef(st, args[0], ef)
        m = args[0].nbytes / self.n_ranks
        p, n = self._stage_net(st)
        pl = st.placement
        if pl is not None and not pl.fits:
            self._advance_local(clock, netmodel.host_fallback_time(m, p))
            self._charge_ring(st, clock, m)
        else:
            # compress locally, tiny scale exchange, half-width RS∘AG walk
            self._advance_local(clock, m / netmodel.accel_rate(p, pl))
            self._advance_ring(clock, st.axis, max(n - 1, 0),
                               self._hop_time(p, max(m / 256, 4), 0.0, pl),
                               ser_hop=max(m / 256, 4) / p.bw)
            self._charge_ring(st, clock, m * 0.5)
            self._charge_ring(st, clock, m * 0.5, compute=False)
        return (total, delivered) if both else (total,)

    def _run_delivered(self, st, args, clock):
        ef = st.ir.nodes[0].op.ef
        _, delivered = self._ef(st, args[0], ef)
        p, _ = self._stage_net(st)
        m = args[0].nbytes / self.n_ranks
        if st.placement is not None and not st.placement.fits:
            self._advance_local(clock, netmodel.host_fallback_time(m, p))
        else:
            self._advance_local(clock,
                                m / netmodel.accel_rate(p, st.placement))
        return (delivered,)

    def _ef(self, st, arg: Array, ef) -> tuple[Array, Array]:
        """Mirror of :func:`repro.core.lookaside.compressed_all_reduce`."""
        dtype = arg.dtype

        def per_ring(blocks):
            tf = [b.astype(np.float32) for b in blocks]
            if ef.compressor == "int8":
                tot, dlv = self._ef_int8(tf)
            elif ef.compressor == "int8_hopquant":
                codec = int8_codec()
                tot = self._allreduce_encoded(tf, codec)
                dlv = [np.asarray(codec.decode(codec.encode(jnp.asarray(t))))
                       for t in tf]
            elif ef.compressor == "topk":
                tot, dlv = self._ef_topk(tf, ef.topk_ratio)
            else:
                raise ValueError(f"unknown compressor {ef.compressor!r}")
            return [(t.astype(dtype), d) for t, d in zip(tot, dlv)]

        results: dict[int, tuple] = {}
        for g in self._rings(st.axis):
            blocks = [arg[r] for r in g]
            for r, o in zip(g, per_ring(blocks)):
                results[int(r)] = o
        tot = np.empty((self.n_ranks,) + results[0][0].shape,
                       results[0][0].dtype)
        dlv = np.empty((self.n_ranks,) + results[0][1].shape,
                       results[0][1].dtype)
        for r, (t, d) in results.items():
            tot[r], dlv[r] = t, d
        return tot, dlv

    @staticmethod
    def _ef_int8(tf: list) -> tuple[list, list]:
        """Shared-scale exact-integer accumulation (lookaside.QBLOCK)."""
        block = 256
        shape = tf[0].shape
        size = tf[0].size
        pad = (-size) % block

        def blocks_of(x):
            flat = x.reshape(-1)
            if pad:
                flat = np.concatenate([flat,
                                       np.zeros((pad,), np.float32)])
            return flat.reshape(-1, block)

        bl = [blocks_of(x) for x in tf]
        absmax = np.max(np.stack([np.max(np.abs(b), axis=1) for b in bl]),
                        axis=0)
        scale = np.where(absmax > 0, absmax / 127.0, 1.0).astype(np.float32)
        qs = [np.clip(np.round(b / scale[:, None]), -127, 127)
              .astype(np.int16) for b in bl]
        qsum = np.sum(np.stack(qs), axis=0, dtype=np.int32).astype(np.int16)
        total = (qsum.astype(np.float32) * scale[:, None]) \
            .reshape(-1)[:size].reshape(shape)
        delivered = [(q.astype(np.float32) * scale[:, None])
                     .reshape(-1)[:size].reshape(shape) for q in qs]
        return [total for _ in tf], delivered

    @staticmethod
    def _ef_topk(tf: list, ratio: float) -> tuple[list, list]:
        size = tf[0].size
        k = max(1, int(size * ratio))
        dense = np.zeros((size,), np.float32)
        delivered = []
        for x in tf:
            flat = x.reshape(-1)
            idx = np.argsort(np.abs(flat))[::-1][:k]
            own = np.zeros((size,), np.float32)
            np.add.at(own, idx, flat[idx])
            np.add.at(dense, idx, flat[idx])
            delivered.append(own.reshape(x.shape))
        total = dense.reshape(tf[0].shape)
        return [total for _ in tf], delivered
