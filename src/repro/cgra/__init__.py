"""repro.cgra — the switch hardware half of the reproduction.

Three layers (paper §IV + §VI):

  * :mod:`repro.cgra.device`   — the parameterized CGRA grid model
    (PEs, op slots, routing/register budgets, line rate).
  * :mod:`repro.cgra.mapper`   — stage compute body → jaxpr → op-graph →
    place-and-route; the :class:`PlaceCGRA` compiler pass attaching a
    :class:`Placement` or explicit :class:`HostFallback` to every stage.
  * :mod:`repro.cgra.simulate` — a discrete-event, multi-port switch
    dataplane simulator executing a :class:`CompiledProgram` across N
    simulated ranks in one process, reporting simulated latency next to
    the :mod:`repro.core.netmodel` analytic prediction.

Only :mod:`.device` is imported eagerly: :mod:`repro.core.netmodel`
derives its accelerator rates from it, so this package ``__init__`` must
stay import-light (mapper/simulate pull in the compiler, which pulls in
netmodel — eager imports here would cycle).
"""

from repro.cgra.device import (CGRADevice, HostFallback, PAPER_CGRA,
                               Placement, placement_rate, route_through)

__all__ = [
    "CGRADevice", "HostFallback", "PAPER_CGRA", "Placement",
    "placement_rate", "route_through",
    # lazy (PEP 562):
    "PlaceCGRA", "place_stage", "SwitchSim", "SimReport", "FaultPlan",
]

_LAZY = {
    "PlaceCGRA": "repro.cgra.mapper",
    "place_stage": "repro.cgra.mapper",
    "lower_jaxpr": "repro.cgra.mapper",
    "trace_body": "repro.cgra.mapper",
    "SwitchSim": "repro.cgra.simulate",
    "SimReport": "repro.cgra.simulate",
    "FaultPlan": "repro.cgra.simulate",
}


def __getattr__(name):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.cgra' has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(mod), name)
