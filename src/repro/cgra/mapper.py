"""CGRA mapper — lower a stage's compute body onto the switch grid.

The paper's §VI toolchain: user source → dataflow graph → schedule /
place onto the CGRA → binary.  Here the "user source" is whatever
compute a compiled stage carries — fused MAP bodies, the collective's
monoid combine, a wire codec's encoded-domain combine, a look-aside
compressor — traced to a jaxpr, lowered to a small op-graph, and
list-scheduled onto the :class:`~repro.cgra.device.CGRADevice` grid:

  * ASAP levels give the pipeline stages; level *l* places on grid row
    ``l % rows``, greedily left to right (spill rows fold into II).
  * ALU primitives take one PE slot; accumulator primitives take one PE
    plus ``log2(extent)`` pipeline depth (a balanced combine tree);
    steering primitives are absorbed by the interconnect.
  * Anything else — ``gather``/``scatter`` (random access), ``sort`` /
    ``top_k`` (no sort network), ``dot_general`` (no MAC array),
    ``scan``/``while`` (no sequential controller) — does not fit, and
    the stage gets an explicit :class:`HostFallback`.

Tracing runs under nested ``jax.vmap(..., axis_name=...)`` frames, one
per topology axis, so compute bodies may legitimately query
``lax.axis_size`` (the compiler's own pad/unpad bookkeeping maps do).
A body that performs *communication* (``ppermute`` et al.) batches into
gathers under those frames and is therefore caught by the same
unsupported-primitive check — a collective inside a MAP body is endpoint
code, not something one switch's array can run.

:class:`PlaceCGRA` is the compiler pass (pipeline position: after
SelectSchedule, before Emit) that attaches a placement — or fallback —
to every stage.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.cgra.device import (ACCUM_PRIMS, ALU_PRIMS, CALL_PRIMS,
                               CGRADevice, HostFallback, PAPER_CGRA,
                               Placement, ROUTE_PRIMS, route_through)
from repro.core import netmodel
from repro.core.program import COLLECTIVE_KINDS, OpKind
from repro.core.wire import IDENTITY

Aval = jax.ShapeDtypeStruct

# Dummy rank-local shape used when no avals were provided to the
# compiler: elementwise op-graphs are shape-independent, so a small
# stand-in is enough to recover the graph structure.
_FALLBACK_AVAL = Aval((64,), jnp.float32)


# ---------------------------------------------------------------------------
# jaxpr → op-graph
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OpGraph:
    """Flattened compute body: primitive names with ASAP levels."""

    ops: tuple            # (prim_name, level) for ALU/accumulator ops
    n_route: int
    depth: int            # pipeline depth incl. accumulator trees

    @property
    def n_ops(self) -> int:
        return len(self.ops)


def _reduced_extent(eqn) -> int:
    """Elements folded by an accumulator primitive (for tree depth)."""
    try:
        (invar,) = eqn.invars[:1]
        size = int(max(
            (d for d in getattr(invar.aval, "shape", (1,)) or (1,)),
            default=1))
        return max(size, 2)
    except Exception:
        return 2


def _walk(jaxpr, levels: dict, ops: list, route: list,
          supported: frozenset) -> None:
    def level_of(v) -> int:
        return levels.get(id(v), 0)

    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        sub = [v for v in eqn.params.values() if hasattr(v, "jaxpr")]
        if name in CALL_PRIMS:
            # recurse through call-like wrappers only; inner vars start
            # at the call site's input level
            base = max((level_of(v) for v in eqn.invars), default=0)
            for closed in sub:
                inner = closed.jaxpr
                for iv, ov in zip(eqn.invars, inner.invars):
                    levels[id(ov)] = level_of(iv)
                _walk(inner, levels, ops, route, supported)
                for iv, ov in zip(inner.outvars, eqn.outvars):
                    levels[id(ov)] = levels.get(id(iv), base)
            continue
        if sub:
            # scan/while/cond and friends: a sequential controller the
            # spatial pipeline does not have — never place these silently
            raise _Unsupported(name)
        lvl = max((level_of(v) for v in eqn.invars), default=0)
        if name in ROUTE_PRIMS:
            route.append(name)
            out_lvl = lvl
        elif name in ACCUM_PRIMS:
            tree = int(math.ceil(math.log2(_reduced_extent(eqn))))
            ops.append((name, lvl))
            out_lvl = lvl + tree
        elif name in supported:
            ops.append((name, lvl))
            out_lvl = lvl + 1
        else:
            raise _Unsupported(name)
        for ov in eqn.outvars:
            levels[id(ov)] = out_lvl


class _Unsupported(Exception):
    def __init__(self, prim: str):
        super().__init__(prim)
        self.prim = prim


def lower_jaxpr(closed_jaxpr,
                supported: frozenset = ALU_PRIMS) -> OpGraph:
    """Lower a (closed) jaxpr to an :class:`OpGraph`.

    ``supported`` is the target device's ALU vocabulary
    (:attr:`CGRADevice.supported`) — raises :class:`_Unsupported` on the
    first primitive outside it (or outside the structural
    accumulator/steering classes).
    """
    levels: dict = {}
    ops: list = []
    route: list = []
    _walk(closed_jaxpr.jaxpr, levels, ops, route, supported)
    depth = max([lvl + 1 for _, lvl in ops], default=0)
    return OpGraph(tuple(ops), len(route), depth)


def trace_body(fn: Callable, avals: Sequence[Aval],
               axis_env: Optional[dict] = None):
    """``jax.make_jaxpr`` of a stage body with topology axes bound.

    ``axis_env`` maps axis name → size; the body is wrapped in one
    ``vmap(axis_name=...)`` frame per axis (sizes default to 2) so
    rank-local bookkeeping such as ``lax.axis_size`` traces.  The batch
    dims are an artifact of the binding — the op-graph reader only looks
    at primitive structure, which vmap preserves for elementwise work.
    """
    axis_env = axis_env or {}
    wrapped = fn
    sizes = []
    for ax, n in reversed(list(axis_env.items())):
        wrapped = jax.vmap(wrapped, axis_name=ax)
        sizes.insert(0, int(n) if n else 2)
    lead = tuple(sizes)
    args = [Aval(lead + tuple(a.shape), a.dtype) for a in avals]
    return jax.make_jaxpr(wrapped)(*args)


# ---------------------------------------------------------------------------
# placement (list scheduling + greedy grid assignment)
# ---------------------------------------------------------------------------

def place_opgraph(graph: OpGraph, device: CGRADevice
                  ) -> "Placement | HostFallback":
    """Place a lowered op-graph onto the grid; the doesn't-fit outcomes
    are explicit so callers can cost the host detour."""
    if graph.n_ops == 0:
        if graph.n_route > device.route_budget:
            return HostFallback(
                f"{graph.n_route} steering ops exceed the routing budget "
                f"({device.route_budget})")
        return route_through(device, graph.n_route)
    if graph.n_ops > device.op_slots:
        return HostFallback(
            f"op graph needs {graph.n_ops} ALU slots, device has "
            f"{device.op_slots} ({device.n_pes} PEs x "
            f"{device.ops_per_pe} slots)")
    if graph.n_route > device.route_budget:
        return HostFallback(
            f"{graph.n_route} steering ops exceed the routing budget "
            f"({device.route_budget})")
    if graph.depth > device.max_depth:
        return HostFallback(
            f"pipeline depth {graph.depth} exceeds the register budget "
            f"({device.max_depth})")

    # Greedy level-major placement: level l starts on row l % rows and
    # claims columns left to right; a level wider than the row wraps to
    # the next row (still one spatial wave as long as PEs remain).
    occupied: list = []
    slot_use: dict = {}
    r = c = 0
    for prim, lvl in sorted(graph.ops, key=lambda o: o[1]):
        placed = False
        for _ in range(device.n_pes * device.ops_per_pe):
            pe = (r, c)
            if slot_use.get(pe, 0) < device.ops_per_pe:
                slot_use[pe] = slot_use.get(pe, 0) + 1
                if pe not in occupied:
                    occupied.append(pe)
                placed = True
                break
            c += 1
            if c == device.cols:
                c, r = 0, (r + 1) % device.rows
        if not placed:                             # pragma: no cover
            return HostFallback("placement overflow")
    ii = max(1, math.ceil(graph.n_ops / device.n_pes))
    return Placement(device=device, n_ops=graph.n_ops,
                     n_route=graph.n_route, depth=graph.depth, ii=ii,
                     pes=tuple(occupied),
                     ops=tuple(p for p, _ in sorted(graph.ops,
                                                    key=lambda o: o[1])))


# ---------------------------------------------------------------------------
# stage compute bodies
# ---------------------------------------------------------------------------

def _codec_combine_body(monoid, codec, aval) -> tuple[Callable, tuple]:
    """What one hop's aggregation unit actually computes for a reduce.

    For an encoded-domain codec, both operands arrive *already encoded*
    (the payload is coded once at injection, not per hop), so the hop
    body is ``combine_encoded`` alone over the encoded leaves.
    """
    if codec is IDENTITY:
        return monoid.combine, (aval, aval)
    if codec.combine_encoded is not None:
        enc = jax.eval_shape(codec.encode, aval)
        leaves, tree = jax.tree_util.tree_flatten(enc)
        k = len(leaves)

        def body(*flat):
            a = jax.tree_util.tree_unflatten(tree, flat[:k])
            b = jax.tree_util.tree_unflatten(tree, flat[k:])
            return codec.combine_encoded(a, b)

        avals = tuple(Aval(tuple(l.shape), l.dtype) for l in leaves)
        return body, avals + avals
    # cast-style codec: hops combine in the wire dtype
    return (lambda a, b: monoid.combine(codec.encode(a), codec.encode(b)),
            (aval, aval))


def _monoid_combine(monoid) -> Callable:
    return monoid.combine


def _int8_local_body(t):
    """Rank-local half of the shared-scale int8 compressor (the part the
    switch pipeline runs per payload block): blockwise absmax → scale →
    quantize → dequantize.  The tiny scale max-allreduce is network, not
    PE work."""
    block = 256
    flat = t.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    blocks = flat.reshape(-1, block)
    absmax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int16)
    return (q.astype(jnp.float32) * scale).reshape(flat.shape)


def _topk_local_body(t, ratio):
    flat = t.reshape(-1)
    k = max(1, int(flat.shape[0] * ratio))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return vals, idx


def _ef_body(ef) -> tuple[Callable, str]:
    if ef.compressor in ("int8", "int8_hopquant"):
        return _int8_local_body, f"{ef.compressor} quantize pipeline"
    if ef.compressor == "topk":
        return (lambda t: _topk_local_body(t, ef.topk_ratio),
                "top-k sparsifier")
    return (lambda t: t), ef.compressor


_MOVEMENT_KINDS = {OpKind.ALLGATHER, OpKind.ALLTOALL, OpKind.BCAST}


def stage_bodies(stage_ir, aval_of: Callable[[int], Aval]
                 ) -> list[tuple[Callable, tuple, str]]:
    """The compute bodies one stage streams through the array.

    Returns ``[(fn, avals, label), ...]`` — fused stages contribute one
    body per compute-carrying node (a map fused into a reduce means the
    pipe runs map *then* combine on every word-group).
    """
    bodies: list = []
    for nd in stage_ir.nodes:
        op = nd.op
        if op.kind == OpKind.MAP:
            avals = tuple(aval_of(v) for v in nd.inputs)
            bodies.append((op.fn, avals, f"map:{op.name or 'fn'}"))
        elif op.kind in (OpKind.REDUCE, OpKind.REDUCE_SCATTER, OpKind.SCAN):
            aval = aval_of(nd.inputs[0])
            if op.ef is not None:
                fn, label = _ef_body(op.ef)
                bodies.append((fn, (aval,), label))
            else:
                label = f"{op.monoid.name}-combine"
                if op.codec is not IDENTITY:
                    label += f"@{op.codec.name}"
                try:
                    fn, avals = _codec_combine_body(op.monoid, op.codec,
                                                    aval)
                except Exception as e:
                    return [((lambda: None), (), f"{label}: uncodable "
                             f"({type(e).__name__})")]
                bodies.append((fn, avals, label))
        elif op.kind == OpKind.DELIVERED and op.ef is not None:
            # in a fused REDUCE+DELIVERED pair the compression runs once
            # and yields both outputs — don't double-count the pipeline
            paired = any(o.op.kind == OpKind.REDUCE and o.op.ef == op.ef
                         for o in stage_ir.nodes)
            if not paired:
                fn, label = _ef_body(op.ef)
                bodies.append((fn, (aval_of(nd.inputs[0]),), label))
        # movement kinds carry no ALU body
    return bodies


def place_stage(stage_ir, device: CGRADevice,
                aval_of: Callable[[int], Aval],
                axis_env: Optional[dict] = None
                ) -> "Placement | HostFallback":
    """Map one fused stage's full compute body onto the device.

    Multiple bodies (map ∘ combine) chain in the pipe: op slots add,
    depths add.  No body at all is pure movement — a route-through.
    """
    bodies = stage_bodies(stage_ir, aval_of)
    if not bodies:
        return route_through(device,
                             note="forwarding/replication, no PE compute")
    ops: list = []
    n_route = 0
    depth = 0
    for fn, avals, label in bodies:
        try:
            jaxpr = trace_body(fn, avals, axis_env)
        except _Unsupported as e:                  # pragma: no cover
            return HostFallback(f"{label}: primitive {e.prim!r} "
                                "not implemented by the switch CGRA")
        except Exception as e:
            return HostFallback(
                f"{label}: body is not a rank-local dataflow graph "
                f"({type(e).__name__}: {e})"[:300])
        try:
            g = lower_jaxpr(jaxpr, device.supported)
        except _Unsupported as e:
            return HostFallback(f"{label}: primitive {e.prim!r} "
                                "not implemented by the switch CGRA")
        ops.extend((p, lvl + depth) for p, lvl in g.ops)
        n_route += g.n_route
        depth += g.depth
    return place_opgraph(OpGraph(tuple(ops), n_route, depth), device)


# ---------------------------------------------------------------------------
# place_groups — the body of the compiler's PlaceCGRA pass
# ---------------------------------------------------------------------------

def place_groups(groups: list, ctx,
                 device: Optional[CGRADevice] = None) -> list:
    """Attach a CGRA placement (or host fallback) to every stage group.

    Called by :class:`repro.core.compiler.PlaceCGRA` (which defers the
    import of this module so the two packages stay import-acyclic).
    """
    device = device \
        or getattr(ctx.config, "cgra_device", None) or PAPER_CGRA
    avals = _value_avals(ctx)

    def aval_of(vid: int) -> Aval:
        return avals.get(vid, _FALLBACK_AVAL)

    axis_env = _axis_env(ctx)
    out = []
    for g in groups:
        pl = place_stage(g, device, aval_of, axis_env)
        desc = g.desc
        t = _stage_model_time(g, pl, ctx, avals)
        note = pl.describe() + (f"; model {t * 1e6:.1f}us"
                                if t is not None else "")
        desc = f"{desc} | {note}" if desc else note
        out.append(dataclasses.replace(g, placement=pl, desc=desc))
    return out


def _axis_env(ctx) -> dict:
    env: dict = {}
    topo = getattr(ctx, "topology", None)
    if topo is not None:
        for a in topo.axes:
            env[a.name] = a.size or 2
    elif getattr(ctx, "axis_name", None):
        env[ctx.axis_name] = getattr(ctx, "axis_size", None) or 2
    return env


def _value_avals(ctx) -> dict[int, Aval]:
    """Best-effort rank-local avals for every DAG value (shapes drive
    body tracing; sizes drive the model re-cost).  Mirrors
    SelectSchedule's byte propagation, but in shape space."""
    if ctx.in_avals is None or ctx.dag is None:
        return {}
    avals: dict[int, Aval] = {
        i: Aval(tuple(a.shape), a.dtype)
        for i, a in enumerate(ctx.in_avals)}
    axis_env = _axis_env(ctx)
    for nd in ctx.dag.nodes:
        k = nd.op.kind
        ins = [avals.get(v) for v in nd.inputs]
        if k == OpKind.MAP:
            if any(a is None for a in ins):
                continue
            try:
                jaxpr = trace_body(nd.op.fn, ins, axis_env)
                out_aval = jaxpr.out_avals[0]
                lead = len(axis_env)
                avals[nd.out] = Aval(tuple(out_aval.shape[lead:]),
                                     out_aval.dtype)
            except Exception:
                pass
            continue
        if ins and ins[0] is not None:
            src = ins[0]
            ax = nd.op.axis if isinstance(nd.op.axis, str) else None
            n = axis_env.get(ax or getattr(ctx, "axis_name", ""), None)
            if k == OpKind.ALLGATHER and n and src.shape:
                avals[nd.out] = Aval((src.shape[0] * n,) + src.shape[1:],
                                     src.dtype)
            elif k == OpKind.REDUCE_SCATTER and n and src.shape:
                avals[nd.out] = Aval(
                    (max(src.shape[0] // n, 1),) + src.shape[1:], src.dtype)
            else:
                avals[nd.out] = src
    return avals


def _stage_model_time(g, placement, ctx, avals) -> Optional[float]:
    """Analytic stage time with the placement-derived rate (None when
    the payload is unknown)."""
    aval = avals.get(g.in_vids[0]) if g.in_vids else None
    if aval is None:
        return None
    m = int(math.prod(aval.shape or (1,))) * jnp.dtype(aval.dtype).itemsize
    axis = g.axis or getattr(ctx, "axis_name", "")
    n = ctx.size_of(axis) if axis else None
    p = ctx.net_of(axis) if axis else getattr(ctx, "net", netmodel.PAPER)
    try:
        return netmodel.stage_time(g.kind, n or 1, m, p,
                                   placement=placement,
                                   schedule=g.schedule,
                                   codec_ratio=_codec_ratio(g))
    except Exception:
        return None


def _codec_ratio(g) -> float:
    for nd in g.nodes:
        if nd.op.kind in COLLECTIVE_KINDS and nd.op.codec is not IDENTITY:
            return float(nd.op.codec.wire_ratio)
    return 1.0


# Re-export of the compiler pass that drives place_groups, so
# `from repro.cgra.mapper import PlaceCGRA` keeps working (the class
# lives in repro.core.compiler to keep module imports acyclic).
from repro.core.compiler import PlaceCGRA  # noqa: E402

__all__ = ["PlaceCGRA", "place_groups", "place_stage", "place_opgraph",
           "stage_bodies", "trace_body", "lower_jaxpr", "OpGraph"]
