"""Deadline-bounded sync: retry/backoff around the masked collective.

The compiled masked sync (``gradient_sync(membership=...)``) is a pure
mechanism — it reduces whatever the mask says is alive.  This module is
the host-side control loop around it: run a sync attempt, judge each
rank's measured completion time against the deadline, mask the late
ranks, and retry with a backed-off deadline so a *transient* divergence
(one slow attempt) doesn't permanently evict a healthy rank's pod —
permanent eviction is the caller's decision, taken from the returned
membership.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

from repro.elastic.membership import Membership
from repro.obs import metrics as _obs


class ElasticSyncError(RuntimeError):
    """Retries exhausted (or every rank went dead) without a clean sync."""


@dataclasses.dataclass(frozen=True)
class SyncOutcome:
    """One deadline-bounded sync: the result of the last attempt, the
    membership after deadline verdicts, and how hard we had to try."""

    result: object
    membership: Membership
    attempts: int
    deadline_s: float            # the (possibly backed-off) final deadline
    masked: tuple[int, ...] = ()  # ranks masked across all attempts


def sync_with_deadline(
    run: Callable[[Membership, float], tuple[object, Sequence[float]]],
    membership: Membership,
    *,
    deadline_s: float,
    max_retries: int = 3,
    backoff: float = 2.0,
) -> SyncOutcome:
    """Run ``run(membership, deadline_s) -> (result, rank_times)`` until
    every *alive* rank meets the deadline.

    Ranks over the deadline are masked (they stop contributing — the
    masked collective renormalizes by the live count) and the attempt is
    retried with the shrunk membership and a ×``backoff`` deadline, up
    to ``max_retries`` retries.  An attempt with no late ranks returns
    immediately; its result IS the sync result — late ranks' data from
    *earlier* attempts is never mixed in.

    Raises :class:`ElasticSyncError` when retries are exhausted with
    ranks still missing the deadline, or when masking would kill the
    last alive rank.
    """
    if membership.n_alive == 0:
        raise ElasticSyncError("no alive ranks to sync over")
    deadline = float(deadline_s)
    masked_total: list[int] = []
    for attempt in range(1, max_retries + 2):
        result, times = run(membership, deadline)
        late = tuple(r for r, t in enumerate(times)
                     if r < membership.n_ranks and membership.alive[r]
                     and t > deadline)
        if not late:
            return SyncOutcome(result=result, membership=membership,
                               attempts=attempt, deadline_s=deadline,
                               masked=tuple(masked_total))
        _obs.RECORDER.count("elastic.deadline_miss", len(late))
        _obs.RECORDER.event("elastic.deadline_miss", attempt=attempt,
                            late=list(late), deadline_s=deadline)
        membership = membership.drop(*late)
        masked_total.extend(late)
        if membership.n_alive == 0:
            raise ElasticSyncError(
                f"every rank missed the {deadline:g}s deadline "
                f"(attempt {attempt})")
        if attempt == max_retries + 1:
            break
        deadline *= backoff
        _obs.RECORDER.count("elastic.retry")
    raise ElasticSyncError(
        f"ranks {late} still over deadline after {max_retries} retries")


def deadline_verdicts(rank_times: Sequence[float], deadline_s: float,
                      *, membership: Optional[Membership] = None
                      ) -> Membership:
    """Pure verdict helper: alive iff within deadline (intersected with
    an existing membership when given — a dead rank stays dead even if
    its reported time is stale-small)."""
    fresh = Membership.from_rank_times(rank_times, deadline_s)
    return fresh if membership is None else membership.merge(fresh)
