"""Membership — the runtime's view of which ranks are alive.

The elastic story splits cleanly in two: the *mechanism* lives in the
compiler (``tracing.masked_reduce`` folds the live count into the payload
ring; the alive mask is a runtime program input so membership flips never
retrace), and the *policy* lives here — who is alive, decided from
measured per-rank spans against a deadline, and what a membership change
means for the compiled artifacts (:class:`TopologyDelta` →
``engine.recompile``).

Rank numbering convention: ``rank = outer_index * |inner| + inner_index``
— the flat row-major order of a ``(outer, inner)`` mesh, matching
``CollectiveEngine._local_alive`` and ``SwitchSim``'s device order.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping, Optional

from repro.obs import metrics as _obs


@dataclasses.dataclass(frozen=True)
class TopologyDelta:
    """What changed between two membership views / network states.

    ``axis_sizes`` stays ``None`` for every change the alive mask can
    absorb (rank dropout, rank return, ×k link degradation): those are
    *shape-preserving* — rank-local buffer shapes don't move, so
    ``engine.recompile`` reuses the cached program and arenas outright.
    Set ``axis_sizes`` only when ranks actually leave the ring (the mesh
    shrinks) and every rank's shard shapes change with it.
    """

    dropped: tuple[int, ...] = ()
    restored: tuple[int, ...] = ()
    # ((axis, k), ...): links on `axis` degraded to 1/k bandwidth
    degraded_links: tuple[tuple[str, float], ...] = ()
    # {axis: new_size} when the mesh itself changes — forces full recompile
    axis_sizes: Optional[tuple[tuple[str, int], ...]] = None

    @property
    def shape_preserving(self) -> bool:
        return self.axis_sizes is None

    def __bool__(self) -> bool:
        return bool(self.dropped or self.restored or self.degraded_links
                    or self.axis_sizes is not None)


@dataclasses.dataclass(frozen=True)
class Membership:
    """Immutable alive-mask over ``n_ranks`` linear ranks.

    Feed it to ``engine.gradient_sync(..., membership=...)`` (the mask
    becomes a runtime input of the compiled masked sync) and to
    ``engine.recompile(membership_a.delta(membership_b), ...)`` when it
    changes.  Build verdicts from measured spans with
    :meth:`from_rank_times` / :meth:`from_report`.
    """

    alive: tuple[bool, ...]

    def __post_init__(self):
        object.__setattr__(self, "alive",
                           tuple(bool(a) for a in self.alive))
        if not self.alive:
            raise ValueError("membership over zero ranks")

    # -- constructors --------------------------------------------------------

    @classmethod
    def all_alive(cls, n_ranks: int) -> "Membership":
        return cls((True,) * n_ranks)

    @classmethod
    def from_rank_times(cls, rank_times: Iterable[float],
                        deadline_s: float) -> "Membership":
        """Deadline verdicts from measured per-rank sync spans (seconds):
        a rank is alive iff it finished within the deadline."""
        return cls(tuple(t <= deadline_s for t in rank_times))

    @classmethod
    def from_report(cls, report, deadline_s: float) -> "Membership":
        """Verdicts from a :class:`repro.cgra.simulate.SimReport` (or any
        object with ``rank_t_end``: per-rank completion times)."""
        return cls.from_rank_times(report.rank_t_end, deadline_s)

    # -- views ---------------------------------------------------------------

    @property
    def n_ranks(self) -> int:
        return len(self.alive)

    @property
    def n_alive(self) -> int:
        return sum(self.alive)

    @property
    def dead(self) -> tuple[int, ...]:
        return tuple(r for r, a in enumerate(self.alive) if not a)

    def mask_array(self, dtype=None):
        """The alive mask as a jnp array (float32 by default) — what
        ``gradient_sync`` indexes by ``axis_index`` at runtime."""
        import jax.numpy as jnp

        return jnp.asarray(self.alive, dtype or jnp.float32)

    # -- updates -------------------------------------------------------------

    def drop(self, *ranks: int) -> "Membership":
        bad = [r for r in ranks if not 0 <= r < self.n_ranks]
        if bad:
            raise ValueError(f"ranks {bad} out of range 0..{self.n_ranks-1}")
        dead = set(ranks)
        return Membership(tuple(a and r not in dead
                                for r, a in enumerate(self.alive)))

    def restore(self, *ranks: int) -> "Membership":
        back = set(ranks)
        return Membership(tuple(a or r in back
                                for r, a in enumerate(self.alive)))

    def merge(self, other: "Membership") -> "Membership":
        """Intersection: alive only where both views agree."""
        if other.n_ranks != self.n_ranks:
            raise ValueError("membership size mismatch")
        return Membership(tuple(a and b
                                for a, b in zip(self.alive, other.alive)))

    def delta(self, new: "Membership",
              degraded_links: Optional[Mapping[str, float]] = None,
              axis_sizes: Optional[Mapping[str, int]] = None
              ) -> TopologyDelta:
        """The :class:`TopologyDelta` taking this view to ``new``."""
        if new.n_ranks != self.n_ranks:
            raise ValueError("membership size mismatch")
        dropped = tuple(r for r in range(self.n_ranks)
                        if self.alive[r] and not new.alive[r])
        restored = tuple(r for r in range(self.n_ranks)
                         if not self.alive[r] and new.alive[r])
        d = TopologyDelta(
            dropped=dropped, restored=restored,
            degraded_links=tuple(sorted((degraded_links or {}).items())),
            axis_sizes=tuple(sorted(axis_sizes.items()))
            if axis_sizes else None)
        if dropped:
            _obs.RECORDER.count("elastic.rank_dropped", len(dropped))
        if restored:
            _obs.RECORDER.count("elastic.rank_restored", len(restored))
        return d
