"""repro.elastic — elastic, fault-tolerant sync as a runtime citizen.

Three pieces (see ROADMAP "Elastic, fault-tolerant sync"):

  * :class:`Membership` — the alive-mask view, with deadline verdicts
    fed from measured per-rank spans (``obs`` / ``SwitchSim`` reports).
  * :func:`sync_with_deadline` — retry/backoff control loop around the
    compiled masked collective (``gradient_sync(membership=...)``).
  * :class:`TopologyDelta` — what changed, and whether
    ``engine.recompile`` may reuse the cached program + arenas
    (shape-preserving) or must compile fresh (shapes moved).

The compiled mechanism itself lives in the compiler
(:func:`repro.core.tracing.masked_reduce`) — the mask is a runtime
program input, so membership changes never retrace.
"""

from repro.elastic.membership import Membership, TopologyDelta
from repro.elastic.sync import (ElasticSyncError, SyncOutcome,
                                deadline_verdicts, sync_with_deadline)

__all__ = [
    "Membership", "TopologyDelta", "ElasticSyncError", "SyncOutcome",
    "deadline_verdicts", "sync_with_deadline",
]
