"""Activation sharding constraints (trace-time, context-managed).

GSPMD's global sharding inference occasionally prefers activation-sized
all-reduces over weight all-gathers (observed: 335 MB/device per layer on
the rwkv6 cell).  The standard discipline (MaxText et al.) pins activation
shardings at block boundaries; model code calls :func:`shard_act` with
logical dim names and the active context maps them to mesh axes.

The context is entered *inside* the traced step function (it is a pure
trace-time effect), so jitted programs built by the cell/step builders get
constraints while eager test code (no context) is untouched.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX: contextvars.ContextVar[Optional["ActCtx"]] = \
    contextvars.ContextVar("act_sharding_ctx", default=None)


class ActCtx:
    def __init__(self, mesh: Mesh, *, dp: bool = True, tp: bool = True,
                 parallelism: str = "fsdp_tp"):
        names = ("pod", "data", "model") if parallelism == "pure_dp" \
            else ("pod", "data")
        self.mesh = mesh
        self.dp_axes = tuple(a for a in names
                             if a in mesh.axis_names) if dp else ()
        self.tp_axis = "model" if tp and parallelism != "pure_dp" \
            and "model" in mesh.axis_names else None


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, *, dp: bool = True, tp: bool = True,
                        parallelism: str = "fsdp_tp"):
    tok = _CTX.set(ActCtx(mesh, dp=dp, tp=tp, parallelism=parallelism))
    try:
        yield
    finally:
        _CTX.reset(tok)


def shard_act(x: jax.Array, *dims: Optional[str]) -> jax.Array:
    """Constrain ``x``; ``dims`` name each axis: "dp" | "tp" | None.

    "tp" is dropped when the dim size doesn't divide the model axis
    (e.g. 12 whisper heads on a 16-way axis).  No-op without a context.
    """
    ctx = _CTX.get()
    if ctx is None:
        return x
    assert len(dims) == x.ndim, (dims, x.shape)
    spec = []
    for d, size in zip(dims, x.shape):
        if d == "dp" and ctx.dp_axes:
            total = 1
            for a in ctx.dp_axes:
                total *= ctx.mesh.shape[a]
            spec.append(ctx.dp_axes if size % total == 0 and size > 1
                        else None)
        elif d == "tp" and ctx.tp_axis and \
                size % ctx.mesh.shape[ctx.tp_axis] == 0:
            spec.append(ctx.tp_axis)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*spec)))
