"""Parameter / activation sharding rules (DP × FSDP × TP × EP).

Logical scheme on the production mesh ("pod", "data", "model"):

  * batch           → ("pod", "data")              (DP)
  * weight in-dims  → "data"                       (FSDP / ZeRO)
  * weight out-dims → "model"                      (TP, Megatron col/row)
  * vocab           → "model"                      (vocab-parallel embed+head)
  * experts         → "model" when divisible (EP), else expert-internal TP
  * scan dim (L)    → unsharded

Rules match on parameter *path* (joined with '/') and param rank; paths
under "layers/" carry a leading stacked dim that gets a None prepended.
Anything unmatched is replicated — norms, gates, biases, small vectors.
"""

from __future__ import annotations

import re
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

FSDP = "data"
TP = "model"


def dp_axes(mesh: Mesh, parallelism: str = "fsdp_tp") -> tuple[str, ...]:
    axes = ("pod", "data", "model") if parallelism == "pure_dp" else \
        ("pod", "data")
    return tuple(a for a in axes if a in mesh.axis_names)


def _div(n: int, mesh: Mesh, axis: str) -> bool:
    return axis in mesh.axis_names and n % mesh.shape[axis] == 0


# (regex, builder(shape, mesh) -> PartitionSpec)  — first match wins.
def _rules():
    return [
        # embedding table: FEATURE-sharded (P(None, model)), not vocab-
        # sharded — SPMD partitions the token gather trivially on the
        # feature dim, whereas a vocab-sharded operand forces involuntary
        # full rematerialization (observed: full [B,T,D] replication).
        # Worst case (nemotron 256k×6144 bf16) is 3.1 GB / 16 = 197 MB/chip.
        (r"embed$", lambda s, m: P(None, _ax(s[1], m, TP))),
        (r"lm_head$", lambda s, m: P(_ax(s[0], m, FSDP), _ax(s[1], m, TP))),
        (r"(dec_pos|enc/pos)$", lambda s, m: P(None, _ax(s[1], m, FSDP))),
        # MoE stacked experts [E, d_in, d_out]
        (r"experts/(wi_gate|wi_up|wi)$", _expert_spec_in),
        (r"experts/wo$", _expert_spec_out),
        (r"router$", lambda s, m: P(_ax(s[0], m, FSDP), None)),
        # rwkv channel-mix wv is an OUTPUT projection [F, D] (row-parallel),
        # unlike attention wv — must precede the generic wv rule or the
        # contraction dims land on different mesh axes (full AG observed).
        (r"ch/wv$", lambda s, m: P(_ax(s[0], m, TP), _ax(s[1], m, FSDP))),
        # attention / mla / ffn projections (col-parallel in, row-parallel out)
        (r"(wq|wk|wv|wi_gate|wi_up|wi|wx|wg|w_dq|w_uq|w_uk|w_uv|w_dkv"
         r"|wr|w_lora_a)$",
         lambda s, m: P(_ax(s[0], m, FSDP), _ax(s[1], m, TP))),
        (r"(wo|wout|w_lora_b)$",
         lambda s, m: P(_ax(s[0], m, TP), _ax(s[1], m, FSDP))),
        # conv kernels [width, C]
        (r"conv/kernel$", lambda s, m: P(None, _ax(s[1], m, TP))),
    ]


def _ax(dim: int, mesh: Mesh, axis: str) -> Optional[str]:
    return axis if _div(dim, mesh, axis) else None


# Expert banks smaller than this replicate entirely when EP is not
# divisible: FSDP-sharding their contraction dim costs an activation-sized
# all-reduce per expert matmul (measured 767 MiB f32 per layer on qwen2),
# which dwarfs the memory saved on a ~1 GB bank.
_EXPERT_REPLICATE_BYTES = 2 << 30


def _expert_bank_bytes(s) -> int:
    n = 1
    for d in s:
        n *= d
    return 2 * n  # bf16


def _expert_spec_in(s, m):
    # [E, D, F]: EP over model when divisible, else TP inside the expert,
    # else (small bank) fully replicated.
    if _div(s[0], m, TP):
        return P(TP, _ax(s[1], m, FSDP), None)
    if _expert_bank_bytes(s) <= _EXPERT_REPLICATE_BYTES:
        return P(None, None, None)
    return P(None, _ax(s[1], m, FSDP), _ax(s[2], m, TP))


def _expert_spec_out(s, m):
    if _div(s[0], m, TP):
        return P(TP, None, _ax(s[2], m, FSDP))
    if _expert_bank_bytes(s) <= _EXPERT_REPLICATE_BYTES:
        return P(None, None, None)
    return P(None, _ax(s[1], m, TP), _ax(s[2], m, FSDP))


def spec_for_path(path: str, shape: tuple[int, ...], mesh: Mesh,
                  *, stacked: bool) -> P:
    body_shape = shape[1:] if stacked else shape
    for pat, builder in _rules():
        if re.search(pat, path):
            spec = builder(body_shape, mesh)
            if stacked:
                spec = P(None, *spec)
            # rank guard: pad/truncate to param rank
            spec = P(*(tuple(spec) + (None,) * (len(shape) - len(spec)))
                     [:len(shape)])
            return spec
    return P()  # replicated


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _is_stacked(path_str: str) -> bool:
    return path_str.startswith("layers/") or "/layers/" in path_str


def param_specs(param_shapes: PyTree, mesh: Mesh,
                parallelism: str = "fsdp_tp") -> PyTree:
    """PartitionSpec pytree for a param (or optimizer-state) shape tree."""
    def one(path, leaf):
        ps = _path_str(path)
        spec = spec_for_path(ps, leaf.shape, mesh, stacked=_is_stacked(ps))
        if parallelism == "pure_dp":
            # strip TP: params replicated over 'model', FSDP over 'data'
            spec = P(*(None if a == TP else a for a in tuple(spec)))
        return spec

    return jax.tree_util.tree_map_with_path(one, param_shapes)


def param_shardings(param_shapes: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(param_shapes, mesh))


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

def batch_spec(mesh: Mesh, extra_dims: int = 1,
               parallelism: str = "fsdp_tp") -> P:
    """[B, ...] activations: batch over the DP axes."""
    return P(dp_axes(mesh, parallelism), *([None] * extra_dims))


def logits_spec(mesh: Mesh) -> P:
    """[B, T, V]: batch over DP, vocab over TP (vocab-parallel CE)."""
    return P(dp_axes(mesh), None, TP)


def constrain(x, mesh: Mesh, spec: P):
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
