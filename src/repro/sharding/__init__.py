"""repro.sharding — DP/FSDP/TP/EP partition rules."""
