"""deepseek-v2-236b — MLA (kv_lora 512) + MoE 160 routed top-6, 2 shared.
[arXiv:2405.04434; hf]  Optimizer: adafactor (memory: 236B params on
16 GB/chip v5e forces a factored second moment; see DESIGN.md §6)."""

from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=1536, vocab=102400, activation="swiglu",
    max_seq=32768, optimizer="adafactor",
    moe=MoEConfig(n_experts=160, top_k=6, n_shared=2,
                  d_ff_expert=1536, d_ff_shared=3072,
                  first_dense_layers=1, d_ff_dense=12288),
    mla=MLAConfig(kv_lora=512, q_lora=1536, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
)

SMOKE = ModelConfig(
    name="deepseek-v2-236b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=96, vocab=512, activation="swiglu", max_seq=256,
    optimizer="adafactor",
    moe=MoEConfig(n_experts=4, top_k=2, n_shared=1,
                  d_ff_expert=64, d_ff_shared=64,
                  first_dense_layers=1, d_ff_dense=128,
                  capacity_factor=4.0),
    mla=MLAConfig(kv_lora=32, q_lora=48, rope_head_dim=8,
                  nope_head_dim=16, v_head_dim=16),
    remat="none",
)
