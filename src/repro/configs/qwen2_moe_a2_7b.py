"""qwen2-moe-a2.7b — MoE: 60 routed top-4 + 4 shared (MHA kv=16).
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=151936, activation="swiglu",
    max_seq=32768,
    moe=MoEConfig(n_experts=60, top_k=4, n_shared=4,
                  d_ff_expert=1408, d_ff_shared=5632),
)

SMOKE = ModelConfig(
    name="qwen2-moe-a2.7b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=96, vocab=512, activation="swiglu", max_seq=256,
    moe=MoEConfig(n_experts=4, top_k=2, n_shared=1,
                  d_ff_expert=96, d_ff_shared=128, capacity_factor=4.0),
    remat="none",
)
