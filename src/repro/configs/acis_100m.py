"""acis-100m — the ~100M-param dense model used by the end-to-end training
example (examples/train_e2e.py) and the quickstart.  Not an assigned arch;
it is the vehicle for demonstrating the paper's gradient-sync collectives
at laptop scale."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="acis-100m", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
    d_ff=2048, vocab=32000, activation="swiglu", max_seq=2048,
    remat="none",
)

SMOKE = ModelConfig(
    name="acis-100m-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, activation="swiglu", max_seq=128,
    remat="none",
)
