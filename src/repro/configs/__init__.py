"""Architecture registry: the 10 assigned configs (+ smoke reductions).

``get(name)`` returns the full published config; ``get_smoke(name)`` a
reduced same-family config for CPU tests (small widths/depths, few experts,
tiny vocab).  The full configs are only ever lowered via ShapeDtypeStruct
(launch/dryrun.py) — never materialized on host.
"""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCHS = [
    "nemotron_4_15b",
    "granite_8b",
    "qwen3_8b",
    "granite_3_8b",
    "qwen2_moe_a2_7b",
    "deepseek_v2_236b",
    "recurrentgemma_9b",
    "rwkv6_1_6b",
    "whisper_small",
    "llama_3_2_vision_11b",
]

# canonical dashed ids from the assignment -> module names
ALIASES = {
    "nemotron-4-15b": "nemotron_4_15b",
    "granite-8b": "granite_8b",
    "qwen3-8b": "qwen3_8b",
    "granite-3-8b": "granite_3_8b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "whisper-small": "whisper_small",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    # paper-native example model (quickstart / e2e driver)
    "acis-100m": "acis_100m",
}


def _module(name: str):
    mod = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> ModelConfig:
    return _module(name).SMOKE


def names() -> list[str]:
    return [k for k in ALIASES if k != "acis-100m"]
