"""llama-3.2-vision-11b — dense GQA backbone with gated cross-attention
image layers every 5th layer; vision frontend is a STUB (input_specs
provides precomputed patch embeddings [B, 1601, 4096]).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""

from repro.models.config import ModelConfig, VLMConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=128256, activation="swiglu",
    rope_theta=500000.0, max_seq=32768,
    vlm=VLMConfig(cross_every=5, image_tokens=1601),
)

SMOKE = ModelConfig(
    name="llama-3.2-vision-11b-smoke", family="vlm",
    n_layers=4, d_model=96, n_heads=6, n_kv_heads=2,
    d_ff=192, vocab=512, activation="swiglu", max_seq=256,
    vlm=VLMConfig(cross_every=2, image_tokens=16),
    remat="none",
)
