"""qwen3-8b — dense GQA with qk-norm, head_dim 128.
[hf:Qwen/Qwen3-8B; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
    d_head=128, d_ff=12288, vocab=151936, activation="swiglu",
    qk_norm=True, rope_theta=1000000.0, max_seq=32768,
)

SMOKE = ModelConfig(
    name="qwen3-8b-smoke", family="dense",
    n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, d_head=32,
    d_ff=192, vocab=512, activation="swiglu", qk_norm=True, max_seq=256,
    remat="none",
)
