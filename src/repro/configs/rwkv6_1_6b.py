"""rwkv6-1.6b (Finch) — attention-free, data-dependent decay.
[arXiv:2404.05892; unverified]  O(1) decode state => long_500k eligible."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=7168, vocab=65536, activation="relu2",
    max_seq=32768, subquadratic=True,
)

SMOKE = ModelConfig(
    name="rwkv6-1.6b-smoke", family="ssm",
    n_layers=2, d_model=128, n_heads=2, n_kv_heads=2,
    d_ff=256, vocab=512, activation="relu2", max_seq=256,
    subquadratic=True, remat="none",
)
