"""granite-3-8b — dense GQA, 40 layers.
[hf:ibm-granite/granite-3.0-2b-base (family); hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=12800, vocab=49155, activation="swiglu",
    rope_theta=10000.0, max_seq=32768,
)

SMOKE = ModelConfig(
    name="granite-3-8b-smoke", family="dense",
    n_layers=2, d_model=96, n_heads=6, n_kv_heads=2,
    d_ff=192, vocab=515, activation="swiglu", max_seq=256,
    remat="none",
)
