"""granite-8b (code) — llama-arch dense GQA. [arXiv:2405.04324; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=49152, activation="swiglu",
    rope_theta=10000.0, max_seq=32768,
)

SMOKE = ModelConfig(
    name="granite-8b-smoke", family="dense",
    n_layers=2, d_model=96, n_heads=6, n_kv_heads=2,
    d_ff=192, vocab=512, activation="swiglu", max_seq=256,
    remat="none",
)
