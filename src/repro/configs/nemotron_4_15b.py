"""nemotron-4-15b — dense, GQA(kv=8), squared-ReLU FFN, 256k vocab.
[arXiv:2402.16819; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=24576, vocab=256000, activation="relu2",
    rope_theta=10000.0, max_seq=32768,
)

SMOKE = ModelConfig(
    name="nemotron-4-15b-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=256, vocab=512, activation="relu2", max_seq=256,
    scan_layers=True, remat="none",
)
