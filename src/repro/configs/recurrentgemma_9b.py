"""recurrentgemma-9b — hybrid RG-LRU + local attention, pattern (R,R,A).
[arXiv:2402.19427; unverified]  38 = 12 x (lru,lru,attn) + (lru,lru).
Sub-quadratic (window 2048 + O(1) recurrent state) => long_500k eligible."""

from repro.models.config import HybridConfig, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab=256000, activation="geglu",
    max_seq=32768, subquadratic=True,
    hybrid=HybridConfig(pattern=("lru", "lru", "attn"), window=2048,
                        lru_width=4096, conv_width=4),
)

SMOKE = ModelConfig(
    name="recurrentgemma-9b-smoke", family="hybrid",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=1,
    d_ff=128, vocab=512, activation="geglu", max_seq=256,
    subquadratic=True,
    hybrid=HybridConfig(pattern=("lru", "lru", "attn"), window=16,
                        lru_width=64, conv_width=4),
    remat="none",
)
