"""whisper-small — encoder-decoder; conv frontend is a STUB (input_specs
provides precomputed frame embeddings [B, 1500, 768]).
[arXiv:2212.04356; unverified]"""

from repro.models.config import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="encdec",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=51865, activation="gelu", norm="layer",
    max_seq=32768,   # assignment decode shapes exceed whisper's native 448
    encdec=EncDecConfig(n_encoder_layers=12, encoder_seq=1500),
)

SMOKE = ModelConfig(
    name="whisper-small-smoke", family="encdec",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=512, activation="gelu", norm="layer", max_seq=256,
    encdec=EncDecConfig(n_encoder_layers=2, encoder_seq=16),
    remat="none",
)
