"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU hosts (kernel bodies execute in
Python for correctness validation) and False on real TPU backends, where
`pl.pallas_call` compiles to Mosaic.  Each wrapper is the drop-in,
signature-compatible implementation of its `repro.kernels.ref` oracle.
"""

from __future__ import annotations

import os

import jax

from repro.kernels import fused_combine as _fc
from repro.kernels import pack_combine as _pc
from repro.kernels import quant_combine as _qc
from repro.kernels import topk_accum as _ta
from repro.kernels import chunk_scan as _cs
from repro.kernels import rwkv6_recurrence as _rw


def _interpret_default() -> bool:
    # Re-checked per call: the active backend can change after import
    # (tests force JAX_PLATFORMS), so caching the first answer is wrong.
    # ACIS_KERNEL_INTERPRET=0/1 overrides the backend heuristic.
    env = os.environ.get("ACIS_KERNEL_INTERPRET")
    if env is not None and env != "":
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def combine_add(x, y):
    return _fc.fused_combine(x, y, op="add", interpret=_interpret_default())


def combine_max(x, y):
    return _fc.fused_combine(x, y, op="max", interpret=_interpret_default())


def combine_min(x, y):
    return _fc.fused_combine(x, y, op="min", interpret=_interpret_default())


def combine_mac(acc, x, alpha: float = 1.0):
    return _fc.fused_combine(acc, x, op="mac", alpha=float(alpha),
                             interpret=_interpret_default())


def pack_combine(arena, *parts, op=None):
    return _pc.fused_pack(arena, *parts, op=op,
                          interpret=_interpret_default())


def quant_combine(qa, sa, qb, sb):
    return _qc.quant_combine(qa, sa, qb, sb, interpret=_interpret_default())


def topk_accumulate(dense, idx, vals):
    return _ta.topk_accumulate(dense, idx, vals,
                               interpret=_interpret_default())


def prefix_sum(x):
    return _cs.prefix_sum(x, interpret=_interpret_default())


def rglru_scan(a, b):
    return _cs.rglru_scan(a, b, interpret=_interpret_default())


def rwkv6_recurrence(r, k, v, w, u):
    return _rw.rwkv6_recurrence(r, k, v, w, u,
                                interpret=_interpret_default())
