"""Pallas TPU kernels: chunked scans (prefix sum + RG-LRU linear recurrence).

Two recurrences power the Type 3 "look-aside loop" collectives and the
SSM/hybrid architectures:

  * ``prefix_sum``  — h_t = h_{t-1} + x_t         (Fig. 5 op)
  * ``rglru_scan``  — h_t = a_t ⊙ h_{t-1} + b_t   (RecurrentGemma RG-LRU)

Tiling: time is chunked (grid dimension, sequential on TPU); the carry lives
in a VMEM scratch buffer that persists across grid steps — exactly the
paper's "state within the operation".  Within a chunk the scan is computed
with a log-step Hillis-Steele over the time axis (vector ops on the lane
dim), so the sequential dependency is only chunk-to-chunk.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CHUNK_T = 256


def _log_steps(n: int) -> list[int]:
    steps, k = [], 1
    while k < n:
        steps.append(k)
        k *= 2
    return steps


# ---------------------------------------------------------------------------
# prefix sum
# ---------------------------------------------------------------------------

def _prefix_kernel(x_ref, o_ref, carry_ref, *, chunk_t: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    x = x_ref[...]                     # [chunk_t, D]
    # intra-chunk inclusive scan (log-step over time)
    for k in _log_steps(chunk_t):
        x = x + jnp.pad(x, ((k, 0), (0, 0)))[:chunk_t]
    out = x + carry_ref[...]
    o_ref[...] = out
    carry_ref[...] = out[-1:, :]


@functools.partial(jax.jit, static_argnames=("interpret",))
def prefix_sum(x: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Inclusive prefix sum over axis 0 of [T] or [T, D] arrays."""
    squeeze = x.ndim == 1
    x2 = x[:, None] if squeeze else x
    t, d = x2.shape
    chunk = min(CHUNK_T, t)
    pad = (-t) % chunk
    if pad:
        x2 = jnp.concatenate([x2, jnp.zeros((pad, d), x2.dtype)])

    out = pl.pallas_call(
        functools.partial(_prefix_kernel, chunk_t=chunk),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x2.dtype),
        grid=(x2.shape[0] // chunk,),
        in_specs=[pl.BlockSpec((chunk, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((chunk, d), lambda i: (i, 0)),
        scratch_shapes=[pltpu_vmem((1, d), x2.dtype)],
        interpret=interpret,
    )(x2)
    out = out[:t]
    return out[:, 0] if squeeze else out


# ---------------------------------------------------------------------------
# RG-LRU gated recurrence  h_t = a_t * h_{t-1} + b_t
# ---------------------------------------------------------------------------

def _rglru_kernel(a_ref, b_ref, o_ref, carry_ref, *, chunk_t: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    a = a_ref[...].astype(jnp.float32)   # [chunk_t, D]
    h = b_ref[...].astype(jnp.float32)
    # Blelloch-free log-step scan of the affine recurrence:
    # pair (a, h) composes as (a2*a1, a2*h1 + h2)
    for k in _log_steps(chunk_t):
        a_prev = jnp.pad(a, ((k, 0), (0, 0)), constant_values=1.0)[:chunk_t]
        h_prev = jnp.pad(h, ((k, 0), (0, 0)))[:chunk_t]
        h = a * h_prev + h
        a = a * a_prev
    out = h + a * carry_ref[...]
    o_ref[...] = out.astype(o_ref.dtype)
    carry_ref[...] = out[-1:, :]


@functools.partial(jax.jit, static_argnames=("interpret",))
def rglru_scan(a: jax.Array, b: jax.Array, *,
               interpret: bool = True) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t over [T, D] inputs (h_0 = 0)."""
    if a.shape != b.shape or a.ndim != 2:
        raise ValueError(f"bad shapes {a.shape} {b.shape}")
    t, d = a.shape
    chunk = min(CHUNK_T, t)
    pad = (-t) % chunk
    if pad:
        a = jnp.concatenate([a, jnp.ones((pad, d), a.dtype)])
        b = jnp.concatenate([b, jnp.zeros((pad, d), b.dtype)])

    out = pl.pallas_call(
        functools.partial(_rglru_kernel, chunk_t=chunk),
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.float32),
        grid=(a.shape[0] // chunk,),
        in_specs=[pl.BlockSpec((chunk, d), lambda i: (i, 0))] * 2,
        out_specs=pl.BlockSpec((chunk, d), lambda i: (i, 0)),
        scratch_shapes=[pltpu_vmem((1, d), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out[:t]


def pltpu_vmem(shape, dtype):
    """VMEM scratch allocation (portable across pallas versions)."""
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
