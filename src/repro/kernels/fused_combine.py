"""Pallas TPU kernel: per-hop reduce combine (the switch aggregation unit).

The hot inner loop of every ACiS reduction schedule is ``combine(incoming,
local)`` applied to a hop-sized message.  On the FPGA this is the
programmable aggregation unit; on TPU it is a VPU-elementwise kernel that
should run at HBM bandwidth.  Tiling: the flat message is viewed as
[rows, 128] (lane-aligned) and blocked (BLOCK_ROWS, 128) into VMEM — three
resident blocks (x, y, out) of (512, 128) f32 = 768 KB, comfortably inside
a v5e core's VMEM while deep enough to amortize grid overhead.

Supported ops: add | max | min | mac(alpha) — the Type 1 fixed set plus the
paper's fused multiply-accumulate example.  ``alpha`` is a compile-time
constant (it is a schedule parameter, not data).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 512

_OPS = {
    "add": lambda x, y: x + y,
    "max": jnp.maximum,
    "min": jnp.minimum,
}


def _combine_kernel(x_ref, y_ref, o_ref, *, op: str, alpha: float):
    x = x_ref[...]
    y = y_ref[...]
    if op == "mac":
        o_ref[...] = x + jnp.asarray(alpha, x.dtype) * y
    else:
        o_ref[...] = _OPS[op](x, y)


def _pad_rows(flat: jax.Array) -> tuple[jax.Array, int]:
    size = flat.shape[0]
    rem = (-size) % LANES
    if rem:
        flat = jnp.concatenate([flat, jnp.zeros((rem,), flat.dtype)])
    return flat.reshape(-1, LANES), size


@functools.partial(jax.jit, static_argnames=("op", "alpha", "interpret"))
def fused_combine(x: jax.Array, y: jax.Array, *, op: str = "add",
                  alpha: float = 1.0, interpret: bool = True) -> jax.Array:
    """combine(x, y) elementwise over arbitrary-shape operands."""
    if x.shape != y.shape:
        raise ValueError(f"shape mismatch {x.shape} vs {y.shape}")
    shape, dtype = x.shape, x.dtype
    x2, size = _pad_rows(x.reshape(-1))
    y2, _ = _pad_rows(y.reshape(-1))
    rows = x2.shape[0]
    block_rows = min(BLOCK_ROWS, rows)
    # pad rows to a multiple of the block
    rpad = (-rows) % block_rows
    if rpad:
        zpad = jnp.zeros((rpad, LANES), dtype)
        x2 = jnp.concatenate([x2, zpad])
        y2 = jnp.concatenate([y2, zpad])
    grid = (x2.shape[0] // block_rows,)

    out = pl.pallas_call(
        functools.partial(_combine_kernel, op=op, alpha=alpha),
        out_shape=jax.ShapeDtypeStruct(x2.shape, dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))] * 2,
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        interpret=interpret,
    )(x2, y2)
    return out.reshape(-1)[:size].reshape(shape)
