"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics contracts: tests sweep shapes/dtypes and assert the
kernels match these references (interpret mode on CPU, compiled on TPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# fused_combine — per-hop reduce combines (the switch aggregation unit)
# ---------------------------------------------------------------------------

def combine_add(x: jax.Array, y: jax.Array) -> jax.Array:
    return x + y


def combine_max(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.maximum(x, y)


def combine_min(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.minimum(x, y)


def combine_mac(acc: jax.Array, x: jax.Array, alpha: float = 1.0) -> jax.Array:
    """acc + alpha * x  (the paper's fused multiply-accumulate example)."""
    return acc + jnp.asarray(alpha, acc.dtype) * x


# ---------------------------------------------------------------------------
# pack_combine — bucket pack (+ optional combine) into a flat arena
# ---------------------------------------------------------------------------

_PACK_COMBINE = {
    "add": lambda a, b: a + b,
    "max": jnp.maximum,
    "min": jnp.minimum,
}


def pack_combine(arena: jax.Array, *parts: jax.Array,
                 op: str | None = None) -> jax.Array:
    """Write flat ``parts`` back to back into ``arena``; with ``op`` set,
    combine each part into the arena's current segment instead."""
    off = 0
    for p in parts:
        p = p.reshape(-1).astype(arena.dtype)
        s = p.shape[0]
        if op is not None:
            p = _PACK_COMBINE[op](jax.lax.dynamic_slice(arena, (off,), (s,)),
                                  p)
        arena = jax.lax.dynamic_update_slice(arena, p, (off,))
        off += s
    return arena


# ---------------------------------------------------------------------------
# quant_combine — encoded-domain int8 combine (dequant-add-requant)
# ---------------------------------------------------------------------------

def quant_combine(qa: jax.Array, sa: jax.Array,
                  qb: jax.Array, sb: jax.Array
                  ) -> tuple[jax.Array, jax.Array]:
    """Combine two blockwise-int8 payloads: q[B, block], s[B]."""
    acc = qa.astype(jnp.float32) * sa[:, None] + \
        qb.astype(jnp.float32) * sb[:, None]
    absmax = jnp.max(jnp.abs(acc), axis=1)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(acc / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


# ---------------------------------------------------------------------------
# topk_accumulate — sparse (idx, val) scatter-add into a dense accumulator
# ---------------------------------------------------------------------------

def topk_accumulate(dense: jax.Array, idx: jax.Array,
                    vals: jax.Array) -> jax.Array:
    """dense[idx] += vals   (duplicate indices accumulate)."""
    return dense.at[idx].add(vals.astype(dense.dtype))


# ---------------------------------------------------------------------------
# prefix_sum — long-vector inclusive scan (chunked in the kernel)
# ---------------------------------------------------------------------------

def prefix_sum(x: jax.Array) -> jax.Array:
    return jnp.cumsum(x, axis=0)


# ---------------------------------------------------------------------------
# rglru_scan — gated linear recurrence  h_t = a_t * h_{t-1} + b_t
# ---------------------------------------------------------------------------

def rglru_scan(a: jax.Array, b: jax.Array,
               h0: jax.Array | None = None) -> jax.Array:
    """a, b: [T, D]; returns h: [T, D] with h_t = a_t*h_{t-1} + b_t."""
    if h0 is None:
        h0 = jnp.zeros(a.shape[1:], a.dtype)

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(step, h0, (a, b))
    return hs


# ---------------------------------------------------------------------------
# rwkv6 — data-dependent-decay WKV recurrence (one head)
# ---------------------------------------------------------------------------

def rwkv6_recurrence(r: jax.Array, k: jax.Array, v: jax.Array,
                     w: jax.Array, u: jax.Array,
                     s0: jax.Array | None = None
                     ) -> tuple[jax.Array, jax.Array]:
    """RWKV-6 "Finch" WKV for a single head.

    r,k,w: [T, K], v: [T, V], u: [K].  State S: [K, V].
      o_t = (S_{t-1} + (u * k_t)^T v_t)^T r_t
      S_t = diag(w_t) S_{t-1} + k_t^T v_t
    Returns (o: [T, V], S_T).
    """
    T, K = r.shape
    V = v.shape[1]
    if s0 is None:
        s0 = jnp.zeros((K, V), jnp.float32)

    def step(S, rkvw):
        rt, kt, vt, wt = rkvw
        kv = kt[:, None] * vt[None, :]                     # [K, V]
        o = ((S + u[:, None] * kv) * rt[:, None]).sum(0)   # [V]
        S = wt[:, None] * S + kv
        return S, o

    sT, o = jax.lax.scan(step, s0.astype(jnp.float32),
                         (r.astype(jnp.float32), k.astype(jnp.float32),
                          v.astype(jnp.float32), w.astype(jnp.float32)))
    return o.astype(v.dtype), sT
