"""Pallas TPU kernel: RWKV-6 "Finch" WKV recurrence (data-dependent decay).

State S[K, V] per head is the densest look-aside memory in the assigned
architecture pool: it must be read+updated every token.  Tiling: grid =
(heads, time-chunks); time chunks are sequential (TPU grid order), the state
lives in a VMEM scratch that persists across the chunk dimension and resets
at chunk 0 of each head.  Within a chunk the recurrence is stepped on the
VPU ([K,V] FMA per token) — the numerically safe form for arbitrary decays
(the chunked-matmul form divides by cumulative decay products and can
overflow f32 for long chunks; see models/rwkv6.py for the MXU training path
with sub-chunked log-space handling).

Per head h, token t:
    kv   = k_t ⊗ v_t
    o_t  = Σ_k r_t[k] · (S[k,:] + u[k]·kv[k,:])
    S    = diag(w_t) S + kv
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CHUNK_T = 64


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, sout_ref, s_ref,
                *, chunk_t: int):
    # NOTE: positional order is (inputs..., outputs..., scratch...).
    @pl.when(pl.program_id(1) == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[...].astype(jnp.float32)[0]  # [chunk_t, K]
    k = k_ref[...].astype(jnp.float32)[0]
    v = v_ref[...].astype(jnp.float32)[0]  # [chunk_t, V]
    w = w_ref[...].astype(jnp.float32)[0]
    u = u_ref[...].astype(jnp.float32)[0]  # [1, K] row

    def step(t, carry):
        s, o = carry
        kt = k[t][:, None]                 # [K, 1]
        vt = v[t][None, :]                 # [1, V]
        kv = kt * vt                       # [K, V]
        ot = ((s + u.T * kv) * r[t][:, None]).sum(axis=0)  # [V]
        s = w[t][:, None] * s + kv
        return s, o.at[t].set(ot)

    s0 = s_ref[...]
    o0 = jnp.zeros((chunk_t, v.shape[1]), jnp.float32)
    s, o = jax.lax.fori_loop(0, chunk_t, step, (s0, o0))
    o_ref[...] = o[None].astype(o_ref.dtype)
    s_ref[...] = s
    sout_ref[...] = s[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def rwkv6_recurrence(r: jax.Array, k: jax.Array, v: jax.Array,
                     w: jax.Array, u: jax.Array, *,
                     interpret: bool = True
                     ) -> tuple[jax.Array, jax.Array]:
    """Multi-head WKV6.

    r, k, w: [H, T, K]; v: [H, T, V]; u: [H, K].
    Returns (o: [H, T, V], s_final: [H, K, V]).
    """
    h, t, kk = r.shape
    vv = v.shape[2]
    chunk = min(CHUNK_T, t)
    pad = (-t) % chunk
    if pad:
        zr = jnp.zeros((h, pad, kk), r.dtype)
        r = jnp.concatenate([r, zr], axis=1)
        k = jnp.concatenate([k, zr.astype(k.dtype)], axis=1)
        w = jnp.concatenate([w, jnp.ones((h, pad, kk), w.dtype)], axis=1)
        v = jnp.concatenate([v, jnp.zeros((h, pad, vv), v.dtype)], axis=1)
    tp = t + pad
    u2 = u[:, None, :]  # [H, 1, K]

    o, s_final = pl.pallas_call(
        functools.partial(_wkv_kernel, chunk_t=chunk),
        out_shape=(jax.ShapeDtypeStruct((h, tp, vv), v.dtype),
                   jax.ShapeDtypeStruct((h, kk, vv), jnp.float32)),
        grid=(h, tp // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, kk), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, kk), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, vv), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, kk), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, kk), lambda i, j: (i, 0, 0)),
        ],
        out_specs=(pl.BlockSpec((1, chunk, vv), lambda i, j: (i, j, 0)),
                   pl.BlockSpec((1, kk, vv), lambda i, j: (i, 0, 0))),
        scratch_shapes=[_vmem((kk, vv), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u2)
    return o[:, :t], s_final


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
