"""Pallas TPU kernel: encoded-domain int8 combine (dequant-add-requant).

The in-switch program for the quantized wire format: two int8 payloads and
their per-block scales come in, one goes out — in a single VMEM pass, so the
decoded f32 intermediates never touch HBM.  This is the aggregation-unit
configuration the paper's Type 2 uses for "sparse/quantized user datatypes".

Layout: payloads are [B, QBLOCK(=256)] int8 rows with scales [B, 1] f32.
Block tiling (64, 256): int8 ops in VMEM, rowwise absmax on the VPU, requant
and emit.  Six resident blocks (qa, qb, sa, sb, qo, so) ≈ 64·256·(1+1+1)B +
small — trivially VMEM-resident; the kernel is HBM-bandwidth-bound, which is
the point: wire bytes = HBM bytes = 1/4 of the f32 stream.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QBLOCK = 256
BLOCK_B = 64


def _quant_combine_kernel(qa_ref, sa_ref, qb_ref, sb_ref, qo_ref, so_ref):
    acc = (qa_ref[...].astype(jnp.float32) * sa_ref[...] +
           qb_ref[...].astype(jnp.float32) * sb_ref[...])
    absmax = jnp.max(jnp.abs(acc), axis=1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    qo_ref[...] = jnp.clip(jnp.round(acc / scale), -127, 127).astype(jnp.int8)
    so_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("interpret",))
def quant_combine(qa: jax.Array, sa: jax.Array, qb: jax.Array,
                  sb: jax.Array, *, interpret: bool = True
                  ) -> tuple[jax.Array, jax.Array]:
    """Combine blockwise-int8 payloads (q: [B, QBLOCK] int8, s: [B] f32)."""
    if qa.shape != qb.shape or qa.shape[1] != QBLOCK:
        raise ValueError(f"bad payload shapes {qa.shape} {qb.shape}")
    b = qa.shape[0]
    sa2 = sa.reshape(b, 1)
    sb2 = sb.reshape(b, 1)
    block_b = min(BLOCK_B, b)
    pad = (-b) % block_b
    if pad:
        qa = jnp.concatenate([qa, jnp.zeros((pad, QBLOCK), qa.dtype)])
        qb = jnp.concatenate([qb, jnp.zeros((pad, QBLOCK), qb.dtype)])
        sa2 = jnp.concatenate([sa2, jnp.ones((pad, 1), sa2.dtype)])
        sb2 = jnp.concatenate([sb2, jnp.ones((pad, 1), sb2.dtype)])
    grid = ((b + pad) // block_b,)

    qo, so = pl.pallas_call(
        _quant_combine_kernel,
        out_shape=(jax.ShapeDtypeStruct(qa.shape, jnp.int8),
                   jax.ShapeDtypeStruct(sa2.shape, jnp.float32)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, QBLOCK), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_b, QBLOCK), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        ],
        out_specs=(pl.BlockSpec((block_b, QBLOCK), lambda i: (i, 0)),
                   pl.BlockSpec((block_b, 1), lambda i: (i, 0))),
        interpret=interpret,
    )(qa, sa2, qb, sb2)
    return qo[:b], so[:b, 0]
