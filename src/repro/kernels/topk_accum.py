"""Pallas TPU kernel: sparse (idx, val) scatter-accumulate.

HW adaptation note (DESIGN.md §2): the FPGA switch scatter-accumulates with
an addressable BRAM; TPUs have no gather/scatter unit, so the TPU-native
formulation is a **one-hot MXU matmul**: for each dense block, accumulate
``vals @ onehot(idx ∈ block)`` — K·B MACs on the systolic array instead of K
random HBM touches.  For the top-k regimes the sparse collective targets
(K ≤ 1% of size) this is far below the HBM roofline of the dense
alternative and has fully regular memory traffic.

Tiling: dense is viewed [S] → [nblk, BLOCK_S]; grid over nblk; idx/vals are
small and VMEM-resident for every grid step (BlockSpec maps them whole).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_S = 2048


def _topk_accum_kernel(dense_ref, idx_ref, vals_ref, o_ref, *, block_s: int):
    blk = pl.program_id(0)
    base = blk * block_s
    idx = idx_ref[...]                    # [K] int32 (whole payload)
    vals = vals_ref[...]                  # [K] f32
    pos = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], block_s), 1)
    local = idx[:, None] - base           # [K, block_s] target offsets
    onehot = (local == pos).astype(vals.dtype)
    contrib = vals[None, :] @ onehot      # [1, block_s] on the MXU
    o_ref[...] = dense_ref[...] + contrib[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def topk_accumulate(dense: jax.Array, idx: jax.Array, vals: jax.Array, *,
                    interpret: bool = True) -> jax.Array:
    """dense[idx] += vals (duplicates accumulate). dense: [S] f32/bf16."""
    s = dense.shape[0]
    pad = (-s) % BLOCK_S
    d = jnp.concatenate([dense, jnp.zeros((pad,), dense.dtype)]) if pad else dense
    nblk = d.shape[0] // BLOCK_S

    out = pl.pallas_call(
        functools.partial(_topk_accum_kernel, block_s=BLOCK_S),
        out_shape=jax.ShapeDtypeStruct(d.shape, d.dtype),
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((BLOCK_S,), lambda i: (i,)),
            pl.BlockSpec(idx.shape, lambda i: (0,)),   # whole payload
            pl.BlockSpec(vals.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_S,), lambda i: (i,)),
        interpret=interpret,
    )(d, idx, vals.astype(dense.dtype))
    return out[:s]
