"""Fused bucket pack (+ optional combine) writing in place into an arena.

The Coalesce pass packs N gradient leaves into one flat bucket before the
ring collective; the emitted default path is one ``dynamic_update_slice``
per leaf — N small XLA kernels and a full copy of the arena per leaf at
worst.  This kernel lowers the whole pack to **one** Pallas launch whose
output aliases the arena input (``input_output_aliases={0: 0}``): with the
arena donated at the jit boundary the leaves land in place, no transient.

``op`` additionally fuses the per-hop combine into the same launch
(``arena[seg] = combine(arena[seg], leaf)``) — the pack+combine round trip
of a ring hop (combine → copy → slice) collapses to one kernel.

Leaf sizes and segment offsets are static (they come from the compile-time
avals), so the kernel body uses static slices — Mosaic-compilable on TPU,
validated in interpret mode on CPU (see ``kernels/ops._interpret_default``).
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_COMBINE = {
    "add": jnp.add,
    "max": jnp.maximum,
    "min": jnp.minimum,
}


def _pack_kernel(a_ref, *refs, sizes, op):
    p_refs, o_ref = refs[:-1], refs[-1]
    # carry the arena through: lanes outside the packed segments (a bucket
    # padded past sum(sizes)) must survive the aliased write
    o_ref[...] = a_ref[...]
    off = 0
    for p, s in zip(p_refs, sizes):
        x = p[...].astype(o_ref.dtype)
        if op is not None:
            x = _COMBINE[op](a_ref[off:off + s], x)
        o_ref[off:off + s] = x
        off += s


@functools.partial(jax.jit, static_argnames=("op", "interpret"))
def fused_pack(arena: jax.Array, *parts: jax.Array,
               op: Optional[str] = None,
               interpret: bool = True) -> jax.Array:
    """Write ``parts`` (flat, pre-cast to the arena dtype) into ``arena``
    back to back, in one Pallas launch aliased onto the arena buffer.

    ``op=None`` is the pure pack; ``op in {"add", "max", "min"}`` combines
    each part into the arena's current segment contents instead (the fused
    pack+combine hop).  Returns the updated arena.
    """
    if not parts:
        return arena
    sizes = tuple(int(p.shape[0]) for p in parts)
    if sum(sizes) > arena.shape[0]:
        raise ValueError(
            f"pack of {sum(sizes)} elements overflows arena of "
            f"{arena.shape[0]}")
    kern = functools.partial(_pack_kernel, sizes=sizes, op=op)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct(arena.shape, arena.dtype),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(arena, *parts)


def pack_parts(xs: Sequence[jax.Array], dtype) -> list[jax.Array]:
    """Flatten + cast leaves to the arena's flat dtype (the pre-kernel
    normalization both the kernel and its oracle share)."""
    return [x.reshape(-1).astype(dtype) for x in xs]
