"""SwitchProgram compiler: fusion rules fire and emitted programs are correct."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import (ADD, AllGather, AllToAll, Map, Reduce, ReduceScatter,
                        Scan, SwitchProgram, Wire, compile_program,
                        compile_rank_local)
from repro.core.program import OpKind
from repro.core.wire import BF16

N = 8


# ---------------------------------------------------------------------------
# fusion-rule structure (the "generated schedule" checks)
# ---------------------------------------------------------------------------

def test_fig5_pattern_fuses_to_one_stage():
    prog = SwitchProgram([AllGather(), Scan(), AllGather()], "fig5")
    compiled = compile_rank_local(prog, "data")
    assert compiled.stage_kinds() == ["scan+allgather"]


def test_nas_is_pattern_fuses():
    prog = SwitchProgram([Reduce(), AllToAll()], "nas_is")
    compiled = compile_rank_local(prog, "data")
    assert compiled.stage_kinds() == ["allreduce+alltoall"]


def test_rs_ag_becomes_allreduce():
    prog = SwitchProgram([ReduceScatter(), AllGather()])
    compiled = compile_rank_local(prog, "data")
    assert compiled.stage_kinds() == ["allreduce"]


def test_map_fuses_into_reduce_scatter():
    prog = SwitchProgram([Map(jnp.square, "sq"), ReduceScatter()])
    compiled = compile_rank_local(prog, "data")
    assert compiled.stage_kinds() == ["map+reduce_scatter"]


def test_allgather_map_fusion():
    prog = SwitchProgram([AllGather(), Map(lambda x: x + 1, "inc")])
    compiled = compile_rank_local(prog, "data")
    assert compiled.stage_kinds() == ["allgather+map"]


def test_wire_codec_sinks_onto_collective():
    prog = SwitchProgram([Wire(BF16), ReduceScatter(), AllGather()])
    compiled = compile_rank_local(prog, "data")
    assert compiled.stage_kinds() == ["allreduce"]


def test_unfusable_chain_stays_multi_stage():
    prog = SwitchProgram([AllToAll(), Reduce()])
    compiled = compile_rank_local(prog, "data")
    assert compiled.stage_kinds() == ["alltoall", "allreduce"]


# ---------------------------------------------------------------------------
# end-to-end: the emitted "CGRA binary" computes the right thing
# ---------------------------------------------------------------------------

def test_compiled_fig5_end_to_end(mesh8, rng):
    x = rng.standard_normal((N * 8,)).astype(np.float32)
    prog = SwitchProgram([AllGather(), Scan(), AllGather()], "fig5")
    fn = compile_program(prog, mesh8, "data", P("data"), P(None))
    assert fn.stages == ["scan+allgather"]
    out = np.asarray(fn(jnp.asarray(x)))
    np.testing.assert_allclose(out, np.cumsum(x), rtol=1e-4, atol=1e-4)


def test_compiled_mapreduce_end_to_end(mesh8, rng):
    x = rng.standard_normal((N, 64)).astype(np.float32)
    prog = SwitchProgram([Map(jnp.square, "sq"), Reduce()], "mapreduce")
    fn = compile_program(prog, mesh8, "data",
                         P("data", None), P("data", None))

    def unshard(y):
        return np.asarray(y)

    out = unshard(fn(jnp.asarray(x.reshape(N, 64))))
    want = np.square(x).sum(axis=0)
    for i in range(N):
        np.testing.assert_allclose(out[i], want, rtol=1e-4, atol=1e-4)


def test_compile_program_accepts_plain_function(mesh8, rng):
    """compile_program traces a raw python function on the fly."""
    from repro import core as acis

    fn = compile_program(
        lambda x: acis.all_gather(acis.scan(acis.all_gather(x))),
        mesh8, "data", P("data"), P(None))
    assert fn.stages == ["scan+allgather"]
    x = rng.standard_normal((N * 4,)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(fn(jnp.asarray(x))),
                               np.cumsum(x), rtol=1e-4, atol=1e-4)


def test_pipeline_is_composable():
    """Dropping FuseHops' patterns must still yield a runnable program —
    every node lowers on its own (the pipeline stages are independent)."""
    from repro.core.compiler import (DEFAULT_PIPELINE, Emit, FuseHops,
                                     Legalize, SelectSchedule,
                                     compile_rank_local)

    from repro.core.compiler import LowerTopology

    unfused = (Legalize(), LowerTopology(), FuseHops(patterns=()),
               SelectSchedule(), Emit())
    prog = SwitchProgram([AllGather(), Scan(), AllGather()], "fig5")
    compiled = compile_rank_local(prog, "data", pipeline=unfused)
    assert compiled.stage_kinds() == ["allgather", "scan", "allgather"]
    assert [type(p).__name__ for p in DEFAULT_PIPELINE] == \
        ["Legalize", "LowerTopology", "Coalesce", "FuseHops",
         "SelectSchedule", "PlaceCGRA", "Emit"]


def test_compile_program_reports_schedules(mesh8):
    from repro import core as acis

    eng = acis.make_engine("acis", latency_optimal_below=1 << 30)
    fn = eng.compile(acis.trace(lambda x: acis.reduce(x)), mesh8,
                     P("data", None), P("data", None),
                     in_avals=(jax.ShapeDtypeStruct((1, 8), jnp.float32),))
    assert fn.stages == ["allreduce"]
    assert fn.schedules == ["latency"]


def test_compiled_bcast_scan_chain(mesh8, rng):
    """A chain the paper can't do in one switch pass still compiles to a
    single SPMD program (one XLA computation, no host round trips)."""
    x = rng.standard_normal((N, 16)).astype(np.float32)
    prog = SwitchProgram([Scan(), Map(lambda v: v / 2, "half"), Reduce()])
    compiled = compile_rank_local(prog, "data")
    assert compiled.stage_kinds() == ["scan", "map+allreduce"]
    fn = compile_program(prog, mesh8, "data", P("data", None), P("data", None))
    out = np.asarray(fn(jnp.asarray(x)))
    scan = np.cumsum(x, axis=0)
    want = (scan / 2).sum(axis=0)
    for i in range(N):
        np.testing.assert_allclose(out[i], want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# dropped-codec warnings & the per-stage explain table
# ---------------------------------------------------------------------------

def test_legalize_warns_when_codec_dropped_at_noncapable_node():
    """A wire codec a fixed-function consumer cannot apply must not
    vanish silently — the warning names the node and the codec."""
    from repro import core as acis

    eng = acis.make_engine("acis")
    with pytest.warns(UserWarning, match="bf16.*allgather"):
        eng.compile(lambda x: acis.all_gather(acis.wire(BF16, x)))


def test_legalize_warns_when_codec_dropped_at_ef_reduce():
    from repro import core as acis

    eng = acis.make_engine("acis")
    with pytest.warns(UserWarning, match="error-feedback"):
        eng.compile(lambda x: acis.ef_reduce(acis.wire(BF16, x),
                                             axis="data")[0])


def test_legalize_silent_when_codec_is_applied():
    import warnings as _w

    prog = SwitchProgram([Wire(BF16), ReduceScatter(), AllGather()])
    with _w.catch_warnings():
        _w.simplefilter("error")        # any warning -> failure
        compiled = compile_rank_local(prog, "data")
    assert compiled.stage_kinds() == ["allreduce"]


def test_explain_renders_stage_table(mesh8):
    from repro import core as acis

    eng = acis.make_engine("acis_hierarchical_compressed",
                           outer_axis="pod")
    c = eng.compile(lambda x: acis.reduce(x, axis="auto"),
                    in_avals=(jax.ShapeDtypeStruct((256,), jnp.float32),),
                    axis_size={"data": 4, "pod": 2})
    txt = c.explain()
    # kind, axis, schedule, codec and placement all present per stage
    assert "reduce_scatter" in txt and "allreduce" in txt
    assert "pod" in txt and "data" in txt
    assert "int8" in txt
    assert "PEs" in txt or "route-through" in txt
    assert txt.count("\n") >= len(c.stages)


def test_legalize_warns_codec_carried_through_map_to_output():
    """A codec that rides through a MAP but never reaches a collective
    is dropped at the program boundary — also announced (regression:
    only direct wire→output drops used to warn)."""
    from repro import core as acis

    eng = acis.make_engine("acis")
    with pytest.warns(UserWarning, match="program output"):
        eng.compile(lambda x: acis.map(jnp.square, acis.wire(BF16, x)))
