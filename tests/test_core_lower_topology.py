"""LowerTopology: multi-axis reduces lower to the hierarchical RS/AR/AG
schedule, the codec rides the thin outer hop only, gradient_sync routes
every acis backend through the compiled pipeline, and flat vs hierarchical
numerics agree on a {data: 2, pod: 2} host mesh."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import core as acis
from repro.core import make_engine
from repro.core.program import OpKind
from repro.core.wire import BF16, IDENTITY


@pytest.fixture(scope="module")
def mesh22():
    """{data: 2, pod: 2} host mesh for flat-vs-hierarchical equivalence."""
    return jax.make_mesh((2, 2), ("pod", "data"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def smap(fn, mesh, in_specs, out_specs):
    return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False))


# ---------------------------------------------------------------------------
# stage inspection: what LowerTopology emits
# ---------------------------------------------------------------------------

def test_auto_reduce_emits_rs_ar_ag_triple_with_codec_on_outer():
    """The acceptance shape: a reduce over axis="auto" on a two-tier
    engine lowers to RS(inner) → AR(outer) → AG(inner) with the engine's
    wire codec on the outer (thin) stage only."""
    eng = make_engine("acis_hierarchical_compressed", outer_axis="pod")
    c = eng.compile(lambda x: acis.reduce(x, axis="auto"))

    assert c.stage_kinds() == ["map", "reduce_scatter", "allreduce",
                               "allgather", "map"]
    assert c.stage_axes() == ["", "data", "pod", "data", ""]
    assert c.axes() == ["data", "pod"]

    kinds = [nd.op.kind for nd in c.source.nodes]
    assert kinds == [OpKind.MAP, OpKind.REDUCE_SCATTER, OpKind.REDUCE,
                     OpKind.ALLGATHER, OpKind.MAP]
    by_kind = {nd.op.kind: nd.op for nd in c.source.nodes}
    # compression exactly at the thin link — and nowhere else
    assert by_kind[OpKind.REDUCE].codec.name.startswith("int8")
    assert by_kind[OpKind.REDUCE_SCATTER].codec is IDENTITY
    assert by_kind[OpKind.REDUCE].axis == "pod"
    assert by_kind[OpKind.REDUCE_SCATTER].axis == "data"
    assert by_kind[OpKind.ALLGATHER].axis == "data"


def test_uncompressed_auto_reduce_keeps_identity_wire():
    eng = make_engine("acis_hierarchical", outer_axis="pod")
    c = eng.compile(lambda x: acis.reduce(x, axis="auto"))
    assert c.stage_kinds() == ["map", "reduce_scatter", "allreduce",
                               "allgather", "map"]
    for nd in c.source.nodes:
        assert nd.op.codec is IDENTITY


def test_explicit_wire_rides_outer_hop():
    """A user-declared wire codec sinks through Legalize and then rides
    the outer stage of the lowered triple."""
    eng = make_engine("acis", outer_axis="pod")
    c = eng.compile(lambda x: acis.reduce(acis.wire(BF16, x), axis="auto"))
    red = next(nd.op for nd in c.source.nodes if nd.op.kind == OpKind.REDUCE)
    rs = next(nd.op for nd in c.source.nodes
              if nd.op.kind == OpKind.REDUCE_SCATTER)
    assert red.axis == "pod" and red.codec is BF16
    assert rs.codec is IDENTITY


def test_auto_on_single_axis_topology_is_a_plain_reduce():
    eng = make_engine("acis")            # no outer axis configured
    c = eng.compile(lambda x: acis.reduce(x, axis="auto"))
    assert c.stage_kinds() == ["allreduce"]
    assert c.stage_axes() == ["data"]


def test_compound_axis_tuple_spelling():
    eng = make_engine("acis", outer_axis="pod")
    c = eng.compile(lambda x: acis.reduce(x, axis=("data", "pod")))
    assert c.stage_kinds() == ["map", "reduce_scatter", "allreduce",
                               "allgather", "map"]


def test_non_reduce_over_compound_axis_is_rejected():
    eng = make_engine("acis", outer_axis="pod")
    with pytest.raises(NotImplementedError, match="compound axis"):
        eng.compile(lambda x: acis.all_gather(x, axis="auto"))


def test_cross_axis_rs_ag_does_not_fuse():
    """RS and AG on different mesh axes must not collapse into one
    all-reduce schedule (a pod-local ring cannot carry inter-pod hops)."""
    eng = make_engine("acis", outer_axis="pod")
    c = eng.compile(lambda x: acis.all_gather(
        acis.reduce_scatter(x, axis="data"), axis="pod"))
    assert c.stage_kinds() == ["reduce_scatter", "allgather"]
    assert c.stage_axes() == ["data", "pod"]


def test_select_schedule_costs_outer_stage_on_dci_tier():
    """The outer stage is costed against the thin DCI link: with no
    explicit threshold, the per-axis crossover differs between tiers."""
    from repro.core import netmodel

    ici = netmodel.ring_crossover_bytes(4, netmodel.ICI)
    dci = netmodel.ring_crossover_bytes(4, netmodel.DCI)
    assert dci < ici           # thin wire → latency ring pays off earlier

    eng = make_engine("acis_hierarchical", outer_axis="pod")
    c = eng.compile(
        lambda x: acis.reduce(x, axis="auto"),
        axis_size=2,
        in_avals=(jax.ShapeDtypeStruct((1 << 16,), jnp.float32),))
    descs = {s.axis: s.desc for s in c.stages if s.kind == "allreduce"}
    assert "[pod]" in descs["pod"]


# ---------------------------------------------------------------------------
# flat vs hierarchical numerical equivalence on {data: 2, pod: 2}
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["acis", "acis_compressed",
                                     "acis_hierarchical",
                                     "acis_hierarchical_compressed"])
def test_gradient_sync_matches_flat_mean_on_2x2(mesh22, rng, backend):
    """All four acis backends (incl. codec + error feedback) sync through
    the compiled pipeline and match the flat mean."""
    g = {"w": rng.standard_normal((4, 33)).astype(np.float32),
         "b": rng.standard_normal((4, 5)).astype(np.float32)}
    eng = make_engine(backend, inner_axis="data", outer_axis="pod")

    def f(wl, bl):
        grads = {"w": wl[0, 0], "b": bl[0, 0]}
        state = eng.init_state(grads)
        synced, new_state = eng.gradient_sync(grads, state)
        return synced["w"][None, None], synced["b"][None, None]

    spec = P("pod", "data", None)
    w, b = smap(f, mesh22, (spec, spec), (spec, spec))(
        jnp.asarray(g["w"].reshape(2, 2, 33)),
        jnp.asarray(g["b"].reshape(2, 2, 5)))
    atol = 5e-2 if "compressed" in backend else 1e-4
    for p in range(2):
        for d in range(2):
            np.testing.assert_allclose(np.asarray(w)[p, d],
                                       g["w"].mean(0), atol=atol)
            np.testing.assert_allclose(np.asarray(b)[p, d],
                                       g["b"].mean(0), atol=atol)


def test_compressed_sync_error_feedback_state_updates(mesh22, rng):
    """The compiled EF program must return a real residual: target minus
    what the lossy wire delivered (nonzero, and exact for zero grads)."""
    g = {"w": rng.standard_normal((4, 64)).astype(np.float32)}
    eng = make_engine("acis_hierarchical_compressed", inner_axis="data",
                      outer_axis="pod")

    def f(wl):
        grads = {"w": wl[0, 0]}
        state = eng.init_state(grads)
        synced, new_state = eng.gradient_sync(grads, state)
        return synced["w"][None, None], new_state["w"][None, None]

    spec = P("pod", "data", None)
    w, r = smap(f, mesh22, spec, (spec, spec))(
        jnp.asarray(g["w"].reshape(2, 2, 64)))
    r = np.asarray(r)
    assert r.shape == (2, 2, 64)
    assert np.all(np.isfinite(r))
    # int8 shared-scale rounding leaves a small but nonzero residual
    assert 0 < np.abs(r).max() < 0.1


def test_hierarchical_all_reduce_matches_flat_on_2x2(mesh22, rng):
    """The thin topology.hierarchical_all_reduce wrapper (now a compiled
    switch program) still equals the flat mean, with and without a codec."""
    from repro.core import topology

    x = rng.standard_normal((4, 33)).astype(np.float32)

    for codec, atol in ((IDENTITY, 1e-4), (BF16, 5e-3)):
        def f(xl):
            return topology.hierarchical_all_reduce(
                xl[0, 0], inner_axis="data", outer_axis="pod",
                outer_codec=codec, mean=True)[None, None]

        out = np.asarray(smap(f, mesh22, P("pod", "data", None),
                              P("pod", "data", None))(
            jnp.asarray(x.reshape(2, 2, 33))))
        np.testing.assert_allclose(out[0, 0], x.mean(0), atol=atol)


def test_ef_reduce_traced_standalone(mesh22, rng):
    """ef_reduce is a first-class traced op: reduced + delivered pair to
    one look-aside stage; dropping `delivered` DCEs the sibling."""
    def both(x):
        red, dlv = acis.ef_reduce(x, axis="data")
        return red, dlv

    eng = make_engine("acis", outer_axis="pod")
    c = eng.compile(both)
    assert c.stage_kinds() == ["ef_allreduce"]
    assert len(c.stages[0].out_vids) == 2

    c_lone = eng.compile(lambda x: acis.ef_reduce(x, axis="data")[0])
    assert c_lone.stage_kinds() == ["ef_allreduce"]
    assert len(c_lone.stages[0].out_vids) == 1

    x = rng.standard_normal((4, 32)).astype(np.float32)

    def f(xl):
        red, dlv = c(xl[0, 0])
        return red[None, None], dlv[None, None]

    spec = P("pod", "data", None)
    red, dlv = smap(f, mesh22, spec, (spec, spec))(
        jnp.asarray(x.reshape(2, 2, 32)))
    # per-pod sum over the two data ranks, quantization-lossy
    want = x.reshape(2, 2, 32)[0].sum(0)
    np.testing.assert_allclose(np.asarray(red)[0, 0], want, atol=5e-2)


def test_custom_pipeline_without_lowertopology_still_runs(mesh22, rng):
    """Omitting LowerTopology (the documented composable-pipeline form)
    must fall back to the program-wide default axis, not crash with an
    unresolved axis at run time."""
    from repro.core import SwitchProgram, Reduce, compile_rank_local
    from repro.core.compiler import (Emit, FuseHops, Legalize,
                                     SelectSchedule)

    pipeline = (Legalize(), FuseHops(), SelectSchedule(), Emit())
    c = compile_rank_local(SwitchProgram([Reduce()]), "data",
                           pipeline=pipeline)
    assert c.stage_axes() == ["data"]

    # …and SelectSchedule still decides from ctx.axis_size, as before
    c_sched = compile_rank_local(
        SwitchProgram([Reduce()]), "data", axis_size=8,
        in_avals=(jax.ShapeDtypeStruct((4,), jnp.float32),),
        config=acis.CollectiveConfig(backend="acis",
                                     latency_optimal_below=16384),
        pipeline=pipeline)
    assert c_sched.stage_schedules() == ["latency"]

    # an unresolved compound axis must error loudly, not silently reduce
    # over the default axis only
    with pytest.raises(ValueError, match="LowerTopology"):
        compile_rank_local(
            SwitchProgram([Reduce(axis=("data", "pod"))]), "data",
            pipeline=pipeline)

    x = rng.standard_normal((4, 8)).astype(np.float32)
    out = np.asarray(smap(lambda v: c(v[0, 0])[0][None, None], mesh22,
                          P("pod", "data", None), P("pod", "data", None))(
        jnp.asarray(x.reshape(2, 2, 8))))
    # per-pod sum over the inner "data" axis only
    np.testing.assert_allclose(out[0, 0], x.reshape(2, 2, 8)[0].sum(0),
                               rtol=1e-5)


def test_wire_on_ef_reduce_is_dropped_not_silently_kept():
    """An EF reduce's wire format is the compressor's own — a user WIRE
    reaching it drops (fixed-function link semantics), it must not linger
    as an ignored codec attribute."""
    eng = make_engine("acis")
    c = eng.compile(lambda x: acis.ef_reduce(acis.wire(BF16, x),
                                             axis="data")[0])
    red = next(nd.op for nd in c.source.nodes
               if nd.op.kind == OpKind.REDUCE)
    assert red.codec is IDENTITY


def test_sync_program_is_cached_per_structure(mesh22):
    eng = make_engine("acis", inner_axis="data", outer_axis="pod")
    g = {"w": jnp.ones((4,)), "b": jnp.ones((3,))}

    def f(wl, bl):
        grads = {"w": wl[0, 0], "b": bl[0, 0]}
        s1, _ = eng.gradient_sync(grads, None)
        s2, _ = eng.gradient_sync(grads, None)
        return s1["w"][None, None], s2["b"][None, None]

    spec = P("pod", "data", None)
    smap(f, mesh22, (spec, spec), (spec, spec))(
        jnp.ones((2, 2, 4)), jnp.ones((2, 2, 3)))
    assert len(eng._sync_cache) == 1   # same treedef → one compile
