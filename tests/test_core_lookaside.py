"""Type 3 look-aside operators: state, loops, memory (8 devices)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import lookaside

N = 8


def smap(fn, mesh, in_specs, out_specs):
    return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False))


# ---------------------------------------------------------------------------
# error-feedback compressed all-reduce
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("compressor", ["int8", "topk"])
def test_error_feedback_identity(mesh8, rng, compressor):
    """The exact EF invariant: over T steps,
        cum_true_mean - cum_synced == mean_over_ranks(final_residual)
    i.e. *nothing is lost* — whatever the lossy wire withheld is still in
    the look-aside memory, to be delivered later."""
    steps = 12
    dim = 256
    grads = rng.standard_normal((steps, N, dim)).astype(np.float32)

    def run(gl):  # gl: [steps, 1, dim]
        def body(res, g):
            red, res = lookaside.error_feedback_all_reduce(
                g[0], res, "data", compressor=compressor, topk_ratio=0.05)
            return res, red
        res0 = jnp.zeros((dim,), jnp.float32)
        res_final, reds = jax.lax.scan(body, res0, gl)
        return reds[:, None, :], res_final[None]

    out, res = smap(run, mesh8, P(None, "data", None),
                    (P(None, "data", None), P("data", None)))(
        jnp.asarray(grads))
    out, res = np.asarray(out), np.asarray(res)
    cum_true = np.cumsum(grads.mean(axis=1), axis=0)[-1]
    cum_got = np.cumsum(out[:, 0, :], axis=0)[-1]
    np.testing.assert_allclose(cum_true - cum_got, res.mean(axis=0),
                               rtol=2e-2, atol=2e-2)
    # and for int8 (dense quantization) the residual itself must be tiny:
    if compressor == "int8":
        lsb = np.abs(grads).max() / 127
        assert np.abs(res).max() < 4 * lsb


def test_error_feedback_all_ranks_identical(mesh8, rng):
    g = rng.standard_normal((N, 300)).astype(np.float32)

    def f(gl):
        red, _ = lookaside.error_feedback_all_reduce(
            gl[0], jnp.zeros((300,), jnp.float32), "data", compressor="int8")
        return red[None]

    out = np.asarray(smap(f, mesh8, P("data", None), P("data", None))(
        jnp.asarray(g)))
    for i in range(1, N):
        np.testing.assert_array_equal(out[i], out[0])


# ---------------------------------------------------------------------------
# PowerSGD (the in-collective loop)
# ---------------------------------------------------------------------------

def test_powersgd_low_rank_exact_for_low_rank_input(mesh8, rng):
    """If the true mean gradient is rank<=r, one power iteration with a
    warm Q recovers it (up to orthonormalization conditioning)."""
    rows, cols, r = 32, 16, 4
    u = rng.standard_normal((rows, r)).astype(np.float32)
    v = rng.standard_normal((cols, r)).astype(np.float32)
    base = u @ v.T
    # every rank holds the same low-rank matrix => mean is low-rank
    m = np.broadcast_to(base, (N, rows, cols)).copy()

    def f(ml, q):
        red, new_q, res = lookaside.powersgd_all_reduce(
            ml[0], q, jnp.zeros((rows, cols), jnp.float32), "data")
        return red[None], new_q, res[None]

    q0 = jnp.asarray(rng.standard_normal((cols, r)).astype(np.float32))
    red, new_q, _ = smap(
        f, mesh8, (P("data", None, None), P(None, None)),
        (P("data", None, None), P(None, None), P("data", None, None)))(
            jnp.asarray(m), q0)
    got = np.asarray(red)[0]
    np.testing.assert_allclose(got, base, rtol=0.03, atol=0.03 * np.abs(base).max())


def test_powersgd_wire_is_smaller():
    from repro.core.compression import powersgd_wire_bytes
    assert powersgd_wire_bytes((1024, 1024), 8) < 4 * 1024 * 1024 / 10


# ---------------------------------------------------------------------------
# distributed prefix sum
# ---------------------------------------------------------------------------

def test_distributed_prefix_sum(mesh8, rng):
    x = rng.standard_normal((N * 16,)).astype(np.float32)

    def f(xl):
        return lookaside.distributed_prefix_sum(xl, "data")

    out = np.asarray(smap(f, mesh8, P("data"), P("data"))(jnp.asarray(x)))
    np.testing.assert_allclose(out, np.cumsum(x), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# GCN aggregation (paper Fig. 4 case study)
# ---------------------------------------------------------------------------

def _random_graph(rng, n_nodes, d):
    adj = (rng.random((n_nodes, n_nodes)) < 0.2).astype(np.float32)
    deg = np.maximum(adj.sum(1, keepdims=True), 1)
    adj = adj / deg                      # row-normalized Â
    x = rng.standard_normal((n_nodes, d)).astype(np.float32)
    return adj, x


@pytest.mark.parametrize("in_network", [True, False])
def test_gcn_aggregate_matches_dense(mesh8, rng, in_network):
    n_nodes, d = N * 8, 12
    adj, x = _random_graph(rng, n_nodes, d)
    want = adj @ x
    rows = n_nodes // N
    # adj_blocks[rank][b] = adj rows of `rank`, cols of block b
    adj_blocks = adj.reshape(N, rows, N, rows).transpose(0, 2, 1, 3)

    def f(al, xl):
        out = lookaside.gcn_aggregate(al[0], xl[0], "data",
                                      in_network=in_network)
        return out[None]

    out = np.asarray(smap(
        f, mesh8, (P("data", None, None, None), P("data", None, None)),
        P("data", None, None))(jnp.asarray(adj_blocks),
                               jnp.asarray(x.reshape(N, rows, d))))
    np.testing.assert_allclose(out.reshape(n_nodes, d), want,
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# look-aside ops routed through engine.compile (not just raw shard_map)
# ---------------------------------------------------------------------------

def test_distributed_prefix_sum_through_engine_compile(mesh8, rng):
    """The Fig. 5 FEM op as a *compiled switch program*: the look-aside
    scan rides a MAP body through the full pass pipeline, and the CGRA
    mapper correctly refuses to place a body that communicates."""
    from repro import core as acis
    from repro.cgra.device import HostFallback

    eng = acis.make_engine("acis")
    fn = eng.compile(
        lambda x: acis.map(
            lambda v: lookaside.distributed_prefix_sum(v, "data"), x,
            name="prefix_sum", fusable=False),
        mesh8, P("data"), P("data"),
        in_avals=(jax.ShapeDtypeStruct((16,), jnp.float32),))
    assert fn.stages == ["map"]
    # a MAP body with a ppermute inside is endpoint code — explicit
    # host-fallback, never a silent in-switch rate
    (pl,) = fn.compiled.stage_placements()
    assert isinstance(pl, HostFallback)

    x = rng.standard_normal((N * 16,)).astype(np.float32)
    out = np.asarray(fn(jnp.asarray(x)))
    np.testing.assert_allclose(out, np.cumsum(x), rtol=1e-4, atol=1e-4)


def test_gcn_aggregate_through_engine_compile(mesh8, rng):
    """The paper's Type 3 GCN case study through engine.compile: a
    two-input MAP whose body ring-rotates feature blocks against the
    HBM-resident accumulator."""
    from repro import core as acis
    from repro.cgra.device import HostFallback

    n_nodes, d = N * 8, 12
    adj, x = _random_graph(rng, n_nodes, d)
    want = adj @ x
    rows = n_nodes // N
    adj_blocks = adj.reshape(N, rows, N, rows).transpose(0, 2, 1, 3)

    eng = acis.make_engine("acis")
    fn = eng.compile(
        lambda a, v: acis.map(
            lambda ab, xb: lookaside.gcn_aggregate(ab[0], xb[0],
                                                   "data")[None],
            a, v, name="gcn_aggregate"),
        mesh8,
        (P("data", None, None, None), P("data", None, None)),
        P("data", None, None),
        in_avals=(jax.ShapeDtypeStruct((1, N, rows, rows), jnp.float32),
                  jax.ShapeDtypeStruct((1, rows, d), jnp.float32)))
    assert fn.stages == ["map"]
    (pl,) = fn.compiled.stage_placements()
    assert isinstance(pl, HostFallback)

    out = np.asarray(fn(jnp.asarray(adj_blocks),
                        jnp.asarray(x.reshape(N, rows, d))))
    np.testing.assert_allclose(out.reshape(n_nodes, d), want,
                               rtol=1e-4, atol=1e-4)


def test_gcn_baseline_through_engine_compile_matches(mesh8, rng):
    """Endpoint baseline (all-gather + SpMM) compiles and agrees with the
    in-network variant — like-for-like through the same entry point."""
    from repro import core as acis

    n_nodes, d = N * 4, 6
    adj, x = _random_graph(rng, n_nodes, d)
    rows = n_nodes // N
    adj_blocks = adj.reshape(N, rows, N, rows).transpose(0, 2, 1, 3)

    eng = acis.make_engine("acis")

    def prog(a, v):
        gathered = acis.all_gather(v)
        return acis.map(
            lambda ab, full: jnp.einsum(
                "brc,bcd->rd", ab[0],
                full.reshape(N, rows, d))[None],
            a, gathered, name="spmm")

    fn = eng.compile(prog, mesh8,
                     (P("data", None, None, None), P("data", None, None)),
                     P("data", None, None))
    out = np.asarray(fn(jnp.asarray(adj_blocks),
                        jnp.asarray(x.reshape(N, rows, d))))
    np.testing.assert_allclose(out.reshape(n_nodes, d), adj @ x,
                               rtol=1e-4, atol=1e-4)
