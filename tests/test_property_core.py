"""Hypothesis property tests on core engine invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.core import ring
from repro.core.types import ADD
from repro.core.wire import dequantize_int8, quantize_int8

N = 8
_MESH = None


def mesh():
    global _MESH
    if _MESH is None:
        _MESH = jax.make_mesh((N,), ("data",),
                              axis_types=(jax.sharding.AxisType.Auto,))
    return _MESH


def smap(fn, in_specs, out_specs):
    return jax.jit(jax.shard_map(fn, mesh=mesh(), in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False))


# cache jitted collectives across hypothesis examples (shape-keyed by jit)
_AR = smap(lambda xl: ring.ring_all_reduce(xl[0], "data", ADD)[None],
           P("data", None), P("data", None))
_A2A = smap(lambda xl: ring.ring_all_to_all(xl[0], "data")[None],
            P("data", None), P("data", None))
_SCAN = smap(lambda xl: ring.rank_prefix_scan(xl[0], "data", ADD)[None],
             P("data", None), P("data", None))


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 64), st.integers(0, 2 ** 31 - 1))
def test_allreduce_equals_sum(dim, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((N, dim)).astype(np.float32)
    out = np.asarray(_AR(jnp.asarray(x)))
    want = x.sum(axis=0)
    for i in range(N):
        np.testing.assert_allclose(out[i], want, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 8), st.integers(0, 2 ** 31 - 1))
def test_alltoall_is_involution(chunk, seed):
    """A2A is a block transpose: applying it twice is the identity."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((N, N * chunk)).astype(np.float32)
    once = _A2A(jnp.asarray(x))
    twice = np.asarray(_A2A(once))
    np.testing.assert_allclose(twice, x, rtol=1e-6, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 32), st.integers(0, 2 ** 31 - 1))
def test_scan_last_rank_equals_allreduce(dim, seed):
    """Inclusive scan at the last rank == the full reduction."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((N, dim)).astype(np.float32)
    scan = np.asarray(_SCAN(jnp.asarray(x)))
    np.testing.assert_allclose(scan[-1], x.sum(axis=0), rtol=1e-4, atol=1e-4)
    # monotone property: scan[i] - scan[i-1] == x[i]
    diffs = scan[1:] - scan[:-1]
    np.testing.assert_allclose(diffs, x[1:], rtol=1e-3, atol=1e-3)


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 1000), st.floats(0.01, 100.0),
       st.integers(0, 2 ** 31 - 1))
def test_quantization_error_bound(size, scale_mag, seed):
    """|x - deq(quant(x))| <= blockwise absmax / 127 / 2 (+eps)."""
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal(size) * scale_mag).astype(np.float32)
    q, s, n = quantize_int8(jnp.asarray(x))
    y = np.asarray(dequantize_int8(q, s, n))
    blocks = np.ceil(size / 256).astype(int)
    pad = blocks * 256 - size
    xp = np.pad(x, (0, pad)).reshape(blocks, 256)
    bound = (np.abs(xp).max(axis=1, keepdims=True) / 127 / 2 + 1e-6)
    err = np.abs(xp - np.pad(y, (0, pad)).reshape(blocks, 256))
    assert np.all(err <= bound * 1.001)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 100), st.integers(1, 16))
def test_pad_to_multiple_roundtrip(size, n):
    x = jnp.arange(float(size))
    padded, orig = ring.pad_to_multiple(x, n)
    assert padded.shape[0] % n == 0
    assert orig == size
    np.testing.assert_array_equal(np.asarray(padded[:size]), np.asarray(x))
