"""Substrate tests: optimizer, train-step strategies, checkpoint/restart,
data determinism, pipeline parallelism."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.core import make_engine
from repro.data.pipeline import BigramStream, DataConfig
from repro.models import Model
from repro.checkpoint import checkpoint as ckpt
from repro.train import optimizer as opt_lib
from repro.train.loop import LoopConfig, TrainLoop, run_with_restarts
from repro.train.step import (TrainState, build_train_step_acis,
                              build_train_step_gspmd, init_state)

ARCH = "acis-100m"


def _setup(mesh, backend="xla", microbatches=1, f32=False):
    import dataclasses
    cfg = configs.get_smoke(ARCH)
    if f32:
        cfg = dataclasses.replace(cfg, param_dtype="float32",
                                  dtype="float32")
    model = Model(cfg)
    optimizer = opt_lib.adamw(lr=1e-2)
    if backend == "xla":
        step = build_train_step_gspmd(model, optimizer, mesh,
                                      microbatches=microbatches,
                                      donate=False)
        engine = None
    else:
        engine = make_engine(backend, inner_axis="data",
                             outer_axis="pod" if "pod" in mesh.axis_names
                             else None)
        step = build_train_step_acis(model, optimizer, mesh, engine,
                                     microbatches=microbatches)
    state = init_state(model, optimizer, jax.random.key(0), engine)
    return cfg, model, step, state


def _stream(cfg, batch=8):
    return BigramStream(DataConfig(vocab=cfg.vocab, seq_len=16,
                                   global_batch=batch, seed=3))


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_descends_quadratic(name):
    o = opt_lib.adamw(1e-1) if name == "adamw" else opt_lib.adafactor(1e-1)
    params = {"w": jnp.array([[3.0, -2.0], [1.0, 4.0]])}
    state = o.init(params)
    val = lambda p: jnp.sum(jnp.square(p["w"]))
    for step in range(200):
        g = jax.grad(val)(params)
        params, state = o.update(g, state, params,
                                 jnp.asarray(step, jnp.int32))
    assert float(val(params)) < 0.05


def test_warmup_cosine_schedule():
    lr = opt_lib.warmup_cosine(1.0, 10, 100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert abs(float(lr(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(lr(jnp.asarray(99))) < 0.2


# ---------------------------------------------------------------------------
# train steps
# ---------------------------------------------------------------------------

def test_gspmd_train_step_descends(mesh_dm):
    cfg, model, step, state = _setup(mesh_dm)
    stream = _stream(cfg)
    with jax.set_mesh(mesh_dm):
        losses = []
        for i in range(12):
            batch = {"tokens": jnp.asarray(stream.batch(i)["tokens"])}
            state, m = step(state, batch)
            losses.append(float(m["nll"]))
    assert losses[-1] < losses[0] - 0.2, losses
    assert int(np.asarray(state.step)) == 12


@pytest.mark.parametrize("microbatches", [1, 4])
def test_gspmd_microbatching_equivalent(mesh_dm, microbatches):
    """Grad accumulation must match the single-shot gradient (same batch)."""
    cfg, model, step1, state = _setup(mesh_dm, microbatches=1)
    _, _, stepm, _ = _setup(mesh_dm, microbatches=microbatches)
    stream = _stream(cfg)
    batch = {"tokens": jnp.asarray(stream.batch(0)["tokens"])}
    with jax.set_mesh(mesh_dm):
        s1, m1 = step1(state, batch)
        sm, mm = stepm(state, batch)
    np.testing.assert_allclose(float(m1["nll"]), float(mm["nll"]), rtol=1e-3)
    l1 = jax.tree.leaves(s1.params)[0]
    lm = jax.tree.leaves(sm.params)[0]
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(lm, np.float32), atol=2e-2)


@pytest.mark.parametrize("backend", ["acis", "acis_compressed"])
def test_acis_step_matches_xla_step(mesh_dm, backend):
    """The MPI-transparency claim: swapping the transport must not change
    training (to reduction-order tolerance for 'acis', to EF-compression
    tolerance otherwise).  f32 params so the comparison isn't dominated by
    bf16 rounding amplified through Adam's rsqrt."""
    cfg, model, step_x, state_x = _setup(mesh_dm, "xla", f32=True)
    _, _, step_a, state_a = _setup(mesh_dm, backend, f32=True)
    stream = _stream(cfg)
    with jax.set_mesh(mesh_dm):
        for i in range(3):
            batch = {"tokens": jnp.asarray(stream.batch(i)["tokens"])}
            state_x, mx = step_x(state_x, batch)
            state_a, ma = step_a(state_a, batch)
    # param-trajectory tolerance: Adam's rsqrt amplifies reduction-order
    # noise on near-zero grads into up to ~2·lr per step for isolated
    # elements (observed: 2/16k elements at 1.3e-2 after 3 steps with
    # lr=1e-2); the tight functional check is the loss match below.
    atol = 6e-2 if "compressed" in backend else 2.5e-2
    for lx, la in zip(jax.tree.leaves(state_x.params),
                      jax.tree.leaves(state_a.params)):
        np.testing.assert_allclose(np.asarray(lx, np.float32),
                                   np.asarray(la, np.float32), atol=atol)
    np.testing.assert_allclose(float(mx["nll"]), float(ma["nll"]), atol=0.05)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_host_sharded():
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=8, seed=1)
    s1, s2 = BigramStream(cfg), BigramStream(cfg)
    np.testing.assert_array_equal(s1.batch(5)["tokens"],
                                  s2.batch(5)["tokens"])
    a = BigramStream(DataConfig(vocab=64, seq_len=8, global_batch=8, seed=1,
                                host_id=0, num_hosts=2))
    b = BigramStream(DataConfig(vocab=64, seq_len=8, global_batch=8, seed=1,
                                host_id=1, num_hosts=2))
    assert a.batch(0)["tokens"].shape == (4, 9)
    assert not np.array_equal(a.batch(0)["tokens"], b.batch(0)["tokens"])


def test_data_has_learnable_structure():
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=4, seed=1,
                     branching=4)
    s = BigramStream(cfg)
    assert s.entropy() < np.log(64) * 0.5   # far below uniform entropy


# ---------------------------------------------------------------------------
# checkpoint / restart / elastic
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path, mesh_dm):
    cfg, model, step, state = _setup(mesh_dm)
    d = str(tmp_path / "ck")
    ckpt.save(d, 7, state)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state)
    restored, stepno, _ = ckpt.restore(d, like)
    assert stepno == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_detects_corruption(tmp_path, mesh_dm):
    cfg, model, step, state = _setup(mesh_dm)
    d = str(tmp_path / "ck")
    path = ckpt.save(d, 1, state)
    # corrupt one shard
    victim = sorted(os.listdir(path))[1]
    arr = np.load(os.path.join(path, victim))
    arr = np.asarray(arr)
    arr.flat[0] += 1
    np.save(os.path.join(path, victim), arr)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state)
    with pytest.raises(IOError, match="checksum"):
        ckpt.restore(d, like)


def test_training_resumes_bit_exact_after_crash(tmp_path, mesh_dm):
    """Kill at step 6, restart from the step-5 checkpoint, final state must
    equal an uninterrupted run (data position is derived from the step)."""
    cfg, model, stepfn, state0 = _setup(mesh_dm)
    stream = _stream(cfg)
    d = str(tmp_path / "ck")

    def make_loop(fail_at=None):
        _, _, stepfn, st = _setup(mesh_dm)
        loop = TrainLoop(stepfn, stream,
                         LoopConfig(total_steps=10, ckpt_every=5,
                                    ckpt_dir=d, fail_at_step=fail_at,
                                    log_every=100))
        return loop, st

    with jax.set_mesh(mesh_dm):
        # uninterrupted reference (no checkpoint dir interference)
        _, _, stepfn_r, st_r = _setup(mesh_dm)
        ref_loop = TrainLoop(stepfn_r, stream,
                             LoopConfig(total_steps=10, log_every=100))
        ref = ref_loop.run(st_r)

        calls = {"n": 0}

        def factory():
            calls["n"] += 1
            return make_loop(fail_at=6 if calls["n"] == 1 else None)

        final, restarts = run_with_restarts(factory)
    assert restarts == 1
    for a, b in zip(jax.tree.leaves(ref.params),
                    jax.tree.leaves(final.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_restore_across_meshes(tmp_path, mesh_dm, mesh8):
    """A checkpoint written under one mesh restores onto a different mesh
    (global arrays are mesh-agnostic)."""
    from repro.sharding import rules
    cfg, model, step, state = _setup(mesh_dm)
    d = str(tmp_path / "ck")
    ckpt.save(d, 3, state.params)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state.params)
    shardings = rules.param_shardings(like, mesh8)
    restored, _, _ = ckpt.restore(d, like, shardings=shardings)
    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# pipeline parallelism
# ---------------------------------------------------------------------------

def test_pipeline_matches_sequential(devices):
    from repro.train.pipeline import run_pipeline
    mesh = jax.make_mesh((4,), ("pipe",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.default_rng(0)
    s, m, mb, dim = 4, 6, 3, 8
    ws = jnp.asarray(rng.standard_normal((s, dim, dim)).astype(np.float32)
                     * 0.5)
    x = jnp.asarray(rng.standard_normal((m, mb, dim)).astype(np.float32))

    def stage_fn(wslice, xin):     # wslice: [1, dim, dim] local stage params
        return jnp.tanh(xin @ wslice[0])

    got = np.asarray(run_pipeline(mesh, stage_fn, ws, x))
    want = np.asarray(x)
    for i in range(s):
        want = np.tanh(want @ np.asarray(ws[i]))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
