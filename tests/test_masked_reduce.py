"""First-class bounded-staleness reduce: ``tracing.masked_reduce``.

The mask is a *runtime* program input: ranks with ``alive == 0``
contribute the monoid identity and the live count travels in the same
flat ring buffer as the payload — one collective launch.  Covers the
trace/legalize expansion (stage shapes on the flat and hierarchical
pipelines), Coalesce bucketing (many masked leaves still cost one
ring), CGRA placement of the pack/renorm epilogues, the analytic
overhead gate, numerics against a shard_map oracle on every engine
backend (error-feedback residuals included), and the plan pipelining
that hides the masked epilogues under neighboring bucket rings.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import make_engine, tracing
from repro.core.types import ADD, MAX

AV = jax.ShapeDtypeStruct

BACKENDS = ["acis", "acis_compressed", "acis_hierarchical",
            "acis_hierarchical_compressed"]


@pytest.fixture(scope="module")
def mesh22():
    return jax.make_mesh((2, 2), ("pod", "data"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def smap(fn, mesh, in_specs, out_specs):
    return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False))


def _compile_masked(n=8, size=64, monoid=ADD, renormalize=True,
                    backend="acis", outer_axis=None, axis_sizes=None):
    kw = {"inner_axis": "data"}
    if outer_axis:
        kw["outer_axis"] = outer_axis
    eng = make_engine(backend, **kw)

    def prog(x, alive):
        return tracing.masked_reduce(x, alive, monoid,
                                     axis="auto", renormalize=renormalize)

    return eng.compile(prog, axis_size=axis_sizes or n,
                       in_avals=(AV((size,), jnp.float32),
                                 AV((), jnp.float32)))


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def test_masked_mean_matches_oracle(mesh8, rng):
    x = rng.standard_normal((8, 64)).astype(np.float32)
    alive = np.array([1, 0, 1, 1, 1, 0, 1, 1], np.float32)
    compiled = _compile_masked()

    def f(xl, al):
        v, c = compiled(xl[0], al[0].reshape(()))
        return v[None], c.reshape(1)

    v, c = smap(f, mesh8, (P("data", None), P("data")),
                (P("data", None), P("data")))(
        jnp.asarray(x), jnp.asarray(alive))
    want = x[alive != 0].mean(axis=0)
    np.testing.assert_allclose(np.asarray(v)[0], want, atol=1e-5)
    assert np.all(np.asarray(c) == 6.0)


def test_masked_max_uses_monoid_identity(mesh8, rng):
    """Dead ranks contribute the monoid identity (-inf for max), not
    zero — a dead rank holding the global max must not leak it."""
    x = rng.standard_normal((8, 16)).astype(np.float32)
    x[3] += 100.0                                  # rank 3 holds the max
    alive = np.ones(8, np.float32)
    alive[3] = 0.0
    compiled = _compile_masked(monoid=MAX, renormalize=False)

    def f(xl, al):
        v, c = compiled(xl[0], al[0].reshape(()))
        return v[None], c.reshape(1)

    v, c = smap(f, mesh8, (P("data", None), P("data")),
                (P("data", None), P("data")))(
        jnp.asarray(x), jnp.asarray(alive))
    want = x[alive != 0].max(axis=0)
    np.testing.assert_allclose(np.asarray(v)[0], want, atol=1e-6)
    # the count lane rides the same ring, so it folds under the same
    # monoid: for max it is any-alive (1.0), not a sum
    assert np.all(np.asarray(c) == 1.0)


def test_all_dead_clamps_count(mesh8):
    x = jnp.ones((8, 8))
    compiled = _compile_masked(size=8)

    def f(xl, al):
        v, c = compiled(xl[0], al[0].reshape(()))
        return v[None], c.reshape(1)

    v, c = smap(f, mesh8, (P("data", None), P("data")),
                (P("data", None), P("data")))(
        x, jnp.zeros((8,), jnp.float32))
    assert np.all(np.isfinite(np.asarray(v)))      # no div-by-zero NaN
    assert np.all(np.asarray(c) == 1.0)            # clamped, never 0


def test_renormalize_requires_add():
    with pytest.raises(ValueError, match="renormaliz"):
        _compile_masked(monoid=MAX, renormalize=True)


# ---------------------------------------------------------------------------
# compiled shape: one ring, count lane folded into the payload buffer
# ---------------------------------------------------------------------------

def test_flat_masked_is_one_ring():
    compiled = _compile_masked()
    kinds = [s.kind for s in compiled.stages]
    assert kinds.count("allreduce") == 1, kinds
    assert kinds == ["map", "allreduce", "map", "map"]


def test_hierarchical_masked_is_one_pipeline():
    compiled = _compile_masked(backend="acis_hierarchical",
                               outer_axis="pod",
                               axis_sizes={"data": 4, "pod": 2})
    kinds = [s.kind for s in compiled.stages]
    colls = [k for k in kinds
             if k in ("reduce_scatter", "allreduce", "allgather")]
    assert colls == ["reduce_scatter", "allreduce", "allgather"], kinds


def test_bucketed_masked_leaves_share_one_ring():
    """Coalesce folds many masked leaves + the count into ONE flat
    buffer — bounded staleness must not cost a ring per leaf."""
    eng = make_engine("acis", inner_axis="data")

    def prog(a, b, c, alive):
        va, _ = tracing.masked_reduce(a, alive, axis="auto")
        vb, _ = tracing.masked_reduce(b, alive, axis="auto")
        vc, _ = tracing.masked_reduce(c, alive, axis="auto")
        return va, vb, vc

    compiled = eng.compile(
        prog, axis_size=8,
        in_avals=(AV((32,), jnp.float32), AV((48,), jnp.float32),
                  AV((16,), jnp.float32), AV((), jnp.float32)))
    kinds = [s.kind for s in compiled.stages]
    assert kinds.count("allreduce") == 1, kinds


def test_masked_epilogues_place_on_cgra():
    """The pack and renorm epilogues must stay on the switch: an int
    index like ``b[-1]`` lowers to a gather the CGRA cannot place and
    silently detours megabytes over PCIe."""
    from repro.cgra.device import HostFallback

    for backend, kw in (("acis", {}),
                        ("acis_hierarchical",
                         {"outer_axis": "pod",
                          "axis_sizes": {"data": 4, "pod": 2}})):
        compiled = _compile_masked(backend=backend, size=4096, **kw)
        fellback = [getattr(s.placement, "reason", "")
                    for s in compiled.stages
                    if isinstance(s.placement, HostFallback)]
        assert not fellback, (backend, fellback)


# ---------------------------------------------------------------------------
# analytic overhead + plan pipelining
# ---------------------------------------------------------------------------

def _sync_programs(masked: bool):
    eng = make_engine("acis", inner_axis="data")
    gl = {"w": jnp.zeros((4096,), jnp.float32),
          "b": jnp.zeros((128,), jnp.float32)}
    treedef = jax.tree_util.tree_structure(gl)
    avals = tuple(AV(l.shape, l.dtype)
                  for l in jax.tree_util.tree_leaves(gl))
    return eng._sync_program(treedef, avals, None,
                             axis_sizes={"data": 8}, masked=masked)


def test_masked_sync_overhead_gate():
    """At zero faults the masked sync prices within 5% of the unmasked
    one — the count lane plus a hidden epilogue, not a second launch."""
    t_plain = _sync_programs(masked=False).program_time()
    t_masked = _sync_programs(masked=True).program_time()
    assert t_masked <= 1.05 * t_plain, (t_masked, t_plain)


def test_plan_staggers_same_axis_rings():
    """Symmetric masked bucket chains pipeline: no wave holds two
    collectives on the same (sole) axis, and every non-final renorm/pack
    map shares a wave with a collective it hides under."""
    eng = make_engine("acis", inner_axis="data")

    def prog(a, b, alive):
        va, _ = tracing.masked_reduce(a, alive, axis="auto")
        vb, _ = tracing.masked_reduce(b, alive, axis="auto")
        return va, vb

    # two leaves far above bucket_bytes => two bucket chains
    compiled = eng.compile(
        prog, axis_size=8,
        in_avals=(AV((1 << 18,), jnp.float32), AV((1 << 17,), jnp.float32),
                  AV((), jnp.float32)))
    plan = compiled.plan
    for wave in plan.waves:
        axes = [plan.stages[i].axis for i in wave if plan.stages[i].axis]
        assert len(axes) == len(set(axes)), plan.waves


def test_pipeline_levels_keep_cross_axis_waves():
    """Collectives on *different* axes in one wave are the overlap the
    tier model rewards — the stagger must not split them."""
    from repro import core as acis

    eng = make_engine("acis", inner_axis="data", outer_axis="pod")

    def prog(x, y):
        return (acis.reduce(x, axis="data"), acis.reduce(y, axis="pod"))

    compiled = eng.compile(prog,
                           in_avals=(AV((256,), jnp.float32),
                                     AV((256,), jnp.float32)),
                           axis_size={"data": 4, "pod": 2})
    plan = compiled.plan
    coll_waves = [w for w in plan.waves
                  if sum(1 for i in w if plan.stages[i].axis) == 2]
    assert coll_waves, plan.waves   # both rings share one wave


# ---------------------------------------------------------------------------
# gradient_sync(membership=...) across every backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["xla"] + BACKENDS)
def test_gradient_sync_membership_all_backends(mesh22, rng, backend):
    from repro.elastic import Membership

    g = {"w": rng.standard_normal((4, 33)).astype(np.float32),
         "b": rng.standard_normal((4, 5)).astype(np.float32)}
    mem = Membership((True, False, True, True))    # rank (pod0, data1) dead
    alive = np.array(mem.alive)
    eng = make_engine(backend, inner_axis="data", outer_axis="pod")

    def f(wl, bl):
        grads = {"w": wl[0, 0], "b": bl[0, 0]}
        state = eng.init_state(grads)
        synced, _ = eng.gradient_sync(grads, state, membership=mem)
        return synced["w"][None, None], synced["b"][None, None]

    spec = P("pod", "data", None)
    w, b = smap(f, mesh22, (spec, spec), (spec, spec))(
        jnp.asarray(g["w"].reshape(2, 2, 33)),
        jnp.asarray(g["b"].reshape(2, 2, 5)))
    atol = 5e-2 if "compressed" in backend else 1e-4
    np.testing.assert_allclose(np.asarray(w)[0, 0], g["w"][alive].mean(0),
                               atol=atol)
    np.testing.assert_allclose(np.asarray(b)[1, 1], g["b"][alive].mean(0),
                               atol=atol)


def test_gradient_sync_membership_is_runtime_input(mesh22, rng):
    """Flipping the mask must not retrace: the same compiled sync serves
    every membership (the mask rides in as a program input)."""
    from repro.elastic import Membership
    from repro.obs import metrics as obs

    g = {"w": rng.standard_normal((4, 12)).astype(np.float32)}
    eng = make_engine("acis", inner_axis="data", outer_axis="pod")

    def run(mem):
        def f(wl):
            grads = {"w": wl[0, 0]}
            state = eng.init_state(grads)
            synced, _ = eng.gradient_sync(grads, state, membership=mem)
            return synced["w"][None, None]
        spec = P("pod", "data", None)
        return smap(f, mesh22, spec, spec)(
            jnp.asarray(g["w"].reshape(2, 2, 12)))

    run(Membership.all_alive(4))                   # warm the cache
    with obs.recording() as rec:
        for dead in (0, 1, 3):
            out = run(Membership.all_alive(4).drop(dead))
            alive = np.ones(4, bool)
            alive[dead] = False
            np.testing.assert_allclose(np.asarray(out)[0, 0],
                                       g["w"][alive].mean(0), atol=1e-4)
    assert rec.counter("compile.cache_miss") == 0
