"""Continuous-batching serving engine tests (per-slot positions)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import Model
from repro.serve.engine import Completion, Request, ServeEngine

ARCH = "acis-100m"


@pytest.fixture(scope="module")
def served():
    cfg = configs.get_smoke(ARCH)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _greedy_reference(model, params, prompt, n_new, vocab):
    """Oracle: full forward re-run per generated token."""
    toks = list(prompt)
    for _ in range(n_new):
        h, _ = model.forward(params, jnp.asarray([toks], jnp.int32))
        lg = model.logits(params, h)[0, -1]
        toks.append(int(np.asarray(lg).argmax()))
    return toks[len(prompt):]


def test_single_request_matches_full_forward(served, rng):
    cfg, model, params = served
    prompt = rng.integers(0, cfg.vocab, 5).astype(np.int32)
    eng = ServeEngine(model, params, slots=2, max_seq=64)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=6))
    done = eng.run_to_completion()
    assert len(done) == 1
    want = _greedy_reference(model, params, prompt, 6, cfg.vocab)
    assert done[0].tokens == want


def test_continuous_batching_heterogeneous_lengths(served, rng):
    """Requests with different prompt/generation lengths sharing slots must
    each match their independent greedy decode (no cache cross-talk)."""
    cfg, model, params = served
    reqs = [
        Request(rid=0, prompt=rng.integers(0, cfg.vocab, 3).astype(np.int32),
                max_new_tokens=8),
        Request(rid=1, prompt=rng.integers(0, cfg.vocab, 7).astype(np.int32),
                max_new_tokens=4),
        Request(rid=2, prompt=rng.integers(0, cfg.vocab, 5).astype(np.int32),
                max_new_tokens=6),
        Request(rid=3, prompt=rng.integers(0, cfg.vocab, 2).astype(np.int32),
                max_new_tokens=9),
        Request(rid=4, prompt=rng.integers(0, cfg.vocab, 4).astype(np.int32),
                max_new_tokens=5),
    ]
    eng = ServeEngine(model, params, slots=2, max_seq=64)
    for r in reqs:
        eng.submit(r)
    done = eng.run_to_completion()
    assert len(done) == 5
    by_rid = {c.rid: c for c in done}
    for r in reqs:
        want = _greedy_reference(model, params, r.prompt, r.max_new_tokens,
                                 cfg.vocab)
        assert by_rid[r.rid].tokens == want, f"rid {r.rid}"


def test_slot_refill_reuses_batch(served, rng):
    """More requests than slots: the engine must recycle slots and keep one
    jitted program (no per-request recompile)."""
    cfg, model, params = served
    eng = ServeEngine(model, params, slots=2, max_seq=64)
    for i in range(4):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab,
                                               3 + i).astype(np.int32),
                           max_new_tokens=4))
    done = eng.run_to_completion()
    assert sorted(c.rid for c in done) == [0, 1, 2, 3]
    assert eng.ticks < 60  # sanity: refills overlapped, not serialized
