"""Type 4 fused collectives: fused == unfused semantics (8 devices)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import fused

N = 8


def smap(fn, mesh, in_specs, out_specs):
    return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False))


def test_allgather_op_allgather_fused_equals_baseline(mesh8, rng):
    x = rng.standard_normal((N * 16,)).astype(np.float32)

    def fzd(xl):
        return fused.allgather_op_allgather(xl, "data")

    def base(xl):
        return fused.allgather_op_allgather_baseline(xl, "data")

    # fused output is replicated content: every rank's slice of the gathered
    # result equals the full prefix sum
    a = np.asarray(smap(fzd, mesh8, P("data"), P(None))(jnp.asarray(x)))
    b = np.asarray(smap(base, mesh8, P("data"), P(None))(jnp.asarray(x)))
    want = np.cumsum(x)
    np.testing.assert_allclose(a, want, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(b, want, rtol=1e-4, atol=1e-4)


def test_fused_allreduce_alltoall(mesh8, rng):
    hist = rng.integers(0, 10, (N, 32)).astype(np.float32)
    keys = rng.standard_normal((N, N * 4)).astype(np.float32)

    def fzd(h, k):
        hh, kk = fused.fused_allreduce_alltoall(h[0], k[0], "data")
        return hh[None], kk[None]

    def base(h, k):
        hh, kk = fused.allreduce_alltoall_baseline(h[0], k[0], "data")
        return hh[None], kk[None]

    spec = (P("data", None), P("data", None))
    ha, ka = smap(fzd, mesh8, spec, spec)(jnp.asarray(hist), jnp.asarray(keys))
    hb, kb = smap(base, mesh8, spec, spec)(jnp.asarray(hist), jnp.asarray(keys))
    np.testing.assert_allclose(np.asarray(ha), np.asarray(hb), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(ka), np.asarray(kb), rtol=1e-6)
    # oracle
    np.testing.assert_allclose(np.asarray(ha)[0], hist.sum(0), rtol=1e-5)


def test_map_reduce_scatter(mesh8, rng):
    x = rng.standard_normal((N, N * 8)).astype(np.float32)

    def f(xl):
        return fused.map_reduce_scatter(xl[0], "data", jnp.square)[None]

    out = np.asarray(smap(f, mesh8, P("data", None), P("data", None))(
        jnp.asarray(x)))
    want = np.square(x).sum(axis=0)
    got = out.reshape(-1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_allgather_map_applied_in_flight(mesh8, rng):
    x = rng.standard_normal((N, 4)).astype(np.float32)

    def f(xl):
        return fused.allgather_map(xl[0], "data", lambda c: c * 3.0)[None]

    out = np.asarray(smap(f, mesh8, P("data", None), P("data", None))(
        jnp.asarray(x)))
    want = (3.0 * x).reshape(-1)
    for i in range(N):
        np.testing.assert_allclose(out[i], want, rtol=1e-6)


# ---------------------------------------------------------------------------
# collective matmul
# ---------------------------------------------------------------------------

def test_allgather_matmul_overlapped_equals_baseline(mesh_dm, rng):
    # mesh_dm: data=2, model=4; operate over 'model'
    m_loc, k, n_loc = 6, 16, 8
    nm = 4
    x = rng.standard_normal((nm * m_loc, k)).astype(np.float32)
    w = rng.standard_normal((k, nm * n_loc)).astype(np.float32)

    def fzd(xl, wl):
        return fused.allgather_matmul(xl, wl, "model")

    def base(xl, wl):
        return fused.allgather_matmul_baseline(xl, wl, "model")

    in_specs = (P("model", None), P(None, "model"))
    a = np.asarray(smap(fzd, mesh_dm, in_specs, P(None, "model"))(
        jnp.asarray(x), jnp.asarray(w)))
    b = np.asarray(smap(base, mesh_dm, in_specs, P(None, "model"))(
        jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(a, x @ w, rtol=1e-4, atol=1e-4)


def test_matmul_reduce_scatter_overlapped_equals_baseline(mesh_dm, rng):
    m, k_loc, n_cols = 6, 8, 32
    nm = 4
    x = rng.standard_normal((m, nm * k_loc)).astype(np.float32)
    w = rng.standard_normal((nm * k_loc, n_cols)).astype(np.float32)

    def fzd(xl, wl):
        return fused.matmul_reduce_scatter(xl, wl, "model")

    def base(xl, wl):
        return fused.matmul_reduce_scatter_baseline(xl, wl, "model")

    in_specs = (P(None, "model"), P("model", None))
    a = np.asarray(smap(fzd, mesh_dm, in_specs, P(None, "model"))(
        jnp.asarray(x), jnp.asarray(w)))
    b = np.asarray(smap(base, mesh_dm, in_specs, P(None, "model"))(
        jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(a, x @ w, rtol=1e-4, atol=1e-4)


def test_collective_matmul_differentiable(mesh_dm, rng):
    """The fused matmul must be trainable (grads flow through ppermute)."""
    m_loc, k, n_loc = 4, 8, 4
    x = jnp.asarray(rng.standard_normal((4 * m_loc, k)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((k, 4 * n_loc)).astype(np.float32))

    def loss(w):
        def f(xl, wl):
            y = fused.allgather_matmul(xl, wl, "model")
            return jnp.sum(y ** 2).reshape(1)
        part = jax.shard_map(f, mesh=mesh_dm,
                             in_specs=(P("model", None), P(None, "model")),
                             out_specs=P("model"), check_vma=False)
        return part(x, w).sum()

    g = jax.grad(loss)(w)
    # oracle: d/dw sum((xw)^2) = 2 x^T (x w); shard_map sums partials over
    # the 4 model ranks (each computes the full loss over its column shard)
    want = 2 * x.T @ (x @ w)
    np.testing.assert_allclose(np.asarray(g), np.asarray(want) * 4.0 / 4.0,
                               rtol=1e-3, atol=1e-3)
