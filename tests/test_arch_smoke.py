"""Per-architecture smoke tests: reduced configs, one forward + one decode
step on CPU, asserting shapes and finiteness.  (Deliverable f.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import Model

ARCHS = configs.names()


def _tokens(rng, cfg, b=2, t=16):
    return jnp.asarray(rng.integers(0, cfg.vocab, (b, t)), jnp.int32)


def _context(rng, model, b):
    spec = model.context_inputs(b)
    if spec is None:
        return None
    return jnp.asarray(rng.standard_normal(spec.shape), spec.dtype)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch, rng):
    cfg = configs.get_smoke(arch)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    toks = _tokens(rng, cfg)
    ctx = _context(rng, model, 2)
    hidden, aux = jax.jit(
        lambda p, t, c: model.forward(p, t, context=c))(params, toks, ctx)
    assert hidden.shape == (2, 16, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(hidden, np.float32)))
    lg = model.logits(params, hidden)
    assert lg.shape == (2, 16, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(lg)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step_loss_decreases(arch, rng):
    """One SGD step on repeated data must reduce next-token loss."""
    cfg = configs.get_smoke(arch)
    model = Model(cfg)
    params = model.init(jax.random.key(1))
    toks = _tokens(rng, cfg, b=2, t=8)
    ctx = _context(rng, model, 2)

    def loss_fn(p):
        h, aux = model.forward(p, toks[:, :-1], context=ctx)
        lg = model.logits(p, h)
        tgt = toks[:, 1:]
        ll = jax.nn.log_softmax(lg, axis=-1)
        nll = -jnp.take_along_axis(ll, tgt[..., None], axis=-1).mean()
        return nll + aux

    g = jax.jit(jax.grad(loss_fn))(params)
    l0 = float(jax.jit(loss_fn)(params))
    params2 = jax.tree.map(
        lambda p, gg: (p.astype(jnp.float32) - 0.5 * gg.astype(jnp.float32))
        .astype(p.dtype), params, g)
    l1 = float(jax.jit(loss_fn)(params2))
    assert np.isfinite(l0) and np.isfinite(l1)
    assert l1 < l0, f"{arch}: loss did not decrease ({l0} -> {l1})"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_matches_forward(arch, rng):
    """Prefill+decode must agree with the teacher-forced forward pass.

    Params are cast to f32 so the check is about *semantics* (cache
    handling, masking, state carries) rather than bf16 rounding noise
    between batched and sequential execution orders."""
    cfg = configs.get_smoke(arch)
    model = Model(cfg)
    params = jax.tree.map(
        lambda p: p.astype(jnp.float32) if p.dtype == jnp.bfloat16 else p,
        model.init(jax.random.key(2)))
    b, t = 2, 8
    toks = _tokens(rng, cfg, b=b, t=t)
    ctx = _context(rng, model, b)
    if ctx is not None:
        ctx = ctx.astype(jnp.float32)

    # teacher-forced logits at the last position
    h, _ = model.forward(params, toks, context=ctx)
    lg_fwd = np.asarray(model.logits(params, h))[:, -1, :]

    cache = jax.tree.map(
        lambda c: c.astype(jnp.float32) if c.dtype == jnp.bfloat16 else c,
        model.init_cache(b, 32))
    lg_pre, cache = jax.jit(
        lambda p, tk, c, cx: model.prefill(p, tk, c, context=cx)
    )(params, toks, cache, ctx)
    np.testing.assert_allclose(np.asarray(lg_pre), lg_fwd, rtol=2e-2,
                               atol=2e-2)

    # one more decode step == forward over t+1 tokens
    nxt = jnp.asarray(rng.integers(0, cfg.vocab, (b,)), jnp.int32)
    lg_dec, _ = jax.jit(
        lambda p, tok, c, cx: model.decode_step(p, tok, c, t, context=cx)
    )(params, nxt, cache, ctx)
    toks2 = jnp.concatenate([toks, nxt[:, None]], axis=1)
    h2, _ = model.forward(params, toks2, context=ctx)
    lg_fwd2 = np.asarray(model.logits(params, h2))[:, -1, :]
    np.testing.assert_allclose(np.asarray(lg_dec), lg_fwd2, rtol=2e-2,
                               atol=2e-2)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_analytic_vs_actual(arch):
    """config.param_count() must track the real init within 10%."""
    cfg = configs.get_smoke(arch)
    model = Model(cfg)
    shapes = model.param_shapes()
    actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
    est = cfg.param_count()
    assert abs(est - actual) / actual < 0.35, (est, actual)


def test_full_configs_param_counts():
    """Full configs match their published parameter classes."""
    expect = {
        "nemotron-4-15b": (15e9, 0.25),
        "granite-8b": (8e9, 0.25),
        "qwen3-8b": (8e9, 0.30),
        "granite-3-8b": (8e9, 0.30),
        "qwen2-moe-a2.7b": (14.3e9, 0.30),   # total (not active) params
        "deepseek-v2-236b": (236e9, 0.25),
        "recurrentgemma-9b": (9e9, 0.35),
        "rwkv6-1.6b": (1.6e9, 0.35),
        "whisper-small": (0.24e9, 0.45),
        "llama-3.2-vision-11b": (10.6e9, 0.30),
    }
    for arch, (target, tol) in expect.items():
        n = configs.get(arch).param_count()
        assert abs(n - target) / target < tol, (arch, n, target)


def test_moe_active_params_smaller_than_total():
    cfg = configs.get("deepseek-v2-236b")
    assert cfg.active_param_count() < 0.2 * cfg.param_count()
