"""Ring/log-step schedule correctness vs numpy oracles (8 devices)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import ring
from repro.core.types import ADD, MAX, MIN, Monoid

N = 8


def smap(fn, mesh, in_specs, out_specs):
    return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False))


# ---------------------------------------------------------------------------
# reduce-scatter
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("monoid", [ADD, MAX, MIN])
def test_ring_reduce_scatter_matches_oracle(mesh8, rng, monoid):
    # global x: [N, N*chunk] -> per-rank rows; RS over flattened rows
    chunk = 16
    x = rng.standard_normal((N, N * chunk)).astype(np.float32)

    def f(xl):  # xl: [1, N*chunk]
        return ring.ring_reduce_scatter(xl[0], "data", monoid)[None]

    out = smap(f, mesh8, P("data", None), P("data", None))(jnp.asarray(x))
    out = np.asarray(out)  # [N, chunk]

    red = {"add": np.sum, "max": np.max, "min": np.min}[monoid.name](x, axis=0)
    for i in range(N):
        np.testing.assert_allclose(out[i], red[i * chunk:(i + 1) * chunk],
                                   rtol=1e-5, atol=1e-5)


def test_ring_reduce_scatter_matches_psum_scatter(mesh8, rng):
    chunk = 8
    x = rng.standard_normal((N, N * chunk)).astype(np.float32)

    def ours(xl):
        return ring.ring_reduce_scatter(xl[0], "data", ADD)[None]

    def xla(xl):
        return jax.lax.psum_scatter(xl[0], "data", tiled=True)[None]

    a = smap(ours, mesh8, P("data", None), P("data", None))(jnp.asarray(x))
    b = smap(xla, mesh8, P("data", None), P("data", None))(jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# all-gather / all-reduce
# ---------------------------------------------------------------------------

def test_ring_all_gather(mesh8, rng):
    x = rng.standard_normal((N, 4, 3)).astype(np.float32)

    def f(xl):
        return ring.ring_all_gather(xl[0], "data")[None]

    out = np.asarray(smap(f, mesh8, P("data", None, None),
                          P("data", None, None))(jnp.asarray(x)))
    want = x.reshape(N * 4, 3)
    for i in range(N):
        np.testing.assert_allclose(out[i], want, rtol=1e-6, atol=1e-6)


def test_ring_all_gather_hop_map_applied_once(mesh8, rng):
    """The in-flight map must be applied exactly once per chunk."""
    x = rng.standard_normal((N, 4)).astype(np.float32)

    def f(xl):
        return ring.ring_all_gather(xl[0], "data",
                                    hop_map=lambda c: 2.0 * c + 1.0)[None]

    out = np.asarray(smap(f, mesh8, P("data", None),
                          P("data", None))(jnp.asarray(x)))
    want = (2.0 * x + 1.0).reshape(-1)
    for i in range(N):
        np.testing.assert_allclose(out[i], want, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("latency_optimal", [False, True])
@pytest.mark.parametrize("shape", [(33,), (8, 5), (128,)])
def test_ring_all_reduce(mesh8, rng, latency_optimal, shape):
    x = rng.standard_normal((N,) + shape).astype(np.float32)

    def f(xl):
        return ring.ring_all_reduce(xl[0], "data", ADD,
                                    latency_optimal=latency_optimal)[None]

    spec = P("data", *([None] * len(shape)))
    out = np.asarray(smap(f, mesh8, spec, spec)(jnp.asarray(x)))
    want = x.sum(axis=0)
    for i in range(N):
        np.testing.assert_allclose(out[i], want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# broadcast
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("root", [0, 3, 7])
@pytest.mark.parametrize("kind", ["ring", "tree"])
def test_broadcast(mesh8, rng, root, kind):
    x = rng.standard_normal((N, 6)).astype(np.float32)
    fn = ring.ring_broadcast if kind == "ring" else ring.tree_broadcast

    def f(xl):
        return fn(xl[0], "data", root)[None]

    out = np.asarray(smap(f, mesh8, P("data", None),
                          P("data", None))(jnp.asarray(x)))
    for i in range(N):
        np.testing.assert_allclose(out[i], x[root], rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# rank prefix scan (Type 3 carry)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("exclusive", [False, True])
def test_rank_prefix_scan_add(mesh8, rng, exclusive):
    x = rng.standard_normal((N, 5)).astype(np.float32)

    def f(xl):
        return ring.rank_prefix_scan(xl[0], "data", ADD,
                                     exclusive=exclusive)[None]

    out = np.asarray(smap(f, mesh8, P("data", None),
                          P("data", None))(jnp.asarray(x)))
    inc = np.cumsum(x, axis=0)
    want = np.concatenate([np.zeros((1, 5), np.float32), inc[:-1]]) \
        if exclusive else inc
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)


def test_rank_prefix_scan_noncommutative(mesh8):
    """Matrix-product scan: order across ranks must be respected."""
    rng = np.random.default_rng(1)
    x = (np.eye(3, dtype=np.float32)[None].repeat(N, 0)
         + 0.1 * rng.standard_normal((N, 3, 3)).astype(np.float32))
    matmul = Monoid("matmul", lambda a, b: a @ b,
                    lambda s: jnp.broadcast_to(jnp.eye(3, dtype=s.dtype),
                                               s.shape), commutative=False)

    def f(xl):
        return ring.rank_prefix_scan(xl[0], "data", matmul)[None]

    out = np.asarray(smap(f, mesh8, P("data", None, None),
                          P("data", None, None))(jnp.asarray(x)))
    acc = np.eye(3, dtype=np.float32)
    for i in range(N):
        # combine(shifted_from_lower_rank, local) => prefix in rank order
        acc = acc @ x[i]
        np.testing.assert_allclose(out[i], acc, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# all-to-all
# ---------------------------------------------------------------------------

def test_ring_all_to_all(mesh8, rng):
    chunk = 3
    x = rng.standard_normal((N, N * chunk, 2)).astype(np.float32)

    def f(xl):
        return ring.ring_all_to_all(xl[0], "data")[None]

    out = np.asarray(smap(f, mesh8, P("data", None, None),
                          P("data", None, None))(jnp.asarray(x)))
    xs = x.reshape(N, N, chunk, 2)
    want = np.swapaxes(xs, 0, 1)  # out[i][j] = xs[j][i]
    np.testing.assert_allclose(out, want.reshape(N, N * chunk, 2),
                               rtol=1e-6, atol=1e-6)


def test_axis_size_one_degenerates():
    mesh1 = jax.make_mesh((1,), ("data",),
                          axis_types=(jax.sharding.AxisType.Auto,))
    x = jnp.arange(8.0)

    def f(xl):
        a = ring.ring_all_reduce(xl, "data")
        b = ring.ring_all_gather(xl, "data")
        c = ring.rank_prefix_scan(xl, "data")
        return a + b + c

    out = jax.shard_map(f, mesh=mesh1, in_specs=P("data"),
                        out_specs=P("data"), check_vma=False)(x)
    np.testing.assert_allclose(np.asarray(out), 3 * np.arange(8.0))
