"""Elastic runtime: Membership, deadline-bounded sync, recompile reuse.

The policy half of bounded staleness — who is alive (deadline verdicts
over measured per-rank spans), the retry/backoff loop around the masked
collective, and what a membership change means for the compiled
artifacts: shape-preserving dropout reuses the cached program + arenas
outright (the mask is a runtime input), a shape-moving delta compiles
fresh.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_engine
from repro.core.api import RecompileReport
from repro.elastic import (ElasticSyncError, Membership, TopologyDelta,
                           deadline_verdicts, sync_with_deadline)
from repro.obs import metrics as obs


# ---------------------------------------------------------------------------
# Membership
# ---------------------------------------------------------------------------

def test_membership_views_and_updates():
    m = Membership.all_alive(4)
    assert m.n_ranks == 4 and m.n_alive == 4 and m.dead == ()
    m2 = m.drop(1, 3)
    assert m2.alive == (True, False, True, False)
    assert m2.dead == (1, 3) and m2.n_alive == 2
    assert m2.restore(3).alive == (True, False, True, True)
    assert m.drop(0).merge(m.drop(2)).dead == (0, 2)
    np.testing.assert_array_equal(
        np.asarray(m2.mask_array()), np.array([1, 0, 1, 0], np.float32))


def test_membership_validation():
    with pytest.raises(ValueError):
        Membership(())
    with pytest.raises(ValueError):
        Membership.all_alive(4).drop(4)
    with pytest.raises(ValueError):
        Membership.all_alive(4).merge(Membership.all_alive(3))


def test_membership_from_rank_times():
    m = Membership.from_rank_times([0.1, 0.9, 0.2, 0.3], deadline_s=0.5)
    assert m.alive == (True, False, True, True)
    # intersected verdicts: an already-dead rank stays dead even when its
    # (stale) reported time looks fine
    merged = deadline_verdicts([0.1, 0.1, 0.1, 0.1], 0.5,
                               membership=m)
    assert merged.alive == m.alive


def test_delta_classifies_and_counts():
    with obs.recording() as rec:
        d = Membership.all_alive(4).delta(Membership.all_alive(4).drop(2))
    assert d.dropped == (2,) and d.restored == ()
    assert d.shape_preserving and bool(d)
    assert rec.counter("elastic.rank_dropped") == 1

    d2 = Membership.all_alive(4).drop(1).delta(Membership.all_alive(4))
    assert d2.restored == (1,) and d2.shape_preserving

    moving = Membership.all_alive(4).delta(Membership.all_alive(4),
                                           axis_sizes={"data": 2})
    assert not moving.shape_preserving
    assert not bool(TopologyDelta())


# ---------------------------------------------------------------------------
# sync_with_deadline
# ---------------------------------------------------------------------------

def _runner(times_per_attempt):
    """Fake sync: returns canned per-rank times, result = attempt no."""
    calls = []

    def run(membership, deadline):
        calls.append((membership, deadline))
        times = times_per_attempt[min(len(calls) - 1,
                                      len(times_per_attempt) - 1)]
        return len(calls), times
    return run, calls


def test_sync_clean_first_attempt():
    run, calls = _runner([[0.1, 0.2, 0.1, 0.2]])
    out = sync_with_deadline(run, Membership.all_alive(4), deadline_s=0.5)
    assert out.result == 1 and out.attempts == 1 and out.masked == ()
    assert out.membership.n_alive == 4
    assert calls[0][1] == 0.5


def test_sync_masks_late_rank_and_backs_off():
    # rank 1 misses attempt 1; attempt 2 (without it) is clean
    run, calls = _runner([[0.1, 9.0, 0.1, 0.1], [0.1, 9.0, 0.1, 0.1]])
    with obs.recording() as rec:
        out = sync_with_deadline(run, Membership.all_alive(4),
                                 deadline_s=0.5, backoff=2.0)
    assert out.attempts == 2 and out.masked == (1,)
    assert out.membership.dead == (1,)
    assert out.deadline_s == 1.0                 # backed off once
    assert calls[1][0].dead == (1,)              # retried w/o the late rank
    assert rec.counter("elastic.deadline_miss") == 1
    assert rec.counter("elastic.retry") == 1


def test_sync_result_never_mixes_attempts():
    """The returned result is the clean attempt's, whole — late ranks'
    partial data from earlier attempts is discarded with the attempt."""
    run, _ = _runner([[9.0, 0.1], [0.1, 0.1]])
    out = sync_with_deadline(run, Membership.all_alive(2), deadline_s=1.0)
    assert out.result == 2                       # attempt 2's result


def test_sync_exhausts_retries():
    run, calls = _runner([[9.0, 0.1, 0.1]])      # rank 0 always late...
    with pytest.raises(ElasticSyncError):
        # ...then 1, then 2: every retry loses another "rank 0" of the
        # shrunk view until retries run out
        sync_with_deadline(_runner([[9.0, 9.0, 9.0]])[0],
                           Membership.all_alive(3),
                           deadline_s=0.5, max_retries=2)


def test_sync_all_dead_raises():
    run, _ = _runner([[9.0, 9.0]])
    with pytest.raises(ElasticSyncError, match="deadline"):
        sync_with_deadline(run, Membership.all_alive(2), deadline_s=0.5)
    with pytest.raises(ElasticSyncError, match="no alive"):
        sync_with_deadline(run, Membership.all_alive(2).drop(0, 1),
                           deadline_s=0.5)


# ---------------------------------------------------------------------------
# engine.recompile
# ---------------------------------------------------------------------------

def _grads():
    return {"w": jnp.zeros((96,), jnp.float32),
            "b": jnp.zeros((7,), jnp.float32)}


def test_recompile_shape_preserving_reuses_everything():
    eng = make_engine("acis_hierarchical", inner_axis="data",
                      outer_axis="pod")
    sizes = {"data": 4, "pod": 2}
    gl = _grads()
    eng.init_arenas(gl, axis_sizes=sizes, masked=True)   # warm caches
    mem = Membership.all_alive(8)
    for r in (1, 5):
        rep = eng.recompile(mem.delta(mem.drop(r)), gl, axis_sizes=sizes)
        assert isinstance(rep, RecompileReport)
        assert rep.programs_reused == 1 and rep.programs_rebuilt == 0
        assert rep.arenas_rebuilt == 0
        assert rep.shape_preserving and not rep.full_recompile
        assert rep.reuse_frac == 1.0


def test_recompile_shape_moving_compiles_fresh():
    eng = make_engine("acis", inner_axis="data")
    gl = _grads()
    eng.init_arenas(gl, axis_sizes={"data": 4}, masked=True)
    rep = eng.recompile(TopologyDelta(axis_sizes=(("data", 8),)), gl,
                        axis_sizes={"data": 4})
    assert not rep.shape_preserving
    assert rep.full_recompile and rep.programs_rebuilt == 1


def test_recompile_emits_counters():
    eng = make_engine("acis", inner_axis="data")
    gl = _grads()
    eng.init_arenas(gl, axis_sizes={"data": 8}, masked=True)
    mem = Membership.all_alive(8)
    with obs.recording() as rec:
        eng.recompile(mem.delta(mem.drop(3)), gl, axis_sizes={"data": 8})
    assert rec.counter("recompile.programs_reused") == 1
    assert rec.counter("recompile.programs_rebuilt") == 0
    events = [f for n, f in rec.events if n == "engine.recompile"]
    assert events and events[0]["shape_preserving"]
