"""Traced-DAG frontend + pass pipeline: tracer round-trip, golden fusion
patterns, multi-output correctness vs the XLA baseline, chain-shim
backward-compat, and the SelectSchedule latency/bandwidth crossover."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import core as acis
from repro.core import MAX, SwitchProgram, compile_rank_local
from repro.core.compiler import CompileContext, Legalize
from repro.core.program import OpKind
from repro.core.wire import BF16

N = 8


# ---------------------------------------------------------------------------
# tracer round-trip
# ---------------------------------------------------------------------------

def test_trace_roundtrip_labels_and_arity():
    def fem(x):
        return acis.all_gather(acis.scan(acis.all_gather(x)))

    prog = acis.trace(fem)
    assert prog.num_inputs == 1
    assert prog.labels() == ["allgather", "scan:add", "allgather"]
    assert len(prog.outputs) == 1

    compiled = compile_rank_local(prog, "data")
    assert compiled.stage_kinds() == ["scan+allgather"]


def test_trace_multi_input_multi_output():
    def two(a, b):
        return acis.reduce(a), acis.all_to_all(b)

    prog = acis.trace(two)
    assert prog.num_inputs == 2
    assert len(prog.outputs) == 2


def test_trace_rejects_untraced_and_foreign_values():
    with pytest.raises(RuntimeError):
        acis.reduce(jnp.ones(3))

    other = acis.trace(lambda x: acis.reduce(x))
    del other

    def bad(x):
        leak = acis.trace(lambda y: acis.reduce(y))
        return x  # returning the input is fine; mixing values is not

    acis.trace(bad)  # nested trace is isolated — must not blow up

    with pytest.raises(TypeError):
        acis.trace(lambda x: 42)  # non-Value output


def test_stale_value_from_finished_trace_is_rejected():
    stash = {}
    acis.trace(lambda x: stash.setdefault("v", acis.reduce(x)))
    # unary op on the stale handle inside a fresh trace must not silently
    # append to the dead graph
    with pytest.raises(ValueError):
        acis.trace(lambda y: acis.all_gather(stash["v"]))
    # ... and outside any trace it's the plain outside-trace error
    with pytest.raises(RuntimeError):
        acis.reduce(stash["v"])


def test_trace_ignores_defaulted_params():
    def fn(x, exclusive=False):
        return acis.scan(x, exclusive=exclusive)

    prog = acis.trace(fn)
    assert prog.num_inputs == 1
    assert prog.labels() == ["scan:add"]
    assert prog.nodes[0].op.exclusive is False


def test_trace_dce_drops_unused_branch():
    def fn(x):
        acis.all_gather(x)          # dead: result unused
        return acis.reduce(x)

    compiled = compile_rank_local(acis.trace(fn), "data")
    assert compiled.stage_kinds() == ["allreduce"]


# ---------------------------------------------------------------------------
# golden stage lists per fusion pattern
# ---------------------------------------------------------------------------

def test_golden_ag_scan_ag():
    prog = acis.trace(lambda x: acis.all_gather(acis.scan(acis.all_gather(x))))
    assert compile_rank_local(prog, "data").stage_kinds() == ["scan+allgather"]


def test_golden_ar_plus_a2a():
    prog = acis.trace(lambda h, k: (acis.reduce(h), acis.all_to_all(k)))
    assert compile_rank_local(prog, "data").stage_kinds() == \
        ["allreduce+alltoall"]


def test_golden_ar_a2a_not_fused_when_dependent():
    # a2a(reduce(x)) is a dependency chain, not the independent pair
    prog = acis.trace(lambda x: acis.all_to_all(acis.reduce(x)))
    assert compile_rank_local(prog, "data").stage_kinds() == \
        ["allreduce", "alltoall"]


def test_golden_ar_a2a_not_fused_for_non_add():
    # the shared-schedule kernel only implements the add combine
    prog = acis.trace(lambda h, k: (acis.reduce(h, MAX), acis.all_to_all(k)))
    kinds = compile_rank_local(prog, "data").stage_kinds()
    assert "allreduce+alltoall" not in kinds


def test_golden_map_into_rs():
    prog = acis.trace(lambda x: acis.reduce_scatter(acis.map(jnp.square, x)))
    assert compile_rank_local(prog, "data").stage_kinds() == \
        ["map+reduce_scatter"]


def test_golden_rs_ag():
    prog = acis.trace(lambda x: acis.all_gather(acis.reduce_scatter(x)))
    assert compile_rank_local(prog, "data").stage_kinds() == ["allreduce"]


def test_golden_wire_sinks_through_pipeline():
    prog = acis.trace(
        lambda x: acis.all_gather(acis.reduce_scatter(acis.wire(BF16, x))))
    compiled = compile_rank_local(prog, "data")
    assert compiled.stage_kinds() == ["allreduce"]
    # the codec must have been attached to the fused all-reduce node
    rs_op = compiled.source.nodes[0].op
    assert rs_op.codec is BF16


def test_wire_codec_travels_through_map():
    """Old chain semantics: a pending codec survives an intervening MAP
    and lands on the reduce it ultimately feeds."""
    prog = acis.trace(
        lambda x: acis.reduce(acis.map(jnp.square, acis.wire(BF16, x))))
    compiled = compile_rank_local(prog, "data")
    assert compiled.stage_kinds() == ["map+allreduce"]
    red_op = next(nd.op for nd in compiled.source.nodes
                  if nd.op.kind == OpKind.REDUCE)
    assert red_op.codec is BF16

    # same through the chain shim spelling
    chain = SwitchProgram([acis.Wire(BF16), acis.Map(jnp.square, "sq"),
                           acis.Reduce()])
    c2 = compile_rank_local(chain, "data")
    assert c2.stage_kinds() == ["map+allreduce"]
    red_op2 = next(nd.op for nd in c2.source.nodes
                   if nd.op.kind == OpKind.REDUCE)
    assert red_op2.codec is BF16


def test_fusion_not_applied_when_intermediate_is_output():
    # the AG result escapes as a program output → Fig. 5 fusion is illegal
    def fn(x):
        g = acis.all_gather(x)
        return g, acis.all_gather(acis.scan(g))

    kinds = compile_rank_local(acis.trace(fn), "data").stage_kinds()
    assert "scan+allgather" not in kinds


def test_legalize_wire_dropped_on_non_codec_consumer():
    prog = acis.trace(lambda x: acis.all_gather(acis.wire(BF16, x)))
    dag = Legalize().run(prog, CompileContext(axis_name="data"))
    assert [nd.op.kind for nd in dag.nodes] == [OpKind.ALLGATHER]


# ---------------------------------------------------------------------------
# chain-shim backward compat
# ---------------------------------------------------------------------------

def test_chain_shim_matches_traced_stage_list():
    chain = SwitchProgram([acis.Map(jnp.square, "sq"), acis.Reduce(),
                           acis.AllToAll()])
    traced = acis.trace(
        lambda h, k: (acis.reduce(acis.map(jnp.square, h)),
                      acis.all_to_all(k)))
    assert compile_rank_local(chain, "data").stage_kinds() == \
        compile_rank_local(traced, "data").stage_kinds() == \
        ["map+allreduce", "alltoall"]


def test_chain_shim_tuple_hack_becomes_two_input_dag():
    dag = SwitchProgram([acis.Reduce(), acis.AllToAll()]).to_dag()
    assert dag.num_inputs == 2 and len(dag.outputs) == 2
    assert compile_rank_local(dag, "data").stage_kinds() == \
        ["allreduce+alltoall"]


# ---------------------------------------------------------------------------
# end-to-end: multi-output program vs XLA baseline on the 8-device mesh
# ---------------------------------------------------------------------------

def test_two_input_program_matches_xla_baseline(mesh8, rng):
    eng = acis.make_engine("acis")

    def histshuf(hist, keys):
        h = acis.reduce(acis.map(jnp.square, hist, name="sq"))
        k = acis.all_to_all(keys)
        return h, k

    fn = eng.compile(histshuf, mesh8, (P("data", None), P("data")),
                     (P("data", None), P("data")))
    assert fn.stages == ["map+allreduce", "alltoall"]

    hist = rng.standard_normal((N, 16)).astype(np.float32)
    keys = rng.standard_normal((N * 8,)).astype(np.float32)
    h, k = fn(jnp.asarray(hist), jnp.asarray(keys))

    # XLA baseline: endpoint compute + built-in collectives
    def base(hl, kl):
        hb = jax.lax.psum(jnp.square(hl), "data")
        ks = kl.reshape(N, -1)
        kb = jax.lax.all_to_all(ks, "data", 0, 0, tiled=False).reshape(-1)
        return hb, kb

    bfn = jax.jit(jax.shard_map(base, mesh=mesh8,
                                in_specs=(P("data", None), P("data")),
                                out_specs=(P("data", None), P("data")),
                                check_vma=False))
    hb, kb = bfn(jnp.asarray(hist), jnp.asarray(keys))
    np.testing.assert_allclose(np.asarray(h), np.asarray(hb),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(k), np.asarray(kb),
                               rtol=1e-5, atol=1e-5)


def test_fused_pair_program_matches_xla_baseline(mesh8, rng):
    eng = acis.make_engine("acis")
    fn = eng.compile(
        acis.trace(lambda h, k: (acis.reduce(h), acis.all_to_all(k)),
                   name="nas_is"),
        mesh8, (P("data", None), P("data")), (P("data", None), P("data")))
    assert fn.stages == ["allreduce+alltoall"]

    hist = rng.standard_normal((N, 32)).astype(np.float32)
    keys = rng.standard_normal((N * 16,)).astype(np.float32)
    h, k = fn(jnp.asarray(hist), jnp.asarray(keys))
    np.testing.assert_allclose(np.asarray(h)[0], hist.sum(0),
                               rtol=1e-4, atol=1e-4)

    def base(kl):
        ks = kl.reshape(N, -1)
        return jax.lax.all_to_all(ks, "data", 0, 0, tiled=False).reshape(-1)

    bfn = jax.jit(jax.shard_map(base, mesh=mesh8, in_specs=P("data"),
                                out_specs=P("data"), check_vma=False))
    np.testing.assert_allclose(np.asarray(k),
                               np.asarray(bfn(jnp.asarray(keys))), rtol=1e-5)


# ---------------------------------------------------------------------------
# SelectSchedule: the latency_optimal_below crossover
# ---------------------------------------------------------------------------

def _compiled_ar(eng, nelems):
    return eng.compile(acis.trace(lambda x: acis.reduce(x)), axis_size=N,
                       in_avals=(jax.ShapeDtypeStruct((nelems,),
                                                      jnp.float32),))


def test_select_schedule_flips_at_threshold():
    eng = acis.make_engine("acis", latency_optimal_below=16384)
    small = _compiled_ar(eng, 64)          # 256 B  << 16 KiB
    big = _compiled_ar(eng, 1 << 20)       # 4 MiB  >> 16 KiB
    assert small.stage_schedules() == ["latency"]
    assert big.stage_schedules() == ["bandwidth"]
    # right at the boundary: payload == threshold is NOT below it
    edge = _compiled_ar(eng, 16384 // 4)
    assert edge.stage_schedules() == ["bandwidth"]


def test_select_schedule_threshold_is_config_driven():
    tiny_thresh = acis.make_engine("acis", latency_optimal_below=8)
    huge_thresh = acis.make_engine("acis", latency_optimal_below=1 << 30)
    assert _compiled_ar(tiny_thresh, 1024).stage_schedules() == ["bandwidth"]
    assert _compiled_ar(huge_thresh, 1024).stage_schedules() == ["latency"]


def test_select_schedule_honest_about_encoded_codecs():
    """A structured codec only exists as the RS∘AG walk — the annotation
    must say bandwidth even when the threshold would pick latency."""
    from repro.core.wire import int8_codec

    eng = acis.make_engine("acis", latency_optimal_below=1 << 30)
    c = eng.compile(
        acis.trace(lambda x: acis.reduce(acis.wire(int8_codec(), x))),
        axis_size=N,
        in_avals=(jax.ShapeDtypeStruct((64,), jnp.float32),))
    assert c.stage_schedules() == ["bandwidth"]
    assert "encoded-domain" in c.stages[0].desc


def test_dag_rejects_zero_input_map():
    from repro.core import DagNode, DagProgram, Map

    with pytest.raises(ValueError, match="at least one input"):
        DagProgram(1, (DagNode(Map(lambda: None), (), 1),), (1,))


def test_select_schedule_default_without_shapes():
    eng = acis.make_engine("acis")
    c = eng.compile(acis.trace(lambda x: acis.reduce(x)))
    assert c.stage_schedules() == ["bandwidth"]


def test_both_schedules_compute_identical_allreduce(mesh8, rng):
    x = rng.standard_normal((N, 24)).astype(np.float32)
    want = np.broadcast_to(x.sum(0), (N, 24))
    for thresh in (1, 1 << 30):            # forces bandwidth / latency
        eng = acis.make_engine("acis", latency_optimal_below=thresh)
        fn = eng.compile(
            acis.trace(lambda v: acis.reduce(v)), mesh8,
            P("data", None), P("data", None),
            in_avals=(jax.ShapeDtypeStruct((1, 24), jnp.float32),))
        out = np.asarray(fn(jnp.asarray(x)))
        np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)


def test_claimed_reduce_is_not_grouped_twice(mesh8, rng):
    """An a2a that pairs with a later reduce must not leave that reduce
    free to be re-grouped by the map-fusion pattern."""
    def fn(keys, hist):
        return acis.all_to_all(keys), acis.reduce(acis.map(jnp.square, hist))

    compiled = compile_rank_local(acis.trace(fn), "data")
    # every value consumed by a stage must be produced exactly once
    produced = [v for s in compiled.stages for v in s.out_vids]
    assert len(produced) == len(set(produced))

    keys = rng.standard_normal((N * 8,)).astype(np.float32)
    hist = rng.standard_normal((N, 16)).astype(np.float32)
    f = jax.jit(jax.shard_map(
        lambda k, h: compiled(k, h), mesh=mesh8,
        in_specs=(P("data"), P("data", None)),
        out_specs=(P("data"), P("data", None)), check_vma=False))
    k, h = f(jnp.asarray(keys), jnp.asarray(hist))
    np.testing.assert_allclose(np.asarray(h)[0], np.square(hist).sum(0),
                               rtol=1e-4, atol=1e-4)


def test_wire_codec_on_plain_reduce_scatter(mesh8, rng):
    """A cast codec on a standalone RS runs the hops in the wire dtype; a
    structured codec is rejected loudly instead of silently dropped."""
    from repro.core.wire import int8_codec

    prog = acis.trace(lambda x: acis.reduce_scatter(acis.wire(BF16, x)))
    compiled = compile_rank_local(prog, "data")
    assert compiled.stage_kinds() == ["reduce_scatter"]

    x = rng.standard_normal((N, N * 4)).astype(np.float32)
    f = jax.jit(jax.shard_map(
        lambda v: compiled(v[0])[0][None], mesh=mesh8,
        in_specs=P("data", None), out_specs=P("data", None),
        check_vma=False))
    out = np.asarray(f(jnp.asarray(x)))
    want = x.sum(0).reshape(N, 4)
    for i in range(N):
        np.testing.assert_allclose(out[i], want[i], rtol=2e-2, atol=2e-2)

    bad = acis.trace(lambda x: acis.reduce_scatter(acis.wire(int8_codec(), x)))
    cbad = compile_rank_local(bad, "data")
    with pytest.raises(ValueError, match="standalone reduce-scatter"):
        jax.jit(jax.shard_map(
            lambda v: cbad(v[0])[0][None], mesh=mesh8,
            in_specs=P("data", None), out_specs=P("data", None),
            check_vma=False))(jnp.asarray(x))


def test_two_parallel_reduce_a2a_chains_do_not_deadlock(mesh8, rng):
    """Cross-branch AR+A2A pairing must not create a cycle between two
    fused groups (each consuming the other's output)."""
    def fn(x, y):
        return acis.all_to_all(acis.reduce(x)), acis.all_to_all(acis.reduce(y))

    compiled = compile_rank_local(acis.trace(fn), "data")
    kinds = compiled.stage_kinds()
    assert len(kinds) == 4 or "allreduce+alltoall" in kinds

    x = rng.standard_normal((N * 8,)).astype(np.float32)
    y = rng.standard_normal((N * 8,)).astype(np.float32)
    f = jax.jit(jax.shard_map(
        lambda a, b: compiled(a, b), mesh=mesh8,
        in_specs=(P("data"), P("data")), out_specs=(P("data"), P("data")),
        check_vma=False))
    xa, ya = f(jnp.asarray(x), jnp.asarray(y))

    def base(a, b):
        def a2a(v):
            return jax.lax.all_to_all(v.reshape(N, -1), "data", 0, 0,
                                      tiled=False).reshape(-1)
        return a2a(jax.lax.psum(a, "data")), a2a(jax.lax.psum(b, "data"))

    bf = jax.jit(jax.shard_map(base, mesh=mesh8,
                               in_specs=(P("data"), P("data")),
                               out_specs=(P("data"), P("data")),
                               check_vma=False))
    bx, by = bf(jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(np.asarray(xa), np.asarray(bx), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(ya), np.asarray(by), rtol=1e-4)


def test_wire_coded_reduce_is_not_pair_fused():
    """A reduce carrying a sunk wire codec must stay unfused — the shared
    AR+A2A schedule cannot apply codecs, and dropping one silently would
    change numerics between fused and unfused compiles."""
    def fn(h, k):
        return acis.reduce(acis.wire(BF16, h)), acis.all_to_all(k)

    compiled = compile_rank_local(acis.trace(fn), "data")
    assert sorted(compiled.stage_kinds()) == ["allreduce", "alltoall"]
    red_op = next(nd.op for nd in compiled.source.nodes
                  if nd.op.kind == OpKind.REDUCE)
    assert red_op.codec is BF16


def test_select_schedule_counts_wire_bytes():
    """The crossover must be judged on what travels, not the decoded size:
    a bf16 codec halves the payload and can flip the ring choice."""
    eng = acis.make_engine("acis", latency_optimal_below=16384)
    nelems = 5000                        # f32: 20000B > 16K; bf16 wire: 10000B
    avals = (jax.ShapeDtypeStruct((nelems,), jnp.float32),)
    plain = eng.compile(acis.trace(lambda x: acis.reduce(x)),
                        axis_size=N, in_avals=avals)
    coded = eng.compile(acis.trace(lambda x: acis.reduce(acis.wire(BF16, x))),
                        axis_size=N, in_avals=avals)
    assert plain.stage_schedules() == ["bandwidth"]
    assert coded.stage_schedules() == ["latency"]


# ---------------------------------------------------------------------------
# engine surface
# ---------------------------------------------------------------------------

def test_engine_init_state_empty_for_uncompressed():
    grads = {"w": jnp.ones((4,))}
    assert acis.make_engine("acis").init_state(grads) is None
    assert acis.make_engine("xla").init_state(grads) is None
    res = acis.make_engine("acis_compressed").init_state(grads)
    assert res is not None and jax.tree.leaves(res)[0].shape == (4,)
