"""CGRA device model + mapper: placements are real, fallbacks are loud.

The acceptance bar: every fused stage of every acis backend carries a
Placement or an explicit host fallback, and netmodel has no silent
constant-rate path left for MAP compute.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core as acis
from repro.core import make_engine, netmodel
from repro.cgra.device import (CGRADevice, HostFallback, PAPER_CGRA,
                               Placement, placement_rate, route_through)
from repro.cgra.mapper import PlaceCGRA

AV = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# device model
# ---------------------------------------------------------------------------

def test_paper_device_matches_table_ii_rate():
    """The device's line rate is the old accel_clock*accel_width constant;
    the NetParams compat properties read through to it."""
    assert PAPER_CGRA.line_rate == 250e6 * 64
    p = netmodel.PAPER
    assert p.accel_clock == PAPER_CGRA.clock_hz
    assert p.accel_width == PAPER_CGRA.lane_bytes
    assert netmodel.accel_rate(p) == PAPER_CGRA.line_rate


def test_placement_rate_drops_with_ii():
    pl = Placement(device=PAPER_CGRA, n_ops=20, n_route=0, depth=3, ii=2)
    assert pl.bytes_per_s == PAPER_CGRA.line_rate / 2
    assert placement_rate(pl) == pl.bytes_per_s
    assert placement_rate(None) == PAPER_CGRA.line_rate


def test_host_fallback_has_no_in_switch_rate():
    with pytest.raises(ValueError, match="host-fallback"):
        placement_rate(HostFallback("because"))


def test_route_through_is_line_rate_zero_pes():
    pl = route_through(PAPER_CGRA, 3)
    assert pl.fits and pl.pes_used == 0
    assert pl.bytes_per_s == PAPER_CGRA.line_rate


# ---------------------------------------------------------------------------
# mapping compiled stages
# ---------------------------------------------------------------------------

def _compile(fn, backend="acis", **kw):
    eng = make_engine(backend, outer_axis=kw.pop("outer_axis", None))
    return eng.compile(fn, **kw)


def test_map_allreduce_stage_gets_placed():
    c = _compile(lambda x: acis.reduce(acis.map(jnp.square, x, name="sq")),
                 in_avals=(AV((64,), jnp.float32),), axis_size=8)
    (st,) = c.stages
    assert st.kind == "map+allreduce"
    pl = st.placement
    assert isinstance(pl, Placement) and pl.fits
    assert pl.n_ops >= 2                  # square + add combine
    assert 0 < pl.pes_used <= PAPER_CGRA.n_pes
    assert pl.bytes_per_s > 0


def test_movement_stage_is_route_through():
    c = _compile(lambda x: acis.all_gather(x))
    (st,) = c.stages
    assert st.placement.fits and st.placement.pes_used == 0


def test_hier_pad_bookkeeping_maps_route_through():
    c = _compile(lambda x: acis.reduce(x, axis="auto"),
                 backend="acis_hierarchical", outer_axis="pod",
                 in_avals=(AV((128,), jnp.float32),),
                 axis_size={"data": 4, "pod": 2})
    kinds = c.stage_kinds()
    assert kinds == ["map", "reduce_scatter", "allreduce", "allgather",
                     "map"]
    pads = [s.placement for s in c.stages if s.kind == "map"]
    assert all(p.fits and p.n_ops == 0 for p in pads)


def test_unsupported_map_body_falls_back_to_host():
    """A matmul body needs a MAC array the switch CGRA does not have —
    explicit host fallback, with the primitive named."""
    c = _compile(
        lambda a, b: acis.reduce(acis.map(lambda x, y: x @ y, a, b,
                                          name="mm")),
        in_avals=(AV((8, 8), jnp.float32), AV((8, 8), jnp.float32)),
        axis_size=8)
    st = next(s for s in c.stages if s.kind == "map")
    assert isinstance(st.placement, HostFallback)
    assert "dot_general" in st.placement.reason


def test_collective_inside_map_body_falls_back():
    """A MAP body that itself communicates is endpoint code, not a
    dataflow graph one switch can run."""
    from repro.core import lookaside

    c = _compile(
        lambda x: acis.map(
            lambda v: lookaside.distributed_prefix_sum(v, "data"), x,
            name="dps"),
        in_avals=(AV((16,), jnp.float32),), axis_size=8)
    (st,) = c.stages
    assert isinstance(st.placement, HostFallback)


def test_topk_compressor_falls_back():
    c = _compile(lambda x: acis.ef_reduce(x, axis="data",
                                          compressor="topk")[0],
                 backend="acis_compressed",
                 in_avals=(AV((256,), jnp.float32),), axis_size=8)
    (st,) = c.stages
    assert isinstance(st.placement, HostFallback)
    assert "top_k" in st.placement.reason


def test_int8_ef_compressor_fits():
    c = _compile(lambda x: acis.ef_reduce(x, axis="data")[0],
                 backend="acis_compressed",
                 in_avals=(AV((1024,), jnp.float32),), axis_size=8)
    (st,) = c.stages
    assert isinstance(st.placement, Placement) and st.placement.fits


def test_encoded_codec_combine_costs_throughput():
    """The int8 encoded-domain combine maps, but at II > 1 — compression
    in the switch is not free, and the placement says by how much."""
    c = _compile(lambda x: acis.reduce(x, axis="auto"),
                 backend="acis_hierarchical_compressed", outer_axis="pod",
                 in_avals=(AV((1 << 14,), jnp.float32),),
                 axis_size={"data": 4, "pod": 2})
    outer = next(s for s in c.stages if s.kind == "allreduce")
    pl = outer.placement
    assert pl.fits and pl.ii > 1
    assert pl.bytes_per_s < PAPER_CGRA.line_rate


def test_tiny_device_forces_fallback():
    """Shrinking the grid below the body's op count flips the outcome —
    the feasibility check is real, not cosmetic."""
    from repro.core.compiler import (Emit, FuseHops, Legalize,
                                     LowerTopology, SelectSchedule,
                                     compile_rank_local)

    tiny = CGRADevice(rows=1, cols=1, ops_per_pe=1)
    pipeline = (Legalize(), LowerTopology(), FuseHops(), SelectSchedule(),
                PlaceCGRA(device=tiny), Emit())
    c = compile_rank_local(
        lambda x: acis.reduce(acis.map(
            lambda v: jnp.tanh(v) * 3 + 1, x, name="body")),
        "data", axis_size=8, in_avals=(AV((64,), jnp.float32),),
        pipeline=pipeline)
    (st,) = c.stages
    assert isinstance(st.placement, HostFallback)
    assert "ALU slots" in st.placement.reason


@pytest.mark.parametrize("backend", ["acis", "acis_compressed",
                                     "acis_hierarchical",
                                     "acis_hierarchical_compressed"])
def test_every_stage_carries_placement_or_fallback(backend):
    """Acceptance: no stage leaves the pipeline unmapped on any backend."""
    hier = "hierarchical" in backend
    eng = make_engine(backend, inner_axis="data",
                      outer_axis="pod" if hier else None)

    def sync(g, r):
        t = acis.map(lambda g_, r_: g_ + r_, g, r, name="ef_target")
        if "compressed" in backend:
            red, dlv = acis.ef_reduce(t, axis="auto")
            out = acis.map(lambda y: y / 8.0, red, name="mean")
            res = acis.map(lambda t_, d: t_ - d, t, dlv, name="ef_residual")
            return out, res
        red = acis.reduce(t, axis="auto")
        return acis.map(lambda y: y / 8.0, red, name="mean"), t

    sizes = {"data": 4, "pod": 2} if hier else {"data": 8}
    c = eng.compile(sync, in_avals=(AV((64,), jnp.float32),) * 2,
                    axis_size=sizes)
    assert len(c.stages) >= 1
    for st in c.stages:
        assert st.placement is not None, f"unmapped stage {st.kind}"
        assert isinstance(st.placement, (Placement, HostFallback))
        assert st.ir is not None


# ---------------------------------------------------------------------------
# netmodel: placement-derived rates, no silent MAP constants
# ---------------------------------------------------------------------------

def test_stage_time_requires_placement_for_map_stages():
    with pytest.raises(ValueError, match="no constant-rate default"):
        netmodel.stage_time("map", 8, 1 << 20, netmodel.PAPER)
    with pytest.raises(ValueError, match="no constant-rate default"):
        netmodel.stage_time("map+allreduce", 8, 1 << 20, netmodel.PAPER)


def test_stage_time_fallback_charges_pcie_and_mpi():
    m = 1 << 20
    fits = Placement(device=PAPER_CGRA, n_ops=2, n_route=0, depth=2, ii=1)
    t_fit = netmodel.stage_time("map+allreduce", 8, m, netmodel.PAPER,
                                placement=fits)
    t_fb = netmodel.stage_time("map+allreduce", 8, m, netmodel.PAPER,
                               placement=HostFallback("too big"))
    assert t_fb > t_fit
    # the detour includes the PCIe + MPI + host-stream terms exactly once
    p = netmodel.PAPER
    assert t_fb >= netmodel.host_fallback_time(m, p)
    assert netmodel.host_fallback_time(m, p) == pytest.approx(
        2 * p.pcie + p.mpi_overhead + m / p.host_bw)


def test_ring_time_slows_with_ii():
    m = 1 << 22
    fast = Placement(device=PAPER_CGRA, n_ops=2, n_route=0, depth=2, ii=1)
    slow = Placement(device=PAPER_CGRA, n_ops=40, n_route=0, depth=4, ii=4)
    t1 = netmodel.ring_allreduce_time(8, m, placement=fast)
    t4 = netmodel.ring_allreduce_time(8, m, placement=slow)
    assert t4 > t1


def test_placecgra_annotates_desc_with_model_time():
    c = _compile(lambda x: acis.reduce(x),
                 in_avals=(AV((1 << 16,), jnp.float32),), axis_size=8)
    (st,) = c.stages
    assert "model" in st.desc and "us" in st.desc


def test_explain_lists_placements():
    c = _compile(lambda x: acis.reduce(acis.map(jnp.square, x, name="sq")),
                 in_avals=(AV((64,), jnp.float32),), axis_size=8)
    txt = c.explain()
    assert "map+allreduce" in txt and "PEs" in txt
    assert "placement" in txt


def test_engine_config_cgra_device_override():
    """The device is an engine config knob: a starved grid turns the same
    program into a host-fallback without touching the pipeline."""
    tiny = CGRADevice(rows=1, cols=1, ops_per_pe=1)
    eng = make_engine("acis", cgra_device=tiny)
    c = eng.compile(
        lambda x: acis.reduce(acis.map(
            lambda v: jnp.tanh(v) * 3 + 1, x, name="body")),
        in_avals=(AV((64,), jnp.float32),), axis_size=8)
    (st,) = c.stages
    assert isinstance(st.placement, HostFallback)


def test_loop_body_falls_back_not_placed():
    """lax.scan / while_loop bodies have a sequential controller the
    spatial pipeline lacks — they must fall back, not place at line
    rate (regression: sub-jaxpr eqns were treated as call wrappers)."""
    import jax.lax as lax

    def loopy(v):
        def body(c, x):
            return c + x, c
        c, _ = lax.scan(body, jnp.zeros_like(v[0]), v)
        return v + c

    c = _compile(lambda x: acis.map(loopy, x, name="loopy"),
                 in_avals=(AV((8, 4), jnp.float32),), axis_size=8)
    (st,) = c.stages
    assert isinstance(st.placement, HostFallback)
    assert "scan" in st.placement.reason or "while" in st.placement.reason


def test_device_supported_set_is_honored():
    """A device without transcendentals must reject a tanh body — the
    ALU vocabulary is per-device, not a global constant."""
    from repro.cgra.device import ALU_PRIMS

    no_tanh = CGRADevice(supported=ALU_PRIMS - {"tanh"})
    eng = make_engine("acis", cgra_device=no_tanh)
    c = eng.compile(
        lambda x: acis.reduce(acis.map(jnp.tanh, x, name="act")),
        in_avals=(AV((64,), jnp.float32),), axis_size=8)
    (st,) = c.stages
    assert isinstance(st.placement, HostFallback)
    assert "tanh" in st.placement.reason

    eng2 = make_engine("acis")        # full vocabulary: places fine
    c2 = eng2.compile(
        lambda x: acis.reduce(acis.map(jnp.tanh, x, name="act")),
        in_avals=(AV((64,), jnp.float32),), axis_size=8)
    assert c2.stages[0].placement.fits
