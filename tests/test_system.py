"""End-to-end behaviour tests for the whole system.

One test drives the full stack the way examples/train_e2e.py does — data
pipeline → model → explicit ACiS compressed gradient sync → optimizer →
checkpoint → resume — and asserts the observable outcomes (loss descends,
resume is bit-exact).  The others cover the serve path and the compiled
SwitchProgram used inside a larger jitted computation.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import make_engine
from repro.data.pipeline import BigramStream, DataConfig
from repro.models import Model
from repro.train import optimizer as opt_lib
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.step import build_train_step_acis, init_state


def test_system_train_acis_compressed_end_to_end(tmp_path, mesh_dm):
    """Train the smoke model for 30 steps through the ACiS compressed
    transport with mid-run checkpointing; loss must descend and a resumed
    run must continue bit-exactly."""
    cfg = configs.get_smoke("acis-100m")
    model = Model(cfg)
    optimizer = opt_lib.adamw(1e-2)
    engine = make_engine("acis_compressed", inner_axis="data")
    step = build_train_step_acis(model, optimizer, mesh_dm, engine)
    state = init_state(model, optimizer, jax.random.key(0), engine)
    stream = BigramStream(DataConfig(vocab=cfg.vocab, seq_len=32,
                                     global_batch=8, seed=11))
    d = str(tmp_path / "ck")
    loop = TrainLoop(step, stream, LoopConfig(
        total_steps=30, ckpt_every=10, ckpt_dir=d, log_every=5))
    with jax.set_mesh(mesh_dm):
        final = loop.run(state)

    nlls = [m["nll"] for m in loop.metrics_log]
    assert nlls[-1] < nlls[0] - 0.2, nlls
    # EF residual is part of the checkpointed state (look-aside memory)
    assert final.ef_residual is not None

    # resume from the step-30 checkpoint: state must match exactly
    state2 = init_state(model, optimizer, jax.random.key(0), engine)
    loop2 = TrainLoop(step, stream, LoopConfig(
        total_steps=30, ckpt_every=10, ckpt_dir=d, log_every=5))
    with jax.set_mesh(mesh_dm):
        state2 = loop2.maybe_restore(state2)
    for a, b in zip(jax.tree.leaves(final.params),
                    jax.tree.leaves(state2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_system_serve_end_to_end(rng):
    """Submit → continuous-batch decode → all requests complete."""
    from repro.serve.engine import Request, ServeEngine
    cfg = configs.get_smoke("acis-100m")
    model = Model(cfg)
    params = model.init(jax.random.key(3))
    eng = ServeEngine(model, params, slots=2, max_seq=48)
    for i in range(3):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab,
                                               3 + i).astype(np.int32),
                           max_new_tokens=5))
    done = eng.run_to_completion()
    assert len(done) == 3
    assert all(len(c.tokens) == 5 for c in done)
    # (per-request oracle equivalence is covered in tests/test_serving.py)


def test_system_fused_program_in_training_context(mesh8, rng):
    """A compiled SwitchProgram used as a building block inside a jitted
    computation (the 'CGRA binary carried as an argument' pattern)."""
    from jax.sharding import PartitionSpec as P
    from repro.core import AllGather, Scan, SwitchProgram, compile_rank_local

    prog = SwitchProgram([AllGather(), Scan(), AllGather()], "fem")
    compiled = compile_rank_local(prog, "data")

    def training_like(xl):
        local = xl * 2.0
        (fem,) = compiled(local)        # fused in-network prefix sum
        return fem.sum() + local.sum()

    f = jax.jit(jax.shard_map(lambda x: training_like(x).reshape(1),
                              mesh=mesh8, in_specs=P("data"),
                              out_specs=P("data"), check_vma=False))
    x = jnp.asarray(rng.standard_normal(16).astype(np.float32))
    out = np.asarray(f(x))
    want = np.cumsum(2 * np.asarray(x)).sum() + \
        (2 * np.asarray(x)).reshape(8, 2).sum(1)
    np.testing.assert_allclose(out, want, rtol=1e-4)
