"""Per-kernel shape/dtype sweeps vs the ref.py pure-jnp oracles.

Every Pallas kernel executes in interpret mode (CPU container; TPU is the
deploy target) and must match its oracle to float tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

SHAPES = [(7,), (128,), (1000,), (64, 64), (3, 129), (2048,), (17, 128)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# fused_combine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("op", ["add", "max", "min"])
def test_fused_combine_sweep(rng, shape, dtype, op):
    x = jnp.asarray(rng.standard_normal(shape), dtype)
    y = jnp.asarray(rng.standard_normal(shape), dtype)
    got = getattr(ops, f"combine_{op}")(x, y)
    want = getattr(ref, f"combine_{op}")(x, y)
    assert got.shape == want.shape and got.dtype == want.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("alpha", [1.0, -0.5, 0.125])
def test_fused_combine_mac(rng, alpha):
    x = jnp.asarray(rng.standard_normal((513,)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((513,)), jnp.float32)
    got = ops.combine_mac(x, y, alpha)
    want = ref.combine_mac(x, y, alpha)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


# ---------------------------------------------------------------------------
# quant_combine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nblocks", [1, 3, 64, 65, 200])
def test_quant_combine_sweep(rng, nblocks):
    qa = jnp.asarray(rng.integers(-127, 128, (nblocks, 256)), jnp.int8)
    qb = jnp.asarray(rng.integers(-127, 128, (nblocks, 256)), jnp.int8)
    sa = jnp.asarray(rng.random(nblocks) + 0.01, jnp.float32)
    sb = jnp.asarray(rng.random(nblocks) + 0.01, jnp.float32)
    gq, gs = ops.quant_combine(qa, sa, qb, sb)
    wq, ws = ref.quant_combine(qa, sa, qb, sb)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(ws), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(gq), np.asarray(wq))


# ---------------------------------------------------------------------------
# topk_accumulate
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("size,k", [(100, 5), (2048, 32), (5000, 100),
                                    (65536, 512)])
def test_topk_accumulate_sweep(rng, size, k):
    dense = jnp.asarray(rng.standard_normal(size), jnp.float32)
    idx = jnp.asarray(rng.integers(0, size, k), jnp.int32)
    vals = jnp.asarray(rng.standard_normal(k), jnp.float32)
    got = ops.topk_accumulate(dense, idx, vals)
    want = ref.topk_accumulate(dense, idx, vals)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_topk_accumulate_duplicate_indices(rng):
    dense = jnp.zeros((512,), jnp.float32)
    idx = jnp.asarray([3, 3, 3, 100, 100], jnp.int32)
    vals = jnp.ones((5,), jnp.float32)
    got = np.asarray(ops.topk_accumulate(dense, idx, vals))
    assert got[3] == 3.0 and got[100] == 2.0


# ---------------------------------------------------------------------------
# prefix_sum / rglru_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(10,), (256,), (1000,), (300, 8),
                                   (1024, 16)])
def test_prefix_sum_sweep(rng, shape):
    x = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    got = ops.prefix_sum(x)
    want = ref.prefix_sum(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("t,d", [(8, 4), (64, 16), (300, 8), (1024, 4)])
def test_rglru_scan_sweep(rng, t, d):
    a = jnp.asarray(rng.random((t, d)) * 0.98, jnp.float32)  # decay in (0,1)
    b = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    got = ops.rglru_scan(a, b)
    want = ref.rglru_scan(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 200), st.integers(1, 8), st.integers(0, 2 ** 31 - 1))
def test_rglru_scan_property(t, d, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.random((t, d)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    got = ops.rglru_scan(a, b)
    want = ref.rglru_scan(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# rwkv6_recurrence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("h,t,k,v", [(1, 16, 8, 8), (2, 64, 16, 16),
                                     (4, 100, 32, 32), (2, 130, 64, 64)])
def test_rwkv6_recurrence_sweep(rng, h, t, k, v):
    r = jnp.asarray(rng.standard_normal((h, t, k)) * 0.5, jnp.float32)
    kk = jnp.asarray(rng.standard_normal((h, t, k)) * 0.5, jnp.float32)
    vv = jnp.asarray(rng.standard_normal((h, t, v)) * 0.5, jnp.float32)
    w = jnp.asarray(0.5 + 0.5 * rng.random((h, t, k)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((h, k)) * 0.1, jnp.float32)
    go, gs = ops.rwkv6_recurrence(r, kk, vv, w, u)
    for head in range(h):
        wo, ws = ref.rwkv6_recurrence(r[head], kk[head], vv[head], w[head],
                                      u[head])
        np.testing.assert_allclose(np.asarray(go[head]), np.asarray(wo),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(gs[head]), np.asarray(ws),
                                   rtol=1e-4, atol=1e-4)


def test_rwkv6_state_carries_across_chunks(rng):
    """t > CHUNK_T forces the VMEM carry path."""
    h, t, k, v = 1, 200, 8, 8
    r = jnp.asarray(rng.standard_normal((h, t, k)) * 0.3, jnp.float32)
    kk = jnp.asarray(rng.standard_normal((h, t, k)) * 0.3, jnp.float32)
    vv = jnp.asarray(rng.standard_normal((h, t, v)) * 0.3, jnp.float32)
    w = jnp.asarray(0.9 * jnp.ones((h, t, k)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((h, k)) * 0.1, jnp.float32)
    go, _ = ops.rwkv6_recurrence(r, kk, vv, w, u)
    wo, _ = ref.rwkv6_recurrence(r[0], kk[0], vv[0], w[0], u[0])
    np.testing.assert_allclose(np.asarray(go[0]), np.asarray(wo),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# switchops registry binding
# ---------------------------------------------------------------------------

def test_switchops_kernel_binding(rng):
    from repro.core import switchops
    switchops.load_kernels()
    x = jnp.asarray(rng.standard_normal(300), jnp.float32)
    y = jnp.asarray(rng.standard_normal(300), jnp.float32)
    got_k = switchops.get("add")(x, y, use_kernel=True)
    got_r = switchops.get("add")(x, y, use_kernel=False)
    np.testing.assert_allclose(np.asarray(got_k), np.asarray(got_r),
                               rtol=1e-6)
