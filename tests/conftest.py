"""Test session setup.

Multi-device collective tests need >1 device, so the *test process* runs
with 8 host platform devices.  This is process-local: benchmarks and the
dry-run launcher configure their own device counts (1 and 512 respectively)
at the top of their own entry points — nothing here leaks into them.
"""

import os

# Must run before jax initializes its backends.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import repro  # noqa: E402,F401  (installs the jax forward-compat shims)

jax.config.update("jax_default_matmul_precision", "highest")

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def devices():
    ds = jax.devices()
    assert len(ds) == 8, f"expected 8 host devices, got {len(ds)}"
    return ds


@pytest.fixture(scope="session")
def mesh8(devices):
    """1-D 8-way mesh for collective tests."""
    return jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))


@pytest.fixture(scope="session")
def mesh24(devices):
    """2x4 mesh: 'pod' x 'data' for hierarchical schedules."""
    return jax.make_mesh((2, 4), ("pod", "data"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


@pytest.fixture(scope="session")
def mesh_dm(devices):
    """2x4 mesh: 'data' x 'model' for train-step tests."""
    return jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
