"""wkv_chunked (MXU path) vs wkv (scan oracle) equivalence."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.rwkv6 import wkv, wkv_chunked


def _inputs(rng, b, t, h, k, v, w_lo=0.3):
    r = jnp.asarray(rng.standard_normal((b, t, h, k)) * 0.5, jnp.float32)
    kk = jnp.asarray(rng.standard_normal((b, t, h, k)) * 0.5, jnp.float32)
    vv = jnp.asarray(rng.standard_normal((b, t, h, v)) * 0.5, jnp.float32)
    w = jnp.asarray(w_lo + (1 - w_lo) * rng.random((b, t, h, k)),
                    jnp.float32)
    u = jnp.asarray(rng.standard_normal((h, k)) * 0.1, jnp.float32)
    return r, kk, vv, w, u


@pytest.mark.parametrize("t,chunk", [(7, 32), (32, 32), (100, 32),
                                     (256, 64), (33, 16)])
def test_chunked_matches_scan(rng, t, chunk):
    r, k, v, w, u = _inputs(rng, 2, t, 2, 8, 8)
    o_ref, s_ref = wkv(r, k, v, w, u)
    o_got, s_got = wkv_chunked(r, k, v, w, u, chunk=chunk)
    np.testing.assert_allclose(np.asarray(o_got), np.asarray(o_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_got), np.asarray(s_ref),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 80), st.integers(0, 2 ** 31 - 1),
       st.floats(0.2, 0.95))
def test_chunked_matches_scan_property(t, seed, w_lo):
    rng = np.random.default_rng(seed)
    r, k, v, w, u = _inputs(rng, 1, t, 1, 4, 4, w_lo=w_lo)
    o_ref, _ = wkv(r, k, v, w, u)
    o_got, _ = wkv_chunked(r, k, v, w, u, chunk=16)
    np.testing.assert_allclose(np.asarray(o_got), np.asarray(o_ref),
                               rtol=5e-4, atol=5e-4)
