"""Overlapped wave dispatch + persistent donation-aware bucket arenas.

Covers the PR-5 runtime half of execution planning: arena reuse
(repeated gradient_sync hits the cached arena — no realloc; donation
verified via buffer identity where the backend exposes it), numerics +
EF residuals allclose vs the per-leaf and XLA pmean paths on all four
acis backends with arenas threaded, the Coalesce elementwise epilogue
hoist, overlapped-vs-serial dispatch equivalence, the wave dispatch
groups, the calibrated-overlap fit, and the fused AR+A2A analytic term
aligned with the dataplane simulator.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import core as acis
from repro.core import make_engine, netmodel, tracing
from repro.core.executor import _issue_order, build_plan

AV = jax.ShapeDtypeStruct
N = 8

BACKENDS = ["acis", "acis_compressed", "acis_hierarchical",
            "acis_hierarchical_compressed"]


@pytest.fixture(scope="module")
def mesh22():
    return jax.make_mesh((2, 2), ("pod", "data"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def smap(fn, mesh, in_specs, out_specs, donate=()):
    return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False),
                   donate_argnums=donate)


def _sync_program(engine, sizes, axis_sizes, n_total, *,
                  shared_mean=True):
    def _mean(y):
        return y / n_total

    def sync(*gs):
        outs = []
        for g in gs:
            r = tracing.reduce(g, axis="auto")
            outs.append(tracing.map(_mean, r, name="mean",
                                    elementwise=shared_mean))
        return tuple(outs)

    prog = tracing.trace(sync, num_inputs=len(sizes))
    return engine.compile(
        prog, in_avals=tuple(AV((s,), jnp.float32) for s in sizes),
        axis_size=axis_sizes)


# ---------------------------------------------------------------------------
# arena allocation, caching, and in-place donation
# ---------------------------------------------------------------------------

def test_arena_avals_match_bucket_layout():
    eng = make_engine("acis", bucket_bytes=8192)     # 2 x 1KiB leaves each
    c = _sync_program(eng, [1024] * 8, {"data": N}, N)
    avals = c.arena_avals
    assert len(avals) == 4
    assert all(a.shape == (2048,) and a.dtype == jnp.float32
               for a in avals)
    arenas = c.make_arenas()
    assert len(arenas) == 4
    assert all(x.shape == a.shape for x, a in zip(arenas, avals))


def test_pack_transient_halves_with_arena():
    eng = make_engine("acis")
    c = _sync_program(eng, [4096] * 16, {"data": N}, N)
    no_arena = c.pack_transient_bytes(arenas=False)
    with_arena = c.pack_transient_bytes(arenas=True)
    assert with_arena > 0
    assert no_arena == 2 * with_arena


def test_pack_transient_tracks_pipelined_waves():
    """The plan pipeliner staggers the same-axis bucket chains (their
    rings would serialize anyway), so at most ONE pack is in flight per
    wave and the peak transient is a single bucket, not the sum of all
    four — the per-wave accounting must follow the waves, not the stage
    count."""
    eng = make_engine("acis", bucket_bytes=8192)   # 4 buckets of 2 leaves
    c = _sync_program(eng, [1024] * 8, {"data": N}, N)
    n_packs = sum(1 for s in c.stages if s.arena_aval is not None)
    assert n_packs == 4
    packs_per_wave = [
        sum(1 for i in w if c.stages[i].arena_aval is not None)
        for w in c.plan.waves]
    assert max(packs_per_wave) == 1, packs_per_wave
    one_bucket = 2048 * 4                           # bytes
    assert c.pack_transient_bytes(arenas=True) == one_bucket
    assert c.pack_transient_bytes(arenas=False) == 2 * one_bucket


def test_init_arenas_cached_no_realloc():
    """Repeated init_arenas for one pytree structure returns the SAME
    buffers (no realloc), and the sync cache holds one program."""
    eng = make_engine("acis")
    grads = {"a": jnp.zeros((512,)), "b": jnp.zeros((64, 3))}
    a1 = eng.init_arenas(grads, axis_sizes={"data": N})
    a2 = eng.init_arenas(grads, axis_sizes={"data": N})
    assert a1 is a2
    assert len(eng._sync_cache) == 1
    assert len(eng._arena_cache) == 1


def test_arena_write_is_donated_in_place(mesh8, rng):
    """Buffer identity where observable: donating the arenas through the
    jit boundary aliases the returned written arenas onto the same
    device buffers (CPU exposes unsafe_buffer_pointer)."""
    eng = make_engine("acis")
    sizes = [256, 1024, 64]
    c = _sync_program(eng, sizes, {"data": N}, N)
    arenas = c.make_arenas()
    assert arenas is not None
    n = len(sizes)

    def body(ar, *ls):
        outs, new_ar = c(*[l[0] for l in ls], arenas=tuple(ar))
        return tuple(o[None] for o in outs) + tuple(new_ar)

    spec = P("data", None)
    fn = smap(body, mesh8, (P(),) + (spec,) * n,
              (spec,) * n + (P(),) * len(arenas), donate=(0,))
    arenas = jax.device_put(arenas, NamedSharding(mesh8, P()))
    ptrs = [[s.data.unsafe_buffer_pointer() for s in a.addressable_shards]
            for a in arenas]
    ls = [jnp.asarray(rng.standard_normal((N, s)).astype(np.float32))
          for s in sizes]
    res = fn(arenas, *ls)
    new_arenas = res[n:]
    new_ptrs = [[s.data.unsafe_buffer_pointer()
                 for s in a.addressable_shards] for a in new_arenas]
    assert ptrs == new_ptrs, "donated arenas were not aliased in place"
    # and the inputs were actually consumed (donation took effect)
    with pytest.raises(RuntimeError):
        np.asarray(arenas[0])


def test_arena_count_mismatch_raises():
    eng = make_engine("acis")
    c = _sync_program(eng, [256, 1024], {"data": N}, N)
    with pytest.raises(TypeError, match="bucket arenas"):
        c(jnp.zeros((256,)), jnp.zeros((1024,)), arenas=())


def test_arena_aval_mismatch_raises():
    """A wrong-dtype (or wrong-shape) arena must be rejected loudly —
    the pack would otherwise silently astype-cast every gradient into
    the arena's dtype."""
    eng = make_engine("acis")
    c = _sync_program(eng, [256, 1024], {"data": N}, N)
    (aval,) = c.arena_avals
    with pytest.raises(TypeError, match="arena 0 must be"):
        c(jnp.zeros((256,)), jnp.zeros((1024,)),
          arenas=(jnp.zeros(aval.shape, jnp.bfloat16),))
    with pytest.raises(TypeError, match="arena 0 must be"):
        c(jnp.zeros((256,)), jnp.zeros((1024,)),
          arenas=(jnp.zeros((aval.shape[0] + 4,), aval.dtype),))


def test_init_arenas_reallocates_after_donation():
    """A donating caller consumes the cached buffers; the next
    init_arenas must hand out fresh arenas, not deleted arrays."""
    eng = make_engine("acis")
    grads = {"a": jnp.zeros((512,)), "b": jnp.zeros((2048,))}
    a1 = eng.init_arenas(grads, axis_sizes={"data": N})
    for a in a1:
        a.delete()                      # what donation does to the input
    a2 = eng.init_arenas(grads, axis_sizes={"data": N})
    assert a2 is not a1
    assert not any(a.is_deleted() for a in a2)


# ---------------------------------------------------------------------------
# numerics with arenas threaded — all four acis backends, EF state incl.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_arena_sync_matches_per_leaf_and_xla(mesh22, rng, backend):
    n_leaves = 6
    shapes = [(4, 3 + 5 * i) for i in range(n_leaves)]
    grads = {f"l{i}": rng.standard_normal((4,) + s).astype(np.float32)
             for i, s in enumerate(shapes)}
    keys = sorted(grads)
    axis_sizes = {"data": 2, "pod": 2}

    def run(eng, arenas=None):
        def f(ar, *ls):
            g = {k: l[0, 0] for k, l in zip(keys, ls)}
            state = eng.init_state(g)
            if ar is not None:
                synced, new_state, new_ar = eng.gradient_sync(
                    g, state, arenas=tuple(ar))
            else:
                synced, new_state = eng.gradient_sync(g, state)
                new_ar = ()
            outs = [synced[k][None, None] for k in keys]
            if state is not None:
                outs += [new_state[k][None, None] for k in keys]
            return tuple(outs) + tuple(new_ar)

        spec = P("pod", "data", None, None)
        n_out = n_leaves * (2 if eng.needs_residual() else 1)
        n_ar = len(arenas) if arenas is not None else 0
        fn = smap(f, mesh22, (P(),) + (spec,) * n_leaves,
                  (spec,) * n_out + (P(),) * n_ar,
                  donate=(0,) if arenas is not None else ())
        args = [jnp.asarray(grads[k].reshape((2, 2) + s))
                for k, s in zip(keys, shapes)]
        if arenas is not None:
            arenas = jax.device_put(tuple(arenas),
                                    NamedSharding(mesh22, P()))
        outs = fn(arenas, *args)
        return [np.asarray(o)[0, 0] for o in outs[:n_out]]

    eng = make_engine(backend, inner_axis="data", outer_axis="pod")
    # rank-local leaf avals (what each rank holds inside the region)
    arenas = eng.init_arenas(
        {k: jnp.zeros(s, jnp.float32) for k, s in zip(keys, shapes)},
        axis_sizes=axis_sizes)
    with_arena = run(eng, arenas if arenas is not None else None)
    plain = run(make_engine(backend, inner_axis="data", outer_axis="pod"))
    per_leaf = run(make_engine(backend, inner_axis="data",
                               outer_axis="pod", bucket_bytes=0))
    xla = run(make_engine("xla", inner_axis="data", outer_axis="pod"))

    atol = 5e-2 if "compressed" in backend else 1e-4
    for i, k in enumerate(keys):
        want = grads[k].mean(0)
        np.testing.assert_allclose(with_arena[i], want, atol=atol,
                                   err_msg=f"{k} vs mean")
        np.testing.assert_allclose(with_arena[i], plain[i], atol=atol)
        np.testing.assert_allclose(with_arena[i], per_leaf[i], atol=atol)
        np.testing.assert_allclose(with_arena[i], xla[i], atol=atol)
    if "compressed" in backend:
        for i in range(n_leaves):
            rb = with_arena[n_leaves + i]
            rp = per_leaf[n_leaves + i]
            assert np.all(np.isfinite(rb))
            np.testing.assert_allclose(rb, rp, atol=atol)


def test_repeated_sync_hits_cached_program_and_arena(mesh8, rng):
    """Two steps through the jitted sync: one compiled program, one
    arena set, and the second step's donated arenas alias the first
    step's outputs."""
    eng = make_engine("acis")
    sizes = [512, 64, 2048]
    grads = {f"l{i}": jnp.zeros((s,), jnp.float32)
             for i, s in enumerate(sizes)}
    arenas = eng.init_arenas(grads, axis_sizes={"data": N})
    assert arenas is not None
    n = len(sizes)

    def f(ar, *ls):
        g = {f"l{i}": l[0] for i, l in enumerate(ls)}
        synced, _, new_ar = eng.gradient_sync(g, None, arenas=tuple(ar))
        return tuple(synced[f"l{i}"][None] for i in range(n)) \
            + tuple(new_ar)

    spec = P("data", None)
    fn = smap(f, mesh8, (P(),) + (spec,) * n,
              (spec,) * n + (P(),) * len(arenas), donate=(0,))
    ls = [jnp.asarray(rng.standard_normal((N, s)).astype(np.float32))
          for s in sizes]
    arenas = jax.device_put(arenas, NamedSharding(mesh8, P()))
    res1 = fn(arenas, *ls)
    n_programs = len(eng._sync_cache)
    n_arenas = len(eng._arena_cache)
    res2 = fn(tuple(res1[n:]), *ls)
    assert len(eng._sync_cache) == n_programs == 1
    assert len(eng._arena_cache) == n_arenas == 1
    for o1, o2, (_, l) in zip(res1[:n], res2[:n],
                              sorted((k, v) for k, v in
                                     zip(range(n), ls))):
        np.testing.assert_allclose(np.asarray(o1)[0],
                                   np.asarray(l).mean(0), atol=1e-4)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   atol=1e-6)


# ---------------------------------------------------------------------------
# Coalesce elementwise epilogue hoist
# ---------------------------------------------------------------------------

def test_elementwise_epilogue_hoisted_onto_bucket():
    eng = make_engine("acis")
    sizes = [64, 96, 32, 128]
    hoisted = _sync_program(eng, sizes, {"data": N}, N, shared_mean=True)
    plain = _sync_program(eng, sizes, {"data": N}, N, shared_mean=False)
    # per-leaf means collapse into one bucket epilogue
    assert len(hoisted.stages) == len(plain.stages) - len(sizes) + 1
    epis = [s for s in hoisted.stages
            if s.ir.nodes[0].op.name == "bucket_epilogue"]
    assert len(epis) == 1
    assert not any(s.ir.nodes[0].op.name == "bucket_epilogue"
                   for s in plain.stages)


def test_epilogue_not_hoisted_for_distinct_fns():
    """A fresh fn object per leaf breaks the shared-fn requirement —
    the hoist must not fire (it cannot prove the maps identical)."""
    eng = make_engine("acis")

    def sync(*gs):
        return tuple(
            tracing.map(lambda y: y / N, tracing.reduce(g, axis="auto"),
                        name="mean", elementwise=True)
            for g in gs)

    prog = tracing.trace(sync, num_inputs=3)
    c = eng.compile(prog, in_avals=(AV((64,), jnp.float32),) * 3,
                    axis_size={"data": N})
    assert not any(s.ir.nodes[0].op.name == "bucket_epilogue"
                   for s in c.stages)


def test_hoisted_sync_numerics_match(mesh8, rng):
    eng = make_engine("acis")
    sizes = [64, 96, 32, 128]
    c = _sync_program(eng, sizes, {"data": N}, N, shared_mean=True)
    n = len(sizes)

    def f(*ls):
        outs = c(*[l[0] for l in ls])
        return tuple(o[None] for o in outs)

    spec = P("data", None)
    fn = smap(f, mesh8, (spec,) * n, (spec,) * n)
    ls = [rng.standard_normal((N, s)).astype(np.float32) for s in sizes]
    outs = fn(*[jnp.asarray(x) for x in ls])
    for x, o in zip(ls, outs):
        np.testing.assert_allclose(np.asarray(o)[0], x.mean(0), atol=1e-4)


# ---------------------------------------------------------------------------
# overlapped wave dispatch
# ---------------------------------------------------------------------------

def test_wave_groups_partition_and_serialize_same_axis():
    # bucket_bytes=0: keep the two same-axis reduces as separate stages
    # (Coalesce would otherwise merge them into one bucket AR)
    eng = make_engine("acis", outer_axis="pod", bucket_bytes=0)

    def prog(x, y, z):
        return (acis.reduce(x, axis="data"), acis.reduce(y, axis="data"),
                acis.reduce(z, axis="pod"))

    c = eng.compile(tracing.trace(prog),
                    in_avals=(AV((1 << 14,), jnp.float32),) * 3,
                    axis_size={"data": 4, "pod": 2})
    plan = c.plan
    assert plan.n_waves == 1
    groups = dict(plan.wave_groups[0])
    assert len(groups["data"]) == 2       # same axis: one serialized group
    assert len(groups["pod"]) == 1
    plan.validate()
    # round-robin issue order interleaves the axis groups
    order = _issue_order(plan.wave_groups[0])
    assert sorted(order) == [0, 1, 2]
    axes = [plan.stages[i].axis for i in order]
    assert axes[0] != axes[1]


def test_overlapped_and_serial_dispatch_agree(mesh22, rng):
    sizes = [257, 64, 1024, 33]
    ls = [rng.standard_normal((4, s)).astype(np.float32) for s in sizes]

    def run(overlap):
        eng = make_engine("acis_hierarchical", inner_axis="data",
                          outer_axis="pod", overlap_dispatch=overlap)
        c = _sync_program(eng, sizes, {"data": 2, "pod": 2}, 4)
        assert c.overlap is overlap

        def f(*xs):
            outs = c(*[x[0, 0] for x in xs])
            return tuple(o[None, None] for o in outs)

        spec = P("pod", "data", None)
        fn = smap(f, mesh22, (spec,) * len(sizes), (spec,) * len(sizes))
        outs = fn(*[jnp.asarray(x.reshape((2, 2, s)))
                    for x, s in zip(ls, sizes)])
        return [np.asarray(o)[0, 0] for o in outs]

    over = run(True)
    serial = run(False)
    for x, o_over, o_serial in zip(ls, over, serial):
        np.testing.assert_allclose(o_over, x.mean(0), atol=1e-4)
        np.testing.assert_allclose(o_over, o_serial, atol=1e-6)


def test_build_plan_duck_types_without_axis():
    class FakeStage:
        def __init__(self, ins, outs):
            self.in_vids, self.out_vids = ins, outs

    plan = build_plan([FakeStage((0,), (1,)), FakeStage((0,), (2,))],
                      1, (1, 2))
    assert plan.waves == ((0, 1),)
    assert plan.wave_groups == ((("", (0,)), ("", (1,))),)


def test_plan_without_wave_groups_still_dispatches():
    """A hand-built plan that omits wave_groups (the field defaults to
    ()) must derive dispatch groups instead of silently running zero
    stages."""
    import dataclasses

    from repro.core.executor import ExecutionPlan, execute

    class FakeStage:
        axis = ""

        def __init__(self, ins, outs, fn):
            self.in_vids, self.out_vids, self._fn = ins, outs, fn
            self.arena_slot = None

        def run(self, args, ax):
            return (self._fn(*args),)

    stages = (FakeStage((0,), (1,), lambda x: x + 1),
              FakeStage((1,), (2,), lambda x: x * 2))
    bare = ExecutionPlan(stages, 1, (2,), ((), (0,)), ((0,), (1,)))
    assert bare.wave_groups == ()
    bare.validate()
    for overlapped in (True, False):
        (out,) = execute(bare, (jnp.asarray(3.0),), overlapped=overlapped)
        assert float(out) == 8.0
    # dataclasses.replace dropping the field behaves the same
    rebuilt = dataclasses.replace(bare)
    (out,) = execute(rebuilt, (jnp.asarray(3.0),))
    assert float(out) == 8.0


# ---------------------------------------------------------------------------
# calibrated overlap model + fused AR+A2A alignment
# ---------------------------------------------------------------------------

def test_fit_tier_overlap_recovers_known_fractions():
    """Fit against program_time itself evaluated at a chosen overlap —
    the least squares must recover it (the model is linear in 1-ov)."""
    eng = make_engine("acis", outer_axis="pod")
    truth = {"ici": 0.41, "dci": 0.17}
    samples = []
    # skew both ways so each tier is the non-critical (exposed) chain in
    # at least one sample — an unexposed tier cannot be fitted
    for mx, my in ((1 << 12, 1 << 14), (1 << 15, 1 << 15),
                   (1 << 19, 1 << 12), (1 << 20, 1 << 13)):
        def prog(x, y):
            return (acis.reduce(x, axis="data"),
                    acis.reduce(y, axis="pod"))

        c = eng.compile(tracing.trace(prog),
                        in_avals=(AV((mx,), jnp.float32),
                                  AV((my,), jnp.float32)),
                        axis_size={"data": 4, "pod": 2})
        t = netmodel.program_time(c.plan, c.topology, overlap=truth)
        samples.append((c.plan, c.topology, t))
    fitted = netmodel.fit_tier_overlap(samples)
    for tier, want in truth.items():
        got = fitted[tier]
        # a tier never exposed in the samples keeps its default; both
        # tiers ARE exposed here across the size mix
        assert got == pytest.approx(want, abs=1e-6), (tier, got)


def test_fit_tier_overlap_collinear_exposure_stays_consistent():
    """Samples whose per-tier exposures are collinear cannot identify
    both fractions; the fit must drop one tier (keeping its committed
    value) and re-solve — and the returned dict must still reproduce
    the measured samples through program_time (regression: the old
    solver silently zeroed the dependent variable while reporting the
    stale constant, making the fit inconsistent with its own data)."""
    from types import SimpleNamespace

    from repro.core.compiler import AxisSpec, Topology

    topo = Topology((AxisSpec("a", 4, "ici"), AxisSpec("b", 4, "ici"),
                     AxisSpec("c", 2, "dci")))

    def stage(axis, m):
        ir = SimpleNamespace(bytes_in=m, bytes_parts=None, nodes=())
        return SimpleNamespace(kind="allreduce", axis=axis, schedule="",
                               placement=None, ir=ir)

    truth = {"ici": 0.5, "dci": 0.25}
    # a SINGLE sample exposing both tiers: one equation, two unknowns —
    # the gram matrix is rank 1, the exposure columns exactly dependent
    stages = [stage("a", 1 << 18), stage("b", 1 << 12),
              stage("c", 1 << 12)]
    plan = SimpleNamespace(stages=stages, waves=((0, 1, 2),))
    t = netmodel.program_time(plan, topo, overlap=truth)
    samples = [(plan, topo, t)]
    fitted = netmodel.fit_tier_overlap(samples)
    assert set(fitted) == {"ici", "dci"}
    # one tier kept its committed value (unfittable), and the returned
    # dict reproduces the measured sample
    assert any(fitted[t] == netmodel.TIER_OVERLAP[t] for t in fitted)
    got = netmodel.program_time(plan, topo, overlap=fitted)
    assert got == pytest.approx(t, rel=1e-6)


def test_program_time_overrides_accept_calibrated_dict():
    eng = make_engine("acis", outer_axis="pod")

    def prog(x, y):
        return (acis.reduce(x, axis="data"), acis.reduce(y, axis="pod"))

    c = eng.compile(tracing.trace(prog),
                    in_avals=(AV((1 << 15,), jnp.float32),) * 2,
                    axis_size={"data": 4, "pod": 2})
    t_none = netmodel.program_time(c.plan, c.topology,
                                   overlap={"ici": 0.0, "dci": 0.0})
    t_full = netmodel.program_time(c.plan, c.topology,
                                   overlap={"ici": 1.0, "dci": 1.0})
    t_cal = c.program_time()
    assert t_full < t_cal < t_none


def test_fused_ar_a2a_analytic_matches_simulator():
    """The per-stage fused AR+A2A term now mirrors the simulator's
    shared-traversal walk — the old 2.4x analytic-vs-simulated gap is
    closed (the application-level emulator term keeps its base cost)."""
    from repro.cgra.simulate import SwitchSim

    eng = make_engine("acis")
    c = eng.compile(lambda h, k: (acis.reduce(h), acis.all_to_all(k)),
                    in_avals=(AV((1024,), jnp.float32),
                              AV((8192,), jnp.float32)),
                    axis_size=8)
    rng = np.random.default_rng(0)
    _, report = SwitchSim(eng.topology(axis_size=8)).run(
        c, rng.standard_normal((8, 1024)).astype(np.float32),
        rng.standard_normal((8, 8192)).astype(np.float32))
    (row,) = [s for s in report.stages if s.kind == "allreduce+alltoall"]
    assert row.t_model is not None
    assert abs(row.t_sim / row.t_model - 1.0) < 0.05
    # the asymmetric split matters: the stamped bytes_parts beat the
    # even-split fallback
    st = next(s for s in c.stages if s.kind == "allreduce+alltoall")
    assert st.ir.bytes_parts == (4096, 32768)


def test_simulator_charges_injection_contention(mesh22):
    """Two same-wave stages on different axes: t_end exceeds the pure
    max-of-branches (the shared port re-exposes the non-critical
    branch's injection serialization) but stays below the serial sum."""
    from repro.cgra.simulate import SwitchSim

    eng = make_engine("acis", inner_axis="data", outer_axis="pod")

    def prog(x, y):
        return (acis.reduce(x, axis="data"), acis.reduce(y, axis="pod"))

    c = eng.compile(tracing.trace(prog),
                    in_avals=(AV((1 << 16,), jnp.float32),) * 2,
                    axis_size={"data": 2, "pod": 2})
    rng = np.random.default_rng(0)
    x = rng.standard_normal((2, 2, 1 << 16)).astype(np.float32)
    y = rng.standard_normal((2, 2, 1 << 16)).astype(np.float32)
    _, report = SwitchSim(
        eng.topology(axis_size={"data": 2, "pod": 2})).run(c, x, y)
    stage_t = [s.t_sim for s in report.stages]
    assert len(stage_t) == 2
    assert report.t_end > max(stage_t) + 1e-9       # contention charged
    assert report.t_end < sum(stage_t) - 1e-9       # but not serialized
